//! Fig. 14: accuracy/F1 vs *net* sparsity with and without DynaTran
//! weight pruning (WP), on (a) the sentiment task (SST-2 proxy) and
//! (b) the span task (SQuAD proxy, F1 metric).
//!
//! Reproduced claim: WP adds only marginal net sparsity (activations
//! dominate the element count, Fig. 1) at a significant performance
//! cost — which is why the paper uses movement-pruned models instead of
//! WP.
//!
//! Run with: `cargo bench --bench fig14_weight_pruning`

use acceltran::coordinator::{evaluate_accuracy, trainer};
use acceltran::nlp::span::SpanTask;
use acceltran::nlp::sentiment::SentimentTask;
use acceltran::nlp::Dataset;
use acceltran::pruning::wp::{net_sparsity, weight_prune_threshold};
use acceltran::runtime::{ParamStore, Runtime};
use acceltran::util::cli::env_usize;
use acceltran::util::json::Json;
use acceltran::util::table::Table;

#[allow(clippy::too_many_arguments)]
fn sweep(
    rt: &mut Runtime,
    params: &[f32],
    val: &Dataset,
    wp_tau: f32,
    label: &str,
    use_f1: bool,
    report: &mut Vec<Json>,
    t: &mut Table,
) {
    let examples = val.examples.len();
    // apply WP at a fixed threshold (the paper's protocol)
    let mut weights = params.to_vec();
    let weight_rho = if wp_tau > 0.0 {
        weight_prune_threshold(&mut weights, wp_tau)
    } else {
        0.0
    };
    // activation sparsity swept via DynaTran tau
    for tau in [0.0f32, 0.02, 0.04, 0.06] {
        let r = evaluate_accuracy(rt, &weights, val, tau, examples).expect("eval");
        let act_elems = 3usize; // activations ~3x weights for tiny @ seq64
        let net = net_sparsity(weight_rho, 1, r.activation_sparsity, act_elems);
        let metric = if use_f1 { r.f1 } else { r.accuracy };
        t.row([
            label.to_string(),
            format!("{weight_rho:.2}"),
            format!("{net:.3}"),
            format!("{metric:.4}"),
        ]);
        report.push(Json::obj(vec![
            ("curve", Json::str(label)),
            ("weight_sparsity", Json::num(weight_rho)),
            ("net_sparsity", Json::num(net)),
            ("metric", Json::num(metric)),
        ]));
    }
}

fn main() {
    println!("== Fig. 14: weight pruning (WP) effect on net sparsity ==\n");
    let mut rt = Runtime::load_default().expect("runtime");
    println!("backend: {}", rt.backend_name());
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let examples = env_usize("ACCELTRAN_EVAL_EXAMPLES", 384);
    let mut report = Vec::new();

    // (a) sentiment (SST-2 proxy) — shared trained checkpoint
    let store = trainer::ensure_trained(
        &mut rt,
        std::path::Path::new("reports/trained_params.bin"),
        200,
        true,
    )
    .expect("training failed");
    let sent_val = SentimentTask::new(vocab, seq, 7).dataset(examples, 2);
    println!("(a) sentiment accuracy vs net sparsity:");
    let mut t = Table::new(["curve", "weight rho", "net sparsity", "accuracy"]);
    sweep(&mut rt, &store.params, &sent_val, 0.0, "no WP", false, &mut report, &mut t);
    sweep(&mut rt, &store.params, &sent_val, 0.02, "WP tau=0.02", false, &mut report, &mut t);
    t.print();

    // (b) span task (SQuAD proxy) — train a second checkpoint on spans
    let span_task = SpanTask::new(vocab, seq);
    let span_train = span_task.dataset(2048, 1);
    let span_val = span_task.dataset(examples, 2);
    let span_steps = env_usize("ACCELTRAN_TRAIN_STEPS", 150);
    // key the cache by steps so a reduced smoke checkpoint is never
    // reused by a full-size run (mirrors trainer::ensure_trained's meta)
    let span_path_buf =
        std::path::PathBuf::from(format!("reports/trained_span_params_s{span_steps}.bin"));
    let span_path = span_path_buf.as_path();
    let span_store = if span_path.exists() {
        ParamStore::from_file(&rt.manifest, span_path).expect("load span params")
    } else {
        let mut s = ParamStore::init(&rt.manifest, 1);
        println!("\ntraining span model ({span_steps} steps)...");
        acceltran::coordinator::train(
            &mut rt, &mut s, &span_train, None, span_steps, 1e-3, 0, false,
        )
        .expect("span training");
        s.save(span_path).ok();
        s
    };
    println!("\n(b) span F1 vs net sparsity:");
    let mut t = Table::new(["curve", "weight rho", "net sparsity", "F1"]);
    sweep(&mut rt, &span_store.params, &span_val, 0.0, "no WP", true, &mut report, &mut t);
    sweep(&mut rt, &span_store.params, &span_val, 0.02, "WP tau=0.02", true, &mut report, &mut t);
    t.print();

    println!(
        "\nShape check (paper Sec. V-A2): WP shifts net sparsity only\n\
         slightly rightward (activations dominate, Fig. 1) while costing\n\
         task performance — hence the paper pairs DynaTran with MP, not WP."
    );
    std::fs::create_dir_all("reports").ok();
    std::fs::write(
        "reports/fig14_weight_pruning.json",
        Json::arr(report).to_string_pretty(),
    )
    .unwrap();
    println!("wrote reports/fig14_weight_pruning.json");
}
