//! Fig. 14: accuracy/F1 vs *net* sparsity with and without DynaTran
//! weight pruning (WP), on (a) the sentiment task (SST-2 proxy) and
//! (b) the span task (SQuAD proxy, token-overlap F1) — (b) runs the
//! real span pipeline: the span head fine-tuned end-to-end with
//! `ensure_trained_span`, scored with `evaluate_span`.
//!
//! Reproduced claim: WP adds only marginal net sparsity (activations
//! dominate the element count, Fig. 1) at a significant performance
//! cost — which is why the paper uses movement-pruned models instead of
//! WP.
//!
//! Run with: `cargo bench --bench fig14_weight_pruning`

use acceltran::coordinator::{evaluate_accuracy, evaluate_span, trainer};
use acceltran::nlp::sentiment::SentimentTask;
use acceltran::nlp::span::{SpanDataset, SpanTask};
use acceltran::nlp::Dataset;
use acceltran::pruning::wp::{net_sparsity, weight_prune_threshold};
use acceltran::runtime::Runtime;
use acceltran::util::cli::env_usize;
use acceltran::util::json::Json;
use acceltran::util::table::Table;

/// Shared WP protocol: prune once at `wp_tau`, then sweep DynaTran tau.
/// Returns `(pruned weights, weight rho)`.
fn apply_wp(params: &[f32], wp_tau: f32) -> (Vec<f32>, f64) {
    let mut weights = params.to_vec();
    let weight_rho = if wp_tau > 0.0 {
        weight_prune_threshold(&mut weights, wp_tau)
    } else {
        0.0
    };
    (weights, weight_rho)
}

const TAUS: [f32; 4] = [0.0, 0.02, 0.04, 0.06];
// activations ~3x weights for tiny @ seq64 (net-sparsity weighting)
const ACT_ELEMS: usize = 3;

fn push_point(
    label: &str,
    weight_rho: f64,
    net: f64,
    metric: f64,
    report: &mut Vec<Json>,
    t: &mut Table,
) {
    t.row([
        label.to_string(),
        format!("{weight_rho:.2}"),
        format!("{net:.3}"),
        format!("{metric:.4}"),
    ]);
    report.push(Json::obj(vec![
        ("curve", Json::str(label)),
        ("weight_sparsity", Json::num(weight_rho)),
        ("net_sparsity", Json::num(net)),
        ("metric", Json::num(metric)),
    ]));
}

fn sweep_sentiment(
    rt: &mut Runtime,
    params: &[f32],
    val: &Dataset,
    wp_tau: f32,
    label: &str,
    report: &mut Vec<Json>,
    t: &mut Table,
) {
    let examples = val.examples.len();
    let (weights, weight_rho) = apply_wp(params, wp_tau);
    for tau in TAUS {
        let r = evaluate_accuracy(rt, &weights, val, tau, examples).expect("eval");
        let net = net_sparsity(weight_rho, 1, r.activation_sparsity, ACT_ELEMS);
        push_point(label, weight_rho, net, r.accuracy, report, t);
    }
}

fn sweep_span(
    rt: &mut Runtime,
    params: &[f32],
    val: &SpanDataset,
    wp_tau: f32,
    label: &str,
    report: &mut Vec<Json>,
    t: &mut Table,
) {
    let examples = val.examples.len();
    let (weights, weight_rho) = apply_wp(params, wp_tau);
    for tau in TAUS {
        let r = evaluate_span(rt, &weights, val, tau, examples).expect("eval");
        let net = net_sparsity(weight_rho, 1, r.activation_sparsity, ACT_ELEMS);
        push_point(label, weight_rho, net, r.f1, report, t);
    }
}

fn main() {
    println!("== Fig. 14: weight pruning (WP) effect on net sparsity ==\n");
    let mut rt = Runtime::load_default().expect("runtime");
    println!("backend: {}", rt.backend_name());
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let examples = env_usize("ACCELTRAN_EVAL_EXAMPLES", 384);
    let mut report = Vec::new();

    // (a) sentiment (SST-2 proxy) — shared trained checkpoint
    let store = trainer::ensure_trained(
        &mut rt,
        std::path::Path::new("reports/trained_params.bin"),
        200,
        true,
    )
    .expect("training failed");
    let sent_val = SentimentTask::new(vocab, seq, 7).dataset(examples, 2);
    println!("(a) sentiment accuracy vs net sparsity:");
    let mut t = Table::new(["curve", "weight rho", "net sparsity", "accuracy"]);
    sweep_sentiment(&mut rt, &store.params, &sent_val, 0.0, "no WP", &mut report, &mut t);
    sweep_sentiment(&mut rt, &store.params, &sent_val, 0.02, "WP tau=0.02", &mut report, &mut t);
    t.print();

    // (b) span task (SQuAD proxy) — a real span fine-tune: start/end
    // logits over context positions, trained with the hand-derived
    // span backprop, scored with token-overlap F1 (the checkpoint is
    // cached under reports/ and keyed by steps via the trainer's meta)
    let span_task = SpanTask::new(vocab, seq);
    let span_val = span_task.dataset(examples, 2);
    let span_store = trainer::ensure_trained_span(
        &mut rt,
        std::path::Path::new("reports/trained_span_params.bin"),
        150,
        true,
    )
    .expect("span training failed");
    println!("\n(b) span F1 vs net sparsity:");
    let mut t = Table::new(["curve", "weight rho", "net sparsity", "F1"]);
    sweep_span(&mut rt, &span_store.params, &span_val, 0.0, "no WP", &mut report, &mut t);
    sweep_span(&mut rt, &span_store.params, &span_val, 0.02, "WP tau=0.02", &mut report, &mut t);
    t.print();

    println!(
        "\nShape check (paper Sec. V-A2): WP shifts net sparsity only\n\
         slightly rightward (activations dominate, Fig. 1) while costing\n\
         task performance — hence the paper pairs DynaTran with MP, not WP."
    );
    std::fs::create_dir_all("reports").ok();
    std::fs::write(
        "reports/fig14_weight_pruning.json",
        Json::arr(report).to_string_pretty(),
    )
    .unwrap();
    println!("wrote reports/fig14_weight_pruning.json");
}
