//! Table III: area, theoretical peak TOP/s, minimum main memory, and the
//! power-consumption breakdown for AccelTran-Server, AccelTran-Edge, and
//! Edge LP mode.
//!
//! Area/TOPs come from the technology + config models; power rows come
//! from *simulating* the paper's workload for each design point
//! (BERT-Base for Server, BERT-Tiny for Edge).
//!
//! Run with: `cargo bench --bench tab03_hw_summary`

use acceltran::model::memreq::{mb, MemReq};
use acceltran::model::TransformerConfig;
use acceltran::sim::engine::{simulate, SparsityProfile};
use acceltran::sim::scheduler::Policy;
use acceltran::sim::tech::AreaBreakdown;
use acceltran::sim::AcceleratorConfig;
use acceltran::util::json::Json;
use acceltran::util::table::Table;

fn main() {
    println!("== Table III: hardware summary ==\n");
    let sp = SparsityProfile::paper_default();
    let rows: Vec<(AcceleratorConfig, TransformerConfig, &str)> = vec![
        (
            AcceleratorConfig::server(),
            TransformerConfig::bert_base(),
            "372.74 TOP/s, 1950.95 mm^2, 95.51 W",
        ),
        (
            AcceleratorConfig::edge(),
            TransformerConfig::bert_tiny(),
            "15.05 TOP/s, 55.12 mm^2, 6.78 W",
        ),
        (
            AcceleratorConfig::edge_lp(),
            TransformerConfig::bert_tiny(),
            "7.52 TOP/s, 55.12 mm^2, 4.13 W",
        ),
    ];
    let mut t = Table::new([
        "accelerator",
        "area mm^2",
        "peak TOP/s",
        "main mem MB",
        "PE W",
        "buffer W",
        "mem W",
        "total W",
        "paper row",
    ]);
    let mut report = Vec::new();
    let mut results = Vec::new();
    for (cfg, model, paper) in &rows {
        let area = AreaBreakdown::compute(cfg);
        let mr = MemReq::compute(model, 1, model.seq, 0.5);
        let r = simulate(cfg, model, 512, Policy::Staggered, sp);
        let seconds = r.latency_s(cfg);
        let w = |pj: f64| pj * 1e-12 / seconds;
        let pe_w = w(r.energy.compute_pj() + r.energy.leakage_pj);
        let buf_w = w(r.energy.buffer_pj);
        let mem_w = w(r.energy.memory_pj);
        let total_w = r.avg_power_w(cfg);
        t.row([
            cfg.name.clone(),
            format!("{:.2}", area.compute_mm2()),
            format!("{:.2}", cfg.peak_ops_per_s() / 1e12),
            format!("{:.1}", mb(mr.main_memory_bytes())),
            format!("{pe_w:.2}"),
            format!("{buf_w:.3}"),
            format!("{mem_w:.2}"),
            format!("{total_w:.2}"),
            paper.to_string(),
        ]);
        report.push(Json::obj(vec![
            ("accelerator", Json::str(cfg.name.clone())),
            ("area_mm2", Json::num(area.compute_mm2())),
            ("peak_tops", Json::num(cfg.peak_ops_per_s() / 1e12)),
            ("main_mem_mb", Json::num(mb(mr.main_memory_bytes()))),
            ("total_w", Json::num(total_w)),
        ]));
        results.push((cfg.name.clone(), total_w, r.throughput_seq_s(cfg)));
    }
    t.print();

    // LP-mode shape check (paper: -39.1% power, -38.7% throughput)
    let edge = results.iter().find(|r| r.0 == "acceltran-edge").unwrap();
    let lp = results.iter().find(|r| r.0 == "acceltran-edge-lp").unwrap();
    let dp = 100.0 * (1.0 - lp.1 / edge.1);
    let dt = 100.0 * (1.0 - lp.2 / edge.2);
    println!(
        "\nLP mode: power -{dp:.1}% (paper -39.1%), throughput -{dt:.1}% \
         (paper -38.7%)"
    );
    std::fs::create_dir_all("reports").ok();
    std::fs::write(
        "reports/tab03_hw_summary.json",
        Json::arr(report).to_string_pretty(),
    )
    .unwrap();
    println!("wrote reports/tab03_hw_summary.json");
}
