//! Fig. 18: area and power breakdown by compute module for
//! AccelTran-Edge.
//!
//! Area comes from the 14nm technology model (back-fitted to the paper's
//! synthesis results — the area panel reproduces Fig. 18(a) by
//! construction, which doubles as a regression test on the constants).
//! Power shares come from *simulation*: the energy ledger of a real
//! BERT-Tiny run driven by a measured sparsity trace (tau = 0.04
//! capture on the fine-tuned reference model, 50% MP weight sparsity
//! overlaid — DESIGN.md "Measured vs assumed sparsity"), so the power
//! panel is a genuine measurement of the modeled workload (paper: MAC
//! 39.3%, softmax 49.9%).
//!
//! Run with: `cargo bench --bench fig18_breakdown`

use acceltran::coordinator;
use acceltran::model::TransformerConfig;
use acceltran::sim::engine::simulate_with;
use acceltran::sim::scheduler::Policy;
use acceltran::sim::tech::AreaBreakdown;
use acceltran::sim::{AcceleratorConfig, SparsitySource};
use acceltran::util::json::Json;
use acceltran::util::table::Table;

fn main() {
    println!("== Fig. 18: AccelTran-Edge area & power breakdown ==\n");
    let cfg = AcceleratorConfig::edge();

    // ---- (a) area ------------------------------------------------------
    let a = AreaBreakdown::compute(&cfg);
    let total = a.compute_mm2();
    let mut t = Table::new(["module", "area mm^2", "share", "paper share"]);
    for (name, mm2, paper) in [
        ("MAC lanes", a.mac_lanes_mm2, 19.2),
        ("softmax modules", a.softmax_mm2, 44.7),
        ("layer-norm modules", a.layernorm_mm2, 10.3),
        ("pre/post sparsity", a.sparsity_mm2, 15.1),
        ("DynaTran+dataflow+DMA", a.other_mm2, 10.7),
    ] {
        t.row([
            name.to_string(),
            format!("{mm2:.2}"),
            format!("{:.1}%", 100.0 * mm2 / total),
            format!("{paper:.1}%"),
        ]);
    }
    t.print();
    println!("total compute area: {total:.2} mm^2 (paper: 55.12 mm^2)\n");

    // ---- (b) power: energy shares of a simulated BERT-Tiny run ---------
    // measured activation sparsity, assumed 50% MP weight sparsity
    let model = TransformerConfig::bert_tiny();
    let trace = coordinator::measured_trace(0.04, true)
        .expect("measured-trace capture")
        .with_assumed_weight_rho(0.5);
    println!(
        "power panel driven by measured trace: mean act sparsity {:.3}\n",
        trace.mean_act_rho()
    );
    let source = SparsitySource::Trace(trace);
    let r = simulate_with(&cfg, &model, 512, Policy::Staggered, &source);
    let e = &r.energy;
    let compute = e.compute_pj();
    let mut t = Table::new(["module", "energy share", "paper power share"]);
    for (name, pj, paper) in [
        ("MAC lanes", e.mac_pj, 39.3),
        ("softmax modules", e.softmax_pj, 49.9),
        ("layer-norm modules", e.layernorm_pj, f64::NAN),
        ("DynaTran modules", e.dynatran_pj, f64::NAN),
        ("sparsity modules", e.sparsity_pj, f64::NAN),
    ] {
        t.row([
            name.to_string(),
            format!("{:.1}%", 100.0 * pj / compute),
            if paper.is_nan() {
                "(within 10.8% rest)".to_string()
            } else {
                format!("{paper:.1}%")
            },
        ]);
    }
    t.print();
    println!(
        "\nShape check: MAC + softmax dominate compute energy \
         ({:.0}% combined; paper: 89.2%).",
        100.0 * (e.mac_pj + e.softmax_pj) / compute
    );
    std::fs::create_dir_all("reports").ok();
    let j = Json::obj(vec![
        ("area_total_mm2", Json::num(total)),
        ("area_mac_share", Json::num(a.mac_lanes_mm2 / total)),
        ("area_softmax_share", Json::num(a.softmax_mm2 / total)),
        ("power_mac_share", Json::num(e.mac_pj / compute)),
        ("power_softmax_share", Json::num(e.softmax_pj / compute)),
    ]);
    std::fs::write("reports/fig18_breakdown.json", j.to_string_pretty()).unwrap();
    println!("wrote reports/fig18_breakdown.json");
}
