//! Fig. 19: effect of net sparsity on accelerator throughput, energy and
//! model accuracy — BERT-Tiny on AccelTran-Edge.
//!
//! Timing/energy come from the simulator at swept activation sparsities;
//! accuracy comes from the trained synthetic-sentiment model through the
//! PJRT runtime (the tau achieving each sparsity level is found via the
//! DynaTran transfer function, exactly as the threshold calculator would).
//!
//! Run with: `cargo bench --bench fig19_sparsity_effect`

use acceltran::coordinator::{self, trainer};
use acceltran::model::TransformerConfig;
use acceltran::nlp::sentiment::SentimentTask;
use acceltran::pruning::wp::net_sparsity;
use acceltran::runtime::Runtime;
use acceltran::sim::engine::{simulate, SparsityProfile};
use acceltran::sim::scheduler::Policy;
use acceltran::sim::AcceleratorConfig;
use acceltran::util::cli::env_usize;
use acceltran::util::json::Json;
use acceltran::util::table::{eng, Table};

fn main() {
    println!("== Fig. 19: sparsity -> throughput / energy / accuracy ==\n");
    let cfg = AcceleratorConfig::edge();
    let model = TransformerConfig::bert_tiny();
    let weight_rho = 0.5; // conservative MP estimate, as in the paper

    // accuracy side: trained model + tau sweep (reference backend by
    // default, PJRT when artifacts are present)
    let accuracy_curve = {
        let mut rt = Runtime::load_default().expect("runtime");
        println!("accuracy backend: {}", rt.backend_name());
        let store = trainer::ensure_trained(
            &mut rt,
            std::path::Path::new("reports/trained_params.bin"),
            200,
            true,
        )
        .expect("training failed");
        let examples = env_usize("ACCELTRAN_EVAL_EXAMPLES", 512);
        let task = SentimentTask::new(rt.manifest.vocab, rt.manifest.seq, 7);
        let val = task.dataset(examples, 2);
        let taus = [0.0f32, 0.01, 0.02, 0.03, 0.05, 0.08];
        Some(
            coordinator::sweep_dynatran(&mut rt, &store.params, &val, &taus, examples)
                .unwrap(),
        )
    };

    let mut t = Table::new([
        "act sparsity",
        "net sparsity",
        "throughput seq/s",
        "energy mJ/seq",
        "accuracy",
    ]);
    let mut report = Vec::new();
    let mut last_tp = 0.0f64;
    let act_rhos = [0.30f64, 0.40, 0.50, 0.60, 0.70];
    for &rho in &act_rhos {
        let r = simulate(
            &cfg,
            &model,
            128,
            Policy::Staggered,
            SparsityProfile { weight_rho, act_rho: rho, inherent_act_rho: 0.1 },
        );
        let tp = r.throughput_seq_s(&cfg);
        let mj = r.energy_mj_per_seq();
        // accuracy at the nearest achieved sparsity on the eval curve
        let acc = accuracy_curve.as_ref().map(|c| {
            c.points
                .iter()
                .min_by(|a, b| {
                    (a.activation_sparsity - rho)
                        .abs()
                        .partial_cmp(&(b.activation_sparsity - rho).abs())
                        .unwrap()
                })
                .map(|p| p.accuracy)
                .unwrap_or(f64::NAN)
        });
        let net = net_sparsity(weight_rho, 1, rho, 2); // act:weight ~2:1 tiny@128
        t.row([
            format!("{rho:.2}"),
            format!("{net:.2}"),
            eng(tp),
            format!("{mj:.4}"),
            acc.map(|a| format!("{a:.3}")).unwrap_or("n/a".into()),
        ]);
        assert!(tp >= last_tp, "throughput must rise with sparsity");
        last_tp = tp;
        report.push(Json::obj(vec![
            ("act_sparsity", Json::num(rho)),
            ("net_sparsity", Json::num(net)),
            ("throughput_seq_s", Json::num(tp)),
            ("energy_mj_per_seq", Json::num(mj)),
            ("accuracy", Json::num(acc.unwrap_or(f64::NAN))),
        ]));
    }
    t.print();
    println!(
        "\nShape check (paper): throughput rises and energy falls as\n\
         sparsity increases, while accuracy declines only gently until\n\
         the high-sparsity cliff."
    );
    std::fs::create_dir_all("reports").ok();
    std::fs::write(
        "reports/fig19_sparsity_effect.json",
        Json::arr(report).to_string_pretty(),
    )
    .unwrap();
    println!("wrote reports/fig19_sparsity_effect.json");
}
