//! Fig. 19: effect of net sparsity on accelerator throughput, energy and
//! model accuracy — BERT-Tiny on AccelTran-Edge.
//!
//! Fully trace-driven: for each DynaTran tau the fine-tuned reference
//! model classifies the eval set while its per-op activation sparsities
//! are *measured* into a `SparsityTrace`; that same trace then drives
//! the cycle-accurate simulator (per-op profiles, 50% MP weight sparsity
//! overlaid) and contributes the accuracy point — so every row's
//! sparsity, timing, energy and accuracy describe one measured operating
//! point instead of a hand-picked scalar (DESIGN.md "Measured vs assumed
//! sparsity").  Problem size shrinks under `ACCELTRAN_TRAIN_STEPS` /
//! `ACCELTRAN_EVAL_EXAMPLES` (the CI smoke job sets both).
//!
//! Run with: `cargo bench --bench fig19_sparsity_effect`

use acceltran::coordinator::{capture, trainer};
use acceltran::model::TransformerConfig;
use acceltran::pruning::wp::net_sparsity;
use acceltran::runtime::Runtime;
use acceltran::sim::engine::simulate_with;
use acceltran::sim::scheduler::Policy;
use acceltran::sim::{AcceleratorConfig, SparsitySource};
use acceltran::util::cli::env_usize;
use acceltran::util::json::Json;
use acceltran::util::table::{eng, Table};

fn main() {
    println!("== Fig. 19: measured sparsity -> throughput / energy / accuracy ==\n");
    let cfg = AcceleratorConfig::edge();
    let model = TransformerConfig::bert_tiny();
    let weight_rho = 0.5; // MP operating point, as in the paper

    // one shared fine-tune; per-tau captures over the same eval set
    let mut rt = Runtime::load_default().expect("runtime");
    println!("capture backend: {}", rt.backend_name());
    let store = trainer::ensure_trained(
        &mut rt,
        std::path::Path::new("reports/trained_params.bin"),
        200,
        true,
    )
    .expect("training failed");
    let examples = env_usize("ACCELTRAN_EVAL_EXAMPLES", 512);

    let mut t = Table::new([
        "tau",
        "measured act rho",
        "net sparsity",
        "throughput seq/s",
        "energy mJ/seq",
        "accuracy",
    ]);
    let mut report = Vec::new();
    let mut last_tp = 0.0f64;
    let mut last_rho = 0.0f64;
    let taus = [0.0f32, 0.02, 0.04, 0.06, 0.08];
    for &tau in &taus {
        let trace = capture::measured_trace_with(&mut rt, &store, tau, examples)
            .expect("trace capture")
            .with_assumed_weight_rho(weight_rho);
        let rho = trace.mean_act_rho();
        let acc = trace.eval_accuracy;
        let source = SparsitySource::Trace(trace);
        let r = simulate_with(&cfg, &model, 128, Policy::Staggered, &source);
        assert_eq!(r.sparsity_source, "trace");
        let tp = r.throughput_seq_s(&cfg);
        let mj = r.energy_mj_per_seq();
        let net = net_sparsity(weight_rho, 1, rho, 2); // act:weight ~2:1 tiny@128
        t.row([
            format!("{tau:.2}"),
            format!("{rho:.3}"),
            format!("{net:.2}"),
            eng(tp),
            format!("{mj:.4}"),
            format!("{acc:.3}"),
        ]);
        // measured sparsity rises with tau, and the simulator must turn
        // that into monotone throughput (the Fig. 19 claim)
        assert!(
            rho + 1e-9 >= last_rho,
            "measured sparsity must be monotone in tau: {rho} after {last_rho}"
        );
        assert!(
            tp + 1e-9 >= last_tp,
            "throughput must rise with measured sparsity: {tp} after {last_tp}"
        );
        last_tp = tp;
        last_rho = rho;
        report.push(Json::obj(vec![
            ("tau", Json::num(tau as f64)),
            ("measured_act_sparsity", Json::num(rho)),
            ("net_sparsity", Json::num(net)),
            ("throughput_seq_s", Json::num(tp)),
            ("energy_mj_per_seq", Json::num(mj)),
            ("accuracy", Json::num(acc)),
        ]));
    }
    t.print();

    // uniform fallback reference point: the legacy 3-scalar profile at
    // the paper's headline operating point, for comparison against the
    // measured rows above
    let uniform = acceltran::sim::simulate(
        &cfg,
        &model,
        128,
        Policy::Staggered,
        acceltran::sim::SparsityProfile::paper_default(),
    );
    println!(
        "\nuniform fallback (assumed 50/50 profile): {} seq/s, {:.4} mJ/seq \
         [source '{}']",
        eng(uniform.throughput_seq_s(&cfg)),
        uniform.energy_mj_per_seq(),
        uniform.sparsity_source
    );
    println!(
        "Shape check (paper): throughput rises and energy falls as measured\n\
         sparsity increases with tau, while accuracy declines only gently\n\
         until the high-sparsity cliff."
    );
    std::fs::create_dir_all("reports").ok();
    std::fs::write(
        "reports/fig19_sparsity_effect.json",
        Json::arr(report).to_string_pretty(),
    )
    .unwrap();
    println!("wrote reports/fig19_sparsity_effect.json");
}
