//! Fig. 12: accuracy vs activation sparsity for DynaTran and top-k, with
//! and without static weight pruning (MP-like 50% magnitude pruning
//! standing in for movement pruning — DESIGN.md §Substitutions).
//!
//! The headline claims reproduced in shape:
//!   * DynaTran reaches higher accuracy than top-k at matched sparsity;
//!   * DynaTran attains higher maximum sparsity without much loss;
//!   * weight-pruned models shift the sparsity range upward.
//!
//! Run with: `cargo bench --bench fig12_acc_vs_sparsity`

use acceltran::coordinator::{self, trainer};
use acceltran::nlp::sentiment::SentimentTask;
use acceltran::pruning::wp::weight_prune_to_sparsity;
use acceltran::runtime::Runtime;
use acceltran::util::cli::env_usize;
use acceltran::util::json::Json;
use acceltran::util::table::Table;

fn main() {
    println!("== Fig. 12: accuracy vs activation sparsity ==\n");
    let mut rt = Runtime::load_default().expect("runtime");
    println!("backend: {}", rt.backend_name());
    let store = trainer::ensure_trained(
        &mut rt,
        std::path::Path::new("reports/trained_params.bin"),
        200,
        true,
    )
    .expect("training failed");
    let examples = env_usize("ACCELTRAN_EVAL_EXAMPLES", 512);
    let task = SentimentTask::new(rt.manifest.vocab, rt.manifest.seq, 7);
    let val = task.dataset(examples, 2);

    let taus = [0.0f32, 0.01, 0.02, 0.03, 0.04, 0.06, 0.08];
    let keeps = [1.0f32, 0.5, 0.25, 0.125];

    // without MP
    let mut dyna =
        coordinator::sweep_dynatran(&mut rt, &store.params, &val, &taus, examples)
            .expect("sweep");
    dyna.label = "DynaTran".into();
    let mut topk = coordinator::sweep_topk(&mut rt, &store.params, &val, &keeps, examples)
        .expect("sweep");
    topk.label = "top-k".into();

    // with MP-like 50% weight pruning (embeddings/LN/bias excluded by
    // pruning the whole flat buffer is too blunt; magnitude-prune only
    // matrix weights by masking via the spec offsets)
    let mut pruned_params = store.params.clone();
    // prune everything except layer-norm gains (init_std < 0) and biases
    {
        let mut off = 0usize;
        for (_name, shape, std) in &rt.manifest.param_specs {
            let n: usize = shape.iter().product();
            if *std > 0.0 {
                weight_prune_to_sparsity(&mut pruned_params[off..off + n], 0.5);
            }
            off += n;
        }
    }
    let mut dyna_mp =
        coordinator::sweep_dynatran(&mut rt, &pruned_params, &val, &taus, examples)
            .expect("sweep");
    dyna_mp.label = "DynaTran + MP".into();
    let mut topk_mp =
        coordinator::sweep_topk(&mut rt, &pruned_params, &val, &keeps, examples)
            .expect("sweep");
    topk_mp.label = "top-k + MP".into();

    let curves = [&dyna, &topk, &dyna_mp, &topk_mp];
    let mut t = Table::new(["method", "act sparsity", "accuracy"]);
    for c in curves {
        for p in &c.points {
            t.row([
                c.label.clone(),
                format!("{:.3}", p.activation_sparsity),
                format!("{:.4}", p.accuracy),
            ]);
        }
    }
    t.print();

    // headline comparisons (annotations of Fig. 12)
    let topk_best = topk.max_accuracy();
    let dyna_at_topk_best = dyna.sparsity_at_accuracy(topk_best - 0.005);
    let topk_at_topk_best = topk.sparsity_at_accuracy(topk_best - 0.005);
    println!("\nmax accuracy: DynaTran {:.4} vs top-k {:.4} (paper: DynaTran +0.46%)",
             dyna.max_accuracy(), topk_best);
    if let (Some(ds), Some(ts)) = (dyna_at_topk_best, topk_at_topk_best) {
        println!(
            "sparsity at top-k's best accuracy: DynaTran {ds:.3} vs top-k {ts:.3} \
             => {:.2}x (paper: 1.17-1.20x)",
            ds / ts.max(1e-9)
        );
    }
    println!(
        "max sparsity within 2% of peak: DynaTran {:.3}, top-k {:.3}",
        dyna.max_sparsity_within(0.02),
        topk.max_sparsity_within(0.02)
    );
    // shape assertion: DynaTran's accuracy at its peak is >= top-k's
    assert!(
        dyna.max_accuracy() >= topk.max_accuracy() - 0.01,
        "DynaTran should match or beat top-k's best accuracy"
    );
    std::fs::create_dir_all("reports").ok();
    std::fs::write(
        "reports/fig12_acc_vs_sparsity.json",
        Json::arr(curves.iter().map(|c| c.to_json())).to_string_pretty(),
    )
    .unwrap();
    println!("wrote reports/fig12_acc_vs_sparsity.json");
}
