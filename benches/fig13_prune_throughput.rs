//! Fig. 13: compute cost of DynaTran vs top-k pruning on a CPU.
//!
//! The paper measures both methods' pruning throughput on an EPYC CPU and
//! an A100 GPU for BERT-Tiny and BERT-Mini activation matrices; DynaTran
//! wins by up to 5.35x (CPU) / 96.38x (GPU) because it is a single
//! comparison pass while top-k sorts every row (O(N^3) over the model).
//! Here we reproduce the CPU half on the host (no A100 in this image;
//! DESIGN.md §Substitutions) over the same matrix shapes.
//!
//! Run with: `cargo bench --bench fig13_prune_throughput`

use acceltran::pruning::{dynatran_prune_inplace, topk_prune_rows};
use acceltran::util::bench::quick;
use acceltran::util::json::Json;
use acceltran::util::rng::Rng;
use acceltran::util::table::Table;

fn main() {
    println!("== Fig. 13: pruning-method throughput (CPU) ==\n");
    let mut rng = Rng::new(42);
    let mut t = Table::new([
        "model matrices",
        "DynaTran (matrices/s)",
        "top-k (matrices/s)",
        "speedup",
        "paper speedup (CPU)",
    ]);
    let mut report = Vec::new();
    // (name, rows, cols, k, paper CPU speedup)
    // attention-score matrices: (batch*heads*seq) x seq
    let cases = [
        ("BERT-Tiny  (2*128)x128", 2 * 128usize, 128usize, 16usize, 2.24),
        ("BERT-Mini  (4*128)x128", 4 * 128, 128, 16, 5.35),
    ];
    for (name, rows, cols, k, paper) in cases {
        let data: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
        let tau = 0.5f32;
        let d = quick(&format!("dynatran {name}"), || {
            let mut x = data.clone();
            dynatran_prune_inplace(&mut x, tau);
            x
        });
        let s = quick(&format!("topk {name}"), || {
            let mut x = data.clone();
            topk_prune_rows(&mut x, cols, k);
            x
        });
        // subtract the clone cost common to both by measuring it
        let c = quick("clone", || data.clone());
        let d_net = (d.median - c.median.min(d.median)).max(std::time::Duration::from_nanos(1));
        let s_net = (s.median - c.median.min(s.median)).max(std::time::Duration::from_nanos(1));
        let speedup = s_net.as_secs_f64() / d_net.as_secs_f64();
        t.row([
            name.to_string(),
            format!("{:.0}", 1.0 / d_net.as_secs_f64()),
            format!("{:.0}", 1.0 / s_net.as_secs_f64()),
            format!("{speedup:.2}x"),
            format!("{paper:.2}x"),
        ]);
        report.push(Json::obj(vec![
            ("case", Json::str(name)),
            ("dynatran_per_s", Json::num(1.0 / d_net.as_secs_f64())),
            ("topk_per_s", Json::num(1.0 / s_net.as_secs_f64())),
            ("speedup", Json::num(speedup)),
            ("paper_speedup", Json::num(paper)),
        ]));
    }
    t.print();
    println!(
        "\nShape check: DynaTran's single-pass comparison beats per-row\n\
         sorting, and the gap widens with matrix count (larger model) —\n\
         the same trend as the paper's CPU bars.  (The paper's 96x GPU\n\
         gap comes from top-k's poor parallelization; no GPU here.)"
    );
    std::fs::create_dir_all("reports").ok();
    std::fs::write(
        "reports/fig13_prune_throughput.json",
        Json::arr(report).to_string_pretty(),
    )
    .unwrap();
    println!("wrote reports/fig13_prune_throughput.json");
}
