//! Fig. 15: dynamic energy and reuse instances for all 24 dataflows
//! under the paper's three W x A scenarios on four MAC lanes.
//!
//! Run with: `cargo bench --bench fig15_dataflows`

use acceltran::sim::dataflow::{replay, Dataflow};
use acceltran::sim::tech;
use acceltran::sim::tiling::tile_matmul_batched;
use acceltran::util::json::Json;
use acceltran::util::table::Table;

fn main() {
    println!("== Fig. 15: dataflow comparison (4 MAC lanes) ==\n");
    // The paper's three W x A scenarios are batch-4 tensor products over
    // 64-wide inner dimensions; the b axis is a real tile loop.  (The
    // source text's figure caption is partially garbled; scenarios (b)
    // and (c) here widen A's output dim, exercising the aspect-ratio
    // trade-off that makes weight-reuse dataflows win.)
    let scenarios = [
        ("(a) 4x64x64 @ 4x64x64", 4usize, 64usize, 64usize, 64usize),
        ("(b) 4x64x64 @ 4x64x128", 4, 64, 64, 128),
        ("(c) 4x64x64 @ 4x64x256", 4, 64, 64, 256),
    ];
    let read_pj = tech::BUFFER_PJ_PER_BYTE * tech::ELEM_BYTES;
    let mut report = Vec::new();
    for (name, b, m, k, n) in scenarios {
        let grid = tile_matmul_batched(b, m, k, n, 16, 16, 16);
        println!(
            "scenario {name}: grid {}x{}x{}x{} tiles",
            grid.nb, grid.ni, grid.nj, grid.nk
        );
        let mut rows: Vec<(String, usize, f64)> = Dataflow::all()
            .into_iter()
            .map(|df| {
                let r = replay(df, &grid, 4, read_pj, tech::MAC_PJ);
                (r.dataflow_name.clone(), r.reuse_instances(), r.dynamic_energy_pj)
            })
            .collect();
        let mut t = Table::new(["dataflow", "reuse instances", "dyn energy (nJ)"]);
        for (name, reuse, e) in &rows {
            t.row([
                name.clone(),
                reuse.to_string(),
                format!("{:.2}", e / 1e3),
            ]);
        }
        t.print();
        rows.sort_by(|a, b| a.2.partial_cmp(&b.2).unwrap());
        let best: Vec<&str> = rows
            .iter()
            .take_while(|r| (r.2 - rows[0].2).abs() < 1e-6)
            .map(|r| r.0.as_str())
            .collect();
        println!(
            "minimum-energy dataflows: {best:?} (paper: [b,i,j,k] and [k,i,j,b])\n"
        );
        report.push(Json::obj(vec![
            ("scenario", Json::str(name)),
            (
                "rows",
                Json::arr(rows.iter().map(|(n, r, e)| {
                    Json::obj(vec![
                        ("dataflow", Json::str(n.clone())),
                        ("reuse", Json::num(*r as f64)),
                        ("energy_pj", Json::num(*e)),
                    ])
                })),
            ),
        ]));
        // shape assertions: the paper's selected dataflows [b,i,j,k] and
        // [k,i,j,b] must both sit in the minimum-energy set
        for picked in ["[b,i,j,k]", "[k,i,j,b]"] {
            let e = rows.iter().find(|r| r.0 == picked).map(|r| r.2).unwrap();
            assert!(
                (e - rows[0].2) / rows[0].2 < 1e-9,
                "{picked} is not minimal in {name}: {e} vs {}",
                rows[0].2
            );
        }
    }
    std::fs::create_dir_all("reports").ok();
    std::fs::write(
        "reports/fig15_dataflows.json",
        Json::arr(report).to_string_pretty(),
    )
    .unwrap();
    println!("wrote reports/fig15_dataflows.json");
}
