//! §Perf hot-path microbenchmarks: the simulator event loop, the
//! host-side pruning kernels, the sparsity pipeline, and the PJRT
//! dispatch overhead.  These are the measurements behind EXPERIMENTS.md
//! §Perf (before/after table).
//!
//! Run with: `cargo bench --bench perf_hotpath`

use std::time::Duration;

use acceltran::model::{OpGraph, TransformerConfig};
use acceltran::pruning::dynatran_prune_inplace;
use acceltran::sim::engine::{Engine, SparsityProfile};
use acceltran::sim::scheduler::Policy;
use acceltran::sim::sparsity::{precompute_align, CompressedTile};
use acceltran::sim::AcceleratorConfig;
use acceltran::util::bench::bench;
use acceltran::util::json::Json;
use acceltran::util::rng::Rng;

fn main() {
    println!("== §Perf: hot-path microbenchmarks ==\n");
    let mut report = Vec::new();
    let mut push = |s: &acceltran::util::bench::Sample, metric: &str, value: f64| {
        println!("{s}   [{metric}: {value:.3}]");
        report.push(Json::obj(vec![
            ("name", Json::str(s.name.clone())),
            ("median_us", Json::num(s.median.as_secs_f64() * 1e6)),
            ("metric", Json::str(metric)),
            ("value", Json::num(value)),
        ]));
    };

    // 1. simulator end-to-end: BERT-Tiny on Edge (the main hot loop)
    let model = TransformerConfig::bert_tiny();
    let cfg = AcceleratorConfig::edge();
    let graph = OpGraph::build(&model, cfg.batch, 128);
    let tiles: usize = graph
        .nodes
        .iter()
        .map(|n| {
            acceltran::sim::tiling::tile_op(&n.dims, 1, 16, 16, 16).total_tiles()
        })
        .sum();
    let s = bench("sim: bert-tiny x edge @128 (full run)", 2,
                  Duration::from_secs(3), || {
        Engine::new(cfg.clone(), &graph, Policy::Staggered,
                    SparsityProfile::paper_default())
            .run()
            .total_cycles
    });
    let tiles_per_s = tiles as f64 / s.median.as_secs_f64();
    push(&s, "simulated tile-ops/s", tiles_per_s);

    // 2. server-scale simulation (batching efficiency of the event loop)
    let server = AcceleratorConfig::server();
    let graph_srv = OpGraph::build(&model, 8, 128);
    let mut srv_cfg = server.clone();
    srv_cfg.batch = 8;
    let s = bench("sim: bert-tiny x server(b8) @128", 1,
                  Duration::from_secs(3), || {
        Engine::new(srv_cfg.clone(), &graph_srv, Policy::Staggered,
                    SparsityProfile::paper_default())
            .run()
            .total_cycles
    });
    push(&s, "runs/s", s.per_sec());

    // 3. DynaTran host prune throughput (GB/s)
    let mut rng = Rng::new(1);
    let data: Vec<f32> = (0..1 << 20).map(|_| rng.normal()).collect();
    let mut buf = data.clone();
    let s = bench("dynatran prune 4MB f32", 3, Duration::from_secs(2), || {
        buf.copy_from_slice(&data);
        dynatran_prune_inplace(&mut buf, 0.5)
    });
    let gbs = (data.len() * 4) as f64 / s.median.as_secs_f64() / 1e9;
    push(&s, "GB/s", gbs);

    // 4. sparsity pipeline: compress + align a 16x16 tile pair
    let w: Vec<f32> = (0..256).map(|_| if rng.chance(0.5) { 0.0 } else { rng.normal() }).collect();
    let a: Vec<f32> = (0..256).map(|_| if rng.chance(0.5) { 0.0 } else { rng.normal() }).collect();
    let s = bench("sparsity: compress+align 16x16 pair", 10,
                  Duration::from_secs(1), || {
        let cw = CompressedTile::compress(&w);
        let ca = CompressedTile::compress(&a);
        precompute_align(&cw, &ca).w.len()
    });
    push(&s, "pairs/s", s.per_sec());

    // 5. runtime dispatch overhead (reference backend by default; PJRT
    // when artifacts are present)
    {
        let mut rt = acceltran::runtime::Runtime::load_default().unwrap();
        let be = rt.backend_name();
        let store = acceltran::runtime::ParamStore::init(&rt.manifest, 0);
        let seq = rt.manifest.seq;
        let ids: Vec<i32> = (0..seq).map(|i| (i % 512) as i32).collect();
        // warm caches (compile cache under PJRT, page/alloc under reference)
        rt.classify(1, &store.params, &ids, 0.0).unwrap();
        let s = bench(
            &format!("{be}: classify_b1 dispatch"),
            3,
            Duration::from_secs(3),
            || rt.classify(1, &store.params, &ids, 0.0).unwrap(),
        );
        push(&s, "req/s", s.per_sec());
        let ids32: Vec<i32> = (0..32 * seq).map(|i| (i % 512) as i32).collect();
        let s = bench(
            &format!("{be}: classify_b32 dispatch"),
            2,
            Duration::from_secs(3),
            || rt.classify(32, &store.params, &ids32, 0.0).unwrap(),
        );
        push(&s, "seq/s", s.per_sec() * 32.0);
        // DynaTran pruning also accelerates the host backend: at tau=0.05
        // most activations zero out and the zero-skipping GEMMs win.
        let s = bench(
            &format!("{be}: classify_b32 dispatch (tau=0.05)"),
            2,
            Duration::from_secs(3),
            || rt.classify(32, &store.params, &ids32, 0.05).unwrap(),
        );
        push(&s, "seq/s", s.per_sec() * 32.0);
    }

    // 6. GEMM kernel sweep: scalar (pre-rewrite) vs blocked microkernel
    // (DESIGN.md "Host microkernel") on an FFN-shaped problem, across
    // DynaTran taus and a structured-sparsity case.  Writes the repo's
    // perf-trajectory file BENCH_gemm.json next to EXPERIMENTS.md.
    {
        use acceltran::runtime::tensor::{matmul_ex, matmul_nt_ex, matmul_scalar, matmul_tn_ex};

        let (m, k, n) = (256usize, 128, 512); // batch*seq x hidden x ff
        let cores =
            std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
        println!("\n-- gemm kernel sweep: ({m}x{k})x({k}x{n}), {cores} cores --");
        let mut rng = Rng::new(2);
        let w = rng.normal_vec(k * n, 1.0);
        let mut rows = Vec::new();
        let mut speedup_at = |tau: f32, label: &str, x: &[f32]| {
            let (_, stats) = matmul_ex(x, &w, m, k, n);
            let s_pre = bench(
                &format!("gemm scalar {label}"),
                2,
                Duration::from_secs(1),
                || matmul_scalar(x, &w, m, k, n).len(),
            );
            let s_post = bench(
                &format!("gemm blocked {label}"),
                2,
                Duration::from_secs(1),
                || matmul_ex(x, &w, m, k, n).0.len(),
            );
            let speedup = s_pre.median.as_secs_f64() / s_post.median.as_secs_f64();
            push(&s_pre, "pre: us/GEMM", s_pre.median.as_secs_f64() * 1e6);
            push(&s_post, "post: speedup x", speedup);
            println!(
                "   {label}: {speedup:.2}x | effectual tiles {:.3} | \
                 effectual MACs {:.3}",
                stats.effectual_tile_fraction(),
                stats.effectual_mac_fraction()
            );
            for (kernel, sample) in [("scalar", &s_pre), ("blocked", &s_post)] {
                rows.push(Json::obj(vec![
                    ("case", Json::str(label)),
                    ("kernel", Json::str(kernel)),
                    ("tau", Json::num(tau as f64)),
                    ("median_us", Json::num(sample.median.as_secs_f64() * 1e6)),
                    ("speedup_vs_scalar", Json::num(if kernel == "blocked" {
                        speedup
                    } else {
                        1.0
                    })),
                    (
                        "effectual_tile_fraction",
                        Json::num(stats.effectual_tile_fraction()),
                    ),
                    (
                        "effectual_mac_fraction",
                        Json::num(stats.effectual_mac_fraction()),
                    ),
                ]));
            }
            speedup
        };

        // DynaTran sweep: activation-scale normals pruned at each tau
        // (std 0.05 puts tau=0.04 near the paper's ~50% operating point)
        let base = rng.normal_vec(m * k, 0.05);
        let mut speedup_tau004 = 0.0;
        for tau in [0.0f32, 0.02, 0.04, 0.08] {
            let mut x = base.clone();
            dynatran_prune_inplace(&mut x, tau);
            let sp = speedup_at(tau, &format!("tau={tau}"), &x);
            if tau == 0.04 {
                speedup_tau004 = sp;
            }
        }
        // structured sparsity: half the token rows pruned away entirely
        // (tile-skip path engages; scattered taus above mostly exercise
        // the element-granular accounting)
        let mut x = base.clone();
        dynatran_prune_inplace(&mut x, 0.04);
        for v in x[..(m / 2) * k].iter_mut() {
            *v = 0.0;
        }
        speedup_at(0.04, "tau=0.04+half-rows-zero", &x);

        // transpose variants at the operating point
        let xp = {
            let mut x = base.clone();
            dynatran_prune_inplace(&mut x, 0.04);
            x
        };
        let ynt = rng.normal_vec(m * n, 0.05);
        let s = bench("gemm_nt blocked tau=0.04", 2, Duration::from_secs(1), || {
            matmul_nt_ex(&ynt, &w, m, n, k).0.len()
        });
        push(&s, "us/GEMM", s.median.as_secs_f64() * 1e6);
        let s = bench("gemm_tn blocked tau=0.04", 2, Duration::from_secs(1), || {
            matmul_tn_ex(&xp, &ynt, m, k, n).0.len()
        });
        push(&s, "us/GEMM", s.median.as_secs_f64() * 1e6);

        let bench_path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("..")
            .join("BENCH_gemm.json");
        std::fs::write(
            &bench_path,
            Json::obj(vec![
                ("bench", Json::str("gemm_kernel_sweep")),
                ("measured", Json::Bool(true)),
                ("shape_m", Json::num(m as f64)),
                ("shape_k", Json::num(k as f64)),
                ("shape_n", Json::num(n as f64)),
                ("cores", Json::num(cores as f64)),
                ("rows", Json::arr(rows)),
            ])
            .to_string_pretty(),
        )
        .unwrap();
        println!("   wrote {}", bench_path.display());

        // acceptance bar (ISSUE 6): blocked >=2x scalar at tau=0.04 on a
        // >=4-core host; ACCELTRAN_BENCH_NO_ASSERT=1 downgrades to warn
        if cores >= 4 && std::env::var_os("ACCELTRAN_BENCH_NO_ASSERT").is_none() {
            assert!(
                speedup_tau004 >= 2.0,
                "blocked GEMM speedup {speedup_tau004:.2}x < 2x at tau=0.04 \
                 on a {cores}-core host (set ACCELTRAN_BENCH_NO_ASSERT=1 to \
                 downgrade to a warning)"
            );
        } else if speedup_tau004 < 2.0 {
            println!(
                "warning: blocked GEMM speedup {speedup_tau004:.2}x < 2x \
                 at tau=0.04 ({cores} cores)"
            );
        }
    }

    std::fs::create_dir_all("reports").ok();
    std::fs::write(
        "reports/perf_hotpath.json",
        Json::arr(report).to_string_pretty(),
    )
    .unwrap();
    println!("\nwrote reports/perf_hotpath.json");
}
