//! §Perf hot-path microbenchmarks: the simulator event loop, the
//! host-side pruning kernels, the sparsity pipeline, and the PJRT
//! dispatch overhead.  These are the measurements behind EXPERIMENTS.md
//! §Perf (before/after table).
//!
//! Run with: `cargo bench --bench perf_hotpath`

use std::time::Duration;

use acceltran::model::{OpGraph, TransformerConfig};
use acceltran::pruning::dynatran_prune_inplace;
use acceltran::sim::engine::{Engine, SparsityProfile};
use acceltran::sim::scheduler::Policy;
use acceltran::sim::sparsity::{precompute_align, CompressedTile};
use acceltran::sim::AcceleratorConfig;
use acceltran::util::bench::bench;
use acceltran::util::json::Json;
use acceltran::util::rng::Rng;

fn main() {
    println!("== §Perf: hot-path microbenchmarks ==\n");
    let mut report = Vec::new();
    let mut push = |s: &acceltran::util::bench::Sample, metric: &str, value: f64| {
        println!("{s}   [{metric}: {value:.3}]");
        report.push(Json::obj(vec![
            ("name", Json::str(s.name.clone())),
            ("median_us", Json::num(s.median.as_secs_f64() * 1e6)),
            ("metric", Json::str(metric)),
            ("value", Json::num(value)),
        ]));
    };

    // 1. simulator end-to-end: BERT-Tiny on Edge (the main hot loop)
    let model = TransformerConfig::bert_tiny();
    let cfg = AcceleratorConfig::edge();
    let graph = OpGraph::build(&model, cfg.batch, 128);
    let tiles: usize = graph
        .nodes
        .iter()
        .map(|n| {
            acceltran::sim::tiling::tile_op(&n.dims, 1, 16, 16, 16).total_tiles()
        })
        .sum();
    let s = bench("sim: bert-tiny x edge @128 (full run)", 2,
                  Duration::from_secs(3), || {
        Engine::new(cfg.clone(), &graph, Policy::Staggered,
                    SparsityProfile::paper_default())
            .run()
            .total_cycles
    });
    let tiles_per_s = tiles as f64 / s.median.as_secs_f64();
    push(&s, "simulated tile-ops/s", tiles_per_s);

    // 2. server-scale simulation (batching efficiency of the event loop)
    let server = AcceleratorConfig::server();
    let graph_srv = OpGraph::build(&model, 8, 128);
    let mut srv_cfg = server.clone();
    srv_cfg.batch = 8;
    let s = bench("sim: bert-tiny x server(b8) @128", 1,
                  Duration::from_secs(3), || {
        Engine::new(srv_cfg.clone(), &graph_srv, Policy::Staggered,
                    SparsityProfile::paper_default())
            .run()
            .total_cycles
    });
    push(&s, "runs/s", s.per_sec());

    // 3. DynaTran host prune throughput (GB/s)
    let mut rng = Rng::new(1);
    let data: Vec<f32> = (0..1 << 20).map(|_| rng.normal()).collect();
    let mut buf = data.clone();
    let s = bench("dynatran prune 4MB f32", 3, Duration::from_secs(2), || {
        buf.copy_from_slice(&data);
        dynatran_prune_inplace(&mut buf, 0.5)
    });
    let gbs = (data.len() * 4) as f64 / s.median.as_secs_f64() / 1e9;
    push(&s, "GB/s", gbs);

    // 4. sparsity pipeline: compress + align a 16x16 tile pair
    let w: Vec<f32> = (0..256).map(|_| if rng.chance(0.5) { 0.0 } else { rng.normal() }).collect();
    let a: Vec<f32> = (0..256).map(|_| if rng.chance(0.5) { 0.0 } else { rng.normal() }).collect();
    let s = bench("sparsity: compress+align 16x16 pair", 10,
                  Duration::from_secs(1), || {
        let cw = CompressedTile::compress(&w);
        let ca = CompressedTile::compress(&a);
        precompute_align(&cw, &ca).w.len()
    });
    push(&s, "pairs/s", s.per_sec());

    // 5. runtime dispatch overhead (reference backend by default; PJRT
    // when artifacts are present)
    {
        let mut rt = acceltran::runtime::Runtime::load_default().unwrap();
        let be = rt.backend_name();
        let store = acceltran::runtime::ParamStore::init(&rt.manifest, 0);
        let seq = rt.manifest.seq;
        let ids: Vec<i32> = (0..seq).map(|i| (i % 512) as i32).collect();
        // warm caches (compile cache under PJRT, page/alloc under reference)
        rt.classify(1, &store.params, &ids, 0.0).unwrap();
        let s = bench(
            &format!("{be}: classify_b1 dispatch"),
            3,
            Duration::from_secs(3),
            || rt.classify(1, &store.params, &ids, 0.0).unwrap(),
        );
        push(&s, "req/s", s.per_sec());
        let ids32: Vec<i32> = (0..32 * seq).map(|i| (i % 512) as i32).collect();
        let s = bench(
            &format!("{be}: classify_b32 dispatch"),
            2,
            Duration::from_secs(3),
            || rt.classify(32, &store.params, &ids32, 0.0).unwrap(),
        );
        push(&s, "seq/s", s.per_sec() * 32.0);
        // DynaTran pruning also accelerates the host backend: at tau=0.05
        // most activations zero out and the zero-skipping GEMMs win.
        let s = bench(
            &format!("{be}: classify_b32 dispatch (tau=0.05)"),
            2,
            Duration::from_secs(3),
            || rt.classify(32, &store.params, &ids32, 0.05).unwrap(),
        );
        push(&s, "seq/s", s.per_sec() * 32.0);
    }

    std::fs::create_dir_all("reports").ok();
    std::fs::write(
        "reports/perf_hotpath.json",
        Json::arr(report).to_string_pretty(),
    )
    .unwrap();
    println!("\nwrote reports/perf_hotpath.json");
}
