//! Table IV: ablation analysis for inference of BERT-Tiny on
//! AccelTran-Server — full config vs w/o DynaTran, w/o MP (weight
//! pruning), w/o sparsity-aware modules, and w/o monolithic-3D RRAM.
//!
//! Run with: `cargo bench --bench tab04_ablation`

use acceltran::model::TransformerConfig;
use acceltran::sim::engine::{simulate, SimResult, SparsityProfile};
use acceltran::sim::scheduler::Policy;
use acceltran::sim::{AcceleratorConfig, MemoryKind};
use acceltran::util::json::Json;
use acceltran::util::table::{eng, Table};

fn main() {
    println!("== Table IV: ablations (BERT-Tiny on AccelTran-Server) ==\n");
    let model = TransformerConfig::bert_tiny();
    let seq = 512;
    let paper_sp = SparsityProfile::paper_default();
    let base = AcceleratorConfig::server();

    let run = |cfg: &AcceleratorConfig, sp: SparsityProfile| -> SimResult {
        simulate(cfg, &model, seq, Policy::Staggered, sp)
    };

    let full = run(&base, paper_sp);

    let mut no_dyna_cfg = base.clone();
    no_dyna_cfg.dynatran_enabled = false;
    let no_dyna = run(&no_dyna_cfg, paper_sp);

    let no_mp = run(&base, SparsityProfile { weight_rho: 0.0, ..paper_sp });

    let mut no_sam_cfg = base.clone();
    no_sam_cfg.sparsity_modules = false;
    let no_sam = run(&no_sam_cfg, paper_sp);

    let mut ddr_cfg = base.clone();
    ddr_cfg.memory = MemoryKind::LpDdr3;
    let ddr = run(&ddr_cfg, paper_sp);

    let paper_rows = [
        ("AccelTran-Server", 172_180.0, 0.1396, 24.04),
        ("w/o DynaTran", 93_333.0, 0.1503, 14.03),
        ("w/o MP", 163_484.0, 0.2009, 32.85),
        ("w/o Sparsity-aware modules", 90_410.0, 0.2701, 24.43),
        ("w/o Monolithic-3D RRAM", 88_736.0, 0.1737, 15.42),
    ];
    let configs: [(&str, &SimResult, &AcceleratorConfig); 5] = [
        ("AccelTran-Server", &full, &base),
        ("w/o DynaTran", &no_dyna, &no_dyna_cfg),
        ("w/o MP", &no_mp, &base),
        ("w/o Sparsity-aware modules", &no_sam, &no_sam_cfg),
        ("w/o Monolithic-3D RRAM", &ddr, &ddr_cfg),
    ];

    let mut t = Table::new([
        "configuration",
        "seq/s",
        "mJ/seq",
        "net W",
        "paper seq/s",
        "paper mJ/seq",
        "paper W",
    ]);
    let mut report = Vec::new();
    for ((name, r, cfg), (pname, ptp, pmj, pw)) in configs.iter().zip(&paper_rows) {
        assert_eq!(name, pname);
        let tp = r.throughput_seq_s(cfg);
        let mj = r.energy_mj_per_seq();
        let w = r.avg_power_w(cfg);
        t.row([
            name.to_string(),
            eng(tp),
            format!("{mj:.4}"),
            format!("{w:.2}"),
            eng(*ptp),
            format!("{pmj:.4}"),
            format!("{pw:.2}"),
        ]);
        report.push(Json::obj(vec![
            ("configuration", Json::str(*name)),
            ("throughput_seq_s", Json::num(tp)),
            ("energy_mj_per_seq", Json::num(mj)),
            ("power_w", Json::num(w)),
            ("paper_throughput", Json::num(*ptp)),
            ("paper_energy", Json::num(*pmj)),
        ]));
    }
    t.print();

    // shape checks mirroring the paper's ordering
    let tp = |r: &SimResult, c: &AcceleratorConfig| r.throughput_seq_s(c);
    assert!(tp(&full, &base) > tp(&no_dyna, &no_dyna_cfg),
            "DynaTran must raise throughput");
    assert!(tp(&full, &base) > tp(&no_sam, &no_sam_cfg),
            "sparsity modules must raise throughput");
    assert!(tp(&full, &base) > tp(&ddr, &ddr_cfg),
            "RRAM must beat DDR");
    assert!(no_sam.energy_mj_per_seq() > full.energy_mj_per_seq(),
            "no-sparsity-modules must cost energy");
    assert!(no_mp.energy_mj_per_seq() > full.energy_mj_per_seq(),
            "dense weights must cost energy");
    println!(
        "\nShape check passed: full config wins throughput against every\n\
         ablation; removing sparsity handling costs the most energy —\n\
         the Table IV ordering."
    );
    std::fs::create_dir_all("reports").ok();
    std::fs::write(
        "reports/tab04_ablation.json",
        Json::arr(report).to_string_pretty(),
    )
    .unwrap();
    println!("wrote reports/tab04_ablation.json");
}
