//! Serving-throughput sweep: request throughput of the concurrent
//! serving engine (`coordinator::serve`) at 1 / 2 / 4 workers over the
//! reference backend — the measurement behind EXPERIMENTS.md §Perf's
//! serve rows and the PR's ≥2x-at-4-workers acceptance bar — plus an
//! HTTP-path wave over the `serve::net` front-end (2 pools × 2
//! workers, loopback keep-alive clients) that bounds the transport tax:
//! HTTP req/s must stay ≥0.8× the in-process 4-worker figure — and a
//! mixed-length wave (native lens ~ U[8, seq]) that pins the
//! continuous-batching win: length-bucketed dispatch must beat the same
//! content padded to seq by ≥1.5× with ≤15% padded tokens (vs a ≥40%
//! pad-to-max baseline).
//!
//! Each worker is pinned to a single intra-op thread
//! (`ACCELTRAN_THREADS=1`) so the sweep isolates *pool* scaling: without
//! the pin a lone worker's row-parallel GEMMs already fan out across
//! cores and the comparison conflates the two parallelism axes.
//!
//! Knobs: `ACCELTRAN_SERVE_REQUESTS` (default 256) shrinks the wave;
//! `ACCELTRAN_BENCH_NO_ASSERT=1` turns the scaling assertions into
//! warnings (for constrained CI runners).
//!
//! Run with: `cargo bench --bench serve_throughput`

use std::time::{Duration, Instant};

use acceltran::coordinator::{ServeConfig, ServePool};
use acceltran::nlp::sentiment::SentimentTask;
use acceltran::runtime::tensor::{gemm_stats_reset, gemm_stats_snapshot};
use acceltran::runtime::{ParamStore, Runtime};
use acceltran::serve::net::{HttpClient, NetConfig, NetServer};
use acceltran::util::cli::env_usize;
use acceltran::util::json::Json;

/// One measured wave: submit every request, drain, return req/s plus
/// dispatch accounting (dispatch count, padded-row and padded-token
/// fractions).
fn wave(
    rt: &Runtime,
    params: &[f32],
    reqs: &[Vec<i32>],
    workers: usize,
    tau: f32,
) -> (f64, u64, f64, f64) {
    let cfg = ServeConfig {
        workers,
        slo: Duration::from_millis(10),
        sim: None,
        // the bench submits its whole wave up front; lift the admission
        // bound out of the way so backpressure never skews the timing
        max_queue: reqs.len().max(1),
        ..Default::default()
    };
    let pool = ServePool::start(rt, params, &cfg).unwrap();
    let t0 = Instant::now();
    for ids in reqs {
        pool.submit(ids.clone(), tau).unwrap();
    }
    let (report, responses) = pool.finish().unwrap();
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(responses.len(), reqs.len(), "every request must be served");
    assert_eq!(report.requests as usize, reqs.len());
    (
        reqs.len() as f64 / dt,
        report.stats.dispatches,
        report.stats.padded_row_fraction(),
        report.stats.padded_token_fraction(),
    )
}

/// One HTTP wave: spread `reqs` across `conns` keep-alive loopback
/// connections against a running front-end; returns req/s (every
/// response must be a 200).
fn http_wave(addr: std::net::SocketAddr, reqs: &[Vec<i32>], conns: usize) -> f64 {
    let bodies: Vec<String> = reqs
        .iter()
        .map(|ids| {
            let arr: Vec<String> = ids.iter().map(|i| i.to_string()).collect();
            format!(r#"{{"ids": [{}], "tau": 0.04}}"#, arr.join(","))
        })
        .collect();
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..conns {
        let mine: Vec<String> = bodies
            .iter()
            .skip(c)
            .step_by(conns)
            .cloned()
            .collect();
        handles.push(std::thread::spawn(move || {
            let mut client = HttpClient::connect(addr).unwrap();
            for body in &mine {
                let resp = client
                    .request("POST", "/v1/classify", Some(body.as_bytes()))
                    .unwrap();
                assert_eq!(resp.status, 200, "HTTP wave hit {}", resp.status);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    reqs.len() as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    // one core per worker: measure pool scaling, not GEMM scaling
    std::env::set_var("ACCELTRAN_THREADS", "1");
    let n = env_usize("ACCELTRAN_SERVE_REQUESTS", 256);
    let tau = 0.04f32;
    let rt = Runtime::load_default().unwrap();
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let params = ParamStore::init(&rt.manifest, 0).params;
    let task = SentimentTask::new(vocab, seq, 11);
    let ds = task.dataset(n, 5);
    let reqs: Vec<Vec<i32>> = ds.examples.iter().map(|e| e.ids.clone()).collect();
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    println!(
        "== serve throughput: {n} requests x {{1,2,4}} workers \
         ['{}' backend, {cores} cores, tau={tau}] ==\n",
        rt.backend_name()
    );

    // warm-up wave (page in params, prime allocator)
    wave(&rt, &params, &reqs[..reqs.len().min(64)], 1, tau);

    let sweep = [1usize, 2, 4];
    let mut rps = Vec::new();
    let mut report = Vec::new();
    for &workers in &sweep {
        // median of 3 waves per point; the tiled-GEMM accumulator spans
        // all 3 (tile stats are rate-independent, so aggregating is fine)
        gemm_stats_reset();
        let mut runs: Vec<(f64, u64, f64, f64)> = (0..3)
            .map(|_| wave(&rt, &params, &reqs, workers, tau))
            .collect();
        let gemm = gemm_stats_snapshot();
        runs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let (med_rps, dispatches, padded, _) = runs[1];
        println!(
            "{workers} worker(s): {med_rps:>9.1} req/s (median of 3) | \
             {dispatches} dispatches | {:.1}% padded rows | \
             effectual tiles {:.3} / MACs {:.3}",
            100.0 * padded,
            gemm.effectual_tile_fraction(),
            gemm.effectual_mac_fraction()
        );
        rps.push(med_rps);
        report.push(Json::obj(vec![
            ("workers", Json::num(workers as f64)),
            ("requests", Json::num(n as f64)),
            ("median_rps", Json::num(med_rps)),
            ("dispatches", Json::num(dispatches as f64)),
            ("padded_row_fraction", Json::num(padded)),
            (
                "effectual_tile_fraction",
                Json::num(gemm.effectual_tile_fraction()),
            ),
            (
                "effectual_mac_fraction",
                Json::num(gemm.effectual_mac_fraction()),
            ),
        ]));
    }

    let speedup_2 = rps[1] / rps[0];
    let speedup_4 = rps[2] / rps[0];
    println!(
        "\nscaling vs 1 worker: 2w {speedup_2:.2}x, 4w {speedup_4:.2}x"
    );
    // paste-ready EXPERIMENTS.md §Perf rows (fill in date + commit)
    println!("\nEXPERIMENTS.md §Perf rows:");
    for (i, &workers) in sweep.iter().enumerate() {
        println!(
            "| <date> | <commit> | serve_throughput ({workers}w, {n} req) | \
             {:.1} req/s | ACCELTRAN_THREADS=1, reference backend |",
            rps[i]
        );
    }

    // ---- HTTP-path wave: same total worker count (2 pools x 2
    // workers = 4), loopback keep-alive clients.  The ratio against
    // the in-process 4-worker median is the transport tax.
    println!("\n== HTTP front-end: 2 pools x 2 workers, 8 connections ==");
    let net_cfg = NetConfig {
        pools: 2,
        serve: ServeConfig {
            workers: 2,
            slo: Duration::from_millis(10),
            sim: None,
            ..Default::default()
        },
        ..NetConfig::default()
    };
    let server = NetServer::start(&rt, &params, &net_cfg).unwrap();
    let addr = server.addr();
    // warm-up (connection setup, first dispatches)
    http_wave(addr, &reqs[..reqs.len().min(64)], 4);
    let mut http_runs: Vec<f64> =
        (0..3).map(|_| http_wave(addr, &reqs, 8)).collect();
    http_runs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let http_rps = http_runs[1];
    let net_report = server.shutdown().unwrap();
    let http_ratio = http_rps / rps[2];
    println!(
        "http: {http_rps:>9.1} req/s (median of 3) | {:.2}x of in-process \
         4-worker | {} conns accepted, 0 expected 5xx (got {})",
        http_ratio, net_report.connections, net_report.server_errors
    );
    assert_eq!(net_report.server_errors, 0, "bench load must not 5xx");
    println!(
        "| <date> | <commit> | serve_throughput (http, 2 pools x 2w, {n} req) | \
         {http_rps:.1} req/s | loopback HTTP, ratio {http_ratio:.2}x vs in-process 4w |"
    );

    // ---- continuous-batching wave: requests of mixed native length
    // (lens ~ U[lo, seq]) through the length-bucketed engine vs the
    // same token content padded to seq (the pre-bucketing behaviour:
    // `reqs` is exactly that wave).  The engine reports its own
    // padded-token fraction for the bucketed wave; the pad-to-max
    // baseline's true fraction is computed here from the known native
    // lengths (the engine sees full-length rows and reports ~0).
    println!("\n== mixed-length wave: bucketed vs pad-to-max, 4 workers ==");
    let lo = 8usize.min(seq);
    let mut state = 0x9e37_79b9_7f4a_7c15u64;
    let mixed: Vec<Vec<i32>> = reqs
        .iter()
        .map(|ids| {
            state = state
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let len = lo + ((state >> 33) as usize) % (seq - lo + 1);
            ids[..len].to_vec()
        })
        .collect();
    let true_tokens: usize = mixed.iter().map(|r| r.len()).sum();
    let baseline_padded_frac =
        1.0 - true_tokens as f64 / (reqs.len() * seq) as f64;
    wave(&rt, &params, &mixed[..mixed.len().min(64)], 4, tau); // warm-up
    let mut mixed_runs: Vec<(f64, u64, f64, f64)> =
        (0..3).map(|_| wave(&rt, &params, &mixed, 4, tau)).collect();
    mixed_runs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let (mixed_rps, mixed_dispatches, _, mixed_token_frac) = mixed_runs[1];
    let mixed_speedup = mixed_rps / rps[2];
    println!(
        "bucketed:   {mixed_rps:>9.1} req/s (median of 3) | \
         {mixed_dispatches} dispatches | {:.1}% padded tokens",
        100.0 * mixed_token_frac
    );
    println!(
        "pad-to-max: {:>9.1} req/s (the 4-worker full-length wave) | \
         {:.1}% padded tokens (true, from native lens)",
        rps[2],
        100.0 * baseline_padded_frac
    );
    println!("speedup: {mixed_speedup:.2}x");
    println!(
        "| <date> | <commit> | serve_throughput (mixed-len, 4w, {n} req) | \
         {mixed_rps:.1} req/s | {mixed_speedup:.2}x vs pad-to-max, \
         {:.1}% padded tokens |",
        100.0 * mixed_token_frac
    );

    std::fs::create_dir_all("reports").ok();
    std::fs::write(
        "reports/serve_throughput.json",
        Json::obj(vec![
            ("backend", Json::str("reference")),
            ("requests", Json::num(n as f64)),
            ("cores", Json::num(cores as f64)),
            ("speedup_2w", Json::num(speedup_2)),
            ("speedup_4w", Json::num(speedup_4)),
            ("http_rps", Json::num(http_rps)),
            ("http_ratio_vs_4w", Json::num(http_ratio)),
            ("mixed_rps", Json::num(mixed_rps)),
            ("mixed_speedup_vs_pad_to_max", Json::num(mixed_speedup)),
            (
                "mixed_padded_token_fraction",
                Json::num(mixed_token_frac),
            ),
            (
                "baseline_padded_token_fraction",
                Json::num(baseline_padded_frac),
            ),
            ("sweep", Json::arr(report)),
        ])
        .to_string_pretty(),
    )
    .unwrap();
    println!("\nwrote reports/serve_throughput.json");

    // perf-trajectory file BENCH_serve.json next to EXPERIMENTS.md —
    // committed as a structure-only placeholder until the first measured
    // run on a real host overwrites it in place (same scheme as
    // BENCH_gemm.json from perf_hotpath)
    let bench_path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_serve.json");
    std::fs::write(
        &bench_path,
        Json::obj(vec![
            ("bench", Json::str("serve_throughput")),
            ("measured", Json::Bool(true)),
            ("requests", Json::num(n as f64)),
            ("cores", Json::num(cores as f64)),
            ("median_rps_1w", Json::num(rps[0])),
            ("median_rps_2w", Json::num(rps[1])),
            ("median_rps_4w", Json::num(rps[2])),
            ("speedup_4w_vs_1w", Json::num(speedup_4)),
            ("http_rps", Json::num(http_rps)),
            ("http_ratio_vs_4w", Json::num(http_ratio)),
            ("mixed_rps", Json::num(mixed_rps)),
            ("mixed_speedup_vs_pad_to_max", Json::num(mixed_speedup)),
            ("mixed_padded_token_fraction", Json::num(mixed_token_frac)),
            (
                "baseline_padded_token_fraction",
                Json::num(baseline_padded_frac),
            ),
        ])
        .to_string_pretty(),
    )
    .unwrap();
    println!("wrote {}", bench_path.display());

    // acceptance bar: >=2x request throughput at 4 workers vs 1 on the
    // reference backend.  `available_parallelism` counts LOGICAL cpus,
    // and 4 single-threaded workers on a 2-core/4-thread SMT host
    // cannot reach 2x — so the hard assert only arms at >=8 logical
    // (>=4 physical on any common SMT config); below that it warns.
    if cores >= 8 && std::env::var_os("ACCELTRAN_BENCH_NO_ASSERT").is_none() {
        assert!(
            speedup_4 >= 2.0,
            "4-worker speedup {speedup_4:.2}x < 2x on a {cores}-logical-cpu \
             host (set ACCELTRAN_BENCH_NO_ASSERT=1 to downgrade to a warning)"
        );
    } else if speedup_4 < 2.0 {
        println!(
            "warning: 4-worker speedup {speedup_4:.2}x < 2x \
             ({cores} logical cpus available)"
        );
    }

    // HTTP acceptance bar: the wire must not cost more than 20% of the
    // in-process throughput at the same worker count.  Same arming rule
    // as above — loopback client threads also need cores to run on.
    if cores >= 8 && std::env::var_os("ACCELTRAN_BENCH_NO_ASSERT").is_none() {
        assert!(
            http_ratio >= 0.8,
            "HTTP req/s is {http_ratio:.2}x of in-process 4-worker (< 0.8x) \
             on a {cores}-logical-cpu host (set ACCELTRAN_BENCH_NO_ASSERT=1 \
             to downgrade to a warning)"
        );
    } else if http_ratio < 0.8 {
        println!(
            "warning: HTTP ratio {http_ratio:.2}x < 0.8x \
             ({cores} logical cpus available)"
        );
    }

    // Continuous-batching acceptance bar: serving lens ~ U[8, seq]
    // through the bucketed engine must beat the same content padded to
    // seq by >=1.5x, with <=15% padded tokens against a >=40% baseline.
    // Same arming rule as the other bars (the speedup needs real cores;
    // the fraction bars are load-independent but asserted together so
    // one knob downgrades everything).
    if cores >= 8 && std::env::var_os("ACCELTRAN_BENCH_NO_ASSERT").is_none() {
        assert!(
            mixed_speedup >= 1.5,
            "mixed-length speedup {mixed_speedup:.2}x < 1.5x vs pad-to-max \
             on a {cores}-logical-cpu host (set ACCELTRAN_BENCH_NO_ASSERT=1 \
             to downgrade to a warning)"
        );
        assert!(
            mixed_token_frac <= 0.15,
            "bucketed padded-token fraction {mixed_token_frac:.3} > 0.15"
        );
        assert!(
            baseline_padded_frac >= 0.4,
            "pad-to-max baseline padded-token fraction \
             {baseline_padded_frac:.3} < 0.4 — the workload no longer \
             exercises the padding waste this bench is pinning"
        );
    } else if mixed_speedup < 1.5 || mixed_token_frac > 0.15 {
        println!(
            "warning: mixed-length wave {mixed_speedup:.2}x / \
             {:.1}% padded tokens (bars: >=1.5x, <=15%; {cores} logical \
             cpus available)",
            100.0 * mixed_token_frac
        );
    }
}
