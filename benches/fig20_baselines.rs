//! Fig. 20: normalized throughput/energy of AccelTran vs baseline
//! platforms — AccelTran-Edge vs Raspberry Pi / Intel NCS / Apple M1
//! (BERT-Tiny) and AccelTran-Server vs A100 / OPTIMUS / SpAtten / Energon
//! (BERT-Base).
//!
//! AccelTran numbers come from the cycle-accurate simulator driven by a
//! *measured* sparsity trace (tau = 0.04 capture on the fine-tuned
//! reference model, 50% MP weight sparsity overlaid; BERT-Base reuses
//! the measured per-layer pattern cyclically — DESIGN.md "Measured vs
//! assumed sparsity"); baselines are analytic platform models normalized
//! to 14nm (see `sim::baselines` and DESIGN.md §Substitutions).  Both
//! the paper's reported factor and our measured factor are printed so
//! the shape (who wins, by roughly what order of magnitude) is
//! auditable.
//!
//! Run with: `cargo bench --bench fig20_baselines`

use acceltran::coordinator;
use acceltran::model::TransformerConfig;
use acceltran::sim::baselines::{edge_baselines, server_baselines, Baseline};
use acceltran::sim::engine::{simulate_with, SimResult};
use acceltran::sim::scheduler::Policy;
use acceltran::sim::{AcceleratorConfig, SparsitySource};
use acceltran::util::json::Json;
use acceltran::util::table::{eng, Table};

fn compare(
    title: &str,
    ours: &SimResult,
    cfg: &AcceleratorConfig,
    baselines: &[Baseline],
    report: &mut Vec<Json>,
) {
    let our_tp = ours.throughput_seq_s(cfg);
    let our_mj = ours.energy_mj_per_seq();
    println!(
        "{title}: simulated {} seq/s, {:.4} mJ/seq\n",
        eng(our_tp),
        our_mj
    );
    let mut t = Table::new([
        "platform",
        "norm seq/s",
        "norm mJ/seq",
        "measured tp factor",
        "paper tp factor",
        "measured E factor",
        "paper E factor",
    ]);
    for b in baselines {
        let tp_factor = our_tp / b.norm_throughput();
        let e_factor = b.norm_energy_mj() / our_mj;
        t.row([
            b.name.to_string(),
            eng(b.norm_throughput()),
            format!("{:.2}", b.norm_energy_mj()),
            format!("{}x", eng(tp_factor)),
            format!("{}x", eng(b.paper_throughput_factor)),
            format!("{}x", eng(e_factor)),
            format!("{}x", eng(b.paper_energy_factor)),
        ]);
        report.push(Json::obj(vec![
            ("setting", Json::str(title)),
            ("platform", Json::str(b.name)),
            ("measured_tp_factor", Json::num(tp_factor)),
            ("paper_tp_factor", Json::num(b.paper_throughput_factor)),
            ("measured_e_factor", Json::num(e_factor)),
            ("paper_e_factor", Json::num(b.paper_energy_factor)),
        ]));
        // shape assertions: AccelTran wins on both axes vs every baseline
        assert!(tp_factor > 1.0, "{}: AccelTran must win throughput", b.name);
        assert!(e_factor > 1.0, "{}: AccelTran must win energy", b.name);
    }
    t.print();
    println!();
}

fn main() {
    println!("== Fig. 20: AccelTran vs baseline platforms ==\n");
    let mut report = Vec::new();
    // measured activation sparsity at the fig11 plateau tau, with the
    // paper's 50% MP weight sparsity overlaid
    let trace = coordinator::measured_trace(0.04, true)
        .expect("measured-trace capture")
        .with_assumed_weight_rho(0.5);
    println!(
        "measured trace: mean act sparsity {:.3} at tau={}\n",
        trace.mean_act_rho(),
        trace.tau
    );
    let source = SparsitySource::Trace(trace);

    // (a) edge: BERT-Tiny on AccelTran-Edge
    let edge_cfg = AcceleratorConfig::edge();
    let edge = simulate_with(
        &edge_cfg,
        &TransformerConfig::bert_tiny(),
        128,
        Policy::Staggered,
        &source,
    );
    compare(
        "(a) AccelTran-Edge x BERT-Tiny",
        &edge,
        &edge_cfg,
        &edge_baselines(),
        &mut report,
    );

    // (b) server: BERT-Base on AccelTran-Server (the 12-layer model
    // cycles through the measured 2-layer pattern)
    let server_cfg = AcceleratorConfig::server();
    let server = simulate_with(
        &server_cfg,
        &TransformerConfig::bert_base(),
        128,
        Policy::Staggered,
        &source,
    );
    compare(
        "(b) AccelTran-Server x BERT-Base",
        &server,
        &server_cfg,
        &server_baselines(),
        &mut report,
    );

    // ordering shape: Energon must be the closest server competitor
    println!(
        "Shape check: baselines order RPi < NCS < M1 (edge) and\n\
         A100 < OPTIMUS < SpAtten < Energon (server), with AccelTran ahead\n\
         of all — matching the paper's Fig. 20 ordering.  Absolute factors\n\
         differ because our baselines are public-benchmark estimates and\n\
         the simulated workload uses seq=128 (see EXPERIMENTS.md)."
    );
    std::fs::create_dir_all("reports").ok();
    std::fs::write(
        "reports/fig20_baselines.json",
        Json::arr(report).to_string_pretty(),
    )
    .unwrap();
    println!("wrote reports/fig20_baselines.json");
}
