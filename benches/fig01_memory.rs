//! Fig. 1: memory requirements for BERT-Tiny and BERT-Base, broken into
//! embeddings / weights / activations, plus the activation-to-weight
//! ratios quoted in Sec. II-A2.
//!
//! Run with: `cargo bench --bench fig01_memory`

use acceltran::model::memreq::{mb, MemReq};
use acceltran::model::TransformerConfig;
use acceltran::util::json::Json;
use acceltran::util::table::Table;

fn main() {
    println!("== Fig. 1: transformer memory requirements ==\n");
    let mut t = Table::new([
        "model",
        "embeddings MB",
        "weights MB",
        "activations MB",
        "act/weight",
        "paper act/weight",
    ]);
    let mut report = Vec::new();
    for (cfg, paper_ratio) in [
        (TransformerConfig::bert_tiny(), 8.98),
        (TransformerConfig::bert_base(), 2.06),
    ] {
        let mr = MemReq::compute(&cfg, 1, cfg.seq, 0.0);
        t.row([
            cfg.name.clone(),
            format!("{:.1}", mb(mr.embedding_bytes)),
            format!("{:.1}", mb(mr.weight_bytes)),
            format!("{:.1}", mb(mr.activation_bytes)),
            format!("{:.2}x", mr.act_to_weight_ratio()),
            format!("{paper_ratio:.2}x"),
        ]);
        report.push(Json::obj(vec![
            ("model", Json::str(cfg.name.clone())),
            ("embedding_mb", Json::num(mb(mr.embedding_bytes))),
            ("weight_mb", Json::num(mb(mr.weight_bytes))),
            ("activation_mb", Json::num(mb(mr.activation_bytes))),
            ("act_weight_ratio", Json::num(mr.act_to_weight_ratio())),
            ("paper_act_weight_ratio", Json::num(paper_ratio)),
        ]));
    }
    t.print();
    println!(
        "\nShape check: activations dominate weights for both models, far\n\
         more so for BERT-Tiny — the motivation for pruning *activations*\n\
         (DynaTran) rather than weights alone."
    );
    std::fs::create_dir_all("reports").ok();
    std::fs::write(
        "reports/fig01_memory.json",
        Json::arr(report).to_string_pretty(),
    )
    .unwrap();
    println!("wrote reports/fig01_memory.json");
}
