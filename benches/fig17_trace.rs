//! Fig. 17: power consumption and resource/buffer utilization of
//! BERT-Tiny over one inference batch on AccelTran-Edge, as a cycle
//! trace.
//!
//! Trace-driven: the per-op activation sparsities come from a *measured*
//! sparsity trace captured on the fine-tuned reference model at
//! tau = 0.04 (the fig11 plateau point), with the paper's 50% movement-
//! pruning weight sparsity overlaid (the checkpoint itself is dense) —
//! DESIGN.md "Measured vs assumed sparsity".  Problem size shrinks under
//! `ACCELTRAN_TRAIN_STEPS` / `ACCELTRAN_EVAL_EXAMPLES`.
//!
//! Run with: `cargo bench --bench fig17_trace`

use acceltran::coordinator;
use acceltran::model::TransformerConfig;
use acceltran::sim::engine::simulate_with;
use acceltran::sim::scheduler::Policy;
use acceltran::sim::{AcceleratorConfig, SparsitySource};
use acceltran::util::json::Json;
use acceltran::util::table::Table;

fn main() {
    println!("== Fig. 17: Edge power / utilization trace (BERT-Tiny) ==\n");
    let mut cfg = AcceleratorConfig::edge();
    // cold first batch: Fig. 17(b) shows utilization at zero while the
    // word/position embeddings stream into the weight buffer (~60% of
    // it), before compute begins
    cfg.embeddings_resident = false;
    let model = TransformerConfig::bert_tiny();
    let trace = coordinator::measured_trace(0.04, true)
        .expect("measured-trace capture")
        .with_assumed_weight_rho(0.5);
    println!(
        "measured trace ({} backend): mean act sparsity {:.3} at tau={}, \
         accuracy {:.4}\n",
        trace.backend,
        trace.mean_act_rho(),
        trace.tau,
        trace.eval_accuracy
    );
    let source = SparsitySource::Trace(trace);
    let r = simulate_with(&cfg, &model, 512, Policy::Staggered, &source);

    // print a decimated trace table (the bench writes the full trace to
    // JSON for plotting)
    let mut t = Table::new([
        "cycle",
        "MAC lanes",
        "softmax",
        "layernorm",
        "act buf %",
        "w buf %",
        "dyn W",
        "leak W",
    ]);
    let stride = (r.trace.len() / 24).max(1);
    for s in r.trace.iter().step_by(stride) {
        t.row([
            s.cycle.to_string(),
            s.mac_lanes_active.to_string(),
            s.softmax_active.to_string(),
            s.layernorm_active.to_string(),
            format!("{:.0}", 100.0 * s.act_buffer_frac),
            format!("{:.0}", 100.0 * s.weight_buffer_frac),
            format!("{:.2}", s.dynamic_power_w),
            format!("{:.3}", s.leakage_power_w),
        ]);
    }
    t.print();

    // Fig. 17 shape checks
    // (a) leakage stays far below dynamic power (power gating)
    let max_dyn = r.trace.iter().map(|s| s.dynamic_power_w).fold(0.0, f64::max);
    let max_leak = r.trace.iter().map(|s| s.leakage_power_w).fold(0.0, f64::max);
    assert!(
        max_leak < 0.2 * max_dyn.max(1e-9),
        "leakage {max_leak} vs dynamic {max_dyn}"
    );
    // (b) both MAC and softmax are active at some point; at least one
    // sample shows simultaneous use (staggered heads)
    assert!(r.trace.iter().any(|s| s.mac_lanes_active > 0));
    assert!(r.trace.iter().any(|s| s.softmax_active > 0));
    let overlap = r
        .trace
        .iter()
        .any(|s| s.mac_lanes_active > 0 && s.softmax_active > 0);
    println!(
        "\nMAC+softmax overlap observed: {overlap} (staggered scheduling, Fig. 10(b))"
    );
    // (c) the weight buffer fills early (embeddings ~60%) then persists
    let early_w = r
        .trace
        .iter()
        .take(r.trace.len() / 4)
        .map(|s| s.weight_buffer_frac)
        .fold(0.0, f64::max);
    println!("peak weight-buffer occupancy in first quarter: {:.0}%", 100.0 * early_w);

    println!(
        "\ntotals: {} cycles, {:.3} mJ/seq, avg power {:.2} W, \
         MAC util {:.1}%, softmax util {:.1}%",
        r.total_cycles,
        r.energy_mj_per_seq(),
        r.avg_power_w(&cfg),
        100.0 * r.mac_utilization,
        100.0 * r.softmax_utilization
    );
    std::fs::create_dir_all("reports").ok();
    let samples = Json::arr(r.trace.iter().map(|s| {
        Json::obj(vec![
            ("cycle", Json::num(s.cycle as f64)),
            ("mac", Json::num(s.mac_lanes_active as f64)),
            ("softmax", Json::num(s.softmax_active as f64)),
            ("ln", Json::num(s.layernorm_active as f64)),
            ("act_buf", Json::num(s.act_buffer_frac)),
            ("w_buf", Json::num(s.weight_buffer_frac)),
            ("dyn_w", Json::num(s.dynamic_power_w)),
            ("leak_w", Json::num(s.leakage_power_w)),
        ])
    }));
    std::fs::write("reports/fig17_trace.json", samples.to_string_pretty()).unwrap();
    println!("wrote reports/fig17_trace.json ({} samples)", r.trace.len());
}
