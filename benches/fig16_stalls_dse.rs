//! Fig. 16: compute + memory stalls as a function of #PEs and net buffer
//! size (4:8:1 act:weight:mask split), for BERT-Tiny on the Edge
//! template, with the paper's chosen point called out.
//!
//! Run with: `cargo bench --bench fig16_stalls_dse`

use acceltran::model::TransformerConfig;
use acceltran::sim::engine::{simulate, SparsityProfile};
use acceltran::sim::scheduler::Policy;
use acceltran::sim::AcceleratorConfig;
use acceltran::util::json::Json;
use acceltran::util::table::{eng, Table};

fn main() {
    println!("== Fig. 16: stalls vs hardware resources ==\n");
    let model = TransformerConfig::bert_tiny();
    let seq = 512;
    let sp = SparsityProfile::paper_default();
    let mut t = Table::new([
        "PEs",
        "net buffer MB",
        "compute stalls",
        "memory stalls",
        "cycles",
    ]);
    let mut report = Vec::new();
    let mut grid: Vec<(usize, usize, u64, u64)> = Vec::new();
    for &pes in &[32usize, 64, 128, 256] {
        for &buf_mb in &[10usize, 13, 16] {
            let mut cfg = AcceleratorConfig::edge();
            cfg.pes = pes;
            let unit = (buf_mb << 20) / 13;
            cfg.act_buffer_bytes = 4 * unit;
            cfg.weight_buffer_bytes = 8 * unit;
            cfg.mask_buffer_bytes = unit;
            let r = simulate(&cfg, &model, seq, Policy::Staggered, sp);
            t.row([
                pes.to_string(),
                buf_mb.to_string(),
                eng(r.stalls.compute_total() as f64),
                eng(r.stalls.memory_total() as f64),
                eng(r.total_cycles as f64),
            ]);
            report.push(Json::obj(vec![
                ("pes", Json::num(pes as f64)),
                ("buffer_mb", Json::num(buf_mb as f64)),
                ("compute_stalls", Json::num(r.stalls.compute_total() as f64)),
                ("memory_stalls", Json::num(r.stalls.memory_total() as f64)),
                ("cycles", Json::num(r.total_cycles as f64)),
            ]));
            grid.push((pes, buf_mb, r.stalls.compute_total(), r.stalls.memory_total()));
        }
    }
    t.print();
    // shape check: fewest PEs has the most compute stalls at every buffer
    for &buf in &[10usize, 13, 16] {
        let at = |p: usize| grid.iter().find(|g| g.0 == p && g.1 == buf).unwrap().2;
        assert!(
            at(32) >= at(256),
            "compute stalls must not increase with PEs (buf {buf}MB)"
        );
    }
    println!(
        "\nChosen point (paper Sec. V-C): 64 PEs / 13 MB — a knee point\n\
         balancing stalls against area/power; see examples/design_space.rs\n\
         for the automated chosen-point logic."
    );
    std::fs::create_dir_all("reports").ok();
    std::fs::write(
        "reports/fig16_stalls.json",
        Json::arr(report).to_string_pretty(),
    )
    .unwrap();
    println!("wrote reports/fig16_stalls.json");
}
