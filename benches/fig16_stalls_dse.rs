//! Fig. 16: compute + memory stalls as a function of #PEs and net buffer
//! size (4:8:1 act:weight:mask split), for BERT-Tiny on the Edge
//! template — now a thin driver over the parallel `sim::dse` sweep,
//! with the paper's chosen point called out against the computed
//! Pareto frontier.
//!
//! Prefers the measured sparsity trace at `reports/sparsity_trace.json`
//! (the PR-4 capture; run `acceltran trace` first) and falls back to
//! the assumed uniform profile so the bench still runs standalone.
//!
//! Run with: `cargo bench --bench fig16_stalls_dse`

use acceltran::model::TransformerConfig;
use acceltran::sim::engine::{SparsityProfile, SparsitySource};
use acceltran::sim::scheduler::Policy;
use acceltran::sim::{dse, AcceleratorConfig};
use acceltran::trace::SparsityTrace;
use acceltran::util::json::Json;
use acceltran::util::table::{eng, Table};

fn main() {
    println!("== Fig. 16: stalls vs hardware resources ==\n");
    let model = TransformerConfig::bert_tiny();
    let seq = 512;

    let trace_path = "reports/sparsity_trace.json";
    let source = match SparsityTrace::load(trace_path) {
        Ok(t) => {
            println!("sparsity: measured trace {trace_path}");
            SparsitySource::Trace(t)
        }
        Err(_) => {
            println!("sparsity: uniform assumed profile (no trace at {trace_path})");
            SparsitySource::Uniform(SparsityProfile::paper_default())
        }
    };

    let mut space = dse::DseSpace::around(AcceleratorConfig::edge());
    space.pes = vec![32, 64, 128, 256];
    space.buffers_mb = vec![10, 13, 16];
    let report = dse::sweep(
        &space,
        &model,
        seq,
        Policy::Staggered,
        &source,
        &dse::SweepOptions { threads: 0, progress: false },
    );

    let mut t = Table::new([
        "PEs",
        "net buffer MB",
        "compute stalls",
        "memory stalls",
        "cycles",
        "frontier",
    ]);
    let mut rows = Vec::new();
    for p in &report.points {
        t.row([
            p.pes.to_string(),
            p.buffer_mb.to_string(),
            eng(p.result.stalls.compute_total() as f64),
            eng(p.result.stalls.memory_total() as f64),
            eng(p.result.total_cycles as f64),
            (if report.frontier.contains(p.index) { "*" } else { "" }).to_string(),
        ]);
        rows.push(Json::obj(vec![
            ("pes", Json::num(p.pes as f64)),
            ("buffer_mb", Json::num(p.buffer_mb as f64)),
            (
                "compute_stalls",
                Json::num(p.result.stalls.compute_total() as f64),
            ),
            (
                "memory_stalls",
                Json::num(p.result.stalls.memory_total() as f64),
            ),
            ("cycles", Json::num(p.result.total_cycles as f64)),
            ("sparsity_source", Json::str(report.sparsity_source.clone())),
            ("on_frontier", Json::Bool(report.frontier.contains(p.index))),
        ]));
    }
    t.print();

    // shape check: fewest PEs has the most compute stalls at every buffer
    for &buf in &[10usize, 13, 16] {
        let at = |pes: usize| {
            report
                .points
                .iter()
                .find(|p| p.pes == pes && p.buffer_mb == buf)
                .unwrap()
                .result
                .stalls
                .compute_total()
        };
        assert!(
            at(32) >= at(256),
            "compute stalls must not increase with PEs (buf {buf}MB)"
        );
    }

    let knee = report.knee_point().expect("non-empty sweep has a knee");
    println!(
        "\nPareto frontier: {} of {} points; knee {} — the paper selects\n\
         64 PEs / 13 MB (Sec. V-C) by the same stalls-vs-area/power\n\
         trade-off; `acceltran dse` sweeps the full dataflow grid too.",
        report.frontier.indices.len(),
        report.points.len(),
        knee.config_name
    );
    std::fs::create_dir_all("reports").ok();
    std::fs::write(
        "reports/fig16_stalls.json",
        Json::arr(rows).to_string_pretty(),
    )
    .unwrap();
    println!("wrote reports/fig16_stalls.json");
}
