//! Fig. 11: accuracy and activation sparsity as a function of (a) the
//! DynaTran pruning threshold tau, and (b) the top-k keep fraction —
//! on the trained synthetic-sentiment model through the runtime.
//!
//! (The paper uses BERT-Base on SST-2; we use the BERT-Tiny-shaped
//! encoder on the synthetic sentiment task — see DESIGN.md
//! §Substitutions.  The curve *shapes* — flat accuracy with rising
//! sparsity, then a cliff; monotone sparsity in tau — are the
//! reproduced claims.)
//!
//! Runs end-to-end on the pure-Rust reference backend (fine-tuning
//! included); uses PJRT artifacts when present.  Problem size shrinks
//! under `ACCELTRAN_TRAIN_STEPS` / `ACCELTRAN_EVAL_EXAMPLES` (the CI
//! smoke job sets both).
//!
//! Run with: `cargo bench --bench fig11_threshold_sweep`

use acceltran::coordinator::{self, trainer};
use acceltran::nlp::sentiment::SentimentTask;
use acceltran::runtime::Runtime;
use acceltran::util::cli::env_usize;
use acceltran::util::json::Json;
use acceltran::util::table::Table;

fn main() {
    println!("== Fig. 11: pruning-knob sweeps ==\n");
    let mut rt = Runtime::load_default().expect("runtime");
    println!("backend: {}", rt.backend_name());
    let examples = env_usize("ACCELTRAN_EVAL_EXAMPLES", 512);
    let store = trainer::ensure_trained(
        &mut rt,
        std::path::Path::new("reports/trained_params.bin"),
        200,
        true,
    )
    .expect("training failed");
    let task = SentimentTask::new(rt.manifest.vocab, rt.manifest.seq, 7);
    let val = task.dataset(examples, 2);

    // (a) DynaTran: tau from 0 to 0.1 (the paper's range)
    let taus = [0.0f32, 0.01, 0.02, 0.03, 0.04, 0.05, 0.06, 0.08, 0.10];
    let dyna =
        coordinator::sweep_dynatran(&mut rt, &store.params, &val, &taus, examples)
            .expect("dynatran sweep");
    println!("(a) DynaTran threshold sweep:");
    let mut t = Table::new(["tau", "activation sparsity", "accuracy"]);
    for p in &dyna.points {
        t.row([
            format!("{:.2}", p.knob),
            format!("{:.3}", p.activation_sparsity),
            format!("{:.4}", p.accuracy),
        ]);
    }
    t.print();

    // (b) top-k: keep fraction in powers of two (the paper varies k in
    // powers of two)
    let keeps = [1.0f32, 0.5, 0.25, 0.125, 0.0625];
    let topk =
        coordinator::sweep_topk(&mut rt, &store.params, &val, &keeps, examples)
            .expect("topk sweep");
    println!("\n(b) top-k keep-fraction sweep:");
    let mut t = Table::new(["keep frac", "net act sparsity", "accuracy"]);
    for p in &topk.points {
        t.row([
            format!("{:.4}", p.knob),
            format!("{:.3}", p.activation_sparsity),
            format!("{:.4}", p.accuracy),
        ]);
    }
    t.print();

    // shape checks
    for w in dyna.points.windows(2) {
        assert!(
            w[1].activation_sparsity >= w[0].activation_sparsity - 1e-6,
            "sparsity must be monotone in tau"
        );
    }
    let base_acc = dyna.points[0].accuracy;
    let cliff_acc = dyna.points.last().unwrap().accuracy;
    assert!(
        base_acc > 0.5,
        "trained model must beat the 50% random baseline at tau=0, got {base_acc:.3}"
    );
    println!(
        "\nShape check: baseline accuracy {base_acc:.3}; accuracy at tau=0.1 \
         {cliff_acc:.3}; max DynaTran sparsity within 1% of peak accuracy: {:.3}",
        dyna.max_sparsity_within(0.01)
    );
    std::fs::create_dir_all("reports").ok();
    std::fs::write(
        "reports/fig11_threshold_sweep.json",
        Json::arr([dyna.to_json(), topk.to_json()]).to_string_pretty(),
    )
    .unwrap();
    println!("wrote reports/fig11_threshold_sweep.json");
}
