//! Golden regression pin for the cycle-accurate perf model: a tiny
//! fixed accelerator config + a fixed measured-style sparsity trace must
//! keep producing the exact same `SimResult` (cycles, stall breakdown,
//! energy ledger, utilizations).  Any perf-model change that bends the
//! fig curves now fails tier-1 here instead of drifting silently.
//!
//! The golden lives at `rust/tests/goldens/sim_golden.json`.  On the
//! first run in a fresh checkout (file absent) the test *seeds* it from
//! the current model and passes with a loud note — commit the generated
//! file to arm the pin.  To intentionally rebaseline after a deliberate
//! perf-model change, delete the file, rerun, and commit the new one.
//! (The engine uses only IEEE-deterministic arithmetic — no libm — so
//! the pinned floats are portable across hosts.)

use std::path::PathBuf;

use acceltran::model::TransformerConfig;
use acceltran::sim::engine::simulate_with;
use acceltran::sim::scheduler::Policy;
use acceltran::sim::{AcceleratorConfig, SimResult, SparsitySource};
use acceltran::trace::{LayerActRho, SparsityTrace, WeightRho};
use acceltran::util::json::Json;

/// The fixed design point: a shrunken Edge so the run is fast and both
/// stall classes are exercised.
fn golden_cfg() -> AcceleratorConfig {
    let mut cfg = AcceleratorConfig::edge();
    cfg.pes = 16;
    cfg.act_buffer_bytes = 1 << 20;
    cfg.weight_buffer_bytes = 2 << 20;
    cfg.mask_buffer_bytes = 1 << 18;
    cfg
}

fn golden_model() -> TransformerConfig {
    TransformerConfig {
        name: "golden-tiny".into(),
        hidden: 32,
        layers: 2,
        heads: 2,
        ff: 64,
        vocab: 1000,
        seq: 64,
    }
}

/// A fixed two-layer trace with distinct values in every cell, standing
/// in for a measured capture (hand-written so the pin is independent of
/// the functional half).
fn golden_trace() -> SparsityTrace {
    SparsityTrace {
        model: "golden-tiny".into(),
        backend: "fixture".into(),
        tau: 0.04,
        examples: 64,
        eval_accuracy: 0.875,
        inherent_act_rho: 0.05,
        weight: WeightRho {
            embedding: 0.0,
            wqkv: 0.5,
            wo: 0.45,
            wf1: 0.55,
            wf2: 0.5,
        },
        layers: vec![
            LayerActRho {
                input: 0.30,
                q: 0.42,
                k: 0.40,
                v: 0.38,
                scores: 0.62,
                context: 0.35,
                proj_out: 0.33,
                ffn_in: 0.28,
                gelu: 0.58,
                ffn_out: 0.31,
            },
            LayerActRho {
                input: 0.34,
                q: 0.46,
                k: 0.44,
                v: 0.41,
                scores: 0.68,
                context: 0.39,
                proj_out: 0.36,
                ffn_in: 0.32,
                gelu: 0.63,
                ffn_out: 0.35,
            },
        ],
    }
}

fn run_golden() -> SimResult {
    simulate_with(
        &golden_cfg(),
        &golden_model(),
        64,
        Policy::Staggered,
        &SparsitySource::Trace(golden_trace()),
    )
}

fn result_to_json(r: &SimResult) -> Json {
    Json::obj(vec![
        ("total_cycles", Json::num(r.total_cycles as f64)),
        ("compute_resource", Json::num(r.stalls.compute_resource as f64)),
        ("compute_operand", Json::num(r.stalls.compute_operand as f64)),
        ("memory_buffer_full", Json::num(r.stalls.memory_buffer_full as f64)),
        (
            "memory_pending_compute",
            Json::num(r.stalls.memory_pending_compute as f64),
        ),
        ("mac_pj", Json::num(r.energy.mac_pj)),
        ("softmax_pj", Json::num(r.energy.softmax_pj)),
        ("layernorm_pj", Json::num(r.energy.layernorm_pj)),
        ("dynatran_pj", Json::num(r.energy.dynatran_pj)),
        ("sparsity_pj", Json::num(r.energy.sparsity_pj)),
        ("buffer_pj", Json::num(r.energy.buffer_pj)),
        ("memory_pj", Json::num(r.energy.memory_pj)),
        ("leakage_pj", Json::num(r.energy.leakage_pj)),
        ("mac_utilization", Json::num(r.mac_utilization)),
        ("softmax_utilization", Json::num(r.softmax_utilization)),
        ("dma_utilization", Json::num(r.dma_utilization)),
    ])
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/goldens/sim_golden.json")
}

#[test]
fn sim_result_matches_pinned_golden() {
    let r = run_golden();
    // the run must be non-trivial for the pin to mean anything
    assert!(r.total_cycles > 1000, "degenerate run: {} cycles", r.total_cycles);
    assert!(r.energy.total_pj() > 0.0);

    // unconditional: re-running reproduces the exact result (the pin's
    // own precondition, checked even before a golden is committed)
    let r2 = run_golden();
    assert_eq!(r.total_cycles, r2.total_cycles);
    assert_eq!(r.stalls, r2.stalls);
    assert_eq!(r.energy.total_pj().to_bits(), r2.energy.total_pj().to_bits());

    let current = result_to_json(&r);
    let path = golden_path();
    let Ok(text) = std::fs::read_to_string(&path) else {
        // first run in a fresh tree: seed the golden and arm the pin by
        // committing the file
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, current.to_string_pretty()).unwrap();
        eprintln!(
            "sim_golden: seeded {} — commit it to pin the perf model",
            path.display()
        );
        return;
    };
    let golden = Json::parse(&text).expect("golden file parses");
    for key in [
        "total_cycles",
        "compute_resource",
        "compute_operand",
        "memory_buffer_full",
        "memory_pending_compute",
    ] {
        let want = golden.get(key).and_then(Json::as_f64).expect(key) as u64;
        let got = current.get(key).and_then(Json::as_f64).unwrap() as u64;
        assert_eq!(
            got, want,
            "perf-model drift on '{key}' (delete {} to rebaseline \
             after an intentional change)",
            path.display()
        );
    }
    for key in [
        "mac_pj",
        "softmax_pj",
        "layernorm_pj",
        "dynatran_pj",
        "sparsity_pj",
        "buffer_pj",
        "memory_pj",
        "leakage_pj",
        "mac_utilization",
        "softmax_utilization",
        "dma_utilization",
    ] {
        let want = golden.get(key).and_then(Json::as_f64).expect(key);
        let got = current.get(key).and_then(Json::as_f64).unwrap();
        let tol = 1e-9 * want.abs().max(1e-12);
        assert!(
            (got - want).abs() <= tol,
            "perf-model drift on '{key}': {got} vs pinned {want} \
             (delete {} to rebaseline)",
            path.display()
        );
    }
}
