//! Property tests for the DSE Pareto-frontier reduction (`sim::dse`).
//!
//! The frontier is the load-bearing output of the design-space sweep —
//! a wrong dominance filter silently recommends the wrong hardware — so
//! its laws are pinned on random objective sets (with deliberate ties
//! and duplicates, the edge cases of *weak* dominance):
//!
//! 1. the frontier is a sorted, de-duplicated subset of the sweep;
//! 2. no frontier point dominates another frontier point;
//! 3. every dominated point is dominated by some *frontier* point
//!    (maximal-element chasing — needs transitivity + acyclicity);
//! 4. the frontier (as a set of objective vectors) is invariant to
//!    input ordering, and so is the knee point;
//! 5. dominance is irreflexive, antisymmetric, and transitive on
//!    random triples — the strict-partial-order laws that make the
//!    chain argument in (3) terminate.
//!
//! `ACCELTRAN_PROPTEST_CASES` scales the case counts (CI runs 256).

use acceltran::model::TransformerConfig;
use acceltran::sim::dse::{
    dominates, frontier_gap, sweep, DsePoint, DseSpace, Objectives,
    ParetoFrontier, SweepOptions,
};
use acceltran::sim::engine::{SparsityProfile, SparsitySource};
use acceltran::sim::scheduler::Policy;
use acceltran::sim::AcceleratorConfig;
use acceltran::util::prop::{self, Gen};

/// Random non-negative objectives; quantized about half the time so
/// equal coordinates (the weak-dominance edge) actually occur.
fn rand_obj(g: &mut Gen) -> Objectives {
    let v = |g: &mut Gen| {
        if g.bool() {
            g.usize_in(0, 4) as f64
        } else {
            g.f32_in(0.0, 10.0) as f64
        }
    };
    Objectives { throughput: v(g), energy: v(g), area: v(g) }
}

fn rand_objs(g: &mut Gen, n: usize) -> Vec<Objectives> {
    (0..n).map(|_| rand_obj(g)).collect()
}

fn obj_bits(o: &Objectives) -> (u64, u64, u64) {
    (o.throughput.to_bits(), o.energy.to_bits(), o.area.to_bits())
}

#[test]
fn frontier_is_sorted_subset_of_sweep() {
    prop::check(0xd5e_0001, prop::cases(64), |g| {
        let objs = rand_objs(g, g.usize_in(0, 40));
        let f = ParetoFrontier::compute(&objs);
        assert!(f.indices.windows(2).all(|w| w[0] < w[1]), "sorted + unique");
        assert!(f.indices.iter().all(|&i| i < objs.len()), "in range");
        match f.knee {
            Some(k) => assert!(f.contains(k), "knee sits on the frontier"),
            None => assert!(f.indices.is_empty(), "knee only absent when empty"),
        }
        if !objs.is_empty() {
            assert!(!f.indices.is_empty(), "non-empty sweep keeps a maximal point");
        }
    });
}

#[test]
fn no_frontier_point_dominates_another() {
    prop::check(0xd5e_0002, prop::cases(64), |g| {
        let objs = rand_objs(g, g.usize_in(0, 40));
        let f = ParetoFrontier::compute(&objs);
        for &i in &f.indices {
            for &j in &f.indices {
                assert!(
                    !dominates(&objs[i], &objs[j]),
                    "frontier point {i} dominates frontier point {j}"
                );
            }
        }
    });
}

#[test]
fn every_dominated_point_is_dominated_by_the_frontier() {
    prop::check(0xd5e_0003, prop::cases(64), |g| {
        let objs = rand_objs(g, g.usize_in(0, 40));
        let f = ParetoFrontier::compute(&objs);
        for (i, o) in objs.iter().enumerate() {
            if f.contains(i) {
                assert_eq!(frontier_gap(&objs, i), 0.0, "frontier point {i} has no gap");
                continue;
            }
            assert!(
                f.indices.iter().any(|&j| dominates(&objs[j], o)),
                "off-frontier point {i} must be dominated by a frontier point"
            );
            assert!(
                frontier_gap(&objs, i) > 0.0,
                "dominated point {i} must have a positive frontier gap"
            );
        }
    });
}

#[test]
fn frontier_is_invariant_to_input_ordering() {
    prop::check(0xd5e_0004, prop::cases(64), |g| {
        let objs = rand_objs(g, g.usize_in(1, 30));
        // Fisher-Yates permutation of the point list.
        let mut perm: Vec<usize> = (0..objs.len()).collect();
        for i in (1..perm.len()).rev() {
            perm.swap(i, g.usize_in(0, i));
        }
        let shuffled: Vec<Objectives> = perm.iter().map(|&i| objs[i]).collect();

        let f = ParetoFrontier::compute(&objs);
        let fs = ParetoFrontier::compute(&shuffled);

        // Compare as multisets of objective vectors — indices shift
        // with the permutation, the selected *points* must not.
        let mut a: Vec<_> = f.indices.iter().map(|&i| obj_bits(&objs[i])).collect();
        let mut b: Vec<_> = fs.indices.iter().map(|&i| obj_bits(&shuffled[i])).collect();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "frontier set changed under permutation");

        // The knee may tie-break to a different duplicate of the same
        // vector, but the vector itself is ordering-independent.
        let knee_a = f.knee.map(|i| obj_bits(&objs[i]));
        let knee_b = fs.knee.map(|i| obj_bits(&shuffled[i]));
        assert_eq!(knee_a, knee_b, "knee objective vector changed under permutation");
    });
}

#[test]
fn dominance_is_a_strict_partial_order() {
    prop::check(0xd5e_0005, prop::cases(256), |g| {
        let a = rand_obj(g);
        let b = rand_obj(g);
        let c = rand_obj(g);
        // Irreflexive.
        assert!(!dominates(&a, &a), "irreflexivity");
        // Antisymmetric (asymmetric, for strict orders).
        assert!(
            !(dominates(&a, &b) && dominates(&b, &a)),
            "antisymmetry: {a:?} <> {b:?}"
        );
        // Transitive.
        if dominates(&a, &b) && dominates(&b, &c) {
            assert!(dominates(&a, &c), "transitivity: {a:?} > {b:?} > {c:?}");
        }
    });
}

/// The laws above on synthetic objectives, once on real engine output:
/// a small Edge-family sweep's report must satisfy the same frontier
/// invariants end-to-end (this is the shape `reports/dse_frontier.json`
/// is asserted against in CI).
#[test]
fn real_sweep_report_satisfies_frontier_invariants() {
    let mut space = DseSpace::around(AcceleratorConfig::edge());
    space.pes = vec![8, 16, 32];
    space.buffers_mb = vec![3, 13];
    let report = sweep(
        &space,
        &TransformerConfig::bert_tiny(),
        64,
        Policy::Staggered,
        &SparsitySource::Uniform(SparsityProfile::paper_default()),
        &SweepOptions { threads: 0, progress: false },
    );
    assert_eq!(report.points.len(), 6);
    let objs: Vec<Objectives> = report.points.iter().map(DsePoint::objectives).collect();
    let f = &report.frontier;
    assert!(!f.indices.is_empty());
    for &i in &f.indices {
        for &j in &f.indices {
            assert!(!dominates(&objs[i], &objs[j]));
        }
    }
    for i in 0..objs.len() {
        if !f.contains(i) {
            assert!(f.indices.iter().any(|&j| dominates(&objs[j], &objs[i])));
        }
    }
    // Recomputing from the report's own objectives reproduces the
    // frontier the sweep reduced to.
    assert_eq!(ParetoFrontier::compute(&objs), *f);
}
