//! Integration: the Rust PJRT runtime executing the AOT HLO artifacts
//! must reproduce the Python-eager goldens bit-for-bit (prune kernel) /
//! to f32 tolerance (model forward) — closing the loop
//! python-eager == HLO-text == rust-PJRT.
//!
//! Tier-1 gate: these tests need (a) the AOT artifacts + goldens from
//! `python/compile/aot.py` / `goldens.py` under `rust/artifacts/`, and
//! (b) a real PJRT backend (the default in-tree `xla` crate is a stub
//! that cannot execute HLO — DESIGN.md §Substitutions).  Set
//! `ACCELTRAN_PJRT_TESTS=1` *and* generate the artifacts to run them;
//! otherwise every test here skips with a message, keeping
//! `cargo test` hermetic.  (The reference backend needs no goldens: its
//! correctness tests — including a finite-difference gradient check —
//! live in `runtime::backend::reference` and always run.)

use std::path::PathBuf;

use acceltran::runtime::params::{read_f32, read_i32};
use acceltran::runtime::{ParamStore, PjrtBackend, Runtime};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn goldens_dir() -> PathBuf {
    artifacts_dir().join("goldens")
}

fn have_artifacts() -> bool {
    std::env::var_os("ACCELTRAN_PJRT_TESTS").is_some()
        && artifacts_dir().join("manifest.json").exists()
        && goldens_dir().join("goldens.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!(
                "skipping: needs ACCELTRAN_PJRT_TESTS=1, a real PJRT \
                 backend, and artifacts from python/compile/aot.py"
            );
            return;
        }
    };
}

fn golden_f32(name: &str) -> Vec<f32> {
    read_f32(&goldens_dir().join(format!("{name}.bin"))).unwrap()
}

fn golden_i32(name: &str) -> Vec<i32> {
    read_i32(&goldens_dir().join(format!("{name}.bin"))).unwrap()
}

fn assert_close(got: &[f32], want: &[f32], atol: f32, rtol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = atol + rtol * w.abs();
        assert!(
            (g - w).abs() <= tol,
            "{what}[{i}]: got {g}, want {w} (tol {tol})"
        );
    }
}

#[test]
fn prune_kernel_matches_golden_bit_exact() {
    require_artifacts!();
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let x = golden_f32("prune_x");
    let (pruned, mask) = rt.dynatran_prune(&x, 0.5).unwrap();
    assert_eq!(pruned, golden_f32("prune_out_tau0p5"), "pruned values");
    assert_eq!(mask, golden_f32("prune_mask_tau0p5"), "mask");
}

#[test]
fn classify_matches_golden_at_tau_zero_and_nonzero() {
    require_artifacts!();
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let params = golden_f32("params");
    let ids = golden_i32("ids_b8");
    for (tau, golden) in [(0.0f32, "logits_b8_tau0"), (0.05, "logits_b8_tau0p05")] {
        let logits = rt.classify(8, &params, &ids, tau).unwrap();
        assert_close(&logits, &golden_f32(golden), 1e-4, 1e-3,
                     &format!("logits tau={tau}"));
    }
}

#[test]
fn activation_sparsity_matches_golden() {
    require_artifacts!();
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let params = golden_f32("params");
    let ids = golden_i32("ids_b8");
    let rho = rt.activation_sparsity(&params, &ids, 0.05).unwrap();
    let want = golden_f32("act_sparsity_tau0p05")[0];
    assert!((rho - want).abs() < 1e-4, "rho {rho} want {want}");
}

#[test]
fn pallas_variant_agrees_with_fused_variant() {
    // classify_pallas_b2 (L1 Pallas kernels lowered into the graph) must
    // agree with classify_b1 x2 (pure-jnp path) on the same inputs —
    // the L1-vs-L2 consistency check, executed entirely from Rust.  Raw
    // artifact execution is PJRT-specific, so this drives PjrtBackend
    // directly rather than the backend-agnostic Runtime.
    require_artifacts!();
    let mut be = PjrtBackend::load(artifacts_dir()).unwrap();
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let params = golden_f32("params");
    let ids = golden_i32("ids_b8");
    let seq = rt.manifest.seq;
    let two = &ids[..2 * seq];
    let ids_lit = xla::Literal::vec1(two)
        .reshape(&[2, seq as i64])
        .unwrap();
    let out = be
        .execute(
            "classify_pallas_b2",
            &[
                xla::Literal::vec1(&params),
                ids_lit,
                xla::Literal::scalar(0.05f32),
            ],
        )
        .unwrap();
    let pallas_logits = out[0].to_vec::<f32>().unwrap();
    let mut fused = Vec::new();
    for b in 0..2 {
        let one = &ids[b * seq..(b + 1) * seq];
        fused.extend(rt.classify(1, &params, one, 0.05).unwrap());
    }
    assert_close(&pallas_logits, &fused, 1e-3, 1e-2, "pallas vs fused");
}

#[test]
fn train_step_reproduces_golden_loss() {
    require_artifacts!();
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let mut params = golden_f32("params");
    let ids8 = golden_i32("ids_b8");
    let labels8 = golden_i32("labels_b8");
    let seq = rt.manifest.seq;
    // goldens.py uses ids8.repeat(4, axis=0): tile pattern 0,0,0,0,1,...
    let mut ids = Vec::new();
    let mut labels = Vec::new();
    for b in 0..8 {
        for _ in 0..4 {
            ids.extend_from_slice(&ids8[b * seq..(b + 1) * seq]);
        }
    }
    for &l in &labels8 {
        for _ in 0..4 {
            labels.push(l);
        }
    }
    let mut m = vec![0.0f32; params.len()];
    let mut v = vec![0.0f32; params.len()];
    let loss = rt
        .train_step(&mut params, &mut m, &mut v, 0.0, &ids, &labels, 1e-3)
        .unwrap();
    let want_loss = golden_f32("train_loss0")[0];
    assert!(
        (loss - want_loss).abs() < 1e-3,
        "loss {loss} want {want_loss}"
    );
    let got_sum: f32 = params.iter().sum();
    let want_sum = golden_f32("train_params1_sum")[0];
    // sum over 536k params: allow loose tolerance for reduction order
    assert!(
        (got_sum - want_sum).abs() < 0.5 + want_sum.abs() * 1e-3,
        "param sum {got_sum} want {want_sum}"
    );
}

#[test]
fn param_store_init_matches_manifest_layout() {
    require_artifacts!();
    let rt = Runtime::load(artifacts_dir()).unwrap();
    let store = ParamStore::init(&rt.manifest, 0);
    assert_eq!(store.params.len(), rt.manifest.param_count);
    let golden = golden_f32("params");
    assert_eq!(store.params.len(), golden.len());
}

#[test]
fn tau_zero_and_large_tau_bracket_behaviour() {
    // Behavioural property through the full rust path: tau=0 keeps the
    // baseline logits; an absurd tau prunes everything and collapses the
    // logits to a constant (bias-only) prediction.
    require_artifacts!();
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let params = golden_f32("params");
    let ids = golden_i32("ids_b8");
    let base = rt.classify(8, &params, &ids, 0.0).unwrap();
    let nuked = rt.classify(8, &params, &ids, 1e9).unwrap();
    assert_ne!(base, nuked);
    // all rows identical when every activation is pruned
    let first = &nuked[..2];
    for row in nuked.chunks(2) {
        assert!((row[0] - first[0]).abs() < 1e-5);
        assert!((row[1] - first[1]).abs() < 1e-5);
    }
}
