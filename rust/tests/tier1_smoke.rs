//! Tier-1 smoke test: drive the simulator hot path end-to-end through
//! the `sim::simulate` convenience entry point and check that the
//! headline derived metrics are present and self-consistent.  This is
//! the one test every future perf PR must keep green before any
//! benchmark numbers mean anything.

use acceltran::model::TransformerConfig;
use acceltran::sim::scheduler::Policy;
use acceltran::sim::{simulate, AcceleratorConfig, SparsityProfile};

#[test]
fn simulate_edge_paper_default_is_self_consistent() {
    let cfg = AcceleratorConfig::edge();
    let model = TransformerConfig::bert_tiny();
    let seq = 128;
    let r = simulate(&cfg, &model, seq, Policy::Staggered, SparsityProfile::paper_default());

    // the run did real work
    assert!(r.total_cycles > 0, "zero cycles");
    assert!(r.energy.total_pj() > 0.0, "zero energy");
    assert_eq!(r.batch, cfg.batch);
    assert_eq!(r.seq, seq);
    assert_eq!(r.config_name, cfg.name);
    assert_eq!(r.model_name, model.name);

    // latency_s is cycles at the configured clock
    let latency = r.latency_s(&cfg);
    assert!(latency > 0.0);
    let expect_latency = r.total_cycles as f64 / cfg.clock_hz;
    assert!(
        (latency - expect_latency).abs() <= 1e-12 * expect_latency.max(1.0),
        "latency {latency} vs cycles/clock {expect_latency}"
    );

    // throughput_seq_s is batch / latency
    let tp = r.throughput_seq_s(&cfg);
    let expect_tp = r.batch as f64 / latency;
    assert!(
        (tp - expect_tp).abs() <= 1e-9 * expect_tp,
        "throughput {tp} vs batch/latency {expect_tp}"
    );

    // energy_mj_per_seq is total energy over the batch, in millijoules
    let mj = r.energy_mj_per_seq();
    let expect_mj = r.energy.total_pj() * 1e-9 / r.batch as f64;
    assert!(
        (mj - expect_mj).abs() <= 1e-9 * expect_mj.max(1e-12),
        "energy {mj} vs ledger-derived {expect_mj}"
    );
    assert!(mj > 0.0);

    // and avg power ties the two together: E / t
    let w = r.avg_power_w(&cfg);
    let expect_w = r.energy.total_pj() * 1e-12 / latency;
    assert!((w - expect_w).abs() <= 1e-9 * expect_w.max(1e-12));
}

#[test]
fn simulate_report_json_carries_derived_metrics() {
    let cfg = AcceleratorConfig::edge();
    let model = TransformerConfig::bert_tiny();
    let r = simulate(&cfg, &model, 64, Policy::Staggered, SparsityProfile::paper_default());
    let j = r.to_json(&cfg);
    for key in [
        "total_cycles",
        "latency_s",
        "throughput_seq_s",
        "energy_mj_per_seq",
        "avg_power_w",
    ] {
        let v = j.get(key).and_then(|v| v.as_f64());
        assert!(v.is_some(), "missing {key}");
        assert!(v.unwrap() > 0.0, "{key} not positive");
    }
}
