//! Tier-1 conformance: variable-length execution is *bit-exact*.
//!
//! The continuous-batching engine pads a length-L request only to its
//! seq bucket, which is sound only if padding cannot perturb the math.
//! The reference backend guarantees it structurally — attention is
//! computed per row over exactly `lens[b]` positions (gather → L×L
//! scores/softmax/context → scatter), and every other op is row-wise —
//! so a length-L row's logits must be IDENTICAL (`assert_eq!` on the
//! f32 bits, not approximately) whether it runs:
//!
//! * solo at `seq = L` (`Runtime::classify` derives the width),
//! * padded to any bucket width `L <= W <= manifest.seq`
//!   (`Runtime::classify_padded`), alone or sharing the batch with rows
//!   of other lengths.
//!
//! If a refactor ever breaks this, batching stops being semantically
//! transparent — a request's answer would depend on queue timing (which
//! bucket/batch it rode in), which is a serving-correctness bug, not a
//! tolerance issue.  Hence exact equality.

use acceltran::model::TransformerConfig;
use acceltran::runtime::{ParamStore, Runtime};

/// Tiny encoder (h=32, 1 layer, 2 heads, seq=16) so debug-mode `cargo
/// test` stays fast; same shape as the coordinator integration suite.
fn tiny_runtime() -> Runtime {
    let model = TransformerConfig {
        name: "varlen-test".into(),
        hidden: 32,
        layers: 1,
        heads: 2,
        ff: 64,
        vocab: 64,
        seq: 16,
    };
    Runtime::reference_for(&model, 2).unwrap()
}

/// Deterministic token row: `len` ids in `[1, vocab)` (0 is reserved as
/// the padding token, so real content avoiding it makes accidental
/// "padding matched content" aliasing impossible).
fn row(len: usize, vocab: usize, seed: usize) -> Vec<i32> {
    (0..len)
        .map(|i| (1 + (seed * 31 + i * 7) % (vocab - 1)) as i32)
        .collect()
}

/// Pad `ids` with token 0 to `width`.
fn pad_to(ids: &[i32], width: usize) -> Vec<i32> {
    let mut out = ids.to_vec();
    out.resize(width, 0);
    out
}

#[test]
fn solo_request_is_bit_identical_at_native_bucket_and_max_width() {
    let mut rt = tiny_runtime();
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let classes = rt.manifest.classes;
    let params = ParamStore::init(&rt.manifest, 0).params;
    for &tau in &[0.0f32, 0.04] {
        for &len in &[1usize, 3, 7, 8, 11, 15, 16] {
            let ids = row(len, vocab, len);
            let solo = rt.classify(1, &params, &ids, tau).unwrap();
            assert_eq!(solo.len(), classes);
            // every legal padded width, including no-padding (W = len)
            // and the full manifest width
            for width in len..=seq {
                let padded = rt
                    .classify_padded(
                        1,
                        width,
                        &[len],
                        &params,
                        &pad_to(&ids, width),
                        tau,
                    )
                    .unwrap();
                assert_eq!(
                    solo, padded,
                    "len {len} at width {width} (tau {tau}) drifted from \
                     its solo run"
                );
            }
        }
    }
}

#[test]
fn mixed_length_batch_rows_match_their_solo_runs() {
    let mut rt = tiny_runtime();
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let classes = rt.manifest.classes;
    let params = ParamStore::init(&rt.manifest, 0).params;
    let tau = 0.04f32;
    let lens = [3usize, 7, 12, 16, 1, 16, 9, 5];
    let rows: Vec<Vec<i32>> =
        lens.iter().enumerate().map(|(i, &l)| row(l, vocab, i)).collect();
    let mut flat = Vec::with_capacity(lens.len() * seq);
    for r in &rows {
        flat.extend_from_slice(&pad_to(r, seq));
    }
    let batched = rt
        .classify_padded(lens.len(), seq, &lens, &params, &flat, tau)
        .unwrap();
    assert_eq!(batched.len(), lens.len() * classes);
    for (b, r) in rows.iter().enumerate() {
        let solo = rt.classify(1, &params, r, tau).unwrap();
        assert_eq!(
            &batched[b * classes..(b + 1) * classes],
            solo.as_slice(),
            "row {b} (len {}) depends on its batch-mates",
            lens[b]
        );
    }
}

#[test]
fn batch_mates_cannot_perturb_a_row() {
    // same row, three different batch compositions — identical logits
    let mut rt = tiny_runtime();
    let vocab = rt.manifest.vocab;
    let params = ParamStore::init(&rt.manifest, 0).params;
    let tau = 0.02f32;
    let probe = row(6, vocab, 99);
    let width = 8; // the bucket a len-6 request lands in
    let extract = |logits: &[f32], b: usize, classes: usize| {
        logits[b * classes..(b + 1) * classes].to_vec()
    };
    let classes = rt.manifest.classes;
    // alone at bucket width
    let alone = rt
        .classify_padded(1, width, &[6], &params, &pad_to(&probe, width), tau)
        .unwrap();
    // with a shorter and a longer batch-mate
    let mates = [row(2, vocab, 7), probe.clone(), row(8, vocab, 13)];
    let lens = [2usize, 6, 8];
    let mut flat = Vec::new();
    for m in &mates {
        flat.extend_from_slice(&pad_to(m, width));
    }
    let mixed = rt
        .classify_padded(3, width, &lens, &params, &flat, tau)
        .unwrap();
    assert_eq!(extract(&mixed, 1, classes), alone);
    // and behind pure-padding tail rows (what assemble_batch emits for
    // an under-filled shape): a padding row is len-1, all token 0
    let lens = [6usize, 1, 1];
    let mut flat = pad_to(&probe, width);
    flat.extend(vec![0i32; width]);
    flat.extend(vec![0i32; width]);
    let tailed = rt
        .classify_padded(3, width, &lens, &params, &flat, tau)
        .unwrap();
    assert_eq!(extract(&tailed, 0, classes), alone);
}

#[test]
fn uniform_full_length_padded_entry_matches_classify_exactly() {
    // the fixed-seq path through classify_padded must be the SAME
    // computation as classify — not merely close — so the serving
    // engine's switch to the padded entry point cannot shift any
    // previously-pinned logits
    let mut rt = tiny_runtime();
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let params = ParamStore::init(&rt.manifest, 0).params;
    for &batch in &[1usize, 3, 8] {
        let mut flat = Vec::with_capacity(batch * seq);
        for b in 0..batch {
            flat.extend_from_slice(&row(seq, vocab, b));
        }
        let lens = vec![seq; batch];
        let via_classify = rt.classify(batch, &params, &flat, 0.04).unwrap();
        let via_padded = rt
            .classify_padded(batch, seq, &lens, &params, &flat, 0.04)
            .unwrap();
        assert_eq!(via_classify, via_padded, "batch {batch}");
    }
}
