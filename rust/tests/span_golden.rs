//! Golden regression pin for the span-extraction path end-to-end: a
//! fixed tiny fine-tune on the synthetic marker task, its held-out
//! token-overlap F1, the measured-sparsity trace captured from the
//! trained model, and the cycle-accurate `SimResult` driven by that
//! trace must all keep reproducing — the Fig. 14(b) pipeline (train →
//! eval → capture → simulate) pinned in one place.
//!
//! Self-seeding like `sim_golden.rs` / `dse_golden.rs`: the pin lives
//! at `rust/tests/goldens/span_golden.json`; on the first run in a
//! fresh tree (file absent) it is seeded from the current model and the
//! test passes with a loud note — commit the file to arm the pin.
//! Delete it and rerun to rebaseline after an intentional change to
//! the span head, the trainer, the capture path, or the perf model.
//!
//! Unlike the pure-sim goldens, the functional half runs through libm
//! (`exp`, `tanh`) — the pinned floats are deterministic per platform
//! (fixed seeds, single-threaded runtime) but a different host's libm
//! may need a rebaseline; CI runs on one platform.

use std::path::PathBuf;

use acceltran::coordinator::{capture_trace_span, evaluate_span, train_span};
use acceltran::model::TransformerConfig;
use acceltran::nlp::span::SpanTask;
use acceltran::runtime::{ParamStore, Runtime};
use acceltran::sim::engine::simulate_with;
use acceltran::sim::scheduler::Policy;
use acceltran::sim::{AcceleratorConfig, SparsitySource};
use acceltran::util::json::Json;

fn goldens_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/goldens")
}

/// SpanTask needs `vocab > 64` for its marker alphabet and `seq >= 16`;
/// everything else is shrunk for tier-1 speed.
fn golden_model() -> TransformerConfig {
    TransformerConfig {
        name: "golden-span-tiny".into(),
        hidden: 32,
        layers: 2,
        heads: 2,
        ff: 64,
        vocab: 128,
        seq: 16,
    }
}

/// Same shrunken-Edge design point as `sim_golden.rs`, so the two pins
/// differ only in where their sparsity trace comes from.
fn golden_cfg() -> AcceleratorConfig {
    let mut cfg = AcceleratorConfig::edge();
    cfg.pes = 16;
    cfg.act_buffer_bytes = 1 << 20;
    cfg.weight_buffer_bytes = 2 << 20;
    cfg.mask_buffer_bytes = 1 << 18;
    cfg
}

const TAU: f32 = 0.1;

fn assert_close(key: &str, got: f64, want: f64, tol: f64, path: &PathBuf) {
    assert!(
        (got - want).abs() <= tol,
        "span-golden drift on '{key}': {got} vs pinned {want} (delete {} \
         to rebaseline after an intentional change)",
        path.display()
    );
}

#[test]
fn trained_span_f1_and_trace_driven_sim_match_pinned_golden() {
    let model = golden_model();
    // single-threaded runtime: one fixed reduction order per host
    let mut rt = Runtime::reference_for(&model, 1).unwrap();
    let task = SpanTask::new(model.vocab, model.seq);
    let train_ds = task.dataset(192, 1);
    let val_ds = task.dataset(96, 2);
    let mut store = ParamStore::init(&rt.manifest, 0);
    train_span(&mut rt, &mut store, &train_ds, None, 100, 3e-3, 0, false)
        .unwrap();

    let dense = evaluate_span(&mut rt, &store.params, &val_ds, 0.0, 64).unwrap();
    let pruned =
        evaluate_span(&mut rt, &store.params, &val_ds, TAU, 64).unwrap();
    let trace =
        capture_trace_span(&mut rt, &store.params, &val_ds, TAU, 64).unwrap();
    let sim = simulate_with(
        &golden_cfg(),
        &model,
        model.seq,
        Policy::Staggered,
        &SparsitySource::Trace(trace.clone()),
    );

    // Non-trivial preconditions, checked even before a golden exists:
    // the fine-tune must have learned something, the capture must carry
    // real sparsity, and the sim must have consumed it.
    assert!(dense.f1 > 0.3, "span fine-tune learned nothing: {}", dense.f1);
    assert!(dense.f1 <= 1.0 && pruned.f1 <= 1.0);
    assert_eq!(trace.examples, 64);
    assert_eq!(trace.layers.len(), model.layers);
    assert!((trace.eval_accuracy - pruned.f1).abs() < 1e-9,
        "capture F1 {} disagrees with evaluate_span {}",
        trace.eval_accuracy, pruned.f1);
    assert!(sim.total_cycles > 1000);

    // mean activation density over every (layer, hook) cell — one
    // scalar summarizing the surface the sim consumed
    let act_rho_mean: f64 = trace
        .layers
        .iter()
        .map(|l| {
            (l.input + l.q + l.k + l.v + l.scores + l.context + l.proj_out
                + l.ffn_in + l.gelu + l.ffn_out)
                / 10.0
        })
        .sum::<f64>()
        / trace.layers.len() as f64;
    assert!((0.0..1.0).contains(&act_rho_mean), "rho {act_rho_mean}");

    let current = Json::obj(vec![
        ("f1_dense", Json::num(dense.f1)),
        ("f1_pruned", Json::num(pruned.f1)),
        ("act_rho_mean", Json::num(act_rho_mean)),
        ("act_sparsity_pruned", Json::num(pruned.activation_sparsity)),
        ("total_cycles", Json::num(sim.total_cycles as f64)),
        ("mac_pj", Json::num(sim.energy.mac_pj)),
        ("memory_pj", Json::num(sim.energy.memory_pj)),
    ]);
    let path = goldens_dir().join("span_golden.json");
    let Ok(text) = std::fs::read_to_string(&path) else {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, current.to_string_pretty()).unwrap();
        eprintln!(
            "span_golden: seeded {} — commit it to pin the span pipeline",
            path.display()
        );
        return;
    };
    let golden = Json::parse(&text).expect("golden file parses");
    let want = |key: &str| -> f64 {
        golden
            .get(key)
            .and_then(|v| v.as_f64())
            .unwrap_or_else(|| panic!("golden missing '{key}'"))
    };

    // F1 and sparsity to a tight absolute tolerance: a real regression
    // moves F1 by at least one flipped example (~1/96), far above it
    assert_close("f1_dense", dense.f1, want("f1_dense"), 1e-6, &path);
    assert_close("f1_pruned", pruned.f1, want("f1_pruned"), 1e-6, &path);
    assert_close("act_rho_mean", act_rho_mean, want("act_rho_mean"), 1e-6, &path);
    assert_close(
        "act_sparsity_pruned",
        pruned.activation_sparsity,
        want("act_sparsity_pruned"),
        1e-6,
        &path,
    );
    // the trace-driven sim: cycles exact, energy to relative tolerance
    assert_eq!(
        sim.total_cycles as f64,
        want("total_cycles"),
        "trace-driven cycle count moved (delete {} to rebaseline)",
        path.display()
    );
    for (key, got) in [("mac_pj", sim.energy.mac_pj), ("memory_pj", sim.energy.memory_pj)] {
        let w = want(key);
        assert_close(key, got, w, 1e-9 * w.abs().max(1e-12), &path);
    }
}
