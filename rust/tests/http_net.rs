//! End-to-end tests of the `serve::net` HTTP front-end, fully hermetic:
//! every scenario binds a loopback port (`127.0.0.1:0`), runs the real
//! accept loop / router / pool stack over the tiny reference runtime,
//! and drives it with the in-crate [`HttpClient`] — no fixtures, no
//! network beyond loopback.
//!
//! The load-bearing properties:
//!   * logits served over HTTP are bit-identical to a direct
//!     `Runtime::classify` call (the wire adds transport, not math);
//!   * hostile bodies (fuzzed) always get valid JSON 4xx answers and
//!     never kill the server;
//!   * drain loses nothing: every 200 handed to a client corresponds to
//!     exactly one pool-served request;
//!   * multi-model servers route `/v1/classify` and `/v1/span` to their
//!     own registered models — per-model shape validation, explicit
//!     `"model"` routing, coherent per-model `/stats` sections, and a
//!     drain that loses neither task's accepted requests.

use acceltran::coordinator::{ModelEntry, TaskKind};
use acceltran::model::TransformerConfig;
use acceltran::runtime::{ParamStore, Runtime};
use acceltran::serve::net::{HttpClient, NetConfig, NetServer};
use acceltran::util::json::Json;
use acceltran::util::prop;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Tiny encoder (h=32, 1 layer, seq=16, vocab=64) so debug-mode tests
/// stay fast.
fn tiny_runtime() -> Runtime {
    let model = TransformerConfig {
        name: "tiny-net-test".into(),
        hidden: 32,
        layers: 1,
        heads: 2,
        ff: 64,
        vocab: 64,
        seq: 16,
    };
    Runtime::reference_for(&model, 2).unwrap()
}

fn start_server(cfg_mut: impl FnOnce(&mut NetConfig)) -> (NetServer, Vec<f32>, Runtime) {
    let rt = tiny_runtime();
    let params = ParamStore::init(&rt.manifest, 0).params;
    let mut cfg = NetConfig::default();
    cfg.serve.workers = 2;
    cfg.serve.slo = std::time::Duration::from_millis(5);
    cfg_mut(&mut cfg);
    let server = NetServer::start(&rt, &params, &cfg).unwrap();
    (server, params, rt)
}

/// Two-model registry behind one listener: the tiny classify encoder
/// plus a deliberately *smaller* span encoder (seq=12, vocab=48), so
/// per-model shape validation is observable on the wire — a row the
/// classify model accepts can be a 400 on `/v1/span`.
fn start_multi_server(
    cfg_mut: impl FnOnce(&mut NetConfig),
) -> (NetServer, Runtime, Runtime) {
    let clf_rt = tiny_runtime();
    let clf_params = ParamStore::init(&clf_rt.manifest, 0).params;
    let span_model = TransformerConfig {
        name: "tiny-net-span".into(),
        hidden: 32,
        layers: 1,
        heads: 2,
        ff: 64,
        vocab: 48,
        seq: 12,
    };
    let span_rt = Runtime::reference_for(&span_model, 2).unwrap();
    let span_params = ParamStore::init(&span_rt.manifest, 1).params;
    let mut cfg = NetConfig::default();
    cfg.serve.workers = 2;
    cfg.serve.slo = std::time::Duration::from_millis(5);
    cfg_mut(&mut cfg);
    let entries = vec![
        ModelEntry {
            name: "clf".into(),
            task: TaskKind::Classify,
            runtime: clf_rt.fork().unwrap(),
            params: clf_params,
            sim: None,
        },
        ModelEntry {
            name: "span".into(),
            task: TaskKind::Span,
            runtime: span_rt.fork().unwrap(),
            params: span_params,
            sim: None,
        },
    ];
    let server = NetServer::start_multi(entries, &cfg).unwrap();
    (server, clf_rt, span_rt)
}

fn ids_body(ids: &[i32], tau: f32) -> Json {
    Json::obj(vec![
        ("ids", Json::arr(ids.iter().map(|&i| Json::num(i as f64)))),
        ("tau", Json::num(tau as f64)),
    ])
}

fn body_with_model(ids: &[i32], tau: f32, model: &str) -> Json {
    Json::obj(vec![
        ("ids", Json::arr(ids.iter().map(|&i| Json::num(i as f64)))),
        ("tau", Json::num(tau as f64)),
        ("model", Json::str(model)),
    ])
}

#[test]
fn http_logits_match_direct_classify() {
    let (server, params, mut rt) = start_server(|_| {});
    let seq = rt.manifest.seq;
    let ids: Vec<i32> = (0..seq as i32).map(|i| i % 64).collect();
    let tau = 0.05f32;

    let mut client = HttpClient::connect(server.addr()).unwrap();
    let (status, resp) =
        client.post_json("/v1/classify", &ids_body(&ids, tau)).unwrap();
    assert_eq!(status, 200, "{resp:?}");
    let got: Vec<f32> = resp
        .get("logits")
        .and_then(|l| l.as_arr())
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect();
    assert_eq!(got.len(), rt.manifest.classes);

    // batch=1 through the wire could still have been padded into a
    // bigger dispatch; the reference backend's per-row math is
    // row-independent, so direct batch-1 logits must agree closely
    let want = rt.classify(1, &params, &ids, tau).unwrap();
    for (g, w) in got.iter().zip(&want) {
        assert!(
            (g - w).abs() < 1e-4,
            "HTTP logits {got:?} diverged from direct {want:?}"
        );
    }

    // batched body: responses come back in request order
    let rows: Vec<Json> = (0..3)
        .map(|r| {
            let ids: Vec<i32> =
                (0..seq as i32).map(|i| (i + r) % 64).collect();
            ids_body(&ids, 0.0)
        })
        .collect();
    let body = Json::obj(vec![("requests", Json::arr(rows))]);
    let (status, resp) = client.post_json("/v1/classify", &body).unwrap();
    assert_eq!(status, 200, "{resp:?}");
    let responses = resp.get("responses").and_then(|r| r.as_arr()).unwrap();
    assert_eq!(responses.len(), 3);
    for r in responses {
        let logits = r.get("logits").and_then(|l| l.as_arr()).unwrap();
        assert_eq!(logits.len(), rt.manifest.classes);
        assert!(logits.iter().all(|v| v.as_f64().is_some()));
    }

    let report = server.shutdown().unwrap();
    assert_eq!(report.requests_served(), 4, "1 single + 3 batch rows");
    assert_eq!(report.ok, 2);
}

#[test]
fn healthz_and_stats_reflect_live_state() {
    let (server, _params, rt) = start_server(|c| c.pools = 2);
    let mut client = HttpClient::connect(server.addr()).unwrap();

    let (status, health) = client.get("/healthz").unwrap();
    assert_eq!(status, 200);
    assert_eq!(health.get("status").and_then(|v| v.as_str()), Some("ok"));
    assert_eq!(
        health.path(&["model", "seq"]).and_then(|v| v.as_usize()),
        Some(rt.manifest.seq)
    );
    assert_eq!(
        health.path(&["model", "vocab"]).and_then(|v| v.as_usize()),
        Some(rt.manifest.vocab)
    );
    assert_eq!(health.get("pools").and_then(|v| v.as_usize()), Some(2));

    // push a few requests through, then /stats must show them
    let ids: Vec<i32> = vec![1; rt.manifest.seq];
    for _ in 0..5 {
        let (s, _) =
            client.post_json("/v1/classify", &ids_body(&ids, 0.02)).unwrap();
        assert_eq!(s, 200);
    }
    let (status, stats) = client.get("/stats").unwrap();
    assert_eq!(status, 200);
    assert_eq!(
        stats.get("state").and_then(|v| v.as_str()),
        Some("accepting")
    );
    let completed = stats
        .path(&["merged", "completed"])
        .and_then(|v| v.as_f64())
        .unwrap();
    assert_eq!(completed, 5.0);
    let rows = stats
        .path(&["merged", "rows_dispatched"])
        .and_then(|v| v.as_f64())
        .unwrap();
    assert!(rows >= 5.0, "dispatched rows must be visible: {rows}");
    assert_eq!(
        stats.get("pools").and_then(|p| p.as_arr()).map(|p| p.len()),
        Some(2)
    );
    // GEMM section present and well-formed (the reference backend
    // routes through the block-sparse microkernel)
    assert!(stats.path(&["gemm", "macs"]).and_then(|v| v.as_f64()).is_some());
    // latency percentiles exist once traffic has flowed
    assert!(stats
        .path(&["merged", "latency_us", "total", "p50_us"])
        .and_then(|v| v.as_f64())
        .is_some());

    server.shutdown().unwrap();
}

#[test]
fn routing_and_validation_status_codes() {
    let (server, _params, rt) = start_server(|c| {
        c.max_batch = 4;
    });
    let seq = rt.manifest.seq;
    let mut client = HttpClient::connect(server.addr()).unwrap();

    // 404 / 405
    let (status, body) = client.get("/nope").unwrap();
    assert_eq!(status, 404);
    assert_eq!(
        body.path(&["error", "code"]).and_then(|v| v.as_str()),
        Some("not_found")
    );
    let (status, _) = client.get("/v1/classify").unwrap();
    assert_eq!(status, 405);
    let resp = client.request("POST", "/stats", Some(b"{}")).unwrap();
    assert_eq!(resp.status, 405);

    // validation 400s surface the api codes.  The length rule is
    // `1 <= len <= seq` — shorter-than-seq is LEGAL now (continuous
    // batching runs it at its native length), so only empty and
    // over-seq rows are bad_shape.
    let cases: Vec<(Json, &str)> = vec![
        (ids_body(&[], 0.0), "bad_shape"),
        (ids_body(&vec![1; seq + 1], 0.0), "bad_shape"),
        (ids_body(&vec![999; seq], 0.0), "bad_token_id"),
        (ids_body(&vec![1; seq], 7.0), "bad_tau"),
        (Json::obj(vec![("wrong", Json::num(1.0))]), "missing_field"),
    ];
    for (body, want_code) in cases {
        let (status, resp) = client.post_json("/v1/classify", &body).unwrap();
        assert_eq!(status, 400, "{resp:?}");
        assert_eq!(
            resp.path(&["error", "code"]).and_then(|v| v.as_str()),
            Some(want_code)
        );
    }

    // 413 on an over-long batch (max_batch = 4)
    let rows: Vec<Json> =
        (0..5).map(|_| ids_body(&vec![1; seq], 0.0)).collect();
    let body = Json::obj(vec![("requests", Json::arr(rows))]);
    let (status, resp) = client.post_json("/v1/classify", &body).unwrap();
    assert_eq!(status, 413, "{resp:?}");

    // connection survived every 4xx (keep-alive): a good request works
    let (status, _) =
        client.post_json("/v1/classify", &ids_body(&vec![1; seq], 0.0)).unwrap();
    assert_eq!(status, 200);
    // ...and so does one shorter than seq, at its native length
    let (status, resp) = client
        .post_json("/v1/classify", &ids_body(&vec![1; seq - 1], 0.0))
        .unwrap();
    assert_eq!(status, 200, "{resp:?}");
    assert!(resp.get("logits").is_some());

    let report = server.shutdown().unwrap();
    assert_eq!(report.requests_served(), 2, "the two valid requests");
    assert!(report.client_errors >= 7);
}

#[test]
fn queue_full_maps_to_429_with_retry_after() {
    // max_queue = 0 makes every admission fail deterministically —
    // the HTTP layer must answer 429 with code "queue_full", count it
    // in rejected_429, and attach a Retry-After header
    let (server, _params, rt) = start_server(|c| {
        c.serve.max_queue = 0;
    });
    let seq = rt.manifest.seq;
    let mut client = HttpClient::connect(server.addr()).unwrap();
    let body = ids_body(&vec![1; seq], 0.0).to_string_compact();
    let resp = client
        .request("POST", "/v1/classify", Some(body.as_bytes()))
        .unwrap();
    assert_eq!(resp.status, 429, "{resp:?}");
    assert_eq!(
        resp.json()
            .unwrap()
            .path(&["error", "code"])
            .and_then(|v| v.as_str()),
        Some("queue_full")
    );
    assert_eq!(
        resp.headers
            .iter()
            .find(|(n, _)| n == "retry-after")
            .map(|(_, v)| v.as_str()),
        Some("1"),
        "429 must carry Retry-After: {:?}",
        resp.headers
    );
    // batch bodies shed atomically too
    let rows: Vec<Json> =
        (0..2).map(|_| ids_body(&vec![1; seq], 0.0)).collect();
    let batch = Json::obj(vec![("requests", Json::arr(rows))]);
    let (status, resp) = client.post_json("/v1/classify", &batch).unwrap();
    assert_eq!(status, 429, "{resp:?}");
    // the connection survives load shedding (it is not an error close)
    let (status, _) = client.get("/healthz").unwrap();
    assert_eq!(status, 200);
    // the shed counter is visible both live and in the final report
    let (_, stats) = client.get("/stats").unwrap();
    assert_eq!(
        stats.path(&["server", "rejected_429"]).and_then(|v| v.as_f64()),
        Some(2.0)
    );
    let report = server.shutdown().unwrap();
    assert_eq!(report.rejected_429, 2);
    assert_eq!(report.requests_served(), 0);
}

#[test]
fn oversized_body_is_rejected_by_limit() {
    let (server, _params, _rt) = start_server(|c| {
        c.limits.max_body_bytes = 256;
    });
    let mut client = HttpClient::connect(server.addr()).unwrap();
    let big = vec![b'x'; 1024];
    let resp = client.request("POST", "/v1/classify", Some(&big)).unwrap();
    assert_eq!(resp.status, 413);
    // over-limit framing closes the connection; a fresh one still works
    let mut client = HttpClient::connect(server.addr()).unwrap();
    let (status, _) = client.get("/healthz").unwrap();
    assert_eq!(status, 200);
    server.shutdown().unwrap();
}

#[test]
fn expect_continue_oversized_is_refused_before_invite() {
    let (server, _params, _rt) = start_server(|c| {
        c.limits.max_body_bytes = 256;
    });
    // raw socket: the test must see exactly what comes back, including
    // whether a "100 Continue" interim response was (wrongly) sent
    let mut stream = std::net::TcpStream::connect(server.addr()).unwrap();
    stream
        .set_read_timeout(Some(std::time::Duration::from_secs(10)))
        .unwrap();
    use std::io::{Read, Write};
    // declares a body far over the cap and waits for the invite; the
    // server must answer 413 straight away, never 100 Continue
    stream
        .write_all(
            b"POST /v1/classify HTTP/1.1\r\nHost: t\r\n\
              Expect: 100-continue\r\nContent-Length: 99999\r\n\r\n",
        )
        .unwrap();
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).unwrap();
    let text = String::from_utf8_lossy(&raw);
    assert!(
        text.starts_with("HTTP/1.1 413 "),
        "expected immediate 413, got: {text}"
    );
    assert!(!text.contains("100 Continue"), "body was invited: {text}");
    server.shutdown().unwrap();
}

#[test]
fn duplicate_content_length_is_rejected() {
    let (server, _params, _rt) = start_server(|_| {});
    let mut client = HttpClient::connect(server.addr()).unwrap();
    // agreeing duplicates are still a smuggling desync vector → 400
    // (no body bytes follow: the server closes on this error, and
    // unread bytes would make the close race the response with an RST)
    client
        .send_raw(
            b"POST /v1/classify HTTP/1.1\r\nHost: t\r\n\
              Content-Length: 4\r\nContent-Length: 4\r\n\r\n",
        )
        .unwrap();
    let resp = client.read_response().unwrap();
    assert_eq!(resp.status, 400, "{resp:?}");
    assert_eq!(
        resp.json().unwrap().path(&["error", "code"]).and_then(|v| v.as_str()),
        Some("malformed")
    );
    server.shutdown().unwrap();
}

#[test]
fn fuzzed_bodies_always_get_valid_json_4xx() {
    let (server, _params, rt) = start_server(|_| {});
    let seq = rt.manifest.seq;
    let addr = server.addr();
    let n = prop::cases(40);
    prop::check(0xbad_b0d1, n, |g| {
        // build a hostile body: structurally broken, wrong-typed, or
        // shape-violating — every one must yield a JSON 4xx, never a
        // hang, 5xx, or connection-killing panic
        let good_ids: Vec<String> =
            (0..seq).map(|i| (i % 64).to_string()).collect();
        let body: String = match g.usize_in(0, 6) {
            // truncated JSON
            0 => {
                let full = format!(r#"{{"ids": [{}]}}"#, good_ids.join(","));
                let cut = g.usize_in(1, full.len() - 1);
                full[..cut].to_string()
            }
            // wrong-typed fields
            1 => r#"{"ids": "not an array"}"#.to_string(),
            2 => format!(
                r#"{{"ids": [{}], "tau": []}}"#,
                good_ids.join(",")
            ),
            // oversized token array
            3 => {
                let n_ids = g.usize_in(seq + 1, seq * 8);
                let ids: Vec<String> =
                    (0..n_ids).map(|i| (i % 64).to_string()).collect();
                format!(r#"{{"ids": [{}]}}"#, ids.join(","))
            }
            // out-of-vocab / negative ids
            4 => {
                let mut ids = good_ids.clone();
                let slot = g.usize_in(0, seq - 1);
                ids[slot] =
                    if g.bool() { "-7".into() } else { "100000".into() };
                format!(r#"{{"ids": [{}]}}"#, ids.join(","))
            }
            // duplicate keys (json hardening) / raw garbage
            5 => format!(
                r#"{{"ids": [{}], "ids": [{}]}}"#,
                good_ids.join(","),
                good_ids.join(",")
            ),
            _ => {
                let len = g.usize_in(1, 64);
                (0..len)
                    .map(|_| (g.usize_in(32, 126) as u8) as char)
                    .collect()
            }
        };
        let mut client = HttpClient::connect(addr).unwrap();
        let resp = client
            .request("POST", "/v1/classify", Some(body.as_bytes()))
            .unwrap();
        assert!(
            (400..500).contains(&resp.status),
            "hostile body {body:?} got status {}",
            resp.status
        );
        let json = resp.json().unwrap_or_else(|e| {
            panic!("non-JSON error response for {body:?}: {e}")
        });
        assert!(
            json.path(&["error", "code"]).and_then(|v| v.as_str()).is_some(),
            "error body missing code: {json:?}"
        );
    });
    // the server survived the barrage and still serves
    let mut client = HttpClient::connect(addr).unwrap();
    let ids: Vec<i32> = vec![1; seq];
    let (status, _) =
        client.post_json("/v1/classify", &ids_body(&ids, 0.0)).unwrap();
    assert_eq!(status, 200);
    let report = server.shutdown().unwrap();
    assert_eq!(report.requests_served(), 1);
    assert_eq!(report.server_errors, 0, "fuzz must never cause a 5xx");
}

#[test]
fn pipelined_requests_are_answered_in_order() {
    let (server, _params, rt) = start_server(|_| {});
    let seq = rt.manifest.seq;
    let mut client = HttpClient::connect(server.addr()).unwrap();
    // three back-to-back framed requests in one write: healthz, a
    // classify, a 404 — answers must come back in order on the same
    // connection
    let classify = ids_body(&vec![2; seq], 0.0).to_string_compact();
    let wire = format!(
        "GET /healthz HTTP/1.1\r\nContent-Length: 0\r\n\r\n\
         POST /v1/classify HTTP/1.1\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\n\r\n{}\
         GET /missing HTTP/1.1\r\nContent-Length: 0\r\n\r\n",
        classify.len(),
        classify
    );
    client.send_raw(wire.as_bytes()).unwrap();
    let r1 = client.read_response().unwrap();
    assert_eq!(r1.status, 200);
    assert_eq!(
        r1.json().unwrap().get("status").and_then(|v| v.as_str()),
        Some("ok")
    );
    let r2 = client.read_response().unwrap();
    assert_eq!(r2.status, 200);
    assert!(r2.json().unwrap().get("logits").is_some());
    let r3 = client.read_response().unwrap();
    assert_eq!(r3.status, 404);
    server.shutdown().unwrap();
}

#[test]
fn drain_under_load_loses_no_accepted_request() {
    let (server, _params, rt) = start_server(|c| {
        c.pools = 2;
    });
    let seq = rt.manifest.seq;
    let addr = server.addr();
    let stop = Arc::new(AtomicBool::new(false));

    // clients hammer single-row classifies until the server goes away;
    // each counts its 200s (anything else — 503 draining, transport
    // errors once the listener closes — ends the loop).  Each client
    // uses a different native length so the drain also exercises the
    // length-bucketed queues: accepted requests parked in DIFFERENT
    // seq buckets must all still be flushed.
    let mut clients = Vec::new();
    for c in 0..4u64 {
        let stop = Arc::clone(&stop);
        clients.push(std::thread::spawn(move || -> u64 {
            let len = seq - 3 * c as usize; // 16, 13, 10, 7 at seq=16
            let ids: Vec<i32> =
                (0..len as i32).map(|i| (i + c as i32) % 64).collect();
            let body = {
                let arr: Vec<String> =
                    ids.iter().map(|i| i.to_string()).collect();
                format!(r#"{{"ids": [{}]}}"#, arr.join(","))
            };
            let mut oks = 0u64;
            'outer: while !stop.load(Ordering::SeqCst) {
                let Ok(mut client) = HttpClient::connect(addr) else {
                    break;
                };
                loop {
                    match client.request(
                        "POST",
                        "/v1/classify",
                        Some(body.as_bytes()),
                    ) {
                        Ok(resp) if resp.status == 200 => oks += 1,
                        Ok(_) | Err(_) => break, // 503 closes the conn
                    }
                    if stop.load(Ordering::SeqCst) {
                        break 'outer;
                    }
                }
            }
            oks
        }));
    }

    // let load build, then drain mid-flight
    while server.completed() < 32 {
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    server.begin_drain();
    let report = server.shutdown().unwrap();
    stop.store(true, Ordering::SeqCst);
    let client_oks: u64 = clients.into_iter().map(|h| h.join().unwrap()).sum();

    // the lossless-drain invariant: every 200 a client received is a
    // request some pool actually served, and the server's own 200
    // count agrees
    assert!(client_oks >= 32, "load never built up: {client_oks}");
    assert_eq!(report.ok, client_oks, "client and server 200 counts differ");
    assert!(
        report.requests_served() >= client_oks,
        "pools served {} < {} acknowledged 200s — a request was dropped",
        report.requests_served(),
        client_oks
    );
    // no request the pools accepted was abandoned either: submitted
    // equals served across shards
    let submitted: u64 = report.pool_reports.iter().map(|r| r.submitted).sum();
    assert_eq!(
        submitted,
        report.requests_served(),
        "drain left accepted requests unserved"
    );
}

// ---- multi-model serving (classify + span on one listener) ------------

/// Per-model requests served across shards, summed by registry name.
fn served_for(report: &acceltran::serve::net::NetReport, name: &str) -> u64 {
    report
        .pool_reports
        .iter()
        .flat_map(|p| &p.models)
        .filter(|m| m.name == name)
        .map(|m| m.requests)
        .sum()
}

#[test]
fn mixed_classify_and_span_interleave_on_one_listener() {
    let (server, clf_rt, span_rt) = start_multi_server(|_| {});
    let clf_seq = clf_rt.manifest.seq;
    let span_seq = span_rt.manifest.seq;
    let mut client = HttpClient::connect(server.addr()).unwrap();

    // /healthz advertises both registered models with their shapes
    let (status, health) = client.get("/healthz").unwrap();
    assert_eq!(status, 200);
    let models = health.get("models").and_then(|m| m.as_arr()).unwrap();
    assert_eq!(models.len(), 2);
    assert_eq!(models[0].get("name").and_then(|v| v.as_str()), Some("clf"));
    assert_eq!(
        models[0].get("task").and_then(|v| v.as_str()),
        Some("classify")
    );
    assert_eq!(models[1].get("name").and_then(|v| v.as_str()), Some("span"));
    assert_eq!(models[1].get("task").and_then(|v| v.as_str()), Some("span"));
    assert_eq!(
        models[1].get("seq").and_then(|v| v.as_usize()),
        Some(span_seq)
    );

    // interleave single classify / span requests on ONE connection, at
    // varying native lengths, so both tasks share the listener and the
    // keep-alive session
    for round in 0..4usize {
        let ids: Vec<i32> =
            (0..clf_seq as i32).map(|i| (i + round as i32) % 64).collect();
        let (status, resp) =
            client.post_json("/v1/classify", &ids_body(&ids, 0.0)).unwrap();
        assert_eq!(status, 200, "{resp:?}");
        assert_eq!(
            resp.get("logits").and_then(|l| l.as_arr()).map(|l| l.len()),
            Some(clf_rt.manifest.classes)
        );
        assert!(resp.get("start").is_none(), "classify carries no span decode");

        let len = span_seq - round; // 12, 11, 10, 9
        let ids: Vec<i32> =
            (0..len as i32).map(|i| (i + round as i32) % 48).collect();
        let (status, resp) =
            client.post_json("/v1/span", &ids_body(&ids, 0.0)).unwrap();
        assert_eq!(status, 200, "{resp:?}");
        // split-half [start..., end...] logits over the NATIVE length,
        // and the decoded argmaxes must agree with the halves they
        // summarize
        let logits: Vec<f64> = resp
            .get("logits")
            .and_then(|l| l.as_arr())
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        assert_eq!(logits.len(), 2 * len, "round {round}: {resp:?}");
        let argmax = |s: &[f64]| {
            s.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        };
        assert_eq!(
            resp.get("start").and_then(|v| v.as_usize()),
            Some(argmax(&logits[..len]))
        );
        assert_eq!(
            resp.get("end").and_then(|v| v.as_usize()),
            Some(argmax(&logits[len..]))
        );
    }

    // batch bodies route per model too, here with an explicit top-level
    // "model" name next to "requests"
    let rows: Vec<Json> = (0..3i32)
        .map(|r| {
            let ids: Vec<i32> =
                (0..span_seq as i32).map(|i| (i * 5 + r) % 48).collect();
            ids_body(&ids, 0.0)
        })
        .collect();
    let body = Json::obj(vec![
        ("model", Json::str("span")),
        ("requests", Json::arr(rows)),
    ]);
    let (status, resp) = client.post_json("/v1/span", &body).unwrap();
    assert_eq!(status, 200, "{resp:?}");
    let responses = resp.get("responses").and_then(|r| r.as_arr()).unwrap();
    assert_eq!(responses.len(), 3);
    for r in responses {
        assert_eq!(
            r.get("logits").and_then(|l| l.as_arr()).map(|l| l.len()),
            Some(2 * span_seq)
        );
        assert!(r.get("start").and_then(|v| v.as_usize()).is_some());
        assert!(r.get("end").and_then(|v| v.as_usize()).is_some());
    }

    let report = server.shutdown().unwrap();
    assert_eq!(report.requests_served(), 4 + 4 + 3);
    // per-model report sections account for every request, by name
    assert_eq!(served_for(&report, "clf"), 4);
    assert_eq!(served_for(&report, "span"), 7);
}

#[test]
fn span_validation_and_model_routing_status_codes() {
    let (server, _clf_rt, span_rt) = start_multi_server(|_| {});
    let span_seq = span_rt.manifest.seq; // 12 (< classify's 16)
    let mut client = HttpClient::connect(server.addr()).unwrap();

    // per-model shape validation on /v1/span: the span model is the
    // SMALLER one, so over-long rows and token ids that the classify
    // model would accept (seq=16, vocab=64) are typed 4xxs here
    let cases: Vec<(Json, u16, &str)> = vec![
        (ids_body(&[], 0.0), 400, "bad_shape"),
        (ids_body(&vec![1; span_seq + 1], 0.0), 400, "bad_shape"),
        (ids_body(&vec![60; span_seq], 0.0), 400, "bad_token_id"),
        (ids_body(&vec![1; span_seq], 9.0), 400, "bad_tau"),
        (Json::obj(vec![("wrong", Json::num(1.0))]), 400, "missing_field"),
        // model routing errors
        (
            body_with_model(&vec![1; span_seq], 0.0, "nope"),
            404,
            "model_not_found",
        ),
        (
            body_with_model(&vec![1; span_seq], 0.0, "clf"),
            400,
            "task_mismatch",
        ),
        // "model" must be a top-level string...
        (
            Json::obj(vec![
                ("ids", Json::arr((0..span_seq).map(|_| Json::num(1.0)))),
                ("model", Json::num(3.0)),
            ]),
            400,
            "bad_type",
        ),
        // ...and is illegal inside a batch item
        (
            Json::obj(vec![(
                "requests",
                Json::arr(vec![body_with_model(&vec![1; span_seq], 0.0, "span")]),
            )]),
            400,
            "unknown_field",
        ),
    ];
    for (body, want_status, want_code) in cases {
        let (status, resp) = client.post_json("/v1/span", &body).unwrap();
        assert_eq!(status, want_status, "{body:?} -> {resp:?}");
        assert_eq!(
            resp.path(&["error", "code"]).and_then(|v| v.as_str()),
            Some(want_code),
            "{body:?} -> {resp:?}"
        );
    }

    // the mismatch is symmetric: a span model named on /v1/classify
    let (status, resp) = client
        .post_json(
            "/v1/classify",
            &body_with_model(&vec![1; span_seq], 0.0, "span"),
        )
        .unwrap();
    assert_eq!(status, 400, "{resp:?}");
    assert_eq!(
        resp.path(&["error", "code"]).and_then(|v| v.as_str()),
        Some("task_mismatch")
    );

    // 405 matrix covers the span route
    let (status, _) = client.get("/v1/span").unwrap();
    assert_eq!(status, 405);

    // the connection survived every 4xx; both tasks still serve on it
    let (status, _) = client
        .post_json("/v1/span", &ids_body(&vec![1; span_seq], 0.0))
        .unwrap();
    assert_eq!(status, 200);
    let (status, _) = client
        .post_json("/v1/classify", &ids_body(&vec![1; span_seq], 0.0))
        .unwrap();
    assert_eq!(status, 200);
    let report = server.shutdown().unwrap();
    assert_eq!(report.requests_served(), 2);
    assert!(report.client_errors >= 10);
}

#[test]
fn span_route_on_single_model_server_is_404() {
    // a classic single-model server registers one classify model; the
    // span endpoint must answer a typed 404, not a decode error
    let (server, _params, rt) = start_server(|_| {});
    let seq = rt.manifest.seq;
    let mut client = HttpClient::connect(server.addr()).unwrap();
    let (status, resp) =
        client.post_json("/v1/span", &ids_body(&vec![1; seq], 0.0)).unwrap();
    assert_eq!(status, 404, "{resp:?}");
    assert_eq!(
        resp.path(&["error", "code"]).and_then(|v| v.as_str()),
        Some("no_model_for_task")
    );
    // classify on the same connection is untouched
    let (status, _) =
        client.post_json("/v1/classify", &ids_body(&vec![1; seq], 0.0)).unwrap();
    assert_eq!(status, 200);
    server.shutdown().unwrap();
}

#[test]
fn fuzzed_span_bodies_always_get_valid_json_4xx() {
    let (server, _clf_rt, span_rt) = start_multi_server(|_| {});
    let seq = span_rt.manifest.seq;
    let addr = server.addr();
    let n = prop::cases(24);
    prop::check(0xbad_b0d2, n, |g| {
        let good_ids: Vec<String> =
            (0..seq).map(|i| (i % 48).to_string()).collect();
        let body: String = match g.usize_in(0, 5) {
            // truncated JSON
            0 => {
                let full = format!(r#"{{"ids": [{}]}}"#, good_ids.join(","));
                let cut = g.usize_in(1, full.len() - 1);
                full[..cut].to_string()
            }
            // wrong-typed ids
            1 => r#"{"ids": "not an array"}"#.to_string(),
            // non-string model
            2 => format!(
                r#"{{"ids": [{}], "model": 7}}"#,
                good_ids.join(",")
            ),
            // unknown model name
            3 => format!(
                r#"{{"ids": [{}], "model": "missing-model"}}"#,
                good_ids.join(",")
            ),
            // wrong-task model
            4 => format!(
                r#"{{"ids": [{}], "model": "clf"}}"#,
                good_ids.join(",")
            ),
            // oversized for the span model (though maybe not for clf)
            _ => {
                let n_ids = g.usize_in(seq + 1, seq * 8);
                let ids: Vec<String> =
                    (0..n_ids).map(|i| (i % 48).to_string()).collect();
                format!(r#"{{"ids": [{}]}}"#, ids.join(","))
            }
        };
        let mut client = HttpClient::connect(addr).unwrap();
        let resp = client
            .request("POST", "/v1/span", Some(body.as_bytes()))
            .unwrap();
        assert!(
            (400..500).contains(&resp.status),
            "hostile span body {body:?} got status {}",
            resp.status
        );
        let json = resp.json().unwrap_or_else(|e| {
            panic!("non-JSON error response for {body:?}: {e}")
        });
        assert!(
            json.path(&["error", "code"]).and_then(|v| v.as_str()).is_some(),
            "error body missing code: {json:?}"
        );
    });
    // both tasks still serve after the barrage
    let mut client = HttpClient::connect(addr).unwrap();
    let (status, _) =
        client.post_json("/v1/span", &ids_body(&vec![1; seq], 0.0)).unwrap();
    assert_eq!(status, 200);
    let (status, _) =
        client.post_json("/v1/classify", &ids_body(&vec![2; seq], 0.0)).unwrap();
    assert_eq!(status, 200);
    let report = server.shutdown().unwrap();
    assert_eq!(report.requests_served(), 2);
    assert_eq!(report.server_errors, 0, "fuzz must never cause a 5xx");
}

#[test]
fn stats_expose_coherent_per_model_sections() {
    let (server, clf_rt, span_rt) = start_multi_server(|c| c.pools = 2);
    let clf_seq = clf_rt.manifest.seq;
    let span_seq = span_rt.manifest.seq;
    let mut client = HttpClient::connect(server.addr()).unwrap();

    for i in 0..6i32 {
        let ids: Vec<i32> = (0..clf_seq as i32).map(|j| (j + i) % 64).collect();
        let (s, _) =
            client.post_json("/v1/classify", &ids_body(&ids, 0.02)).unwrap();
        assert_eq!(s, 200);
    }
    for i in 0..4usize {
        // mixed native lengths so the span model's padding accounting
        // has something to count
        let ids: Vec<i32> = vec![3; span_seq - i];
        let (s, _) = client.post_json("/v1/span", &ids_body(&ids, 0.0)).unwrap();
        assert_eq!(s, 200);
    }

    let (status, stats) = client.get("/stats").unwrap();
    assert_eq!(status, 200);
    let models = stats.get("models").and_then(|m| m.as_arr()).unwrap();
    assert_eq!(models.len(), 2);
    let by_name = |name: &str| {
        models
            .iter()
            .find(|m| m.get("name").and_then(|v| v.as_str()) == Some(name))
            .unwrap_or_else(|| panic!("no '{name}' section in {stats:?}"))
    };
    let clf = by_name("clf");
    let span = by_name("span");
    assert_eq!(clf.get("task").and_then(|v| v.as_str()), Some("classify"));
    assert_eq!(span.get("task").and_then(|v| v.as_str()), Some("span"));
    assert_eq!(clf.get("served").and_then(|v| v.as_f64()), Some(6.0));
    assert_eq!(span.get("served").and_then(|v| v.as_f64()), Some(4.0));
    // responses were all delivered, so nothing is still pending
    assert_eq!(clf.get("pending").and_then(|v| v.as_f64()), Some(0.0));
    assert_eq!(span.get("pending").and_then(|v| v.as_f64()), Some(0.0));
    // per-model sections must sum to the merged rollup
    assert_eq!(
        stats.path(&["merged", "completed"]).and_then(|v| v.as_f64()),
        Some(10.0)
    );
    for m in [clf, span] {
        let frac = m
            .get("padded_token_fraction")
            .and_then(|v| v.as_f64())
            .unwrap();
        assert!((0.0..=1.0).contains(&frac), "{m:?}");
        assert!(
            m.path(&["latency_us", "total", "p50_us"])
                .and_then(|v| v.as_f64())
                .is_some(),
            "{m:?}"
        );
    }

    server.shutdown().unwrap();
}

#[test]
fn drain_under_mixed_load_loses_neither_tasks_requests() {
    let (server, clf_rt, span_rt) = start_multi_server(|c| c.pools = 2);
    let clf_seq = clf_rt.manifest.seq;
    let span_seq = span_rt.manifest.seq;
    let addr = server.addr();
    let stop = Arc::new(AtomicBool::new(false));

    // two classify and two span clients hammer the listener until it
    // drains; each counts its 200s.  Different native lengths per
    // client exercise each model's own length buckets under drain.
    let mut clients = Vec::new();
    for c in 0..4u64 {
        let stop = Arc::clone(&stop);
        let span_task = c >= 2;
        let (path, len, vocab) = if span_task {
            ("/v1/span", span_seq - 3 * (c as usize - 2), 48) // 12, 9
        } else {
            ("/v1/classify", clf_seq - 3 * c as usize, 64) // 16, 13
        };
        clients.push(std::thread::spawn(move || -> (bool, u64) {
            let ids: Vec<i32> =
                (0..len as i32).map(|i| (i + c as i32) % vocab).collect();
            let body = {
                let arr: Vec<String> =
                    ids.iter().map(|i| i.to_string()).collect();
                format!(r#"{{"ids": [{}]}}"#, arr.join(","))
            };
            let mut oks = 0u64;
            'outer: while !stop.load(Ordering::SeqCst) {
                let Ok(mut client) = HttpClient::connect(addr) else {
                    break;
                };
                loop {
                    match client.request("POST", path, Some(body.as_bytes()))
                    {
                        Ok(resp) if resp.status == 200 => oks += 1,
                        Ok(_) | Err(_) => break, // 503 closes the conn
                    }
                    if stop.load(Ordering::SeqCst) {
                        break 'outer;
                    }
                }
            }
            (span_task, oks)
        }));
    }

    // let load build on BOTH models, then drain mid-flight
    while server.completed() < 48 {
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    server.begin_drain();
    let report = server.shutdown().unwrap();
    stop.store(true, Ordering::SeqCst);
    let mut clf_oks = 0u64;
    let mut span_oks = 0u64;
    for h in clients {
        let (span_task, oks) = h.join().unwrap();
        if span_task {
            span_oks += oks;
        } else {
            clf_oks += oks;
        }
    }

    assert!(
        clf_oks + span_oks >= 48,
        "load never built up: {clf_oks} classify + {span_oks} span"
    );
    assert!(clf_oks > 0, "classify clients never got a 200");
    assert!(span_oks > 0, "span clients never got a 200");
    // every 200 a client received was served — globally AND per model
    assert_eq!(
        report.ok,
        clf_oks + span_oks,
        "client and server 200 counts differ"
    );
    assert!(
        served_for(&report, "clf") >= clf_oks,
        "classify served {} < {} acknowledged 200s",
        served_for(&report, "clf"),
        clf_oks
    );
    assert!(
        served_for(&report, "span") >= span_oks,
        "span served {} < {} acknowledged 200s",
        served_for(&report, "span"),
        span_oks
    );
    // nothing the pools accepted was abandoned
    let submitted: u64 = report.pool_reports.iter().map(|r| r.submitted).sum();
    assert_eq!(
        submitted,
        report.requests_served(),
        "drain left accepted requests unserved"
    );
}
