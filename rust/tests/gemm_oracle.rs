//! Property-test oracle suite pinning the block-sparse tiled GEMM
//! microkernel (`runtime::tensor`, DESIGN.md "Host microkernel").
//!
//! Three layers of defense:
//!
//! 1. **Value correctness** — every variant (`matmul`, `matmul_nt`,
//!    `matmul_tn`), both dispatch paths (scalar and blocked), against a
//!    trivially-correct f64 triple-loop oracle kept in this file, over
//!    randomized shapes that straddle every block boundary (MR=4,
//!    NR=16, KC=128, NC=256), plus 1x1 and degenerate 0-dim edges.
//! 2. **Bitwise agreement** — the blocked kernel must return *exactly*
//!    (`assert_eq!` on f32 bits) what the pre-rewrite scalar kernels
//!    return, including on DynaTran-pruned and structured-sparse
//!    inputs where whole tiles are skipped.
//! 3. **Stats invariants** — `BlockSparsity` counts must be internally
//!    consistent and agree with `pruning::TileMap`, the mask ->
//!    tile-bitmap handoff.
//!
//! Case counts scale with `ACCELTRAN_PROPTEST_CASES` (CI tier1 runs the
//! suite elevated); failures print a per-case replay seed.

use acceltran::pruning::{dynatran_prune_inplace, dynatran_prune_tiled, TileMap};
use acceltran::runtime::tensor::{
    matmul, matmul_ex, matmul_nt, matmul_nt_ex, matmul_nt_scalar, matmul_scalar, matmul_tn,
    matmul_tn_ex, matmul_tn_scalar, BlockSparsity, GEMM_KC, GEMM_MR,
};
use acceltran::util::prop::{self, Gen};

// ---------------------------------------------------------------------------
// The oracle: f64 triple loops, no blocking, no skipping, no threads.
// ---------------------------------------------------------------------------

fn oracle_mm(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut out = vec![0.0f64; m * n];
    for i in 0..m {
        for kk in 0..k {
            let a = x[i * k + kk] as f64;
            for j in 0..n {
                out[i * n + j] += a * w[kk * n + j] as f64;
            }
        }
    }
    out
}

fn oracle_nt(x: &[f32], w: &[f32], m: usize, n: usize, k: usize) -> Vec<f64> {
    let mut out = vec![0.0f64; m * k];
    for i in 0..m {
        for kk in 0..k {
            let mut acc = 0.0f64;
            for j in 0..n {
                acc += x[i * n + j] as f64 * w[kk * n + j] as f64;
            }
            out[i * k + kk] = acc;
        }
    }
    out
}

fn oracle_tn(x: &[f32], y: &[f32], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut out = vec![0.0f64; k * n];
    for i in 0..m {
        for kk in 0..k {
            let a = x[i * k + kk] as f64;
            for j in 0..n {
                out[kk * n + j] += a * y[i * n + j] as f64;
            }
        }
    }
    out
}

/// |got - want| <= 1e-4 * max(|want|, 1): absolute near zero, relative
/// away from it — wide enough for f32 resummation error at depth <= 320,
/// tight enough to catch any indexing or packing bug.
fn assert_close_oracle(got: &[f32], want: &[f64], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        let tol = 1e-4 * w.abs().max(1.0);
        assert!(
            (g as f64 - w).abs() <= tol,
            "{what}[{i}]: got {g}, oracle {w} (tol {tol})"
        );
    }
}

/// Random dimension: mostly small (hits 0/1 and ragged edges), sometimes
/// large enough to cross KC=128 / NC=256 and the tiled-dispatch and
/// parallel thresholds.
fn dim(g: &mut Gen) -> usize {
    if g.bool() {
        g.usize_in(0, 20)
    } else {
        g.usize_in(1, 160)
    }
}

/// Random operand: dense normals, near-DynaTran-sparse, or all-zero.
fn operand(g: &mut Gen, len: usize) -> Vec<f32> {
    match g.usize_in(0, 3) {
        0 => g.normal_vec(len, 1.0),
        1 | 2 => {
            let mut v = g.normal_vec(len, 0.05);
            dynatran_prune_inplace(&mut v, 0.04);
            v
        }
        _ => vec![0.0; len],
    }
}

fn check_stats(s: &BlockSparsity, rows: usize, depth: usize, cols: usize, what: &str) {
    assert_eq!(s.macs, (rows * depth * cols) as u64, "{what}: macs");
    assert_eq!(s.elems, (rows * depth) as u64, "{what}: elems");
    let row_tiles = (rows + GEMM_MR - 1) / GEMM_MR;
    let depth_blocks = (depth + GEMM_KC - 1) / GEMM_KC;
    assert_eq!(s.tiles, (row_tiles * depth_blocks) as u64, "{what}: tiles");
    assert!(s.zero_tiles <= s.tiles, "{what}: zero_tiles <= tiles");
    assert!(s.zero_elems <= s.elems, "{what}: zero_elems <= elems");
    assert!(s.tile_skipped_macs <= s.macs, "{what}: skipped <= macs");
    for f in [
        s.effectual_tile_fraction(),
        s.effectual_mac_fraction(),
        s.tile_skipped_mac_fraction(),
    ] {
        assert!((0.0..=1.0).contains(&f), "{what}: fraction {f} out of range");
    }
    // tile skipping can never elide more than element granularity sees
    assert!(
        s.tile_skipped_mac_fraction() <= 1.0 - s.effectual_mac_fraction() + 1e-12,
        "{what}: tile skipping outran element sparsity"
    );
}

// ---------------------------------------------------------------------------
// Property: all variants, randomized shapes, oracle + bitwise + stats.
// ---------------------------------------------------------------------------

#[test]
fn matmul_matches_oracle_and_scalar_bitwise() {
    prop::check(0xACCE1, prop::cases(64), |g| {
        let (m, k, n) = (dim(g), dim(g), dim(g));
        let x = operand(g, m * k);
        let w = operand(g, k * n);
        let want = oracle_mm(&x, &w, m, k, n);
        let scalar = matmul_scalar(&x, &w, m, k, n);
        let dispatched = matmul(&x, &w, m, k, n);
        let (blocked, stats) = matmul_ex(&x, &w, m, k, n);
        assert_close_oracle(&scalar, &want, "matmul_scalar");
        assert_close_oracle(&blocked, &want, "matmul_ex");
        assert_eq!(blocked, scalar, "blocked vs scalar must be bitwise identical");
        assert_eq!(dispatched, scalar, "dispatch must be bitwise transparent");
        if m > 0 && k > 0 && n > 0 {
            check_stats(&stats, m, k, n, "matmul_ex");
        }
    });
}

#[test]
fn matmul_nt_matches_oracle_and_scalar_bitwise() {
    prop::check(0xACCE2, prop::cases(64), |g| {
        let (m, n, k) = (dim(g), dim(g), dim(g));
        let x = operand(g, m * n);
        let w = operand(g, k * n);
        let want = oracle_nt(&x, &w, m, n, k);
        let scalar = matmul_nt_scalar(&x, &w, m, n, k);
        let dispatched = matmul_nt(&x, &w, m, n, k);
        let (blocked, stats) = matmul_nt_ex(&x, &w, m, n, k);
        assert_close_oracle(&scalar, &want, "matmul_nt_scalar");
        assert_close_oracle(&blocked, &want, "matmul_nt_ex");
        assert_eq!(blocked, scalar, "nt: blocked vs scalar bitwise");
        assert_eq!(dispatched, scalar, "nt: dispatch bitwise");
        if m > 0 && n > 0 && k > 0 {
            // nt reduces over n: broadcast operand is x (m rows, depth n)
            check_stats(&stats, m, n, k, "matmul_nt_ex");
        }
    });
}

#[test]
fn matmul_tn_matches_oracle_and_scalar_bitwise() {
    prop::check(0xACCE3, prop::cases(64), |g| {
        let (m, k, n) = (dim(g), dim(g), dim(g));
        let x = operand(g, m * k);
        let y = operand(g, m * n);
        let want = oracle_tn(&x, &y, m, k, n);
        let scalar = matmul_tn_scalar(&x, &y, m, k, n);
        let dispatched = matmul_tn(&x, &y, m, k, n);
        let (blocked, stats) = matmul_tn_ex(&x, &y, m, k, n);
        assert_close_oracle(&scalar, &want, "matmul_tn_scalar");
        assert_close_oracle(&blocked, &want, "matmul_tn_ex");
        assert_eq!(blocked, scalar, "tn: blocked vs scalar bitwise");
        assert_eq!(dispatched, scalar, "tn: dispatch bitwise");
        if m > 0 && k > 0 && n > 0 {
            // tn's broadcast operand is x-transposed: k rows, depth m
            check_stats(&stats, k, m, n, "matmul_tn_ex");
        }
    });
}

// ---------------------------------------------------------------------------
// Edges the random shapes might miss on a short run.
// ---------------------------------------------------------------------------

#[test]
fn one_by_one_and_degenerate_dims() {
    // 1x1 x 1x1
    let (y, s) = matmul_ex(&[3.0], &[4.0], 1, 1, 1);
    assert_eq!(y, vec![12.0]);
    assert_eq!((s.tiles, s.zero_tiles, s.macs), (1, 0, 1));
    assert_eq!(matmul_nt_ex(&[3.0], &[4.0], 1, 1, 1).0, vec![12.0]);
    assert_eq!(matmul_tn_ex(&[3.0], &[4.0], 1, 1, 1).0, vec![12.0]);

    // every way a dimension can be zero, all variants
    for &(m, k, n) in &[(0, 3, 4), (3, 0, 4), (3, 4, 0), (0, 0, 0)] {
        let x = vec![1.0f32; m * k];
        let w = vec![1.0f32; k * n];
        let (out, stats) = matmul_ex(&x, &w, m, k, n);
        assert_eq!(out, vec![0.0; m * n], "({m},{k},{n})");
        assert_eq!(out, matmul_scalar(&x, &w, m, k, n));
        assert_eq!(out, matmul(&x, &w, m, k, n));
        assert_eq!(stats, BlockSparsity::default(), "empty GEMM records nothing");
        // nt: x is m x n here, w is k x n, out m x k — reuse shapes
        let xnt = vec![1.0f32; m * n];
        let wnt = vec![1.0f32; k * n];
        assert_eq!(matmul_nt_ex(&xnt, &wnt, m, n, k).0, vec![0.0; m * k]);
        assert_eq!(matmul_nt_scalar(&xnt, &wnt, m, n, k), vec![0.0; m * k]);
        let ytn = vec![1.0f32; m * n];
        assert_eq!(matmul_tn_ex(&x, &ytn, m, k, n).0, vec![0.0; k * n]);
        assert_eq!(matmul_tn_scalar(&x, &ytn, m, k, n), vec![0.0; k * n]);
    }
}

/// Structured sparsity: zero row blocks, zero depth blocks, fully zero,
/// fully dense — the block-skip path must return exactly what the dense
/// path returns, with the expected tile accounting.
#[test]
fn structured_sparsity_block_skip_is_exact() {
    let mut g = Gen::replay(0x515);
    let (m, k, n) = (16, 256, 48); // 4 row tiles x 2 depth blocks
    let w = g.normal_vec(k * n, 1.0);

    // (a) MR-aligned zero rows: rows 4..12 zeroed => 2 of 4 row tiles skip
    let mut x = g.normal_vec(m * k, 1.0);
    for v in x[4 * k..12 * k].iter_mut() {
        *v = 0.0;
    }
    let (out, s) = matmul_ex(&x, &w, m, k, n);
    assert_eq!(out, matmul_scalar(&x, &w, m, k, n), "zero rows: bitwise");
    assert_eq!(s.tiles, 8);
    assert_eq!(s.zero_tiles, 4);
    assert_eq!(s.tile_skipped_macs, (8 * k * n) as u64);
    assert!((s.effectual_tile_fraction() - 0.5).abs() < 1e-12);

    // (b) a zero depth block: columns 0..128 of x zeroed in every row
    let mut x = g.normal_vec(m * k, 1.0);
    for r in 0..m {
        for v in x[r * k..r * k + GEMM_KC].iter_mut() {
            *v = 0.0;
        }
    }
    let (out, s) = matmul_ex(&x, &w, m, k, n);
    assert_eq!(out, matmul_scalar(&x, &w, m, k, n), "zero depth block: bitwise");
    assert_eq!(s.zero_tiles, 4, "one depth block zero across 4 row tiles");

    // (c) fully zero activation: everything skips, result is exactly 0
    let x = vec![0.0f32; m * k];
    let (out, s) = matmul_ex(&x, &w, m, k, n);
    assert_eq!(out, vec![0.0; m * n]);
    assert_eq!(out, matmul_scalar(&x, &w, m, k, n), "fully zero: bitwise");
    assert_eq!(s.zero_tiles, s.tiles);
    assert_eq!(s.effectual_tile_fraction(), 0.0);
    assert_eq!(s.tile_skipped_macs, s.macs);

    // (d) fully dense nonzero: nothing skips
    let x: Vec<f32> = (0..m * k).map(|i| 1.0 + (i % 7) as f32).collect();
    let (out, s) = matmul_ex(&x, &w, m, k, n);
    assert_eq!(out, matmul_scalar(&x, &w, m, k, n), "dense: bitwise");
    assert_eq!(s.zero_tiles, 0);
    assert_eq!(s.tile_skipped_macs, 0);
    assert_eq!(s.effectual_tile_fraction(), 1.0);
}

// ---------------------------------------------------------------------------
// DynaTran integration: pruned activations through the tiled kernel.
// ---------------------------------------------------------------------------

/// The end-to-end sparsity contract: prune with the shared DynaTran
/// primitive, multiply with the tiled kernel — bitwise equal to the
/// scalar kernel on the same pruned matrix, and the tile accounting
/// agrees exactly with the `TileMap` handoff.
#[test]
fn dynatran_pruned_tiled_matches_scalar_and_tile_map() {
    prop::check(0xD1A, prop::cases(32), |g| {
        let m = g.usize_in(1, 24);
        let k = g.usize_in(1, 300);
        let n = g.usize_in(1, 40);
        let tau = *g.pick(&[0.02f32, 0.04, 0.08, 1.0]);
        let mut x = g.normal_vec(m * k, 0.05);
        let w = g.normal_vec(k * n, 1.0);
        let (pruned_a, map) = {
            let mut a = x.clone();
            let r = dynatran_prune_tiled(&mut a, tau, m, k);
            (a, r.1)
        };
        dynatran_prune_inplace(&mut x, tau);
        assert_eq!(x, pruned_a, "fused and plain prune agree");

        let (blocked, stats) = matmul_ex(&x, &w, m, k, n);
        assert_eq!(
            blocked,
            matmul_scalar(&x, &w, m, k, n),
            "pruned activation: tiled vs scalar bitwise (tau={tau})"
        );
        assert_eq!(
            stats.zero_tiles as usize,
            map.zero_tiles(),
            "kernel zero-tile count vs TileMap handoff (tau={tau})"
        );
        assert_eq!(stats.tiles as usize, map.tiles());
        assert_eq!(map.row_tiles, (m + GEMM_MR - 1) / GEMM_MR);
        assert_eq!(map.depth_blocks, (k + GEMM_KC - 1) / GEMM_KC);
        let tf = map.effectual_tile_fraction();
        assert!(
            (stats.effectual_tile_fraction() - tf).abs() < 1e-12,
            "effectual-tile fraction: kernel vs TileMap"
        );
        if tau >= 1.0 {
            // tau=1.0 prunes every normal(0.05) draw: whole matrix zero
            assert_eq!(stats.zero_tiles, stats.tiles);
        }
    });
}

/// `TileMap::from_matrix` (rescan) and the fused prune build the same
/// bitmap the kernel observes — three independent code paths, one truth.
#[test]
fn tile_map_rescan_agrees_with_fused_build() {
    prop::check(0x7117, prop::cases(32), |g| {
        let rows = g.usize_in(1, 20);
        let cols = g.usize_in(1, 280);
        let mut v = g.normal_vec(rows * cols, 0.05);
        let (_, fused) = dynatran_prune_tiled(&mut v, 0.04, rows, cols);
        assert_eq!(fused, TileMap::from_matrix(&v, rows, cols));
    });
}
