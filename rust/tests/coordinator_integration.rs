//! Integration: coordinator (batcher + trainer + eval) over the real
//! PJRT runtime and artifacts.
//!
//! Tier-1 gate: needs AOT artifacts (`python/compile/aot.py`) plus a
//! real PJRT backend (the in-tree `xla` crate is a stub — DESIGN.md
//! §Substitutions).  Set `ACCELTRAN_PJRT_TESTS=1` with artifacts in
//! place to run; otherwise these tests skip, keeping `cargo test`
//! hermetic.

use std::path::PathBuf;

use acceltran::coordinator::{self, BatchServer};
use acceltran::nlp::sentiment::SentimentTask;
use acceltran::runtime::{ParamStore, Runtime};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    std::env::var_os("ACCELTRAN_PJRT_TESTS").is_some()
        && artifacts_dir().join("manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!(
                "skipping: needs ACCELTRAN_PJRT_TESTS=1, a real PJRT \
                 backend, and artifacts from python/compile/aot.py"
            );
            return;
        }
    };
}

#[test]
fn batch_server_serves_all_requests() {
    require_artifacts!();
    let rt = Runtime::load(artifacts_dir()).unwrap();
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let classes = rt.manifest.classes;
    let params = ParamStore::init(&rt.manifest, 0).params_literal();
    let mut server = BatchServer::new(rt, params);
    let task = SentimentTask::new(vocab, seq, 3);
    let ds = task.dataset(50, 1);
    let mut ids: Vec<u64> = Vec::new();
    for ex in &ds.examples {
        ids.push(server.submit(ex.ids.clone(), 0.02));
    }
    let responses = server.drain().unwrap();
    assert_eq!(responses.len(), 50);
    let mut got: Vec<u64> = responses.iter().map(|r| r.id).collect();
    got.sort_unstable();
    assert_eq!(got, ids);
    for r in &responses {
        assert_eq!(r.logits.len(), classes);
        assert!(r.logits.iter().all(|v| v.is_finite()));
    }
    assert!(server.stats.dispatches < 50, "batching must group requests");
}

#[test]
fn short_training_run_reduces_loss_through_runtime() {
    require_artifacts!();
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let task = SentimentTask::new(vocab, seq, 7);
    let train_ds = task.dataset(256, 1);
    let mut store = ParamStore::init(&rt.manifest, 0);
    let log = coordinator::train(
        &mut rt, &mut store, &train_ds, None, 30, 3e-3, 0, false,
    )
    .unwrap();
    assert_eq!(log.losses.len(), 30);
    let (head, tail) = log.head_tail_means(5);
    assert!(
        tail < head,
        "loss did not decrease: head {head:.4} tail {tail:.4}"
    );
    assert!(log.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn eval_sweep_produces_monotone_sparsity() {
    require_artifacts!();
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let params = ParamStore::init(&rt.manifest, 0).params_literal();
    let task = SentimentTask::new(vocab, seq, 7);
    let ds = task.dataset(64, 2);
    let curve = coordinator::sweep_dynatran(
        &mut rt,
        &params,
        &ds,
        &[0.0, 0.03, 0.08],
        64,
    )
    .unwrap();
    assert_eq!(curve.points.len(), 3);
    // activation sparsity must be non-decreasing in tau
    for w in curve.points.windows(2) {
        assert!(
            w[1].activation_sparsity >= w[0].activation_sparsity - 1e-6,
            "{:?}",
            curve.points
        );
    }
    // accuracy stays in [0, 1]
    assert!(curve
        .points
        .iter()
        .all(|p| (0.0..=1.0).contains(&p.accuracy)));
}

#[test]
fn topk_sweep_runs() {
    require_artifacts!();
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let params = ParamStore::init(&rt.manifest, 0).params_literal();
    let task = SentimentTask::new(vocab, seq, 7);
    let ds = task.dataset(64, 2);
    let curve =
        coordinator::sweep_topk(&mut rt, &params, &ds, &[1.0, 0.5, 0.25], 64)
            .unwrap();
    assert_eq!(curve.points.len(), 3);
    // smaller keep fraction => more pruned attention => higher sparsity
    assert!(
        curve.points[2].activation_sparsity > curve.points[0].activation_sparsity
    );
}
