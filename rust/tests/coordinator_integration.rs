//! Integration: coordinator (batcher + trainer + eval) over the runtime.
//!
//! Most scenarios run un-gated on the pure-Rust reference backend
//! (`Runtime::reference_for` on a deliberately tiny encoder so debug-mode
//! `cargo test` stays fast).  The PJRT-golden variants at the bottom
//! additionally need AOT artifacts (`python/compile/aot.py`) plus a real
//! PJRT backend (the in-tree `xla` crate is a stub — DESIGN.md
//! §Substitutions): set `ACCELTRAN_PJRT_TESTS=1` with artifacts in place
//! to run them; otherwise they skip, keeping `cargo test` hermetic.

use std::path::PathBuf;
use std::time::Duration;

use acceltran::coordinator::{self, BatchServer, ServeConfig, ServePool};
use acceltran::model::TransformerConfig;
use acceltran::nlp::sentiment::SentimentTask;
use acceltran::runtime::{ParamStore, Runtime};

/// Tiny encoder for debug-mode tests: h=32, 1 layer, 2 heads, seq=16.
fn tiny_runtime() -> Runtime {
    let model = TransformerConfig {
        name: "tiny-test".into(),
        hidden: 32,
        layers: 1,
        heads: 2,
        ff: 64,
        vocab: 64,
        seq: 16,
    };
    Runtime::reference_for(&model, 2).unwrap()
}

// ---- reference-backend scenarios (always run) ------------------------

#[test]
fn batch_server_submit_step_drain_roundtrip() {
    let rt = tiny_runtime();
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let classes = rt.manifest.classes;
    let params = ParamStore::init(&rt.manifest, 0).params;
    let mut server = BatchServer::new(rt, params);
    let task = SentimentTask::new(vocab, seq, 3);
    let ds = task.dataset(50, 1);
    let mut ids: Vec<u64> = Vec::new();
    for ex in &ds.examples {
        ids.push(server.submit(ex.ids.clone(), 0.02).unwrap());
    }
    let responses = server.drain().unwrap();
    assert_eq!(responses.len(), 50);
    let mut got: Vec<u64> = responses.iter().map(|r| r.id).collect();
    got.sort_unstable();
    assert_eq!(got, ids);
    for r in &responses {
        assert_eq!(r.logits.len(), classes);
        assert!(r.logits.iter().all(|v| v.is_finite()));
    }
    assert!(server.stats.dispatches < 50, "batching must group requests");
    assert_eq!(server.stats.queue_depth_high_water, 50);
}

#[test]
fn drain_pads_only_the_sub_batch_tail() {
    // Regression for the tail-padding path: 11 queued requests on a
    // non-multiple-of-8 boundary must dispatch as one full 8-batch plus
    // a 3-in-8 tail — 5 padded rows total, never a 21-row pad-up to 32.
    let rt = tiny_runtime();
    let seq = rt.manifest.seq;
    let params = ParamStore::init(&rt.manifest, 0).params;
    let mut server = BatchServer::new(rt, params);
    for i in 0..11 {
        server.submit(vec![(i % 4) as i32; seq], 0.0).unwrap();
    }
    let responses = server.drain().unwrap();
    assert_eq!(responses.len(), 11);
    let s = &server.stats;
    assert_eq!(s.dispatches, 2, "11 requests = one full 8 + one tail");
    assert_eq!(s.served, 11);
    assert_eq!(s.padded_rows, 5);
    assert_eq!(s.rows_dispatched, 16);
    assert!((s.padded_row_fraction() - 5.0 / 16.0).abs() < 1e-12);
    assert_eq!(s.queue_depth_high_water, 11);
    // the first 8 responses rode the full batch, the tail rode an 8-shape
    assert_eq!(responses[0].batch, 8);
    assert_eq!(responses[10].batch, 8);
}

#[test]
fn batch_server_deadline_flushes_underfilled_batch() {
    // A request older than its SLO budget must force a flush even when
    // no exported shape has filled (3 requests never fill an 8-shape).
    let rt = tiny_runtime();
    let seq = rt.manifest.seq;
    let params = ParamStore::init(&rt.manifest, 0).params;
    let mut server = BatchServer::new(rt, params);
    // generous SLO so the immediate step below rarely races the deadline
    server.max_wait = Duration::from_millis(150);
    for i in 0..3 {
        server.submit(vec![(i % 4) as i32; seq], 0.0).unwrap();
    }
    let early = server.step().unwrap();
    let flushed = if early.is_empty() {
        // normal path: deadlines have not passed yet, the batcher waits;
        // sleep past them and the step must flush under-filled
        assert_eq!(server.pending(), 3);
        std::thread::sleep(Duration::from_millis(180));
        server.step().unwrap()
    } else {
        // pathological scheduler stall (>150 ms between submit and
        // step): the deadline already expired, which still exercises
        // exactly the under-filled deadline flush under test
        early
    };
    assert_eq!(flushed.len(), 3, "expired SLO must force the flush");
    assert_eq!(flushed[0].batch, 8, "3 requests pad up to the covering shape");
    assert_eq!(server.stats.padded_rows, 5);
    assert_eq!(server.pending(), 0);
}

#[test]
fn batch_server_per_request_slo_overrides_default() {
    // submit_with_slo: a generous default but one urgent request — the
    // urgent deadline (at the queue head) drives the flush timing
    let rt = tiny_runtime();
    let seq = rt.manifest.seq;
    let params = ParamStore::init(&rt.manifest, 0).params;
    let mut server = BatchServer::new(rt, params);
    server.max_wait = Duration::from_secs(3600); // default: effectively never
    server
        .submit_with_slo(vec![1i32; seq], 0.0, Duration::from_millis(2))
        .unwrap();
    server.submit(vec![2i32; seq], 0.0).unwrap();
    std::thread::sleep(Duration::from_millis(6));
    let out = server.step().unwrap();
    assert_eq!(out.len(), 2, "urgent head request must flush the queue");
}

#[test]
fn urgent_request_behind_lax_head_still_flushes() {
    // the nearest deadline in the queue drives the flush even when the
    // queue HEAD has an hour of budget left: batching is FIFO, so the
    // flush dispatches the lax head and the urgent request rides along
    let rt = tiny_runtime();
    let seq = rt.manifest.seq;
    let params = ParamStore::init(&rt.manifest, 0).params;
    let mut server = BatchServer::new(rt, params);
    server.max_wait = Duration::from_secs(3600);
    server.submit(vec![2i32; seq], 0.0).unwrap(); // lax, at the head
    server
        .submit_with_slo(vec![1i32; seq], 0.0, Duration::from_millis(2))
        .unwrap();
    std::thread::sleep(Duration::from_millis(6));
    let out = server.step().unwrap();
    assert_eq!(
        out.len(),
        2,
        "a tight SLO behind a lax head must still force the flush"
    );
}

#[test]
fn serve_pool_matches_batch_server_accounting() {
    // the concurrent engine over the same tiny runtime: every request
    // answered once, merged stats self-consistent
    let rt = tiny_runtime();
    let classes = rt.manifest.classes;
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let params = ParamStore::init(&rt.manifest, 0).params;
    let cfg = ServeConfig {
        workers: 2,
        slo: Duration::from_millis(5),
        sim: None,
        ..Default::default()
    };
    let pool = ServePool::start(&rt, &params, &cfg).unwrap();
    let task = SentimentTask::new(vocab, seq, 3);
    let ds = task.dataset(50, 1);
    let mut ids: Vec<u64> = Vec::new();
    for ex in &ds.examples {
        ids.push(pool.submit(ex.ids.clone(), 0.02).unwrap());
    }
    let (report, responses) = pool.finish().unwrap();
    assert_eq!(responses.len(), 50);
    let mut got: Vec<u64> = responses.iter().map(|r| r.id).collect();
    got.sort_unstable();
    assert_eq!(got, ids);
    for r in &responses {
        assert_eq!(r.logits.len(), classes);
        assert!(r.logits.iter().all(|v| v.is_finite()));
    }
    let s = &report.stats;
    assert_eq!(s.served, 50);
    assert_eq!(s.rows_dispatched, s.served + s.padded_rows);
    assert!(s.dispatches < 50, "batching must group requests");
    assert!(s.queue_depth_high_water >= 1 && s.queue_depth_high_water <= 50);
    // host-measured histograms carry one sample per request
    assert_eq!(report.total_latency.count(), 50);
    assert_eq!(report.compute_latency.count(), 50);
    // and the report serializes
    let json = report.to_json();
    assert!(json.get("throughput_rps").is_some());
    assert!(json.path(&["latency_us", "total"]).is_some());
}

#[test]
fn short_training_run_reduces_loss_through_runtime() {
    let mut rt = tiny_runtime();
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let task = SentimentTask::new(vocab, seq, 7);
    let train_ds = task.dataset(128, 1);
    let mut store = ParamStore::init(&rt.manifest, 0);
    let log = coordinator::train(
        &mut rt, &mut store, &train_ds, None, 25, 3e-3, 0, false,
    )
    .unwrap();
    assert_eq!(log.losses.len(), 25);
    let (head, tail) = log.head_tail_means(5);
    assert!(
        tail < head,
        "loss did not decrease: head {head:.4} tail {tail:.4}"
    );
    assert!(log.losses.iter().all(|l| l.is_finite()));
    assert_eq!(store.step, 25.0);
}

#[test]
fn eval_sweep_produces_monotone_sparsity() {
    let mut rt = tiny_runtime();
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let params = ParamStore::init(&rt.manifest, 0).params;
    let task = SentimentTask::new(vocab, seq, 7);
    let ds = task.dataset(32, 2);
    // widely-separated taus: 0 (no pruning), mid, and prune-everything
    let curve = coordinator::sweep_dynatran(
        &mut rt,
        &params,
        &ds,
        &[0.0, 0.05, 10.0],
        32,
    )
    .unwrap();
    assert_eq!(curve.points.len(), 3);
    for w in curve.points.windows(2) {
        assert!(
            w[1].activation_sparsity >= w[0].activation_sparsity - 1e-6,
            "{:?}",
            curve.points
        );
    }
    assert!(curve.points[2].activation_sparsity > 0.9, "{:?}", curve.points);
    assert!(curve
        .points
        .iter()
        .all(|p| (0.0..=1.0).contains(&p.accuracy)));
}

#[test]
fn dynatran_and_topk_sweeps_order_consistently() {
    let mut rt = tiny_runtime();
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let params = ParamStore::init(&rt.manifest, 0).params;
    let task = SentimentTask::new(vocab, seq, 7);
    let ds = task.dataset(32, 2);
    let topk =
        coordinator::sweep_topk(&mut rt, &params, &ds, &[1.0, 0.5, 0.25], 32)
            .unwrap();
    assert_eq!(topk.points.len(), 3);
    // smaller keep fraction => more pruned attention => higher net sparsity
    for w in topk.points.windows(2) {
        assert!(
            w[1].activation_sparsity > w[0].activation_sparsity,
            "{:?}",
            topk.points
        );
    }
    // the identity points of the two methods are the same forward pass
    let dyna = coordinator::sweep_dynatran(&mut rt, &params, &ds, &[0.0], 32).unwrap();
    assert!(
        (dyna.points[0].accuracy - topk.points[0].accuracy).abs() < 1e-9,
        "tau=0 and keep=1 must agree: {} vs {}",
        dyna.points[0].accuracy,
        topk.points[0].accuracy
    );
}

// ---- PJRT goldens (gated) --------------------------------------------

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    std::env::var_os("ACCELTRAN_PJRT_TESTS").is_some()
        && artifacts_dir().join("manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!(
                "skipping: needs ACCELTRAN_PJRT_TESTS=1, a real PJRT \
                 backend, and artifacts from python/compile/aot.py"
            );
            return;
        }
    };
}

#[test]
fn pjrt_batch_server_serves_all_requests() {
    require_artifacts!();
    let rt = Runtime::load(artifacts_dir()).unwrap();
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let params = ParamStore::init(&rt.manifest, 0).params;
    let mut server = BatchServer::new(rt, params);
    let task = SentimentTask::new(vocab, seq, 3);
    let ds = task.dataset(50, 1);
    for ex in &ds.examples {
        server.submit(ex.ids.clone(), 0.02).unwrap();
    }
    let responses = server.drain().unwrap();
    assert_eq!(responses.len(), 50);
    assert!(server.stats.dispatches < 50);
}

#[test]
fn pjrt_training_reduces_loss() {
    require_artifacts!();
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let task = SentimentTask::new(vocab, seq, 7);
    let train_ds = task.dataset(256, 1);
    let mut store = ParamStore::init(&rt.manifest, 0);
    let log = coordinator::train(
        &mut rt, &mut store, &train_ds, None, 30, 3e-3, 0, false,
    )
    .unwrap();
    let (head, tail) = log.head_tail_means(5);
    assert!(tail < head, "head {head:.4} tail {tail:.4}");
}
