//! Integration: coordinator (batcher + trainer + eval) over the runtime.
//!
//! Most scenarios run un-gated on the pure-Rust reference backend
//! (`Runtime::reference_for` on a deliberately tiny encoder so debug-mode
//! `cargo test` stays fast).  The PJRT-golden variants at the bottom
//! additionally need AOT artifacts (`python/compile/aot.py`) plus a real
//! PJRT backend (the in-tree `xla` crate is a stub — DESIGN.md
//! §Substitutions): set `ACCELTRAN_PJRT_TESTS=1` with artifacts in place
//! to run them; otherwise they skip, keeping `cargo test` hermetic.

use std::path::PathBuf;

use acceltran::coordinator::{self, BatchServer};
use acceltran::model::TransformerConfig;
use acceltran::nlp::sentiment::SentimentTask;
use acceltran::runtime::{ParamStore, Runtime};

/// Tiny encoder for debug-mode tests: h=32, 1 layer, 2 heads, seq=16.
fn tiny_runtime() -> Runtime {
    let model = TransformerConfig {
        name: "tiny-test".into(),
        hidden: 32,
        layers: 1,
        heads: 2,
        ff: 64,
        vocab: 64,
        seq: 16,
    };
    Runtime::reference_for(&model, 2).unwrap()
}

// ---- reference-backend scenarios (always run) ------------------------

#[test]
fn batch_server_submit_step_drain_roundtrip() {
    let rt = tiny_runtime();
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let classes = rt.manifest.classes;
    let params = ParamStore::init(&rt.manifest, 0).params;
    let mut server = BatchServer::new(rt, params);
    let task = SentimentTask::new(vocab, seq, 3);
    let ds = task.dataset(50, 1);
    let mut ids: Vec<u64> = Vec::new();
    for ex in &ds.examples {
        ids.push(server.submit(ex.ids.clone(), 0.02));
    }
    let responses = server.drain().unwrap();
    assert_eq!(responses.len(), 50);
    let mut got: Vec<u64> = responses.iter().map(|r| r.id).collect();
    got.sort_unstable();
    assert_eq!(got, ids);
    for r in &responses {
        assert_eq!(r.logits.len(), classes);
        assert!(r.logits.iter().all(|v| v.is_finite()));
    }
    assert!(server.stats.dispatches < 50, "batching must group requests");
    assert_eq!(server.stats.queue_depth_high_water, 50);
}

#[test]
fn drain_pads_only_the_sub_batch_tail() {
    // Regression for the tail-padding path: 11 queued requests on a
    // non-multiple-of-8 boundary must dispatch as one full 8-batch plus
    // a 3-in-8 tail — 5 padded rows total, never a 21-row pad-up to 32.
    let rt = tiny_runtime();
    let seq = rt.manifest.seq;
    let params = ParamStore::init(&rt.manifest, 0).params;
    let mut server = BatchServer::new(rt, params);
    for i in 0..11 {
        server.submit(vec![(i % 4) as i32; seq], 0.0);
    }
    let responses = server.drain().unwrap();
    assert_eq!(responses.len(), 11);
    let s = &server.stats;
    assert_eq!(s.dispatches, 2, "11 requests = one full 8 + one tail");
    assert_eq!(s.served, 11);
    assert_eq!(s.padded_rows, 5);
    assert_eq!(s.rows_dispatched, 16);
    assert!((s.padded_row_fraction() - 5.0 / 16.0).abs() < 1e-12);
    assert_eq!(s.queue_depth_high_water, 11);
    // the first 8 responses rode the full batch, the tail rode an 8-shape
    assert_eq!(responses[0].batch, 8);
    assert_eq!(responses[10].batch, 8);
}

#[test]
fn short_training_run_reduces_loss_through_runtime() {
    let mut rt = tiny_runtime();
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let task = SentimentTask::new(vocab, seq, 7);
    let train_ds = task.dataset(128, 1);
    let mut store = ParamStore::init(&rt.manifest, 0);
    let log = coordinator::train(
        &mut rt, &mut store, &train_ds, None, 25, 3e-3, 0, false,
    )
    .unwrap();
    assert_eq!(log.losses.len(), 25);
    let (head, tail) = log.head_tail_means(5);
    assert!(
        tail < head,
        "loss did not decrease: head {head:.4} tail {tail:.4}"
    );
    assert!(log.losses.iter().all(|l| l.is_finite()));
    assert_eq!(store.step, 25.0);
}

#[test]
fn eval_sweep_produces_monotone_sparsity() {
    let mut rt = tiny_runtime();
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let params = ParamStore::init(&rt.manifest, 0).params;
    let task = SentimentTask::new(vocab, seq, 7);
    let ds = task.dataset(32, 2);
    // widely-separated taus: 0 (no pruning), mid, and prune-everything
    let curve = coordinator::sweep_dynatran(
        &mut rt,
        &params,
        &ds,
        &[0.0, 0.05, 10.0],
        32,
    )
    .unwrap();
    assert_eq!(curve.points.len(), 3);
    for w in curve.points.windows(2) {
        assert!(
            w[1].activation_sparsity >= w[0].activation_sparsity - 1e-6,
            "{:?}",
            curve.points
        );
    }
    assert!(curve.points[2].activation_sparsity > 0.9, "{:?}", curve.points);
    assert!(curve
        .points
        .iter()
        .all(|p| (0.0..=1.0).contains(&p.accuracy)));
}

#[test]
fn dynatran_and_topk_sweeps_order_consistently() {
    let mut rt = tiny_runtime();
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let params = ParamStore::init(&rt.manifest, 0).params;
    let task = SentimentTask::new(vocab, seq, 7);
    let ds = task.dataset(32, 2);
    let topk =
        coordinator::sweep_topk(&mut rt, &params, &ds, &[1.0, 0.5, 0.25], 32)
            .unwrap();
    assert_eq!(topk.points.len(), 3);
    // smaller keep fraction => more pruned attention => higher net sparsity
    for w in topk.points.windows(2) {
        assert!(
            w[1].activation_sparsity > w[0].activation_sparsity,
            "{:?}",
            topk.points
        );
    }
    // the identity points of the two methods are the same forward pass
    let dyna = coordinator::sweep_dynatran(&mut rt, &params, &ds, &[0.0], 32).unwrap();
    assert!(
        (dyna.points[0].accuracy - topk.points[0].accuracy).abs() < 1e-9,
        "tau=0 and keep=1 must agree: {} vs {}",
        dyna.points[0].accuracy,
        topk.points[0].accuracy
    );
}

// ---- PJRT goldens (gated) --------------------------------------------

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    std::env::var_os("ACCELTRAN_PJRT_TESTS").is_some()
        && artifacts_dir().join("manifest.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!(
                "skipping: needs ACCELTRAN_PJRT_TESTS=1, a real PJRT \
                 backend, and artifacts from python/compile/aot.py"
            );
            return;
        }
    };
}

#[test]
fn pjrt_batch_server_serves_all_requests() {
    require_artifacts!();
    let rt = Runtime::load(artifacts_dir()).unwrap();
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let params = ParamStore::init(&rt.manifest, 0).params;
    let mut server = BatchServer::new(rt, params);
    let task = SentimentTask::new(vocab, seq, 3);
    let ds = task.dataset(50, 1);
    for ex in &ds.examples {
        server.submit(ex.ids.clone(), 0.02);
    }
    let responses = server.drain().unwrap();
    assert_eq!(responses.len(), 50);
    assert!(server.stats.dispatches < 50);
}

#[test]
fn pjrt_training_reduces_loss() {
    require_artifacts!();
    let mut rt = Runtime::load(artifacts_dir()).unwrap();
    let vocab = rt.manifest.vocab;
    let seq = rt.manifest.seq;
    let task = SentimentTask::new(vocab, seq, 7);
    let train_ds = task.dataset(256, 1);
    let mut store = ParamStore::init(&rt.manifest, 0);
    let log = coordinator::train(
        &mut rt, &mut store, &train_ds, None, 30, 3e-3, 0, false,
    )
    .unwrap();
    let (head, tail) = log.head_tail_means(5);
    assert!(tail < head, "head {head:.4} tail {tail:.4}");
}
