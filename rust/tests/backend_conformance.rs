//! Cross-backend conformance: the contracts that keep the functional
//! half's backends interchangeable and the measured-sparsity capture
//! path honest.
//!
//! Un-gated portion (runs in tier-1 on the pure-Rust reference
//! executor):
//!
//! * checkpoints round-trip bit-exactly through `ParamStore` + the
//!   `Manifest` layout, across backend instances;
//! * trace capture (`classify_traced`) never perturbs logits — the
//!   capture-on and capture-off forwards are bitwise identical — and
//!   labels every `(layer, hook)` cell;
//! * the span objective's analytic gradients match central finite
//!   differences in every parameter group, and span AdamW training
//!   improves token-overlap F1 on a held-out split (the contracts
//!   behind the Fig. 14(b) fine-tune).
//!
//! The PJRT variant at the bottom additionally needs AOT artifacts and
//! a real PJRT backend (the in-tree `xla` crate is a stub — DESIGN.md
//! §Substitutions): set `ACCELTRAN_PJRT_TESTS=1` with artifacts in
//! place; otherwise it skips, keeping `cargo test` hermetic.

use std::path::PathBuf;

use acceltran::coordinator::{evaluate_span, train_span};
use acceltran::model::TransformerConfig;
use acceltran::nlp::span::SpanTask;
use acceltran::runtime::{ParamStore, Runtime};
use acceltran::trace::ActHook;

/// Tiny encoder so debug-mode `cargo test` stays fast.
fn tiny_model() -> TransformerConfig {
    TransformerConfig {
        name: "conformance-tiny".into(),
        hidden: 32,
        layers: 2,
        heads: 2,
        ff: 64,
        vocab: 64,
        seq: 16,
    }
}

fn tiny_runtime() -> Runtime {
    Runtime::reference_for(&tiny_model(), 2).unwrap()
}

fn sample_ids(rt: &Runtime, batch: usize) -> Vec<i32> {
    (0..batch * rt.manifest.seq)
        .map(|i| ((i * 7 + 3) % rt.manifest.vocab) as i32)
        .collect()
}

#[test]
fn checkpoint_roundtrips_bit_exactly_across_backend_instances() {
    let mut rt = tiny_runtime();
    let store = ParamStore::init(&rt.manifest, 11);
    let ids = sample_ids(&rt, 3);
    let before = rt.classify(3, &store.params, &ids, 0.03).unwrap();

    // write -> read back through the Manifest layout contract
    let path: PathBuf = std::env::temp_dir()
        .join(format!("acceltran_conformance_{}.bin", std::process::id()));
    store.save(&path).unwrap();
    let loaded = ParamStore::from_file(&rt.manifest, &path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(store.params, loaded.params, "raw f32 round-trip");

    // a *fresh* backend instance over the same manifest must classify
    // the loaded checkpoint bit-for-bit like the writer did
    let mut rt2 = tiny_runtime();
    let after = rt2.classify(3, &loaded.params, &ids, 0.03).unwrap();
    assert_eq!(before, after, "backend instances must be interchangeable");
}

#[test]
fn trace_capture_does_not_perturb_logits() {
    let mut rt = tiny_runtime();
    let params = ParamStore::init(&rt.manifest, 5).params;
    let ids = sample_ids(&rt, 4);
    for tau in [0.0f32, 0.05, 0.3] {
        let plain = rt.classify(4, &params, &ids, tau).unwrap();
        let (traced, records) = rt.classify_traced(4, &params, &ids, tau).unwrap();
        assert_eq!(plain, traced, "tau={tau}: capture must be bitwise inert");
        // full hook inventory: layers x 10 hooks, labelled in order
        assert_eq!(records.len(), rt.manifest.layers * ActHook::ALL.len());
        for (i, rec) in records.iter().enumerate() {
            assert_eq!(rec.layer, i / ActHook::ALL.len());
            assert_eq!(rec.hook, ActHook::ALL[i % ActHook::ALL.len()]);
            assert!((0.0..=1.0).contains(&rec.zero_frac));
            assert!(rec.elems > 0);
        }
    }
}

#[test]
fn repeated_traced_runs_are_identical() {
    // The capture path itself is deterministic: same inputs, same
    // records (the trace-file determinism test builds on this).
    let mut rt = tiny_runtime();
    let params = ParamStore::init(&rt.manifest, 9).params;
    let ids = sample_ids(&rt, 2);
    let (la, ra) = rt.classify_traced(2, &params, &ids, 0.04).unwrap();
    let (lb, rb) = rt.classify_traced(2, &params, &ids, 0.04).unwrap();
    assert_eq!(la, lb);
    assert_eq!(ra.len(), rb.len());
    for (a, b) in ra.iter().zip(&rb) {
        assert_eq!(a.zero_frac.to_bits(), b.zero_frac.to_bits());
        assert_eq!(a.elems, b.elems);
    }
}

#[test]
fn span_gradients_match_finite_differences_in_every_param_group() {
    // The span counterpart of the classify gradcheck: central-difference
    // the span loss wrt one parameter from EVERY spec group — embedding,
    // attention, FFN, layer norms, pooler, and the (reused) cls head the
    // span logits read per position — and compare to the hand-derived
    // backprop behind `span_train_step`.
    let mut rt = tiny_runtime();
    let specs = rt.manifest.param_specs.clone();
    let params = ParamStore::init(&rt.manifest, 5).params;
    let ids = sample_ids(&rt, 2);
    // one answerable row, one unanswerable (gold (0, 0)) so both loss
    // branches contribute gradient
    let starts = vec![2, 0];
    let ends = vec![4, 0];
    let (loss, grads) =
        rt.span_loss_grads(2, &params, &ids, &starts, &ends).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!(grads.iter().any(|&g| g.abs() > 1e-6), "gradients are all ~zero");

    let mut loss_at = |p: &[f32]| {
        rt.span_loss_grads(2, p, &ids, &starts, &ends).unwrap().0
    };
    let eps = 5e-3f32;
    let mut off = 0usize;
    for (name, shape, _std) in &specs {
        let len: usize = shape.iter().product();
        let idx = off + len / 2;
        let mut pp = params.clone();
        pp[idx] += eps;
        let mut pm = params.clone();
        pm[idx] -= eps;
        let fd = (loss_at(&pp) - loss_at(&pm)) / (2.0 * eps);
        let got = grads[idx];
        assert!(
            (got - fd).abs() <= 1.5e-3 + 0.08 * fd.abs(),
            "{name}[{idx}]: analytic {got} vs finite-difference {fd}"
        );
        off += len;
    }
}

#[test]
fn span_adamw_training_improves_f1_on_held_out_split() {
    // SpanTask needs vocab > 64 for its marker-token alphabet.
    let model = TransformerConfig {
        name: "conformance-span".into(),
        hidden: 32,
        layers: 2,
        heads: 2,
        ff: 64,
        vocab: 128,
        seq: 16,
    };
    let mut rt = Runtime::reference_for(&model, 2).unwrap();
    let task = SpanTask::new(model.vocab, model.seq);
    let train_ds = task.dataset(256, 1);
    let val_ds = task.dataset(128, 2);
    let mut store = ParamStore::init(&rt.manifest, 0);
    let before = evaluate_span(&mut rt, &store.params, &val_ds, 0.0, 128).unwrap();
    let log = train_span(
        &mut rt, &mut store, &train_ds, None, 150, 3e-3, 0, false,
    )
    .unwrap();
    let (head, tail) = log.head_tail_means(10);
    assert!(
        tail < head,
        "span loss did not decrease: head {head:.4} tail {tail:.4}"
    );
    let after = evaluate_span(&mut rt, &store.params, &val_ds, 0.0, 128).unwrap();
    assert!(
        after.f1 > before.f1,
        "span F1 did not improve: {:.4} -> {:.4}",
        before.f1,
        after.f1
    );
}

// ---- PJRT conformance (gated) ----------------------------------------

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    std::env::var_os("ACCELTRAN_PJRT_TESTS").is_some()
        && artifacts_dir().join("manifest.json").exists()
}

#[test]
fn pjrt_classifies_like_the_reference_backend() {
    if !have_artifacts() {
        eprintln!(
            "skipping: needs ACCELTRAN_PJRT_TESTS=1, a real PJRT backend, \
             and artifacts from python/compile/aot.py"
        );
        return;
    }
    let mut pjrt = Runtime::load(artifacts_dir()).unwrap();
    // the reference backend over the *same* manifest shape
    let model = TransformerConfig::bert_tiny_synth(
        pjrt.manifest.vocab,
        pjrt.manifest.seq,
    );
    let mut reference = Runtime::reference_for(&model, pjrt.manifest.classes).unwrap();
    assert_eq!(pjrt.manifest.param_count, reference.manifest.param_count);
    let store = ParamStore::init(&pjrt.manifest, 0);
    let ids = sample_ids(&pjrt, 2);
    let a = pjrt.classify(2, &store.params, &ids, 0.02).unwrap();
    let b = reference.classify(2, &store.params, &ids, 0.02).unwrap();
    assert_eq!(a.len(), b.len());
    // f32-close (reduction orders differ — DESIGN.md "Reference executor
    // vs PJRT") and classification-identical
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 1e-3, "pjrt {x} vs reference {y}");
    }
    let argmax = |row: &[f32]| {
        row.iter()
            .enumerate()
            .max_by(|p, q| p.1.partial_cmp(q.1).unwrap())
            .map(|(i, _)| i)
            .unwrap()
    };
    let classes = pjrt.manifest.classes;
    for i in 0..2 {
        assert_eq!(
            argmax(&a[i * classes..(i + 1) * classes]),
            argmax(&b[i * classes..(i + 1) * classes]),
            "row {i} classification must agree"
        );
    }
}
