//! Cross-backend conformance: the contracts that keep the functional
//! half's backends interchangeable and the measured-sparsity capture
//! path honest.
//!
//! Un-gated portion (runs in tier-1 on the pure-Rust reference
//! executor):
//!
//! * checkpoints round-trip bit-exactly through `ParamStore` + the
//!   `Manifest` layout, across backend instances;
//! * trace capture (`classify_traced`) never perturbs logits — the
//!   capture-on and capture-off forwards are bitwise identical — and
//!   labels every `(layer, hook)` cell.
//!
//! The PJRT variant at the bottom additionally needs AOT artifacts and
//! a real PJRT backend (the in-tree `xla` crate is a stub — DESIGN.md
//! §Substitutions): set `ACCELTRAN_PJRT_TESTS=1` with artifacts in
//! place; otherwise it skips, keeping `cargo test` hermetic.

use std::path::PathBuf;

use acceltran::model::TransformerConfig;
use acceltran::runtime::{ParamStore, Runtime};
use acceltran::trace::ActHook;

/// Tiny encoder so debug-mode `cargo test` stays fast.
fn tiny_model() -> TransformerConfig {
    TransformerConfig {
        name: "conformance-tiny".into(),
        hidden: 32,
        layers: 2,
        heads: 2,
        ff: 64,
        vocab: 64,
        seq: 16,
    }
}

fn tiny_runtime() -> Runtime {
    Runtime::reference_for(&tiny_model(), 2).unwrap()
}

fn sample_ids(rt: &Runtime, batch: usize) -> Vec<i32> {
    (0..batch * rt.manifest.seq)
        .map(|i| ((i * 7 + 3) % rt.manifest.vocab) as i32)
        .collect()
}

#[test]
fn checkpoint_roundtrips_bit_exactly_across_backend_instances() {
    let mut rt = tiny_runtime();
    let store = ParamStore::init(&rt.manifest, 11);
    let ids = sample_ids(&rt, 3);
    let before = rt.classify(3, &store.params, &ids, 0.03).unwrap();

    // write -> read back through the Manifest layout contract
    let path: PathBuf = std::env::temp_dir()
        .join(format!("acceltran_conformance_{}.bin", std::process::id()));
    store.save(&path).unwrap();
    let loaded = ParamStore::from_file(&rt.manifest, &path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(store.params, loaded.params, "raw f32 round-trip");

    // a *fresh* backend instance over the same manifest must classify
    // the loaded checkpoint bit-for-bit like the writer did
    let mut rt2 = tiny_runtime();
    let after = rt2.classify(3, &loaded.params, &ids, 0.03).unwrap();
    assert_eq!(before, after, "backend instances must be interchangeable");
}

#[test]
fn trace_capture_does_not_perturb_logits() {
    let mut rt = tiny_runtime();
    let params = ParamStore::init(&rt.manifest, 5).params;
    let ids = sample_ids(&rt, 4);
    for tau in [0.0f32, 0.05, 0.3] {
        let plain = rt.classify(4, &params, &ids, tau).unwrap();
        let (traced, records) = rt.classify_traced(4, &params, &ids, tau).unwrap();
        assert_eq!(plain, traced, "tau={tau}: capture must be bitwise inert");
        // full hook inventory: layers x 10 hooks, labelled in order
        assert_eq!(records.len(), rt.manifest.layers * ActHook::ALL.len());
        for (i, rec) in records.iter().enumerate() {
            assert_eq!(rec.layer, i / ActHook::ALL.len());
            assert_eq!(rec.hook, ActHook::ALL[i % ActHook::ALL.len()]);
            assert!((0.0..=1.0).contains(&rec.zero_frac));
            assert!(rec.elems > 0);
        }
    }
}

#[test]
fn repeated_traced_runs_are_identical() {
    // The capture path itself is deterministic: same inputs, same
    // records (the trace-file determinism test builds on this).
    let mut rt = tiny_runtime();
    let params = ParamStore::init(&rt.manifest, 9).params;
    let ids = sample_ids(&rt, 2);
    let (la, ra) = rt.classify_traced(2, &params, &ids, 0.04).unwrap();
    let (lb, rb) = rt.classify_traced(2, &params, &ids, 0.04).unwrap();
    assert_eq!(la, lb);
    assert_eq!(ra.len(), rb.len());
    for (a, b) in ra.iter().zip(&rb) {
        assert_eq!(a.zero_frac.to_bits(), b.zero_frac.to_bits());
        assert_eq!(a.elems, b.elems);
    }
}

// ---- PJRT conformance (gated) ----------------------------------------

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    std::env::var_os("ACCELTRAN_PJRT_TESTS").is_some()
        && artifacts_dir().join("manifest.json").exists()
}

#[test]
fn pjrt_classifies_like_the_reference_backend() {
    if !have_artifacts() {
        eprintln!(
            "skipping: needs ACCELTRAN_PJRT_TESTS=1, a real PJRT backend, \
             and artifacts from python/compile/aot.py"
        );
        return;
    }
    let mut pjrt = Runtime::load(artifacts_dir()).unwrap();
    // the reference backend over the *same* manifest shape
    let model = TransformerConfig::bert_tiny_synth(
        pjrt.manifest.vocab,
        pjrt.manifest.seq,
    );
    let mut reference = Runtime::reference_for(&model, pjrt.manifest.classes).unwrap();
    assert_eq!(pjrt.manifest.param_count, reference.manifest.param_count);
    let store = ParamStore::init(&pjrt.manifest, 0);
    let ids = sample_ids(&pjrt, 2);
    let a = pjrt.classify(2, &store.params, &ids, 0.02).unwrap();
    let b = reference.classify(2, &store.params, &ids, 0.02).unwrap();
    assert_eq!(a.len(), b.len());
    // f32-close (reduction orders differ — DESIGN.md "Reference executor
    // vs PJRT") and classification-identical
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 1e-3, "pjrt {x} vs reference {y}");
    }
    let argmax = |row: &[f32]| {
        row.iter()
            .enumerate()
            .max_by(|p, q| p.1.partial_cmp(q.1).unwrap())
            .map(|(i, _)| i)
            .unwrap()
    };
    let classes = pjrt.manifest.classes;
    for i in 0..2 {
        assert_eq!(
            argmax(&a[i * classes..(i + 1) * classes]),
            argmax(&b[i * classes..(i + 1) * classes]),
            "row {i} classification must agree"
        );
    }
}
