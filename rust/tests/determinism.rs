//! End-to-end determinism of the measured-sparsity pipeline: capturing
//! a trace twice over the same inputs must yield *byte-identical*
//! `SparsityTrace` JSON, and feeding it to `sim::simulate_with` twice
//! must yield identical `SimResult`s — which catches, among other
//! things, the scoped-thread GEMM chunking in `runtime/tensor.rs`
//! leaking nondeterminism into the capture forward passes.

use acceltran::coordinator::capture::capture_trace;
use acceltran::model::TransformerConfig;
use acceltran::nlp::sentiment::SentimentTask;
use acceltran::pruning::dynatran_prune_inplace;
use acceltran::runtime::tensor::{
    matmul_ex_threads, matmul_nt_ex_threads, matmul_scalar, matmul_tn_ex_threads,
};
use acceltran::runtime::{ParamStore, Runtime};
use acceltran::sim::dataflow::Dataflow;
use acceltran::sim::dse::{sweep, DseSpace, SweepOptions};
use acceltran::sim::engine::simulate_with;
use acceltran::sim::scheduler::Policy;
use acceltran::sim::{AcceleratorConfig, SimResult, SparsitySource};
use acceltran::trace::SparsityTrace;
use acceltran::util::rng::Rng;

fn tiny_model() -> TransformerConfig {
    TransformerConfig {
        name: "determinism-tiny".into(),
        hidden: 32,
        layers: 2,
        heads: 2,
        ff: 64,
        vocab: 64,
        seq: 16,
    }
}

/// One full capture: fixed seed params, fixed dataset, fixed tau.
fn capture_once() -> SparsityTrace {
    let mut rt = Runtime::reference_for(&tiny_model(), 2).unwrap();
    let params = ParamStore::init(&rt.manifest, 4).params;
    let task = SentimentTask::new(rt.manifest.vocab, rt.manifest.seq, 5);
    let ds = task.dataset(12, 3);
    capture_trace(&mut rt, &params, &ds, 0.04, 12).unwrap()
}

fn assert_results_identical(a: &SimResult, b: &SimResult) {
    assert_eq!(a.total_cycles, b.total_cycles);
    assert_eq!(a.stalls, b.stalls);
    for (x, y) in [
        (a.energy.mac_pj, b.energy.mac_pj),
        (a.energy.softmax_pj, b.energy.softmax_pj),
        (a.energy.layernorm_pj, b.energy.layernorm_pj),
        (a.energy.dynatran_pj, b.energy.dynatran_pj),
        (a.energy.sparsity_pj, b.energy.sparsity_pj),
        (a.energy.buffer_pj, b.energy.buffer_pj),
        (a.energy.memory_pj, b.energy.memory_pj),
        (a.energy.leakage_pj, b.energy.leakage_pj),
        (a.mac_utilization, b.mac_utilization),
        (a.dma_utilization, b.dma_utilization),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{x} vs {y}");
    }
}

/// Kernel-level pin: the blocked GEMM must be bit-identical serial vs
/// parallel (the `_ex_threads` hooks force the worker count without
/// racing on `ACCELTRAN_THREADS`, which other tests in this process may
/// read) and across reruns, for all three variants — the
/// by-construction guarantee from DESIGN.md "Host microkernel",
/// checked rather than trusted.
#[test]
fn blocked_gemm_is_bitwise_thread_count_invariant() {
    let mut rng = Rng::new(90);
    // big enough that 4 workers actually get multiple MR-chunks each
    let (m, k, n) = (67, 190, 53);
    let x = rng.normal_vec(m * k, 1.0);
    let w = rng.normal_vec(k * n, 1.0);
    let y = rng.normal_vec(m * n, 1.0);

    let (mm1, s1) = matmul_ex_threads(&x, &w, m, k, n, 1);
    let (mm4, s4) = matmul_ex_threads(&x, &w, m, k, n, 4);
    assert_eq!(mm1, mm4, "matmul: 1 vs 4 workers");
    assert_eq!(s1, s4, "matmul: BlockSparsity must not depend on worker count");
    let (rerun, _) = matmul_ex_threads(&x, &w, m, k, n, 4);
    assert_eq!(mm4, rerun, "matmul: rerun vs rerun");

    let (nt1, t1) = matmul_nt_ex_threads(&y, &w, m, n, k, 1);
    let (nt4, t4) = matmul_nt_ex_threads(&y, &w, m, n, k, 4);
    assert_eq!(nt1, nt4, "matmul_nt: 1 vs 4 workers");
    assert_eq!(t1, t4, "matmul_nt: stats invariant");

    let (tn1, u1) = matmul_tn_ex_threads(&x, &y, m, k, n, 1);
    let (tn4, u4) = matmul_tn_ex_threads(&x, &y, m, k, n, 4);
    assert_eq!(tn1, tn4, "matmul_tn: 1 vs 4 workers");
    assert_eq!(u1, u4, "matmul_tn: stats invariant");
}

/// Regression pin from the kernel rewrite: a DynaTran-pruned activation
/// through the tiled kernel (serial and parallel) matches the original
/// un-tiled scalar kernel bit-for-bit — tile skipping over pruned zeros
/// is an exact no-op on the result.
#[test]
fn pruned_activation_tiled_matches_untiled_bitwise() {
    let mut rng = Rng::new(91);
    let (m, k, n) = (48, 256, 64);
    let mut x = rng.normal_vec(m * k, 0.05);
    let w = rng.normal_vec(k * n, 1.0);
    dynatran_prune_inplace(&mut x, 0.04);
    let untiled = matmul_scalar(&x, &w, m, k, n);
    let (tiled_serial, stats) = matmul_ex_threads(&x, &w, m, k, n, 1);
    let (tiled_par, _) = matmul_ex_threads(&x, &w, m, k, n, 4);
    assert_eq!(tiled_serial, untiled, "tiled(1) vs original scalar");
    assert_eq!(tiled_par, untiled, "tiled(4) vs original scalar");
    // sanity: the pruning actually produced element sparsity to skip
    assert!(stats.effectual_mac_fraction() < 0.8, "fixture should be sparse");
}

#[test]
fn trace_capture_is_byte_identical_across_runs() {
    let a = capture_once();
    let b = capture_once();
    assert_eq!(a, b, "structural equality");
    let ja = a.to_json().to_string_pretty();
    let jb = b.to_json().to_string_pretty();
    assert_eq!(ja.as_bytes(), jb.as_bytes(), "serialized bytes");
    // ...and the bytes round-trip losslessly
    let reparsed =
        SparsityTrace::from_json(&acceltran::util::json::Json::parse(&ja).unwrap())
            .unwrap();
    assert_eq!(a, reparsed);
}

#[test]
fn trace_driven_simulation_is_deterministic() {
    let trace = capture_once();
    let source = SparsitySource::Trace(trace);
    let mut cfg = AcceleratorConfig::edge();
    cfg.pes = 16; // small machine: stalls exercised, run stays fast
    let model = tiny_model();
    let a = simulate_with(&cfg, &model, 16, Policy::Staggered, &source);
    let b = simulate_with(&cfg, &model, 16, Policy::Staggered, &source);
    assert_eq!(a.sparsity_source, "trace");
    assert_results_identical(&a, &b);
}

/// The DSE sweep is the first multi-threaded consumer of the sim
/// engine; its contract is that worker count is *unobservable* in the
/// output: 1 vs 4 forced workers (forced via `SweepOptions.threads`,
/// not the `ACCELTRAN_THREADS` env var — parallel test binaries would
/// race on the process environment) must produce byte-identical report
/// JSON and bit-identical per-point `SimResult`s, across reruns.
#[test]
fn dse_sweep_is_bitwise_thread_count_invariant() {
    let trace = capture_once();
    let source = SparsitySource::Trace(trace);
    let model = tiny_model();
    let mut space = DseSpace::around(AcceleratorConfig::edge());
    space.pes = vec![8, 16, 32];
    space.buffers_mb = vec![3, 13];
    space.dataflows = vec![
        Dataflow::parse("bijk").unwrap(),
        Dataflow::parse("kjib").unwrap(),
    ];

    let run = |threads: usize| {
        sweep(
            &space,
            &model,
            16,
            Policy::Staggered,
            &source,
            &SweepOptions { threads, progress: false },
        )
    };
    let serial = run(1);
    let parallel = run(4);
    let rerun = run(4);

    assert_eq!(serial.points.len(), 12);
    for (a, b) in serial.points.iter().zip(&parallel.points) {
        assert_eq!(a.index, b.index);
        assert_eq!(a.config_name, b.config_name);
        assert_results_identical(&a.result, &b.result);
        for (x, y) in [
            (a.throughput_seq_s, b.throughput_seq_s),
            (a.energy_mj_per_seq, b.energy_mj_per_seq),
            (a.area_mm2, b.area_mm2),
        ] {
            assert_eq!(x.to_bits(), y.to_bits(), "{x} vs {y}");
        }
    }
    assert_eq!(serial.frontier, parallel.frontier);

    // The serialized report (what `acceltran dse` writes to
    // reports/dse_frontier.json) is byte-identical 1w vs 4w and across
    // 4w reruns — nothing scheduling-dependent may leak into it.
    let ja = serial.to_json().to_string_pretty();
    let jb = parallel.to_json().to_string_pretty();
    let jc = rerun.to_json().to_string_pretty();
    assert_eq!(ja.as_bytes(), jb.as_bytes(), "report bytes: 1 vs 4 workers");
    assert_eq!(jb.as_bytes(), jc.as_bytes(), "report bytes: rerun vs rerun");
}

#[test]
fn capture_then_simulate_pipeline_is_deterministic_end_to_end() {
    // the full loop twice: capture -> serialize -> parse -> simulate
    let run = || {
        let trace = capture_once();
        let text = trace.to_json().to_string_pretty();
        let parsed = SparsityTrace::from_json(
            &acceltran::util::json::Json::parse(&text).unwrap(),
        )
        .unwrap();
        let cfg = AcceleratorConfig::edge();
        (
            text,
            simulate_with(
                &cfg,
                &tiny_model(),
                16,
                Policy::Staggered,
                &SparsitySource::Trace(parsed),
            ),
        )
    };
    let (ta, ra) = run();
    let (tb, rb) = run();
    assert_eq!(ta, tb);
    assert_results_identical(&ra, &rb);
}
