//! End-to-end determinism of the measured-sparsity pipeline: capturing
//! a trace twice over the same inputs must yield *byte-identical*
//! `SparsityTrace` JSON, and feeding it to `sim::simulate_with` twice
//! must yield identical `SimResult`s — which catches, among other
//! things, the scoped-thread GEMM chunking in `runtime/tensor.rs`
//! leaking nondeterminism into the capture forward passes.

use acceltran::coordinator::capture::capture_trace;
use acceltran::model::TransformerConfig;
use acceltran::nlp::sentiment::SentimentTask;
use acceltran::runtime::{ParamStore, Runtime};
use acceltran::sim::engine::simulate_with;
use acceltran::sim::scheduler::Policy;
use acceltran::sim::{AcceleratorConfig, SimResult, SparsitySource};
use acceltran::trace::SparsityTrace;

fn tiny_model() -> TransformerConfig {
    TransformerConfig {
        name: "determinism-tiny".into(),
        hidden: 32,
        layers: 2,
        heads: 2,
        ff: 64,
        vocab: 64,
        seq: 16,
    }
}

/// One full capture: fixed seed params, fixed dataset, fixed tau.
fn capture_once() -> SparsityTrace {
    let mut rt = Runtime::reference_for(&tiny_model(), 2).unwrap();
    let params = ParamStore::init(&rt.manifest, 4).params;
    let task = SentimentTask::new(rt.manifest.vocab, rt.manifest.seq, 5);
    let ds = task.dataset(12, 3);
    capture_trace(&mut rt, &params, &ds, 0.04, 12).unwrap()
}

fn assert_results_identical(a: &SimResult, b: &SimResult) {
    assert_eq!(a.total_cycles, b.total_cycles);
    assert_eq!(a.stalls, b.stalls);
    for (x, y) in [
        (a.energy.mac_pj, b.energy.mac_pj),
        (a.energy.softmax_pj, b.energy.softmax_pj),
        (a.energy.layernorm_pj, b.energy.layernorm_pj),
        (a.energy.dynatran_pj, b.energy.dynatran_pj),
        (a.energy.sparsity_pj, b.energy.sparsity_pj),
        (a.energy.buffer_pj, b.energy.buffer_pj),
        (a.energy.memory_pj, b.energy.memory_pj),
        (a.energy.leakage_pj, b.energy.leakage_pj),
        (a.mac_utilization, b.mac_utilization),
        (a.dma_utilization, b.dma_utilization),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{x} vs {y}");
    }
}

#[test]
fn trace_capture_is_byte_identical_across_runs() {
    let a = capture_once();
    let b = capture_once();
    assert_eq!(a, b, "structural equality");
    let ja = a.to_json().to_string_pretty();
    let jb = b.to_json().to_string_pretty();
    assert_eq!(ja.as_bytes(), jb.as_bytes(), "serialized bytes");
    // ...and the bytes round-trip losslessly
    let reparsed =
        SparsityTrace::from_json(&acceltran::util::json::Json::parse(&ja).unwrap())
            .unwrap();
    assert_eq!(a, reparsed);
}

#[test]
fn trace_driven_simulation_is_deterministic() {
    let trace = capture_once();
    let source = SparsitySource::Trace(trace);
    let mut cfg = AcceleratorConfig::edge();
    cfg.pes = 16; // small machine: stalls exercised, run stays fast
    let model = tiny_model();
    let a = simulate_with(&cfg, &model, 16, Policy::Staggered, &source);
    let b = simulate_with(&cfg, &model, 16, Policy::Staggered, &source);
    assert_eq!(a.sparsity_source, "trace");
    assert_results_identical(&a, &b);
}

#[test]
fn capture_then_simulate_pipeline_is_deterministic_end_to_end() {
    // the full loop twice: capture -> serialize -> parse -> simulate
    let run = || {
        let trace = capture_once();
        let text = trace.to_json().to_string_pretty();
        let parsed = SparsityTrace::from_json(
            &acceltran::util::json::Json::parse(&text).unwrap(),
        )
        .unwrap();
        let cfg = AcceleratorConfig::edge();
        (
            text,
            simulate_with(
                &cfg,
                &tiny_model(),
                16,
                Policy::Staggered,
                &SparsitySource::Trace(parsed),
            ),
        )
    };
    let (ta, ra) = run();
    let (tb, rb) = run();
    assert_eq!(ta, tb);
    assert_results_identical(&ra, &rb);
}
