//! Integration tests over the full simulator stack: op-graph -> tiling ->
//! scheduling -> engine, exercising the paper's system-level orderings
//! (Table IV ablations, Fig. 16 trends, Fig. 19 sparsity effect) across
//! module boundaries.

use acceltran::model::TransformerConfig;
use acceltran::sim::engine::{simulate, SparsityProfile};
use acceltran::sim::scheduler::Policy;
use acceltran::sim::AcceleratorConfig;

fn paper() -> SparsityProfile {
    SparsityProfile::paper_default()
}

/// Table IV ordering: the full configuration must beat every ablation on
/// throughput; removing the sparsity modules must cost the most energy.
#[test]
fn table_iv_ablation_ordering() {
    let model = TransformerConfig::bert_tiny();
    let seq = 128;
    let mut server = AcceleratorConfig::server();
    server.batch = 8; // keep the test fast; ordering is batch-invariant

    let full = simulate(&server, &model, seq, Policy::Staggered, paper());

    let mut no_dynatran_cfg = server.clone();
    no_dynatran_cfg.dynatran_enabled = false;
    let no_dynatran =
        simulate(&no_dynatran_cfg, &model, seq, Policy::Staggered, paper());

    let no_mp = simulate(
        &server,
        &model,
        seq,
        Policy::Staggered,
        SparsityProfile { weight_rho: 0.0, ..paper() },
    );

    let mut no_sparsity_cfg = server.clone();
    no_sparsity_cfg.sparsity_modules = false;
    let no_sparsity =
        simulate(&no_sparsity_cfg, &model, seq, Policy::Staggered, paper());

    let mut ddr_cfg = server.clone();
    ddr_cfg.memory = acceltran::sim::MemoryKind::LpDdr3;
    let ddr = simulate(&ddr_cfg, &model, seq, Policy::Staggered, paper());

    // throughput: full beats every ablation (Table IV column 2)
    for (name, r) in [
        ("w/o DynaTran", &no_dynatran),
        ("w/o MP", &no_mp),
        ("w/o sparsity modules", &no_sparsity),
        ("w/o mono-3D RRAM", &ddr),
    ] {
        assert!(
            full.total_cycles <= r.total_cycles,
            "{name}: full {} vs ablated {}",
            full.total_cycles,
            r.total_cycles
        );
    }
    // energy: ablating the sparsity modules hurts energy the most among
    // compute-side ablations (Table IV column 3: 0.2701 vs 0.1396/0.1503)
    assert!(no_sparsity.energy.total_pj() > full.energy.total_pj());
    assert!(no_sparsity.energy.total_pj() > no_dynatran.energy.total_pj());
}

/// Fig. 16: compute stalls grow as PEs shrink; memory stalls appear as
/// buffers shrink.
#[test]
fn fig16_stall_trends() {
    let model = TransformerConfig::bert_tiny();
    let mk = |pes: usize, buf_mb: usize| {
        let mut cfg = AcceleratorConfig::edge();
        cfg.pes = pes;
        let unit = (buf_mb << 20) / 13;
        cfg.act_buffer_bytes = 4 * unit;
        cfg.weight_buffer_bytes = 8 * unit;
        cfg.mask_buffer_bytes = unit;
        simulate(&cfg, &model, 128, Policy::Staggered, paper())
    };
    let small = mk(32, 13);
    let large = mk(256, 13);
    assert!(
        small.stalls.compute_total() > large.stalls.compute_total(),
        "32 PEs {} vs 256 PEs {}",
        small.stalls.compute_total(),
        large.stalls.compute_total()
    );
    // latency ordering follows stalls
    assert!(small.total_cycles > large.total_cycles);
}

/// Fig. 19: sweeping activation sparsity upward monotonically improves
/// throughput and energy.
#[test]
fn fig19_sparsity_monotonicity() {
    let model = TransformerConfig::bert_tiny();
    let cfg = AcceleratorConfig::edge();
    let mut last_cycles = u64::MAX;
    let mut last_energy = f64::INFINITY;
    for rho in [0.0, 0.25, 0.5, 0.75] {
        let r = simulate(
            &cfg,
            &model,
            128,
            Policy::Staggered,
            SparsityProfile { act_rho: rho, ..paper() },
        );
        assert!(
            r.total_cycles <= last_cycles,
            "rho {rho}: {} > previous {}",
            r.total_cycles,
            last_cycles
        );
        assert!(r.energy.total_pj() <= last_energy);
        last_cycles = r.total_cycles;
        last_energy = r.energy.total_pj();
    }
}

/// Server at paper batch sizes yields far higher throughput than Edge
/// (Fig. 20 structure) and the trace/utilization outputs are well-formed.
#[test]
fn server_outscales_edge() {
    let model = TransformerConfig::bert_tiny();
    let edge_cfg = AcceleratorConfig::edge();
    let server_cfg = AcceleratorConfig::server();
    let edge = simulate(&edge_cfg, &model, 128, Policy::Staggered, paper());
    let server = simulate(&server_cfg, &model, 128, Policy::Staggered, paper());
    let edge_tp = edge.throughput_seq_s(&edge_cfg);
    let server_tp = server.throughput_seq_s(&server_cfg);
    assert!(
        server_tp > 3.0 * edge_tp,
        "server {server_tp:.0} vs edge {edge_tp:.0} seq/s"
    );
    assert!(!server.trace.is_empty());
}

/// Graph-level determinism: identical inputs give identical results.
#[test]
fn simulation_is_deterministic() {
    let model = TransformerConfig::bert_tiny();
    let cfg = AcceleratorConfig::edge();
    let a = simulate(&cfg, &model, 128, Policy::Staggered, paper());
    let b = simulate(&cfg, &model, 128, Policy::Staggered, paper());
    assert_eq!(a.total_cycles, b.total_cycles);
    assert_eq!(a.stalls, b.stalls);
    assert!((a.energy.total_pj() - b.energy.total_pj()).abs() < 1e-6);
}

/// A deeper model (bert-mini) takes proportionally more cycles.
#[test]
fn deeper_model_costs_more() {
    let cfg = AcceleratorConfig::edge();
    let tiny = simulate(
        &cfg,
        &TransformerConfig::bert_tiny(),
        128,
        Policy::Staggered,
        paper(),
    );
    let mini = simulate(
        &cfg,
        &TransformerConfig::bert_mini(),
        128,
        Policy::Staggered,
        paper(),
    );
    assert!(mini.total_cycles > tiny.total_cycles);
    assert!(mini.energy.total_pj() > tiny.energy.total_pj());
}

/// Longer sequences shift work toward the attention (softmax) modules.
#[test]
fn longer_sequences_grow_softmax_share() {
    let model = TransformerConfig::bert_tiny();
    let cfg = AcceleratorConfig::edge();
    let short = simulate(&cfg, &model, 64, Policy::Staggered, paper());
    let long = simulate(&cfg, &model, 256, Policy::Staggered, paper());
    let share = |r: &acceltran::sim::SimResult| {
        r.energy.softmax_pj / r.energy.compute_pj()
    };
    assert!(
        share(&long) > share(&short),
        "short {:.4} long {:.4}",
        share(&short),
        share(&long)
    );
}
