//! Golden regression pin for the DSE reduction: a fixed small design
//! space swept on the *committed* capture-trace fixture must keep
//! producing the exact same frontier point set (and knee, and per-point
//! cycle counts).  A cost-model or dominance change that re-shapes the
//! Fig. 16 surface now fails tier-1 here instead of silently moving the
//! recommended design point.
//!
//! Self-seeding like `sim_golden.rs`: the pin lives at
//! `rust/tests/goldens/dse_golden.json`; on the first run in a fresh
//! tree (file absent) it is seeded from the current model and the test
//! passes with a loud note — commit the file to arm the pin.  Delete it
//! and rerun to rebaseline after an intentional perf-model change.
//! The input trace is `rust/tests/goldens/dse_trace.json`, a committed
//! fixture in the PR-4 capture format (same values as `sim_golden.rs`'s
//! hand-written trace, so the two pins guard the same surface from two
//! directions).

use std::path::PathBuf;

use acceltran::model::TransformerConfig;
use acceltran::sim::dataflow::Dataflow;
use acceltran::sim::dse::{sweep, DseReport, DseSpace, SweepOptions};
use acceltran::sim::scheduler::Policy;
use acceltran::sim::{AcceleratorConfig, SparsitySource};
use acceltran::trace::SparsityTrace;
use acceltran::util::json::Json;

fn goldens_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/goldens")
}

fn golden_model() -> TransformerConfig {
    TransformerConfig {
        name: "golden-tiny".into(),
        hidden: 32,
        layers: 2,
        heads: 2,
        ff: 64,
        vocab: 1000,
        seq: 64,
    }
}

/// The fixed space: shrunken-Edge family, two buffer sizes, the paper's
/// dataflow plus the worst-reuse one — 12 points, all stall classes
/// exercised, fast enough for tier-1.
fn golden_space() -> DseSpace {
    let mut space = DseSpace::around(AcceleratorConfig::edge());
    space.pes = vec![8, 16, 32];
    space.buffers_mb = vec![3, 6];
    space.dataflows = vec![
        Dataflow::parse("bijk").unwrap(),
        Dataflow::parse("kjib").unwrap(),
    ];
    space
}

fn run_golden() -> DseReport {
    let trace = SparsityTrace::load(goldens_dir().join("dse_trace.json"))
        .expect("committed trace fixture loads");
    sweep(
        &golden_space(),
        &golden_model(),
        64,
        Policy::Staggered,
        &SparsitySource::Trace(trace),
        &SweepOptions { threads: 0, progress: false },
    )
}

/// What gets pinned: the frontier index set, the knee, and per-point
/// integer cycles (floats in the full report are covered to 1e-9 via
/// energy below; cycles are exact-u64 compared).
fn report_to_golden_json(r: &DseReport) -> Json {
    Json::obj(vec![
        (
            "frontier",
            Json::arr(r.frontier.indices.iter().map(|&i| Json::num(i as f64))),
        ),
        (
            "knee",
            match r.frontier.knee {
                Some(i) => Json::num(i as f64),
                None => Json::Null,
            },
        ),
        (
            "configs",
            Json::arr(r.points.iter().map(|p| Json::str(p.config_name.clone()))),
        ),
        (
            "cycles",
            Json::arr(
                r.points
                    .iter()
                    .map(|p| Json::num(p.result.total_cycles as f64)),
            ),
        ),
        (
            "energy_mj_per_seq",
            Json::arr(r.points.iter().map(|p| Json::num(p.energy_mj_per_seq))),
        ),
    ])
}

#[test]
fn dse_frontier_matches_pinned_golden() {
    let r = run_golden();
    // Non-trivial preconditions, checked even before a golden exists.
    assert_eq!(r.points.len(), 12);
    assert!(!r.frontier.indices.is_empty());
    assert!(r.points.iter().all(|p| p.result.total_cycles > 1000));
    assert_eq!(r.sparsity_source, "trace");

    let current = report_to_golden_json(&r);
    let path = goldens_dir().join("dse_golden.json");
    let Ok(text) = std::fs::read_to_string(&path) else {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, current.to_string_pretty()).unwrap();
        eprintln!(
            "dse_golden: seeded {} — commit it to pin the DSE surface",
            path.display()
        );
        return;
    };
    let golden = Json::parse(&text).expect("golden file parses");

    // Exact comparisons: frontier set, knee, config naming, cycles.
    for key in ["frontier", "configs", "cycles"] {
        let want = golden.get(key).expect(key);
        let got = current.get(key).unwrap();
        assert_eq!(
            got, want,
            "DSE drift on '{key}' (delete {} to rebaseline after an \
             intentional perf-model change)",
            path.display()
        );
    }
    assert_eq!(
        current.get("knee"),
        golden.get("knee"),
        "DSE knee moved (delete {} to rebaseline)",
        path.display()
    );

    // Energy to relative tolerance (still IEEE-deterministic, but the
    // looser compare keeps the message readable on drift).
    let want = golden
        .get("energy_mj_per_seq")
        .and_then(Json::as_arr)
        .expect("energy_mj_per_seq");
    let got = current.get("energy_mj_per_seq").and_then(Json::as_arr).unwrap();
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let (g, w) = (g.as_f64().unwrap(), w.as_f64().unwrap());
        let tol = 1e-9 * w.abs().max(1e-12);
        assert!(
            (g - w).abs() <= tol,
            "DSE energy drift at point {i}: {g} vs pinned {w} (delete {} \
             to rebaseline)",
            path.display()
        );
    }
}
