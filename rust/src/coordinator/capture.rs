//! Measured-sparsity capture: run the functional model over an
//! evaluation set and aggregate the per-activation observations into a
//! [`SparsityTrace`] the simulator consumes — the pipeline that closes
//! the loop between the serving/accuracy half and the timing half
//! (paper Figs. 17-19 feed measured sparsity, not assumed scalars).
//!
//! Layers of the pipeline, lowest first:
//!
//! * [`measure_weight_rho`] — static zero fractions of the checkpoint's
//!   weight matrices, grouped by trace class.
//! * [`capture_trace`] — classify the eval set at a DynaTran `tau`
//!   through `Runtime::classify_traced`, fold every
//!   [`crate::trace::HookRecord`] into a [`TraceBuilder`], probe the
//!   inherent (tau = 0) sparsity, and record accuracy — all in the same
//!   pass the trace describes.
//! * [`measured_trace`] — the turnkey driver the benches and the
//!   `acceltran trace` subcommand share: fine-tune (cached via
//!   `trainer::ensure_trained`), build the eval set, capture.
//!
//! Problem size honours `ACCELTRAN_TRAIN_STEPS` /
//! `ACCELTRAN_EVAL_EXAMPLES` like every other experiment driver.

use std::path::Path;

use anyhow::Result;

use crate::nlp::sentiment::SentimentTask;
use crate::nlp::span::{span_f1, SpanDataset};
use crate::nlp::Dataset;
use crate::runtime::{Manifest, Runtime};
use crate::trace::{require_records, SparsityTrace, TraceBuilder, WeightRho};
use crate::util::cli::env_usize;

/// Measured zero fractions of the checkpoint's weight matrices, grouped
/// the way M-OPs stream them (biases and layer-norm affines are not
/// weight-buffer traffic and are excluded).  A freshly fine-tuned
/// checkpoint is dense (~0 everywhere); movement-pruned checkpoints
/// report their real sparsity.
pub fn measure_weight_rho(manifest: &Manifest, params: &[f32]) -> WeightRho {
    // (zeros, total) per class: embedding, wqkv, wo, wf1, wf2
    let mut acc = [(0usize, 0usize); 5];
    let mut off = 0usize;
    for (name, shape, _std) in &manifest.param_specs {
        let len: usize = shape.iter().product();
        let slice = &params[off..off + len];
        off += len;
        let class = if name.starts_with("embed.") {
            Some(0)
        } else if name.ends_with(".attn.wq")
            || name.ends_with(".attn.wk")
            || name.ends_with(".attn.wv")
        {
            Some(1)
        } else if name.ends_with(".attn.wo") {
            Some(2)
        } else if name.ends_with(".ffn.w1") {
            Some(3)
        } else if name.ends_with(".ffn.w2") {
            Some(4)
        } else {
            None
        };
        if let Some(c) = class {
            acc[c].0 += slice.iter().filter(|&&v| v == 0.0).count();
            acc[c].1 += len;
        }
    }
    let frac = |(z, n): (usize, usize)| if n == 0 { 0.0 } else { z as f64 / n as f64 };
    WeightRho {
        embedding: frac(acc[0]),
        wqkv: frac(acc[1]),
        wo: frac(acc[2]),
        wf1: frac(acc[3]),
        wf2: frac(acc[4]),
    }
}

/// Classify `ds` at DynaTran threshold `tau` while capturing sparsity
/// observations; returns the aggregated [`SparsityTrace`] (accuracy over
/// the same examples rides along in `eval_accuracy`).  Errors when the
/// runtime's backend has no traced inference path.
///
/// Unlike the eval loops (which pad the tail batch to a fixed exported
/// shape), batches here are *exact-fill*: padding rows would re-enter
/// the element-weighted aggregation and bias the measured sparsity
/// toward whichever example padded the tail.  The traced path requires
/// a flexible-batch backend (the reference executor) anyway.
pub fn capture_trace(
    rt: &mut Runtime,
    params: &[f32],
    ds: &Dataset,
    tau: f32,
    max_examples: usize,
) -> Result<SparsityTrace> {
    let classes = rt.manifest.classes;
    let n = ds.examples.len().min(max_examples.max(1));
    let mut builder = TraceBuilder::new(rt.manifest.layers);
    let mut correct = 0usize;
    let mut scored = 0usize;
    let batch = 32usize;
    let mut i = 0usize;
    while i < n {
        let fill = batch.min(n - i);
        let mut ids = Vec::with_capacity(fill * ds.seq);
        for b in 0..fill {
            ids.extend_from_slice(&ds.examples[i + b].ids);
        }
        let (logits, records) = rt.classify_traced(fill, params, &ids, tau)?;
        require_records(&records, rt.backend_name())?;
        builder.add_all(&records);
        for b in 0..fill {
            let row = &logits[b * classes..(b + 1) * classes];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as i32)
                .unwrap_or(0);
            if pred == ds.examples[i + b].label {
                correct += 1;
            }
            scored += 1;
        }
        i += fill;
    }

    // inherent sparsity: natural zeros with DynaTran off (tau = 0),
    // probed on the first few examples like the eval sparsity probe
    let probe = 8.min(n);
    let mut probe_ids = Vec::with_capacity(probe * ds.seq);
    for b in 0..probe {
        probe_ids.extend_from_slice(&ds.examples[b].ids);
    }
    let (_, probe_records) = rt.classify_traced(probe, params, &probe_ids, 0.0)?;
    let mut inherent_builder = TraceBuilder::new(rt.manifest.layers);
    inherent_builder.add_all(&probe_records);

    let weight = measure_weight_rho(&rt.manifest, params);
    Ok(builder.finish(
        rt.manifest.model_name.clone(),
        rt.backend_name(),
        tau as f64,
        scored,
        correct as f64 / scored.max(1) as f64,
        inherent_builder.mean(),
        weight,
    ))
}

/// [`capture_trace`] for the span task: capture sparsity over a span
/// eval set at `tau`, with mean token-overlap F1 riding along in
/// `eval_accuracy` (the Fig. 14(b) metric).
///
/// The traced hooks all live in the *encoder* — embeddings through the
/// last FFN — which classify and span share exactly (the heads differ
/// only after the final hidden states), so the records come from
/// `classify_traced` over the span eval ids; the span head runs
/// separately on the same batches for the F1 score.
pub fn capture_trace_span(
    rt: &mut Runtime,
    params: &[f32],
    ds: &SpanDataset,
    tau: f32,
    max_examples: usize,
) -> Result<SparsityTrace> {
    let seq = ds.seq;
    let n = ds.examples.len().min(max_examples.max(1));
    let mut builder = TraceBuilder::new(rt.manifest.layers);
    let mut f1_sum = 0.0f64;
    let mut scored = 0usize;
    let batch = 32usize;
    let mut i = 0usize;
    while i < n {
        let fill = batch.min(n - i);
        let mut ids = Vec::with_capacity(fill * seq);
        for b in 0..fill {
            ids.extend_from_slice(&ds.examples[i + b].ids);
        }
        let (_, records) = rt.classify_traced(fill, params, &ids, tau)?;
        require_records(&records, rt.backend_name())?;
        builder.add_all(&records);
        let logits = rt.span_logits(fill, params, &ids, tau)?;
        for b in 0..fill {
            let row = &logits[b * seq * 2..(b + 1) * seq * 2];
            let (mut s_best, mut e_best) = (0usize, 0usize);
            let (mut smax, mut emax) = (f32::NEG_INFINITY, f32::NEG_INFINITY);
            for p in 0..seq {
                if row[p * 2] > smax {
                    smax = row[p * 2];
                    s_best = p;
                }
                if row[p * 2 + 1] > emax {
                    emax = row[p * 2 + 1];
                    e_best = p;
                }
            }
            let ex = &ds.examples[i + b];
            f1_sum += span_f1((s_best, e_best), (ex.start, ex.end));
            scored += 1;
        }
        i += fill;
    }

    let probe = 8.min(n);
    let mut probe_ids = Vec::with_capacity(probe * seq);
    for b in 0..probe {
        probe_ids.extend_from_slice(&ds.examples[b].ids);
    }
    let (_, probe_records) = rt.classify_traced(probe, params, &probe_ids, 0.0)?;
    let mut inherent_builder = TraceBuilder::new(rt.manifest.layers);
    inherent_builder.add_all(&probe_records);

    let weight = measure_weight_rho(&rt.manifest, params);
    Ok(builder.finish(
        rt.manifest.model_name.clone(),
        rt.backend_name(),
        tau as f64,
        scored,
        f1_sum / scored.max(1) as f64,
        inherent_builder.mean(),
        weight,
    ))
}

/// Capture at `tau` over *the* shared eval set — the seed-7 sentiment
/// task, dataset variant 2, the same set every accuracy bench sweeps.
/// This is the single place that eval-set contract lives; the benches,
/// `measured_trace`, and the `acceltran trace` subcommand all go
/// through here so their traces describe the same operating point.
pub fn measured_trace_with(
    rt: &mut Runtime,
    store: &crate::runtime::ParamStore,
    tau: f32,
    examples: usize,
) -> Result<SparsityTrace> {
    let task = SentimentTask::new(rt.manifest.vocab, rt.manifest.seq, 7);
    let ds = task.dataset(examples, 2);
    capture_trace(rt, &store.params, &ds, tau, examples)
}

/// Turnkey measured-trace pipeline: fine-tune the synthetic-sentiment
/// model (cached under `reports/trained_params.bin`, shrunk by
/// `ACCELTRAN_TRAIN_STEPS`), then [`measured_trace_with`] over the
/// shared eval set (shrunk by `ACCELTRAN_EVAL_EXAMPLES`).  This is what
/// the fig17/18/20 benches run.
pub fn measured_trace(tau: f32, verbose: bool) -> Result<SparsityTrace> {
    let mut rt = Runtime::load_default()?;
    let store = super::trainer::ensure_trained(
        &mut rt,
        Path::new("reports/trained_params.bin"),
        200,
        verbose,
    )?;
    let examples = env_usize("ACCELTRAN_EVAL_EXAMPLES", 512);
    measured_trace_with(&mut rt, &store, tau, examples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TransformerConfig;
    use crate::runtime::ParamStore;

    fn tiny_runtime() -> Runtime {
        let model = TransformerConfig {
            name: "tiny-test".into(),
            hidden: 32,
            layers: 2,
            heads: 2,
            ff: 64,
            vocab: 64,
            seq: 16,
        };
        Runtime::reference_for(&model, 2).unwrap()
    }

    #[test]
    fn capture_aggregates_per_layer_cells() {
        let mut rt = tiny_runtime();
        let params = ParamStore::init(&rt.manifest, 0).params;
        let task = SentimentTask::new(rt.manifest.vocab, rt.manifest.seq, 3);
        let ds = task.dataset(12, 1);
        let t = capture_trace(&mut rt, &params, &ds, 0.05, 12).unwrap();
        assert_eq!(t.layers.len(), 2);
        assert_eq!(t.backend, "reference");
        assert_eq!(t.examples, 12);
        assert!((0.0..=1.0).contains(&t.eval_accuracy));
        for l in &t.layers {
            for h in crate::trace::ActHook::ALL {
                assert!((0.0..=1.0).contains(&l.get(h)));
            }
        }
        // random normal init + biases-in-play: the pruned cells must
        // actually show zeros at a meaningful tau
        assert!(t.mean_act_rho() > 0.0, "{t:?}");
        // the checkpoint is dense — measured weight sparsity ~ 0
        assert!(t.weight.wqkv < 0.01 && t.weight.wf1 < 0.01);
    }

    #[test]
    fn capture_sparsity_is_monotone_in_tau() {
        let mut rt = tiny_runtime();
        let params = ParamStore::init(&rt.manifest, 0).params;
        let task = SentimentTask::new(rt.manifest.vocab, rt.manifest.seq, 3);
        let ds = task.dataset(8, 2);
        let lo = capture_trace(&mut rt, &params, &ds, 0.01, 8).unwrap();
        let hi = capture_trace(&mut rt, &params, &ds, 1.0, 8).unwrap();
        assert!(hi.mean_act_rho() > lo.mean_act_rho());
        // inherent probe is tau-independent: same value both captures
        assert_eq!(lo.inherent_act_rho, hi.inherent_act_rho);
    }

    #[test]
    fn span_capture_aggregates_and_scores_f1() {
        // SpanTask needs vocab > 64 for its marker tokens
        let model = TransformerConfig {
            name: "tiny-span-test".into(),
            hidden: 32,
            layers: 2,
            heads: 2,
            ff: 64,
            vocab: 128,
            seq: 16,
        };
        let mut rt = Runtime::reference_for(&model, 2).unwrap();
        let params = ParamStore::init(&rt.manifest, 0).params;
        let task =
            crate::nlp::span::SpanTask::new(rt.manifest.vocab, rt.manifest.seq);
        let ds = task.dataset(12, 1);
        let t = capture_trace_span(&mut rt, &params, &ds, 0.05, 12).unwrap();
        assert_eq!(t.layers.len(), 2);
        assert_eq!(t.examples, 12);
        // eval_accuracy carries mean span F1 here
        assert!((0.0..=1.0).contains(&t.eval_accuracy));
        assert!(t.mean_act_rho() > 0.0, "{t:?}");
    }

    #[test]
    fn weight_rho_counts_real_zeros() {
        let rt = tiny_runtime();
        let mut params = ParamStore::init(&rt.manifest, 0).params;
        let dense = measure_weight_rho(&rt.manifest, &params);
        assert!(dense.wqkv < 0.01, "normal init has no exact zeros");
        // zero out the whole buffer: every weight class reads 1.0
        for v in params.iter_mut() {
            *v = 0.0;
        }
        let zeroed = measure_weight_rho(&rt.manifest, &params);
        assert_eq!(zeroed.wqkv, 1.0);
        assert_eq!(zeroed.wf2, 1.0);
        assert_eq!(zeroed.embedding, 1.0);
    }
}
