//! L3 coordination: the serving front-end and experiment drivers that tie
//! the functional runtime (any `runtime::ExecBackend` — the pure-Rust
//! reference executor by default, PJRT artifacts when present) and the
//! timing model (the AccelTran simulator) together.
//!
//! * [`batcher`] — request router + dynamic batcher: incoming classify
//!   requests are queued per sequence-length bucket, grouped to the
//!   nearest exported batch shape (b1 / b8 / b32), padded only within
//!   their bucket, flushed on fill-or-deadline (interactive priority
//!   first, bounded-queue admission control), and answered with
//!   per-request logits plus row- and token-granular padding
//!   accounting.
//! * [`serve`] — the concurrent serving engine: N worker threads (one
//!   forked backend each) drain the shared queue under the same
//!   batching policy, stream per-request latencies into allocation-free
//!   histograms, and — in sim-in-the-loop mode — cost every dispatched
//!   batch on the cycle-accurate engine as well (the AccelTran-Server
//!   vs Energon serving comparison of Sec. V-E).  Pools can host
//!   several named `(checkpoint, task)` models at once — classify and
//!   span runtimes side by side — with per-model queues (a batch never
//!   mixes checkpoints), accounting, and sim costing.
//! * [`eval`] — evaluation loops over `nlp` datasets: accuracy / F1 /
//!   activation-sparsity sweeps across DynaTran tau and top-k keep
//!   fractions (the Figs. 11/12/14 drivers).
//! * [`trainer`] — the end-to-end training driver: AdamW steps through
//!   the runtime's `train_step`, loss-curve logging, checkpoints.
//! * [`capture`] — measured-sparsity capture: classify an eval set
//!   while recording per-activation sparsity, aggregate into a
//!   `trace::SparsityTrace`, and hand it to the simulator (the
//!   trace-driven Figs. 17-20 pipeline).

pub mod batcher;
pub mod capture;
pub mod eval;
pub mod serve;
pub mod trainer;

pub use batcher::{
    seq_buckets, BatchServer, Priority, Request, Response, ServerStats,
    SubmitError, DEFAULT_MAX_QUEUE,
};
pub use capture::{
    capture_trace, capture_trace_span, measured_trace, measured_trace_with,
};
pub use eval::{
    evaluate_accuracy, evaluate_span, sweep_dynatran, sweep_dynatran_span,
    sweep_topk, EvalReport,
};
pub use serve::{
    LatencyHistogram, ModelEntry, ModelInfo, ModelReport, ModelSnapshot,
    PoolSnapshot, ServeConfig, ServePool, ServeReport, ShapeModel, SimInLoop,
    TaskKind,
};
pub use trainer::{ensure_trained, ensure_trained_span, train, train_span, TrainLog};
