//! Request router + dynamic batcher.
//!
//! The runtime backends export fixed batch shapes (1, 8, 32 for the AOT
//! artifacts; the reference executor accepts the same shapes).  The
//! batcher drains its queue into the largest shape it can *fill*; only a
//! sub-8 tail is padded up to a covering shape (padded rows are computed
//! and discarded), amortizing the per-dispatch overhead exactly like the
//! serving-side dynamic batching of vLLM-style routers, scaled to this
//! repo's single-process setting.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::runtime::Runtime;

/// Exported batch shapes, largest first.
const BATCH_SHAPES: &[usize] = &[32, 8, 1];

/// One classification request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// `seq`-length token ids.
    pub ids: Vec<i32>,
    /// DynaTran threshold for this request's dynamic-inference level.
    pub tau: f32,
    pub enqueued_at: Instant,
}

/// One completed response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub latency: Duration,
    /// Batch shape the request was served in.
    pub batch: usize,
}

/// Aggregate serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub served: u64,
    pub dispatches: u64,
    pub padded_rows: u64,
    /// Total rows dispatched (served + padded) — the padded-fraction
    /// denominator.
    pub rows_dispatched: u64,
    /// Deepest the queue has ever been (updated on submit).
    pub queue_depth_high_water: u64,
    latencies_us: Vec<u64>,
}

impl ServerStats {
    pub fn record(&mut self, latency: Duration, batch_fill: usize, batch: usize) {
        self.served += batch_fill as u64;
        self.dispatches += 1;
        self.padded_rows += (batch - batch_fill) as u64;
        self.rows_dispatched += batch as u64;
        self.latencies_us.push(latency.as_micros() as u64);
    }

    /// Fraction of dispatched rows that were padding (wasted compute);
    /// 0.0 before the first dispatch.
    pub fn padded_row_fraction(&self) -> f64 {
        if self.rows_dispatched == 0 {
            return 0.0;
        }
        self.padded_rows as f64 / self.rows_dispatched as f64
    }

    /// Latency percentile over *dispatch* latencies, p in [0, 100].
    pub fn latency_percentile(&self, p: f64) -> Duration {
        if self.latencies_us.is_empty() {
            return Duration::ZERO;
        }
        let mut xs = self.latencies_us.clone();
        xs.sort_unstable();
        let idx = ((p / 100.0) * (xs.len() - 1) as f64).round() as usize;
        Duration::from_micros(xs[idx])
    }

    pub fn mean_latency(&self) -> Duration {
        if self.latencies_us.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_micros(
            self.latencies_us.iter().sum::<u64>() / self.latencies_us.len() as u64,
        )
    }
}

/// Flush-time shape choice for a queue of depth `n` (see
/// [`BatchServer::choose_shape`]): the largest shape that fills
/// completely when that avoids padding waste, otherwise the smallest
/// covering shape for the sub-8 tail.
fn flush_shape(n: usize) -> usize {
    let full = BATCH_SHAPES.iter().copied().filter(|&b| b <= n).max().unwrap_or(1);
    if full >= 8 || full == n {
        return full;
    }
    BATCH_SHAPES
        .iter()
        .copied()
        .filter(|&b| b >= n)
        .min()
        .unwrap_or(BATCH_SHAPES[0])
}

/// The batching server.
pub struct BatchServer {
    runtime: Runtime,
    params: Vec<f32>,
    queue: VecDeque<Request>,
    pub stats: ServerStats,
    next_id: u64,
    /// Maximum queue dwell before a partial batch is flushed.
    pub max_wait: Duration,
}

impl BatchServer {
    pub fn new(runtime: Runtime, params: Vec<f32>) -> BatchServer {
        BatchServer {
            runtime,
            params,
            queue: VecDeque::new(),
            stats: ServerStats::default(),
            next_id: 0,
            max_wait: Duration::from_millis(5),
        }
    }

    /// Enqueue a request; returns its id.
    pub fn submit(&mut self, ids: Vec<i32>, tau: f32) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Request {
            id,
            ids,
            tau,
            enqueued_at: Instant::now(),
        });
        self.stats.queue_depth_high_water =
            self.stats.queue_depth_high_water.max(self.queue.len() as u64);
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Pick the batch shape for the current queue: dispatch the largest
    /// exported shape once it fills; otherwise keep accumulating until
    /// the oldest request has dwelled past `max_wait`, then flush —
    /// preferring a completely-filled shape (8 then covers an 11-deep
    /// queue with zero padding where covering it with 32 would pad 21
    /// rows) and padding only the final sub-8 tail.
    fn choose_shape(&self) -> Option<usize> {
        let n = self.queue.len();
        if n == 0 {
            return None;
        }
        let largest = BATCH_SHAPES[0];
        if n >= largest {
            return Some(largest);
        }
        let oldest = self.queue.front().unwrap().enqueued_at;
        if oldest.elapsed() >= self.max_wait {
            return Some(flush_shape(n));
        }
        None
    }

    /// Serve at most one batch; returns the responses (empty if the
    /// batcher decided to keep waiting).
    pub fn step(&mut self) -> Result<Vec<Response>> {
        let Some(batch) = self.choose_shape() else {
            return Ok(Vec::new());
        };
        let fill = batch.min(self.queue.len());
        let reqs: Vec<Request> = (0..fill).map(|_| self.queue.pop_front().unwrap()).collect();
        let seq = self.runtime.manifest.seq;
        let mut ids = Vec::with_capacity(batch * seq);
        for r in &reqs {
            assert_eq!(r.ids.len(), seq, "request seq mismatch");
            ids.extend_from_slice(&r.ids);
        }
        // pad with copies of the last request
        for _ in fill..batch {
            let last = &reqs[fill - 1];
            ids.extend_from_slice(&last.ids);
        }
        // per-batch tau: requests are grouped FIFO; use the max tau so no
        // request gets *more* pruning than it asked for... conservative
        // choice is min (least pruning = most accurate).
        let tau = reqs.iter().map(|r| r.tau).fold(f32::INFINITY, f32::min);
        let t0 = Instant::now();
        let logits = self.runtime.classify(batch, &self.params, &ids, tau)?;
        let elapsed = t0.elapsed();
        let classes = self.runtime.manifest.classes;
        let mut out = Vec::with_capacity(fill);
        for (i, r) in reqs.into_iter().enumerate() {
            out.push(Response {
                id: r.id,
                logits: logits[i * classes..(i + 1) * classes].to_vec(),
                latency: r.enqueued_at.elapsed(),
                batch,
            });
        }
        self.stats.record(elapsed, fill, batch);
        Ok(out)
    }

    /// Drain the queue completely.
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        // force flush regardless of dwell time
        let saved = self.max_wait;
        self.max_wait = Duration::ZERO;
        while self.pending() > 0 {
            out.extend(self.step()?);
        }
        self.max_wait = saved;
        Ok(out)
    }

    pub fn runtime_mut(&mut self) -> &mut Runtime {
        &mut self.runtime
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Shape-choice logic is pure; test it without a runtime via a probe
    // mirroring the policy exactly.
    fn choose(n: usize, waited: bool) -> Option<usize> {
        if n == 0 {
            return None;
        }
        if n >= BATCH_SHAPES[0] {
            return Some(BATCH_SHAPES[0]);
        }
        if waited {
            return Some(flush_shape(n));
        }
        None
    }

    #[test]
    fn full_batches_dispatch_immediately() {
        assert_eq!(choose(32, false), Some(32));
        assert_eq!(choose(40, false), Some(32));
    }

    #[test]
    fn partial_batches_wait_then_flush() {
        // partial batches accumulate toward the big shape...
        assert_eq!(choose(8, false), None);
        assert_eq!(choose(5, false), None);
        assert_eq!(choose(1, false), None);
        // ...and flush preferring completely-filled shapes: an 11-deep
        // queue dispatches 8 full rows (the 3-tail goes next round), a
        // sub-8 queue pads up to the smallest covering shape.
        assert_eq!(choose(5, true), Some(8));
        assert_eq!(choose(8, true), Some(8));
        assert_eq!(choose(9, true), Some(8));
        assert_eq!(choose(11, true), Some(8));
        assert_eq!(choose(31, true), Some(8));
        assert_eq!(choose(1, true), Some(1));
        assert_eq!(choose(0, true), None);
    }

    #[test]
    fn flush_shape_minimizes_padding() {
        // total padding across a full drain of n requests
        let drain_padding = |mut n: usize| {
            let mut padded = 0;
            while n > 0 {
                let b = flush_shape(n);
                let fill = b.min(n);
                padded += b - fill;
                n -= fill;
            }
            padded
        };
        assert_eq!(drain_padding(32), 0);
        assert_eq!(drain_padding(11), 5); // 8 full + 3-in-8 tail
        assert_eq!(drain_padding(9), 0); // 8 full + 1-in-1 tail
        assert_eq!(drain_padding(5), 3); // 5-in-8
        // the old "smallest covering shape" policy padded 11 -> 32 (21
        // wasted rows); the fill-first policy never pads more than 7.
        for n in 1..=40 {
            assert!(drain_padding(n) <= 7, "n={n}");
        }
    }

    #[test]
    fn stats_percentiles() {
        let mut s = ServerStats::default();
        for us in [100u64, 200, 300, 400, 1000] {
            s.record(Duration::from_micros(us), 8, 8);
        }
        assert_eq!(s.latency_percentile(0.0), Duration::from_micros(100));
        assert_eq!(s.latency_percentile(50.0), Duration::from_micros(300));
        assert_eq!(s.latency_percentile(100.0), Duration::from_micros(1000));
        assert_eq!(s.served, 40);
        assert_eq!(s.padded_rows, 0);
        assert_eq!(s.padded_row_fraction(), 0.0);
    }

    #[test]
    fn stats_track_padding_and_rows() {
        let mut s = ServerStats::default();
        s.record(Duration::from_micros(50), 8, 8); // full
        s.record(Duration::from_micros(50), 3, 8); // tail: 5 padded
        assert_eq!(s.served, 11);
        assert_eq!(s.dispatches, 2);
        assert_eq!(s.padded_rows, 5);
        assert_eq!(s.rows_dispatched, 16);
        assert!((s.padded_row_fraction() - 5.0 / 16.0).abs() < 1e-12);
    }
}
