//! Request router + dynamic batcher (the single-threaded half of the
//! serving story behind the paper's Sec. V-E throughput comparison;
//! [`super::serve`] drives the same policy from a worker pool).
//!
//! **Batch shapes.**  The runtime backends export fixed batch shapes
//! (1, 8, 32 for the AOT artifacts; the reference executor accepts the
//! same shapes).  The batcher drains a queue into the largest shape it
//! can *fill*; only a sub-8 tail is padded up to a covering shape
//! (padded rows are computed and discarded), amortizing the
//! per-dispatch overhead exactly like the serving-side dynamic batching
//! of vLLM-style routers, scaled to this repo's single-process setting.
//!
//! **Length buckets.**  Requests carry their *native* token count (any
//! `1..=manifest.seq`) and are queued per sequence-length bucket
//! ([`seq_buckets`]: multiples of 8 up to the manifest's seq).  A
//! dispatch claims rows from exactly one bucket and pads each row only
//! up to that bucket's seq — never to the manifest maximum — so on
//! mixed-length traffic the padded-*token* fraction
//! ([`ServerStats::padded_token_fraction`]) collapses from the
//! pad-to-max baseline's ~40% to under ~10% (ineffectual MACs the
//! paper's DynaTran machinery would otherwise have to prune at the
//! tile level).  The execution contract that makes this safe is
//! [`crate::runtime::Runtime::classify_padded`]: a row's logits are
//! bit-identical at any padded width.
//!
//! **Flushing** is *deadline-aware*: every request carries an SLO
//! budget, fixed at submit time as `deadline = enqueued_at + slo`.  A
//! batch dispatches the moment any bucket fills the largest shape, or
//! as soon as the nearest deadline anywhere in the queues expires —
//! whichever comes first (fill-or-deadline).  Until that instant the
//! deadline-armed bucket keeps accepting late same-bucket arrivals
//! ("topping off"): the claim happens at dispatch time, so everything
//! queued in the window rides the flush.  Within a bucket,
//! `Priority::Interactive` rows are claimed ahead of
//! `Priority::Batch` rows.
//!
//! **Admission control.**  Queues carry a configurable depth bound;
//! submits beyond it fail fast with [`SubmitError::QueueFull`]
//! (backpressure the HTTP front-end maps to 429 + `Retry-After`) rather
//! than letting latency collapse for everyone already queued.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::runtime::Runtime;

/// Exported batch shapes, largest first (the shapes
/// `python/compile/aot.py` AOT-lowers; the reference executor accepts
/// any batch but the batcher sticks to these so both backends see the
/// same dispatch stream).
pub(crate) const BATCH_SHAPES: &[usize] = &[32, 8, 1];

/// Default admission bound per engine: submits fail with
/// [`SubmitError::QueueFull`] once this many requests are pending.
pub const DEFAULT_MAX_QUEUE: usize = 1024;

/// The largest exported batch shape (a full batch dispatches
/// immediately, no deadline consulted).
pub(crate) fn largest_shape() -> usize {
    BATCH_SHAPES[0]
}

/// Sequence-length buckets for a model whose positional table spans
/// `max_seq`: multiples of 8 up to (and always including) `max_seq`.
///
/// Stride-8 buckets rather than the powers of two a first sketch
/// suggests: for lengths uniform in `[8, max_seq]` powers of two waste
/// an expected ~24% of tokens to in-bucket padding (the 2x gaps near
/// the top dominate), while stride 8 wastes ~9% — which is what lets
/// the serving engines hold `padded_token_fraction` under the 0.15
/// acceptance bar.  The bucket count stays small (8 buckets at
/// seq=64), so per-bucket queue fragmentation is negligible.
pub fn seq_buckets(max_seq: usize) -> Vec<usize> {
    assert!(max_seq > 0, "model seq must be positive");
    let mut out = Vec::new();
    let mut b = 8;
    while b < max_seq {
        out.push(b);
        b += 8;
    }
    out.push(max_seq);
    out
}

/// Scheduling class of a request: within a bucket, interactive rows
/// are claimed ahead of batch rows whenever a flush dispatches fewer
/// rows than are queued (deadline flushes order interactive first).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Priority {
    /// Latency-sensitive traffic (the default): claimed first.
    #[default]
    Interactive,
    /// Throughput traffic: claimed once no interactive rows remain in
    /// the bucket, typically submitted under a laxer SLO.
    Batch,
}

impl Priority {
    /// Parse the wire names used by the HTTP API ("interactive" |
    /// "batch").
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "interactive" => Some(Priority::Interactive),
            "batch" => Some(Priority::Batch),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }
}

/// Typed submit rejection — the two ways admission can fail.  Callers
/// that don't care about the distinction can `?` it into
/// `anyhow::Error`; the HTTP front-end maps the variants to 400 / 429.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The request's token count is outside `[1, manifest.seq]`.
    BadLength { got: usize, max_seq: usize },
    /// Admission control: the engine's queue is at its depth bound;
    /// retry after some in-flight work drains.
    QueueFull { pending: usize, bound: usize },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::BadLength { got, max_seq } => {
                write!(f, "request has {got} token ids, want between 1 and {max_seq}")
            }
            SubmitError::QueueFull { pending, bound } => {
                write!(f, "queue full ({pending} pending, bound {bound})")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// One classification request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Native-length token ids (`1..=manifest.seq`); padding up to the
    /// bucket's seq happens only at dispatch, in [`assemble_batch`].
    pub ids: Vec<i32>,
    /// DynaTran threshold for this request's dynamic-inference level.
    pub tau: f32,
    pub enqueued_at: Instant,
    /// Flush-by time: `enqueued_at + slo`.  Once any queued request
    /// passes this instant the batcher dispatches even an under-filled
    /// batch.
    pub deadline: Instant,
    /// Scheduling class (see [`Priority`]).
    pub priority: Priority,
    /// Synchronous completion channel: when set, the worker that serves
    /// this request sends the [`Response`] here instead of retaining it
    /// for the end-of-run collection — the per-request delivery path the
    /// HTTP front-end ([`crate::serve::net`]) rides, which also keeps a
    /// long-lived server from accumulating every response in memory.
    pub reply: Option<mpsc::Sender<Response>>,
}

/// One completed response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub latency: Duration,
    /// Batch shape the request was served in.
    pub batch: usize,
}

/// Per-length-bucket FIFO queues with two priority classes each — the
/// queue structure both serving engines share.  Rows are claimed from
/// exactly one bucket per dispatch, interactive class first, FIFO
/// within a class.
pub(crate) struct BucketQueues {
    seqs: Vec<usize>,
    interactive: Vec<VecDeque<Request>>,
    batch: Vec<VecDeque<Request>>,
}

impl BucketQueues {
    pub(crate) fn new(max_seq: usize) -> BucketQueues {
        let seqs = seq_buckets(max_seq);
        let n = seqs.len();
        BucketQueues {
            seqs,
            interactive: (0..n).map(|_| VecDeque::new()).collect(),
            batch: (0..n).map(|_| VecDeque::new()).collect(),
        }
    }

    /// The bucket seqs, ascending; the last is the manifest's seq.
    pub(crate) fn seqs(&self) -> &[usize] {
        &self.seqs
    }

    /// Index of the smallest bucket covering a `len`-token request.
    pub(crate) fn bucket_for(&self, len: usize) -> Option<usize> {
        self.seqs.iter().position(|&b| b >= len)
    }

    /// Enqueue into the request's covering bucket (length validated at
    /// submit); returns the bucket index.
    pub(crate) fn push(&mut self, req: Request) -> usize {
        let b = self
            .bucket_for(req.ids.len())
            .expect("request length validated at submit");
        match req.priority {
            Priority::Interactive => self.interactive[b].push_back(req),
            Priority::Batch => self.batch[b].push_back(req),
        }
        b
    }

    pub(crate) fn len(&self) -> usize {
        self.interactive.iter().chain(&self.batch).map(|q| q.len()).sum()
    }

    pub(crate) fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Per-bucket total depth (both classes) — the [`dispatch_shape`]
    /// input.
    pub(crate) fn depths(&self) -> Vec<usize> {
        self.seqs
            .iter()
            .enumerate()
            .map(|(i, _)| self.interactive[i].len() + self.batch[i].len())
            .collect()
    }

    /// Minimum deadline over every queued request, with its bucket —
    /// the minimum over the *whole* structure, not any queue's head:
    /// claiming is FIFO-per-class, so when a tight-SLO request sits
    /// behind lax ones, flushing its bucket dispatches the older rows
    /// and the urgent one rides along (or heads an immediately
    /// flushable remainder).  Linear scan; queue depths here are at
    /// most the admission bound.
    pub(crate) fn nearest_deadline(&self) -> Option<(Instant, usize)> {
        let mut best: Option<(Instant, usize)> = None;
        for (i, q) in self.interactive.iter().chain(&self.batch).enumerate() {
            let bucket = i % self.seqs.len();
            for r in q {
                if best.map(|(d, _)| r.deadline < d).unwrap_or(true) {
                    best = Some((r.deadline, bucket));
                }
            }
        }
        best
    }

    /// Claim up to `n` rows from one bucket: interactive first, then
    /// batch, FIFO within each class.
    pub(crate) fn claim(&mut self, bucket: usize, n: usize) -> Vec<Request> {
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            if let Some(r) = self.interactive[bucket].pop_front() {
                out.push(r);
            } else if let Some(r) = self.batch[bucket].pop_front() {
                out.push(r);
            } else {
                break;
            }
        }
        out
    }

}

/// Aggregate serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub served: u64,
    pub dispatches: u64,
    pub padded_rows: u64,
    /// Total rows dispatched (served + padded) — the padded-row-fraction
    /// denominator.
    pub rows_dispatched: u64,
    /// Total tokens dispatched (`shape * bucket_seq` per dispatch) —
    /// the padded-token-fraction denominator.
    pub tokens_dispatched: u64,
    /// Tokens of those that were padding: in-row tails past each
    /// request's native length plus every token of the padded tail
    /// rows.  The token-granular sibling of `padded_rows` — on
    /// mixed-length traffic this is the number that shows the
    /// length-bucketing win.
    pub padded_tokens: u64,
    /// Deepest the queue has ever been (updated on submit).
    pub queue_depth_high_water: u64,
    latencies_us: Vec<u64>,
}

impl ServerStats {
    /// Record one dispatch: `batch_fill` real rows served in a
    /// `batch`-row batch at the bucket's `bucket_seq`, whose real rows
    /// carried `true_tokens` native tokens in total.
    pub fn record(
        &mut self,
        latency: Duration,
        batch_fill: usize,
        batch: usize,
        bucket_seq: usize,
        true_tokens: usize,
    ) {
        self.served += batch_fill as u64;
        self.dispatches += 1;
        self.padded_rows += (batch - batch_fill) as u64;
        self.rows_dispatched += batch as u64;
        let tokens = (batch * bucket_seq) as u64;
        self.tokens_dispatched += tokens;
        self.padded_tokens += tokens - true_tokens as u64;
        self.latencies_us.push(latency.as_micros() as u64);
    }

    /// Fold another worker's counters into this one (high-water takes
    /// the max — the worker-pool merge in [`super::serve`]).
    pub fn merge(&mut self, other: &ServerStats) {
        self.served += other.served;
        self.dispatches += other.dispatches;
        self.padded_rows += other.padded_rows;
        self.rows_dispatched += other.rows_dispatched;
        self.tokens_dispatched += other.tokens_dispatched;
        self.padded_tokens += other.padded_tokens;
        self.queue_depth_high_water =
            self.queue_depth_high_water.max(other.queue_depth_high_water);
        self.latencies_us.extend_from_slice(&other.latencies_us);
    }

    /// Fraction of dispatched rows that were padding (wasted compute);
    /// 0.0 before the first dispatch.
    pub fn padded_row_fraction(&self) -> f64 {
        if self.rows_dispatched == 0 {
            return 0.0;
        }
        self.padded_rows as f64 / self.rows_dispatched as f64
    }

    /// Fraction of dispatched *tokens* that were padding — the
    /// token-granular sibling of [`ServerStats::padded_row_fraction`];
    /// 0.0 before the first dispatch.
    pub fn padded_token_fraction(&self) -> f64 {
        if self.tokens_dispatched == 0 {
            return 0.0;
        }
        self.padded_tokens as f64 / self.tokens_dispatched as f64
    }

    /// Latency percentile over *dispatch* latencies, p in `0..=100`.
    pub fn latency_percentile(&self, p: f64) -> Duration {
        if self.latencies_us.is_empty() {
            return Duration::ZERO;
        }
        let mut xs = self.latencies_us.clone();
        xs.sort_unstable();
        let idx = ((p / 100.0) * (xs.len() - 1) as f64).round() as usize;
        Duration::from_micros(xs[idx])
    }

    pub fn mean_latency(&self) -> Duration {
        if self.latencies_us.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_micros(
            self.latencies_us.iter().sum::<u64>() / self.latencies_us.len() as u64,
        )
    }
}

/// Flush-time shape choice for a bucket of depth `n` (see
/// [`dispatch_shape`]): the largest shape that fills completely when
/// that avoids padding waste, otherwise the smallest covering shape for
/// the sub-8 tail.
pub(crate) fn flush_shape(n: usize) -> usize {
    let full = BATCH_SHAPES.iter().copied().filter(|&b| b <= n).max().unwrap_or(1);
    if full >= 8 || full == n {
        return full;
    }
    BATCH_SHAPES
        .iter()
        .copied()
        .filter(|&b| b >= n)
        .min()
        .unwrap_or(BATCH_SHAPES[0])
}

/// The fill-or-deadline dispatch policy over length buckets, pure so
/// both the single-threaded [`BatchServer`] and the worker pool in
/// [`super::serve`] share it (and so it unit-tests without a clock).
/// Input is the per-bucket queue depths plus the nearest deadline
/// anywhere in the queues (with its bucket); output is `(bucket,
/// shape)` to claim, or `None` to keep waiting.
///
/// Preference order:
///
/// 1. A bucket that fills the largest exported shape dispatches
///    immediately at its *native* length — the deepest such bucket
///    wins (ties to the shortest seq).  Full native-length batches
///    never wait on a deadline.
/// 2. On force-drain, the deepest non-empty bucket flushes at its
///    padding-minimizing [`flush_shape`].
/// 3. Once the nearest deadline has passed, *that request's* bucket
///    flushes — under-filled if need be — which is what bounds tail
///    latency under a trickle of traffic.  Until that instant the
///    policy returns `None`, so the deadline-armed bucket keeps
///    accepting late arrivals that ride the eventual flush (in-flight
///    topping-off; the claim happens at dispatch time).
pub(crate) fn dispatch_shape(
    depths: &[usize],
    nearest_deadline: Option<(Instant, usize)>,
    now: Instant,
    force: bool,
) -> Option<(usize, usize)> {
    dispatch_multi(&[depths], &[nearest_deadline], now, force).map(|(_, b, s)| (b, s))
}

/// Multi-model generalization of [`dispatch_shape`]: one dispatch
/// decision over *several* models' bucket queues (`depths[m][b]`,
/// `deadlines[m]` = model `m`'s nearest deadline with its bucket).
/// Returns `(model, bucket, shape)` — a batch always claims from
/// exactly one model's one bucket, so a dispatched batch can never mix
/// checkpoints (the no-mixed-model invariant holds by construction).
///
/// Preference order mirrors the single-model policy:
///
/// 1. Any `(model, bucket)` that fills the largest exported shape
///    dispatches immediately — the deepest wins (ties to the
///    first-registered model, then the shortest seq).
/// 2. On force-drain, the deepest non-empty `(model, bucket)` flushes
///    at its padding-minimizing [`flush_shape`].
/// 3. Among *expired* deadlines, the earliest one wins its bucket's
///    flush.  Only rule 1's full batches ever preempt a deadline, so
///    one model's trickle of partial batches can never delay another
///    model's armed deadline — the isolation property the multi-model
///    property suite pins.
pub(crate) fn dispatch_multi(
    depths: &[&[usize]],
    deadlines: &[Option<(Instant, usize)>],
    now: Instant,
    force: bool,
) -> Option<(usize, usize, usize)> {
    debug_assert_eq!(depths.len(), deadlines.len());
    let mut full: Option<(usize, usize)> = None;
    for (m, md) in depths.iter().enumerate() {
        for (b, &d) in md.iter().enumerate() {
            if d >= largest_shape()
                && full.map(|(fm, fb)| d > depths[fm][fb]).unwrap_or(true)
            {
                full = Some((m, b));
            }
        }
    }
    if let Some((m, b)) = full {
        return Some((m, b, largest_shape()));
    }
    if force {
        let mut pick: Option<(usize, usize)> = None;
        for (m, md) in depths.iter().enumerate() {
            for (b, &d) in md.iter().enumerate() {
                if d > 0 && pick.map(|(pm, pb)| d > depths[pm][pb]).unwrap_or(true) {
                    pick = Some((m, b));
                }
            }
        }
        let (m, b) = pick?;
        return Some((m, b, flush_shape(depths[m][b])));
    }
    let mut expired: Option<(Instant, usize, usize)> = None;
    for (m, dl) in deadlines.iter().enumerate() {
        if let Some((deadline, b)) = *dl {
            if now >= deadline
                && depths[m].get(b).copied().unwrap_or(0) > 0
                && expired.map(|(d, _, _)| deadline < d).unwrap_or(true)
            {
                expired = Some((deadline, m, b));
            }
        }
    }
    let (_, m, b) = expired?;
    Some((m, b, flush_shape(depths[m][b])))
}

/// Assemble a claimed single-bucket batch for dispatch: concatenate the
/// requests' token ids row-major at the bucket's `bucket_seq` width
/// (each row's tail past its native length is token 0, masked out by
/// the runtime's length-aware attention), fill the batch tail with
/// pure-padding rows (a single masked token 0 each — their attention
/// block is 1x1, the cheapest well-formed row), and resolve the batch
/// tau conservatively (min over the batch = least pruning any member
/// asked for).  Returns `(ids, lens, tau)` with `lens[b]` the row's
/// true token count, ready for
/// [`crate::runtime::Runtime::classify_padded`].
///
/// Shared by [`BatchServer`] and the worker pool in [`super::serve`] so
/// the two engines cannot drift apart on padding or tau policy.
/// Request lengths are validated at submit; the debug asserts guard the
/// queue invariant itself.
pub(crate) fn assemble_batch(
    reqs: &[Request],
    shape: usize,
    bucket_seq: usize,
) -> (Vec<i32>, Vec<usize>, f32) {
    debug_assert!(!reqs.is_empty() && reqs.len() <= shape);
    let fill = reqs.len();
    let mut ids = Vec::with_capacity(shape * bucket_seq);
    let mut lens = Vec::with_capacity(shape);
    for r in reqs {
        debug_assert!(
            !r.ids.is_empty() && r.ids.len() <= bucket_seq,
            "request {} has {} ids outside its {bucket_seq}-bucket",
            r.id,
            r.ids.len()
        );
        lens.push(r.ids.len());
        ids.extend_from_slice(&r.ids);
        ids.resize(ids.len() + (bucket_seq - r.ids.len()), 0);
    }
    for _ in fill..shape {
        lens.push(1);
        ids.resize(ids.len() + bucket_seq, 0);
    }
    let tau = reqs.iter().map(|r| r.tau).fold(f32::INFINITY, f32::min);
    (ids, lens, tau)
}

/// The batching server.
pub struct BatchServer {
    runtime: Runtime,
    params: Vec<f32>,
    queues: BucketQueues,
    pub stats: ServerStats,
    next_id: u64,
    /// Default SLO budget stamped onto requests at submit time
    /// (`deadline = enqueued_at + max_wait`); [`BatchServer::submit_with_slo`]
    /// overrides per request.
    pub max_wait: Duration,
    /// Admission bound: submits fail with [`SubmitError::QueueFull`]
    /// once this many requests are pending.
    pub max_queue: usize,
}

impl BatchServer {
    pub fn new(runtime: Runtime, params: Vec<f32>) -> BatchServer {
        let max_seq = runtime.manifest.seq;
        BatchServer {
            runtime,
            params,
            queues: BucketQueues::new(max_seq),
            stats: ServerStats::default(),
            next_id: 0,
            max_wait: Duration::from_millis(5),
            max_queue: DEFAULT_MAX_QUEUE,
        }
    }

    /// Enqueue a request under the server's default SLO budget
    /// (`max_wait`); returns its id.
    pub fn submit(&mut self, ids: Vec<i32>, tau: f32) -> Result<u64, SubmitError> {
        let slo = self.max_wait;
        self.submit_with_slo(ids, tau, slo)
    }

    /// Enqueue a request with an explicit SLO budget: the batcher will
    /// flush an under-filled batch rather than let this request dwell
    /// past `enqueued_at + slo`.
    pub fn submit_with_slo(
        &mut self,
        ids: Vec<i32>,
        tau: f32,
        slo: Duration,
    ) -> Result<u64, SubmitError> {
        self.submit_with_priority(ids, tau, slo, Priority::Interactive)
    }

    /// Full-control enqueue: explicit SLO budget and scheduling class.
    /// Rejects (rather than panics on) a token count outside
    /// `[1, manifest.seq]` or a queue at its admission bound — the
    /// typed error keeps one bad request from poisoning a whole batch
    /// at dispatch time and gives the caller a backpressure signal.
    pub fn submit_with_priority(
        &mut self,
        ids: Vec<i32>,
        tau: f32,
        slo: Duration,
        priority: Priority,
    ) -> Result<u64, SubmitError> {
        let max_seq = self.runtime.manifest.seq;
        if ids.is_empty() || ids.len() > max_seq {
            return Err(SubmitError::BadLength { got: ids.len(), max_seq });
        }
        let pending = self.queues.len();
        if pending >= self.max_queue {
            return Err(SubmitError::QueueFull { pending, bound: self.max_queue });
        }
        let id = self.next_id;
        self.next_id += 1;
        let enqueued_at = Instant::now();
        self.queues.push(Request {
            id,
            ids,
            tau,
            enqueued_at,
            deadline: enqueued_at + slo,
            priority,
            reply: None,
        });
        self.stats.queue_depth_high_water =
            self.stats.queue_depth_high_water.max(self.queues.len() as u64);
        Ok(id)
    }

    pub fn pending(&self) -> usize {
        self.queues.len()
    }

    /// Serve at most one batch; returns the responses (empty if the
    /// batcher decided to keep waiting).
    pub fn step(&mut self) -> Result<Vec<Response>> {
        self.step_inner(false)
    }

    fn step_inner(&mut self, force: bool) -> Result<Vec<Response>> {
        let Some((bucket, shape)) = dispatch_shape(
            &self.queues.depths(),
            self.queues.nearest_deadline(),
            Instant::now(),
            force,
        ) else {
            return Ok(Vec::new());
        };
        let reqs = self.queues.claim(bucket, shape);
        let fill = reqs.len();
        let bucket_seq = self.queues.seqs()[bucket];
        let true_tokens: usize = reqs.iter().map(|r| r.ids.len()).sum();
        let (ids, lens, tau) = assemble_batch(&reqs, shape, bucket_seq);
        let t0 = Instant::now();
        let logits = self
            .runtime
            .classify_padded(shape, bucket_seq, &lens, &self.params, &ids, tau)?;
        let elapsed = t0.elapsed();
        let classes = self.runtime.manifest.classes;
        let mut out = Vec::with_capacity(fill);
        for (i, r) in reqs.into_iter().enumerate() {
            out.push(Response {
                id: r.id,
                logits: logits[i * classes..(i + 1) * classes].to_vec(),
                latency: r.enqueued_at.elapsed(),
                batch: shape,
            });
        }
        self.stats.record(elapsed, fill, shape, bucket_seq, true_tokens);
        Ok(out)
    }

    /// Drain the queues completely, flushing regardless of deadlines.
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        while self.pending() > 0 {
            out.extend(self.step_inner(true)?);
        }
        Ok(out)
    }

    pub fn runtime_mut(&mut self) -> &mut Runtime {
        &mut self.runtime
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn mk(id: u64, len: usize, tau: f32, v: i32) -> Request {
        let now = Instant::now();
        Request {
            id,
            ids: vec![v; len],
            tau,
            enqueued_at: now,
            deadline: now,
            priority: Priority::Interactive,
            reply: None,
        }
    }

    #[test]
    fn bucket_geometry_is_stride_8_capped_at_max_seq() {
        assert_eq!(seq_buckets(64), vec![8, 16, 24, 32, 40, 48, 56, 64]);
        assert_eq!(seq_buckets(16), vec![8, 16]);
        assert_eq!(seq_buckets(12), vec![8, 12]);
        assert_eq!(seq_buckets(8), vec![8]);
        assert_eq!(seq_buckets(4), vec![4]);
        let q = BucketQueues::new(64);
        assert_eq!(q.bucket_for(1), Some(0));
        assert_eq!(q.bucket_for(8), Some(0));
        assert_eq!(q.bucket_for(9), Some(1));
        assert_eq!(q.bucket_for(64), Some(7));
        assert_eq!(q.bucket_for(65), None);
    }

    // The policy is pure; drive `dispatch_shape` directly with a
    // synthetic clock.  `waited` arms an already-expired deadline in
    // bucket 0.
    fn choose(n: usize, waited: bool) -> Option<(usize, usize)> {
        let now = Instant::now();
        let deadline = if waited {
            now.checked_sub(Duration::from_millis(1)).unwrap_or(now)
        } else {
            now + Duration::from_secs(60)
        };
        dispatch_shape(&[n], (n > 0).then_some((deadline, 0)), now, false)
    }

    #[test]
    fn full_batches_dispatch_immediately() {
        assert_eq!(choose(32, false), Some((0, 32)));
        assert_eq!(choose(40, false), Some((0, 32)));
        // the deepest full bucket wins; ties go to the shortest seq
        let now = Instant::now();
        assert_eq!(dispatch_shape(&[5, 33, 40], None, now, false), Some((2, 32)));
        assert_eq!(dispatch_shape(&[33, 33], None, now, false), Some((0, 32)));
    }

    #[test]
    fn partial_batches_wait_then_flush() {
        // partial batches accumulate toward the big shape...
        assert_eq!(choose(8, false), None);
        assert_eq!(choose(5, false), None);
        assert_eq!(choose(1, false), None);
        // ...and flush preferring completely-filled shapes: an 11-deep
        // bucket dispatches 8 full rows (the 3-tail goes next round), a
        // sub-8 bucket pads up to the smallest covering shape.
        assert_eq!(choose(5, true), Some((0, 8)));
        assert_eq!(choose(8, true), Some((0, 8)));
        assert_eq!(choose(9, true), Some((0, 8)));
        assert_eq!(choose(11, true), Some((0, 8)));
        assert_eq!(choose(31, true), Some((0, 8)));
        assert_eq!(choose(1, true), Some((0, 1)));
        assert_eq!(choose(0, true), None);
    }

    #[test]
    fn force_flushes_the_deepest_bucket() {
        // drain-time semantics: dispatch whatever is queued regardless
        // of how recently it arrived, deepest bucket first
        let now = Instant::now();
        let far = now + Duration::from_secs(60);
        assert_eq!(dispatch_shape(&[5], Some((far, 0)), now, true), Some((0, 8)));
        assert_eq!(dispatch_shape(&[1], Some((far, 0)), now, true), Some((0, 1)));
        assert_eq!(dispatch_shape(&[2, 9, 4], None, now, true), Some((1, 8)));
        assert_eq!(dispatch_shape(&[0, 0], None, now, true), None);
    }

    #[test]
    fn deadline_at_now_flushes_the_deadlines_bucket() {
        // boundary: `now >= deadline` flushes (not strictly-greater),
        // and the flush targets the bucket that owns the deadline even
        // when another bucket is deeper
        let now = Instant::now();
        assert_eq!(dispatch_shape(&[3], Some((now, 0)), now, false), Some((0, 8)));
        assert_eq!(
            dispatch_shape(&[3, 12], Some((now, 0)), now, false),
            Some((0, 8))
        );
    }

    #[test]
    fn flush_shape_minimizes_padding() {
        // total padding across a full drain of n requests
        let drain_padding = |mut n: usize| {
            let mut padded = 0;
            while n > 0 {
                let b = flush_shape(n);
                let fill = b.min(n);
                padded += b - fill;
                n -= fill;
            }
            padded
        };
        assert_eq!(drain_padding(32), 0);
        assert_eq!(drain_padding(11), 5); // 8 full + 3-in-8 tail
        assert_eq!(drain_padding(9), 0); // 8 full + 1-in-1 tail
        assert_eq!(drain_padding(5), 3); // 5-in-8
        // the old "smallest covering shape" policy padded 11 -> 32 (21
        // wasted rows); the fill-first policy never pads more than 7.
        for n in 1..=40 {
            assert!(drain_padding(n) <= 7, "n={n}");
        }
    }

    #[test]
    fn assemble_batch_pads_within_bucket_and_takes_min_tau() {
        // mixed native lengths in a 4-bucket: rows pad to the bucket's
        // seq with masked token 0, tail rows are 1-token padding rows
        let reqs = vec![mk(0, 4, 0.05, 1), mk(1, 2, 0.02, 2), mk(2, 3, 0.08, 3)];
        let (ids, lens, tau) = assemble_batch(&reqs, 8, 4);
        assert_eq!(ids.len(), 8 * 4);
        assert_eq!(lens, vec![4, 2, 3, 1, 1, 1, 1, 1]);
        assert_eq!(&ids[..4], &[1, 1, 1, 1]);
        assert_eq!(&ids[4..8], &[2, 2, 0, 0]); // in-row tail padded with 0
        assert_eq!(&ids[8..12], &[3, 3, 3, 0]);
        assert_eq!(&ids[12..16], &[0; 4]); // pure-padding tail row
        assert_eq!(&ids[28..32], &[0; 4]);
        // conservative tau: least pruning any member asked for
        assert_eq!(tau, 0.02);
        // exact fill: no padding, same fold
        let (ids, lens, tau) = assemble_batch(&reqs[..1], 1, 4);
        assert_eq!(ids, vec![1; 4]);
        assert_eq!(lens, vec![4]);
        assert_eq!(tau, 0.05);
    }

    #[test]
    fn claim_orders_interactive_before_batch_fifo_within_class() {
        let mut q = BucketQueues::new(16);
        let mut with_pri = |id, pri| {
            let mut r = mk(id, 8, 0.0, id as i32);
            r.priority = pri;
            r
        };
        q.push(with_pri(0, Priority::Batch));
        q.push(with_pri(1, Priority::Interactive));
        q.push(with_pri(2, Priority::Batch));
        q.push(with_pri(3, Priority::Interactive));
        assert_eq!(q.depths(), vec![4, 0]);
        let claimed = q.claim(0, 3);
        let order: Vec<u64> = claimed.iter().map(|r| r.id).collect();
        assert_eq!(order, vec![1, 3, 0], "interactive first, FIFO within");
        assert_eq!(q.claim(0, 8).len(), 1);
        assert!(q.is_empty());
    }

    #[test]
    fn stats_percentiles() {
        let mut s = ServerStats::default();
        for us in [100u64, 200, 300, 400, 1000] {
            s.record(Duration::from_micros(us), 8, 8, 4, 32);
        }
        assert_eq!(s.latency_percentile(0.0), Duration::from_micros(100));
        assert_eq!(s.latency_percentile(50.0), Duration::from_micros(300));
        assert_eq!(s.latency_percentile(100.0), Duration::from_micros(1000));
        assert_eq!(s.served, 40);
        assert_eq!(s.padded_rows, 0);
        assert_eq!(s.padded_row_fraction(), 0.0);
        assert_eq!(s.padded_token_fraction(), 0.0);
    }

    #[test]
    fn stats_track_padding_rows_and_tokens() {
        let mut s = ServerStats::default();
        // full 8-batch in a 16-bucket, every row native-length
        s.record(Duration::from_micros(50), 8, 8, 16, 8 * 16);
        // 3-fill tail in an 8-bucket: rows carried 6+7+8 real tokens
        s.record(Duration::from_micros(50), 3, 8, 8, 21);
        assert_eq!(s.served, 11);
        assert_eq!(s.dispatches, 2);
        assert_eq!(s.padded_rows, 5);
        assert_eq!(s.rows_dispatched, 16);
        assert_eq!(s.tokens_dispatched, 128 + 64);
        assert_eq!(s.padded_tokens, 64 - 21);
        assert!((s.padded_row_fraction() - 5.0 / 16.0).abs() < 1e-12);
        assert!((s.padded_token_fraction() - 43.0 / 192.0).abs() < 1e-12);
    }

    #[test]
    fn stats_merge_sums_counters_and_maxes_high_water() {
        let mut a = ServerStats::default();
        a.record(Duration::from_micros(100), 8, 8, 4, 32);
        a.queue_depth_high_water = 12;
        let mut b = ServerStats::default();
        b.record(Duration::from_micros(300), 3, 8, 4, 12);
        b.record(Duration::from_micros(500), 8, 8, 4, 32);
        b.queue_depth_high_water = 7;
        a.merge(&b);
        assert_eq!(a.served, 19);
        assert_eq!(a.dispatches, 3);
        assert_eq!(a.padded_rows, 5);
        assert_eq!(a.rows_dispatched, 24);
        assert_eq!(a.tokens_dispatched, 96);
        assert_eq!(a.padded_tokens, 20);
        assert_eq!(a.queue_depth_high_water, 12);
        assert_eq!(a.latency_percentile(100.0), Duration::from_micros(500));
        assert_eq!(a.mean_latency(), Duration::from_micros(300));
    }

    #[test]
    fn submit_rejects_bad_lengths_and_full_queues_with_typed_errors() {
        let rt = Runtime::reference_for(
            &crate::model::TransformerConfig {
                name: "micro".into(),
                hidden: 8,
                layers: 1,
                heads: 2,
                ff: 16,
                vocab: 12,
                seq: 4,
            },
            2,
        )
        .unwrap();
        let params = crate::runtime::ParamStore::init(&rt.manifest, 0).params;
        let mut srv = BatchServer::new(rt, params);
        srv.max_queue = 2;
        assert_eq!(
            srv.submit(vec![], 0.0),
            Err(SubmitError::BadLength { got: 0, max_seq: 4 })
        );
        assert_eq!(
            srv.submit(vec![0; 5], 0.0),
            Err(SubmitError::BadLength { got: 5, max_seq: 4 })
        );
        // a shorter-than-seq request is now legal...
        assert!(srv.submit(vec![0, 1], 0.0).is_ok());
        assert!(srv.submit(vec![0, 1, 2, 3], 0.0).is_ok());
        // ...and the third submit hits the admission bound
        assert_eq!(
            srv.submit(vec![0], 0.0),
            Err(SubmitError::QueueFull { pending: 2, bound: 2 })
        );
        // errors render usefully through anyhow
        let e: anyhow::Error = SubmitError::QueueFull { pending: 2, bound: 2 }.into();
        assert!(e.to_string().contains("queue full"));
        // draining frees capacity and serves both accepted requests
        let served = srv.drain().unwrap();
        assert_eq!(served.len(), 2);
        assert!(srv.submit(vec![0], 0.0).is_ok());
    }

    #[test]
    fn mixed_length_drain_serves_every_request_with_low_token_padding() {
        let rt = Runtime::reference_for(
            &crate::model::TransformerConfig {
                name: "micro-serve".into(),
                hidden: 8,
                layers: 1,
                heads: 2,
                ff: 16,
                vocab: 12,
                seq: 16,
            },
            2,
        )
        .unwrap();
        let params = crate::runtime::ParamStore::init(&rt.manifest, 0).params;
        let mut srv = BatchServer::new(rt, params);
        let mut want = Vec::new();
        for i in 0..40usize {
            let len = 1 + (i % 16);
            let ids: Vec<i32> = (0..len).map(|j| ((i + j) % 12) as i32).collect();
            want.push(srv.submit(ids, 0.0).unwrap());
        }
        let got = srv.drain().unwrap();
        assert_eq!(got.len(), 40);
        let mut ids: Vec<u64> = got.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, want);
        // bucketed dispatch pads far fewer tokens than pad-to-max
        // would (which for this wave would be ~1 - mean(len)/16 = 47%)
        assert!(
            srv.stats.padded_token_fraction() < 0.45,
            "padded token fraction {}",
            srv.stats.padded_token_fraction()
        );
        assert!(srv.stats.tokens_dispatched > 0);
    }

    // For any claimed single-bucket batch, assembling at the bucket's
    // seq never pads more tokens (absolutely or fractionally) than the
    // old pad-to-max rule would for the *same* dispatch; summed over
    // any dispatch stream, the bucketed engine's padded_token_fraction
    // therefore never exceeds the pad-to-max baseline's.
    #[test]
    fn prop_bucketing_never_increases_padded_token_fraction() {
        let max_seq = 64;
        let buckets = seq_buckets(max_seq);
        prop::check(0xACC8_0001, prop::cases(128), |g| {
            let bi = g.usize_in(0, buckets.len() - 1);
            let lo = if bi == 0 { 1 } else { buckets[bi - 1] + 1 };
            let hi = buckets[bi];
            let n = g.usize_in(1, 32);
            let reqs: Vec<Request> = (0..n)
                .map(|i| mk(i as u64, g.usize_in(lo, hi), 0.0, 1))
                .collect();
            let shape = flush_shape(n);
            let claimed = &reqs[..shape.min(n)];
            let true_tokens: usize = claimed.iter().map(|r| r.ids.len()).sum();
            let (bids, blens, _) = assemble_batch(claimed, shape, buckets[bi]);
            let (mids, _, _) = assemble_batch(claimed, shape, max_seq);
            assert_eq!(bids.len(), shape * buckets[bi]);
            assert_eq!(blens.len(), shape);
            let padded_bucket = bids.len() - true_tokens;
            let padded_max = mids.len() - true_tokens;
            assert!(
                padded_bucket <= padded_max,
                "bucketed {padded_bucket} > pad-to-max {padded_max}"
            );
            let frac_bucket = padded_bucket as f64 / bids.len() as f64;
            let frac_max = padded_max as f64 / mids.len() as f64;
            assert!(
                frac_bucket <= frac_max + 1e-12,
                "bucketed fraction {frac_bucket} > pad-to-max {frac_max}"
            );
        });
    }

    // Topping-off window: while a forming batch's deadline is armed and
    // no bucket has filled, the policy must keep returning `None` —
    // late same-bucket arrivals join the queue and are claimed at the
    // dispatch instant — and at the first check at-or-after the
    // deadline it must flush that bucket with everything that
    // accumulated in the window.  Dispatch never happens early.
    #[test]
    fn prop_topping_off_never_violates_an_armed_deadline() {
        prop::check(0xACC8_0002, prop::cases(128), |g| {
            let nb = g.usize_in(1, 8);
            let bucket = g.usize_in(0, nb - 1);
            let mut depths: Vec<usize> = (0..nb).map(|_| g.usize_in(0, 7)).collect();
            if depths[bucket] == 0 {
                depths[bucket] = 1;
            }
            let base = Instant::now();
            let deadline = base + Duration::from_millis(20);
            let mut t = base;
            for _ in 0..g.usize_in(0, 6) {
                // a late same-bucket arrival strictly inside the window
                t = (t + Duration::from_micros(g.usize_in(1, 2000) as u64))
                    .min(deadline - Duration::from_nanos(1));
                if depths[bucket] < 31 {
                    depths[bucket] += 1;
                }
                assert_eq!(
                    dispatch_shape(&depths, Some((deadline, bucket)), t, false),
                    None,
                    "dispatched before the armed deadline"
                );
            }
            // the dispatch instant claims everything that arrived
            assert_eq!(
                dispatch_shape(&depths, Some((deadline, bucket)), deadline, false),
                Some((bucket, flush_shape(depths[bucket])))
            );
        });
    }

    // Multi-model drain: every dispatched batch claims from exactly one
    // model's queues (requests are tagged with their model's index as
    // the token value), every submitted request is eventually served,
    // and no claim ever exceeds the dispatched shape.
    #[test]
    fn prop_multi_model_drain_never_mixes_models() {
        prop::check(0xACC8_0003, prop::cases(64), |g| {
            let nm = g.usize_in(2, 3);
            let mut queues: Vec<BucketQueues> =
                (0..nm).map(|_| BucketQueues::new(16)).collect();
            let mut submitted = vec![0usize; nm];
            let mut next_id = 0u64;
            for m in 0..nm {
                for _ in 0..g.usize_in(1, 40) {
                    let len = g.usize_in(1, 16);
                    queues[m].push(mk(next_id, len, 0.0, m as i32));
                    next_id += 1;
                    submitted[m] += 1;
                }
            }
            let mut served = vec![0usize; nm];
            let now = Instant::now();
            loop {
                let depth_vecs: Vec<Vec<usize>> =
                    queues.iter().map(|q| q.depths()).collect();
                let depth_refs: Vec<&[usize]> =
                    depth_vecs.iter().map(|d| d.as_slice()).collect();
                let deadlines: Vec<Option<(Instant, usize)>> =
                    queues.iter().map(|q| q.nearest_deadline()).collect();
                let Some((m, b, shape)) =
                    dispatch_multi(&depth_refs, &deadlines, now, true)
                else {
                    break;
                };
                let claimed = queues[m].claim(b, shape);
                assert!(!claimed.is_empty() && claimed.len() <= shape);
                for r in &claimed {
                    assert_eq!(
                        r.ids[0], m as i32,
                        "batch for model {m} claimed a model-{} request",
                        r.ids[0]
                    );
                }
                served[m] += claimed.len();
            }
            assert_eq!(served, submitted, "drain lost or duplicated requests");
            assert!(queues.iter().all(|q| q.is_empty()));
        });
    }

    // Per-model padding: with per-model bucket queues, each model's
    // assembled batches never pad more tokens (absolutely or
    // fractionally) than padding that model's same dispatch to the
    // manifest max would — bucketing's guarantee survives sharding the
    // queues by model.
    #[test]
    fn prop_multi_model_padding_no_worse_than_pad_to_max_per_model() {
        let max_seq = 64;
        let buckets = seq_buckets(max_seq);
        prop::check(0xACC8_0004, prop::cases(64), |g| {
            for m in 0..g.usize_in(2, 3) {
                let bi = g.usize_in(0, buckets.len() - 1);
                let lo = if bi == 0 { 1 } else { buckets[bi - 1] + 1 };
                let n = g.usize_in(1, 32);
                let reqs: Vec<Request> = (0..n)
                    .map(|i| mk(i as u64, g.usize_in(lo, buckets[bi]), 0.0, m as i32))
                    .collect();
                let shape = flush_shape(n);
                let claimed = &reqs[..shape.min(n)];
                let true_tokens: usize = claimed.iter().map(|r| r.ids.len()).sum();
                let (bids, _, _) = assemble_batch(claimed, shape, buckets[bi]);
                let (mids, _, _) = assemble_batch(claimed, shape, max_seq);
                let padded_bucket = bids.len() - true_tokens;
                let padded_max = mids.len() - true_tokens;
                assert!(padded_bucket <= padded_max, "model {m}");
                assert!(
                    padded_bucket as f64 / bids.len() as f64
                        <= padded_max as f64 / mids.len() as f64 + 1e-12,
                    "model {m}"
                );
            }
        });
    }

    // Deadline isolation: when a model's armed deadline has expired and
    // no (model, bucket) anywhere fills the largest shape, the dispatch
    // goes to the model owning the *earliest* expired deadline — another
    // model's partial queues, however deep, can never delay it.  Before
    // any deadline expires the policy keeps waiting.
    #[test]
    fn prop_expired_deadline_is_isolated_from_other_models_queues() {
        prop::check(0xACC8_0005, prop::cases(128), |g| {
            let nb = g.usize_in(1, 4);
            let base = Instant::now();
            // model 0: an expired deadline in a random bucket
            let b0 = g.usize_in(0, nb - 1);
            let mut d0: Vec<usize> = (0..nb).map(|_| g.usize_in(0, 31)).collect();
            if d0[b0] == 0 {
                d0[b0] = 1;
            }
            let expired0 =
                base.checked_sub(Duration::from_millis(5)).unwrap_or(base);
            // model 1: deep-but-partial queues; its deadline is either
            // unexpired or expired strictly later than model 0's
            let d1: Vec<usize> = (0..nb).map(|_| g.usize_in(0, 31)).collect();
            let b1 = g.usize_in(0, nb - 1);
            let dl1 = if g.bool() {
                base + Duration::from_secs(60)
            } else {
                expired0 + Duration::from_millis(1)
            };
            let now = base;
            let got = dispatch_multi(
                &[&d0, &d1],
                &[Some((expired0, b0)), Some((dl1, b1))],
                now,
                false,
            );
            assert_eq!(
                got,
                Some((0, b0, flush_shape(d0[b0]))),
                "model 0's expired deadline was delayed (d1 = {d1:?})"
            );
            // before expiry nothing dispatches, however deep model 1 is
            let early = dispatch_multi(
                &[&d0, &d1],
                &[
                    Some((now + Duration::from_secs(60), b0)),
                    Some((now + Duration::from_secs(60), b1)),
                ],
                now,
                false,
            );
            assert_eq!(early, None);
        });
    }
}
