//! Request router + dynamic batcher.
//!
//! The AOT artifacts export fixed batch shapes (1, 8, 32).  The batcher
//! drains its queue into the largest shape it can fill (padding the tail
//! with copies of the last request — padded rows are computed and
//! discarded), amortizing the per-dispatch overhead exactly like the
//! serving-side dynamic batching of vLLM-style routers, scaled to this
//! repo's single-process setting.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::runtime::Runtime;

/// Exported batch shapes, largest first.
const BATCH_SHAPES: &[usize] = &[32, 8, 1];

/// One classification request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// `seq`-length token ids.
    pub ids: Vec<i32>,
    /// DynaTran threshold for this request's dynamic-inference level.
    pub tau: f32,
    pub enqueued_at: Instant,
}

/// One completed response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub latency: Duration,
    /// Batch shape the request was served in.
    pub batch: usize,
}

/// Aggregate serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub served: u64,
    pub dispatches: u64,
    pub padded_rows: u64,
    latencies_us: Vec<u64>,
}

impl ServerStats {
    pub fn record(&mut self, latency: Duration, batch_fill: usize, batch: usize) {
        self.served += batch_fill as u64;
        self.dispatches += 1;
        self.padded_rows += (batch - batch_fill) as u64;
        self.latencies_us.push(latency.as_micros() as u64);
    }

    /// Latency percentile over *dispatch* latencies, p in [0, 100].
    pub fn latency_percentile(&self, p: f64) -> Duration {
        if self.latencies_us.is_empty() {
            return Duration::ZERO;
        }
        let mut xs = self.latencies_us.clone();
        xs.sort_unstable();
        let idx = ((p / 100.0) * (xs.len() - 1) as f64).round() as usize;
        Duration::from_micros(xs[idx])
    }

    pub fn mean_latency(&self) -> Duration {
        if self.latencies_us.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_micros(
            self.latencies_us.iter().sum::<u64>() / self.latencies_us.len() as u64,
        )
    }
}

/// The batching server.
pub struct BatchServer {
    runtime: Runtime,
    params: xla::Literal,
    queue: VecDeque<Request>,
    pub stats: ServerStats,
    next_id: u64,
    /// Maximum queue dwell before a partial batch is flushed.
    pub max_wait: Duration,
}

impl BatchServer {
    pub fn new(runtime: Runtime, params: xla::Literal) -> BatchServer {
        BatchServer {
            runtime,
            params,
            queue: VecDeque::new(),
            stats: ServerStats::default(),
            next_id: 0,
            max_wait: Duration::from_millis(5),
        }
    }

    /// Enqueue a request; returns its id.
    pub fn submit(&mut self, ids: Vec<i32>, tau: f32) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Request {
            id,
            ids,
            tau,
            enqueued_at: Instant::now(),
        });
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Pick the batch shape for the current queue: dispatch the largest
    /// exported shape once it fills; otherwise keep accumulating until
    /// the oldest request has dwelled past `max_wait`, then flush with
    /// the smallest shape that covers the queue (padding the remainder).
    fn choose_shape(&self) -> Option<usize> {
        let n = self.queue.len();
        if n == 0 {
            return None;
        }
        let largest = BATCH_SHAPES[0];
        if n >= largest {
            return Some(largest);
        }
        let oldest = self.queue.front().unwrap().enqueued_at;
        if oldest.elapsed() >= self.max_wait {
            // flush: smallest shape that covers the queue
            let b = *BATCH_SHAPES
                .iter()
                .filter(|&&b| b >= n)
                .min()
                .unwrap_or(&largest);
            return Some(b);
        }
        None
    }

    /// Serve at most one batch; returns the responses (empty if the
    /// batcher decided to keep waiting).
    pub fn step(&mut self) -> Result<Vec<Response>> {
        let Some(batch) = self.choose_shape() else {
            return Ok(Vec::new());
        };
        let fill = batch.min(self.queue.len());
        let reqs: Vec<Request> = (0..fill).map(|_| self.queue.pop_front().unwrap()).collect();
        let seq = self.runtime.manifest.seq;
        let mut ids = Vec::with_capacity(batch * seq);
        for r in &reqs {
            assert_eq!(r.ids.len(), seq, "request seq mismatch");
            ids.extend_from_slice(&r.ids);
        }
        // pad with copies of the last request
        for _ in fill..batch {
            let last = &reqs[fill - 1];
            ids.extend_from_slice(&last.ids);
        }
        // per-batch tau: requests are grouped FIFO; use the max tau so no
        // request gets *more* pruning than it asked for... conservative
        // choice is min (least pruning = most accurate).
        let tau = reqs.iter().map(|r| r.tau).fold(f32::INFINITY, f32::min);
        let t0 = Instant::now();
        let logits = self.runtime.classify(batch, &self.params, &ids, tau)?;
        let elapsed = t0.elapsed();
        let classes = self.runtime.manifest.classes;
        let mut out = Vec::with_capacity(fill);
        for (i, r) in reqs.into_iter().enumerate() {
            out.push(Response {
                id: r.id,
                logits: logits[i * classes..(i + 1) * classes].to_vec(),
                latency: r.enqueued_at.elapsed(),
                batch,
            });
        }
        self.stats.record(elapsed, fill, batch);
        Ok(out)
    }

    /// Drain the queue completely.
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        // force flush regardless of dwell time
        let saved = self.max_wait;
        self.max_wait = Duration::ZERO;
        while self.pending() > 0 {
            out.extend(self.step()?);
        }
        self.max_wait = saved;
        Ok(out)
    }

    pub fn runtime_mut(&mut self) -> &mut Runtime {
        &mut self.runtime
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Shape-choice logic is pure; test it without a runtime via a probe
    // mirroring the policy exactly.
    fn choose(n: usize, waited: bool) -> Option<usize> {
        if n == 0 {
            return None;
        }
        if n >= BATCH_SHAPES[0] {
            return Some(BATCH_SHAPES[0]);
        }
        if waited {
            return Some(
                *BATCH_SHAPES
                    .iter()
                    .filter(|&&b| b >= n)
                    .min()
                    .unwrap_or(&BATCH_SHAPES[0]),
            );
        }
        None
    }

    #[test]
    fn full_batches_dispatch_immediately() {
        assert_eq!(choose(32, false), Some(32));
        assert_eq!(choose(40, false), Some(32));
    }

    #[test]
    fn partial_batches_wait_then_flush() {
        // partial batches accumulate toward the big shape...
        assert_eq!(choose(8, false), None);
        assert_eq!(choose(5, false), None);
        assert_eq!(choose(1, false), None);
        // ...and flush to the smallest covering shape after max_wait.
        assert_eq!(choose(5, true), Some(8));
        assert_eq!(choose(8, true), Some(8));
        assert_eq!(choose(9, true), Some(32));
        assert_eq!(choose(1, true), Some(1));
        assert_eq!(choose(0, true), None);
    }

    #[test]
    fn stats_percentiles() {
        let mut s = ServerStats::default();
        for us in [100u64, 200, 300, 400, 1000] {
            s.record(Duration::from_micros(us), 8, 8);
        }
        assert_eq!(s.latency_percentile(0.0), Duration::from_micros(100));
        assert_eq!(s.latency_percentile(50.0), Duration::from_micros(300));
        assert_eq!(s.latency_percentile(100.0), Duration::from_micros(1000));
        assert_eq!(s.served, 40);
        assert_eq!(s.padded_rows, 0);
    }
}
