//! Request router + dynamic batcher (the single-threaded half of the
//! serving story behind the paper's Sec. V-E throughput comparison;
//! [`super::serve`] drives the same policy from a worker pool).
//!
//! The runtime backends export fixed batch shapes (1, 8, 32 for the AOT
//! artifacts; the reference executor accepts the same shapes).  The
//! batcher drains its queue into the largest shape it can *fill*; only a
//! sub-8 tail is padded up to a covering shape (padded rows are computed
//! and discarded), amortizing the per-dispatch overhead exactly like the
//! serving-side dynamic batching of vLLM-style routers, scaled to this
//! repo's single-process setting.
//!
//! Flushing is **deadline-aware**: every request carries an SLO budget,
//! fixed at submit time as `deadline = enqueued_at + slo`.  A batch
//! dispatches the moment the largest shape fills, or as soon as the
//! nearest deadline anywhere in the queue expires — whichever comes
//! first (fill-or-deadline).  A request older than its SLO budget
//! therefore forces a flush even under-filled, which is what bounds
//! tail latency under a trickle of traffic.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::runtime::Runtime;

/// Exported batch shapes, largest first (the shapes
/// `python/compile/aot.py` AOT-lowers; the reference executor accepts
/// any batch but the batcher sticks to these so both backends see the
/// same dispatch stream).
pub(crate) const BATCH_SHAPES: &[usize] = &[32, 8, 1];

/// The largest exported batch shape (a full batch dispatches
/// immediately, no deadline consulted).
pub(crate) fn largest_shape() -> usize {
    BATCH_SHAPES[0]
}

/// One classification request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// `seq`-length token ids.
    pub ids: Vec<i32>,
    /// DynaTran threshold for this request's dynamic-inference level.
    pub tau: f32,
    pub enqueued_at: Instant,
    /// Flush-by time: `enqueued_at + slo`.  Once any queued request
    /// passes this instant the batcher dispatches even an under-filled
    /// batch.
    pub deadline: Instant,
    /// Synchronous completion channel: when set, the worker that serves
    /// this request sends the [`Response`] here instead of retaining it
    /// for the end-of-run collection — the per-request delivery path the
    /// HTTP front-end ([`crate::serve::net`]) rides, which also keeps a
    /// long-lived server from accumulating every response in memory.
    pub reply: Option<mpsc::Sender<Response>>,
}

/// One completed response.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub latency: Duration,
    /// Batch shape the request was served in.
    pub batch: usize,
}

/// Aggregate serving statistics.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub served: u64,
    pub dispatches: u64,
    pub padded_rows: u64,
    /// Total rows dispatched (served + padded) — the padded-fraction
    /// denominator.
    pub rows_dispatched: u64,
    /// Deepest the queue has ever been (updated on submit).
    pub queue_depth_high_water: u64,
    latencies_us: Vec<u64>,
}

impl ServerStats {
    pub fn record(&mut self, latency: Duration, batch_fill: usize, batch: usize) {
        self.served += batch_fill as u64;
        self.dispatches += 1;
        self.padded_rows += (batch - batch_fill) as u64;
        self.rows_dispatched += batch as u64;
        self.latencies_us.push(latency.as_micros() as u64);
    }

    /// Fold another worker's counters into this one (high-water takes
    /// the max — the worker-pool merge in [`super::serve`]).
    pub fn merge(&mut self, other: &ServerStats) {
        self.served += other.served;
        self.dispatches += other.dispatches;
        self.padded_rows += other.padded_rows;
        self.rows_dispatched += other.rows_dispatched;
        self.queue_depth_high_water =
            self.queue_depth_high_water.max(other.queue_depth_high_water);
        self.latencies_us.extend_from_slice(&other.latencies_us);
    }

    /// Fraction of dispatched rows that were padding (wasted compute);
    /// 0.0 before the first dispatch.
    pub fn padded_row_fraction(&self) -> f64 {
        if self.rows_dispatched == 0 {
            return 0.0;
        }
        self.padded_rows as f64 / self.rows_dispatched as f64
    }

    /// Latency percentile over *dispatch* latencies, p in `0..=100`.
    pub fn latency_percentile(&self, p: f64) -> Duration {
        if self.latencies_us.is_empty() {
            return Duration::ZERO;
        }
        let mut xs = self.latencies_us.clone();
        xs.sort_unstable();
        let idx = ((p / 100.0) * (xs.len() - 1) as f64).round() as usize;
        Duration::from_micros(xs[idx])
    }

    pub fn mean_latency(&self) -> Duration {
        if self.latencies_us.is_empty() {
            return Duration::ZERO;
        }
        Duration::from_micros(
            self.latencies_us.iter().sum::<u64>() / self.latencies_us.len() as u64,
        )
    }
}

/// Flush-time shape choice for a queue of depth `n` (see
/// [`BatchServer::choose_shape`]): the largest shape that fills
/// completely when that avoids padding waste, otherwise the smallest
/// covering shape for the sub-8 tail.
pub(crate) fn flush_shape(n: usize) -> usize {
    let full = BATCH_SHAPES.iter().copied().filter(|&b| b <= n).max().unwrap_or(1);
    if full >= 8 || full == n {
        return full;
    }
    BATCH_SHAPES
        .iter()
        .copied()
        .filter(|&b| b >= n)
        .min()
        .unwrap_or(BATCH_SHAPES[0])
}

/// The fill-or-deadline dispatch policy, pure so both the
/// single-threaded [`BatchServer`] and the worker pool in
/// [`super::serve`] share it (and so it unit-tests without a clock):
/// dispatch the largest exported shape the moment it fills; otherwise
/// dispatch only once the *nearest* deadline anywhere in the queue has
/// passed (or the queue is force-drained), preferring
/// completely-filled shapes and padding only the final sub-8 tail.
///
/// `nearest_deadline` must be the minimum over the whole queue, not the
/// head's: batching is FIFO, so when a tight-SLO request sits behind a
/// lax one, flushing dispatches the head requests — and the urgent
/// request rides along (or becomes the head of an immediately
/// flushable remainder).
pub(crate) fn dispatch_shape(
    n: usize,
    nearest_deadline: Option<Instant>,
    now: Instant,
    force: bool,
) -> Option<usize> {
    if n == 0 {
        return None;
    }
    if n >= largest_shape() {
        return Some(largest_shape());
    }
    if force || nearest_deadline.map(|d| now >= d).unwrap_or(false) {
        return Some(flush_shape(n));
    }
    None
}

/// Minimum deadline over a request queue (linear scan; queue depths
/// here are at most a few hundred, and uniform-SLO traffic keeps
/// deadlines near-sorted anyway).
pub(crate) fn nearest_deadline(queue: &VecDeque<Request>) -> Option<Instant> {
    queue.iter().map(|r| r.deadline).min()
}

/// Assemble a claimed batch for dispatch: concatenate the requests'
/// token ids row-major, pad the tail with copies of the last request
/// (computed and discarded), and resolve the batch tau conservatively
/// (min over the batch = least pruning any member asked for).  Shared
/// by [`BatchServer`] and the worker pool in [`super::serve`] so the
/// two engines cannot drift apart on padding or tau policy.  Request
/// lengths are validated at submit; the debug assert guards the queue
/// invariant itself.
pub(crate) fn assemble_batch(reqs: &[Request], shape: usize, seq: usize) -> (Vec<i32>, f32) {
    debug_assert!(!reqs.is_empty() && reqs.len() <= shape);
    let fill = reqs.len();
    let mut ids = Vec::with_capacity(shape * seq);
    for r in reqs {
        debug_assert_eq!(r.ids.len(), seq, "request {} seq mismatch", r.id);
        ids.extend_from_slice(&r.ids);
    }
    for _ in fill..shape {
        ids.extend_from_slice(&reqs[fill - 1].ids);
    }
    let tau = reqs.iter().map(|r| r.tau).fold(f32::INFINITY, f32::min);
    (ids, tau)
}

/// The batching server.
pub struct BatchServer {
    runtime: Runtime,
    params: Vec<f32>,
    queue: VecDeque<Request>,
    pub stats: ServerStats,
    next_id: u64,
    /// Default SLO budget stamped onto requests at submit time
    /// (`deadline = enqueued_at + max_wait`); [`BatchServer::submit_with_slo`]
    /// overrides per request.
    pub max_wait: Duration,
}

impl BatchServer {
    pub fn new(runtime: Runtime, params: Vec<f32>) -> BatchServer {
        BatchServer {
            runtime,
            params,
            queue: VecDeque::new(),
            stats: ServerStats::default(),
            next_id: 0,
            max_wait: Duration::from_millis(5),
        }
    }

    /// Enqueue a request under the server's default SLO budget
    /// (`max_wait`); returns its id.
    pub fn submit(&mut self, ids: Vec<i32>, tau: f32) -> u64 {
        let slo = self.max_wait;
        self.submit_with_slo(ids, tau, slo)
    }

    /// Enqueue a request with an explicit SLO budget: the batcher will
    /// flush an under-filled batch rather than let this request dwell
    /// past `enqueued_at + slo`.
    ///
    /// Panics when `ids.len()` disagrees with the runtime's `seq` —
    /// rejecting the bad request here keeps it from poisoning a whole
    /// batch at dispatch time.
    pub fn submit_with_slo(&mut self, ids: Vec<i32>, tau: f32, slo: Duration) -> u64 {
        let seq = self.runtime.manifest.seq;
        assert_eq!(
            ids.len(),
            seq,
            "request has {} ids, runtime expects seq={seq}",
            ids.len()
        );
        let id = self.next_id;
        self.next_id += 1;
        let enqueued_at = Instant::now();
        self.queue.push_back(Request {
            id,
            ids,
            tau,
            enqueued_at,
            deadline: enqueued_at + slo,
            reply: None,
        });
        self.stats.queue_depth_high_water =
            self.stats.queue_depth_high_water.max(self.queue.len() as u64);
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Pick the batch shape for the current queue via the shared
    /// fill-or-deadline policy ([`dispatch_shape`]).
    fn choose_shape(&self, force: bool) -> Option<usize> {
        dispatch_shape(
            self.queue.len(),
            nearest_deadline(&self.queue),
            Instant::now(),
            force,
        )
    }

    /// Serve at most one batch; returns the responses (empty if the
    /// batcher decided to keep waiting).
    pub fn step(&mut self) -> Result<Vec<Response>> {
        self.step_inner(false)
    }

    fn step_inner(&mut self, force: bool) -> Result<Vec<Response>> {
        let Some(batch) = self.choose_shape(force) else {
            return Ok(Vec::new());
        };
        let fill = batch.min(self.queue.len());
        let reqs: Vec<Request> = (0..fill).map(|_| self.queue.pop_front().unwrap()).collect();
        let seq = self.runtime.manifest.seq;
        let (ids, tau) = assemble_batch(&reqs, batch, seq);
        let t0 = Instant::now();
        let logits = self.runtime.classify(batch, &self.params, &ids, tau)?;
        let elapsed = t0.elapsed();
        let classes = self.runtime.manifest.classes;
        let mut out = Vec::with_capacity(fill);
        for (i, r) in reqs.into_iter().enumerate() {
            out.push(Response {
                id: r.id,
                logits: logits[i * classes..(i + 1) * classes].to_vec(),
                latency: r.enqueued_at.elapsed(),
                batch,
            });
        }
        self.stats.record(elapsed, fill, batch);
        Ok(out)
    }

    /// Drain the queue completely, flushing regardless of deadlines.
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        while self.pending() > 0 {
            out.extend(self.step_inner(true)?);
        }
        Ok(out)
    }

    pub fn runtime_mut(&mut self) -> &mut Runtime {
        &mut self.runtime
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Shape-choice logic is pure; drive `dispatch_shape` directly with a
    // synthetic clock.
    fn choose(n: usize, waited: bool) -> Option<usize> {
        let now = Instant::now();
        let deadline = if waited {
            // oldest request's deadline already passed
            now.checked_sub(Duration::from_millis(1)).unwrap_or(now)
        } else {
            now + Duration::from_secs(60)
        };
        dispatch_shape(n, (n > 0).then_some(deadline), now, false)
    }

    #[test]
    fn full_batches_dispatch_immediately() {
        assert_eq!(choose(32, false), Some(32));
        assert_eq!(choose(40, false), Some(32));
    }

    #[test]
    fn partial_batches_wait_then_flush() {
        // partial batches accumulate toward the big shape...
        assert_eq!(choose(8, false), None);
        assert_eq!(choose(5, false), None);
        assert_eq!(choose(1, false), None);
        // ...and flush preferring completely-filled shapes: an 11-deep
        // queue dispatches 8 full rows (the 3-tail goes next round), a
        // sub-8 queue pads up to the smallest covering shape.
        assert_eq!(choose(5, true), Some(8));
        assert_eq!(choose(8, true), Some(8));
        assert_eq!(choose(9, true), Some(8));
        assert_eq!(choose(11, true), Some(8));
        assert_eq!(choose(31, true), Some(8));
        assert_eq!(choose(1, true), Some(1));
        assert_eq!(choose(0, true), None);
    }

    #[test]
    fn force_flushes_without_a_deadline() {
        // drain-time semantics: dispatch whatever is queued regardless
        // of how recently it arrived
        let now = Instant::now();
        let far = now + Duration::from_secs(60);
        assert_eq!(dispatch_shape(5, Some(far), now, true), Some(8));
        assert_eq!(dispatch_shape(1, Some(far), now, true), Some(1));
        assert_eq!(dispatch_shape(0, None, now, true), None);
    }

    #[test]
    fn deadline_at_now_flushes() {
        // boundary: `now >= deadline` flushes (not strictly-greater)
        let now = Instant::now();
        assert_eq!(dispatch_shape(3, Some(now), now, false), Some(8));
    }

    #[test]
    fn flush_shape_minimizes_padding() {
        // total padding across a full drain of n requests
        let drain_padding = |mut n: usize| {
            let mut padded = 0;
            while n > 0 {
                let b = flush_shape(n);
                let fill = b.min(n);
                padded += b - fill;
                n -= fill;
            }
            padded
        };
        assert_eq!(drain_padding(32), 0);
        assert_eq!(drain_padding(11), 5); // 8 full + 3-in-8 tail
        assert_eq!(drain_padding(9), 0); // 8 full + 1-in-1 tail
        assert_eq!(drain_padding(5), 3); // 5-in-8
        // the old "smallest covering shape" policy padded 11 -> 32 (21
        // wasted rows); the fill-first policy never pads more than 7.
        for n in 1..=40 {
            assert!(drain_padding(n) <= 7, "n={n}");
        }
    }

    #[test]
    fn assemble_batch_pads_with_last_and_takes_min_tau() {
        let now = Instant::now();
        let mk = |id: u64, tau: f32, v: i32| Request {
            id,
            ids: vec![v; 4],
            tau,
            enqueued_at: now,
            deadline: now,
            reply: None,
        };
        let reqs = vec![mk(0, 0.05, 1), mk(1, 0.02, 2), mk(2, 0.08, 3)];
        let (ids, tau) = assemble_batch(&reqs, 8, 4);
        assert_eq!(ids.len(), 8 * 4);
        assert_eq!(&ids[..4], &[1; 4]);
        assert_eq!(&ids[4..8], &[2; 4]);
        // padded tail rows replicate the last real request
        assert_eq!(&ids[8..12], &[3; 4]);
        assert_eq!(&ids[28..32], &[3; 4]);
        // conservative tau: least pruning any member asked for
        assert_eq!(tau, 0.02);
        // exact fill: no padding, same fold
        let (ids, tau) = assemble_batch(&reqs[..1], 1, 4);
        assert_eq!(ids, vec![1; 4]);
        assert_eq!(tau, 0.05);
    }

    #[test]
    fn stats_percentiles() {
        let mut s = ServerStats::default();
        for us in [100u64, 200, 300, 400, 1000] {
            s.record(Duration::from_micros(us), 8, 8);
        }
        assert_eq!(s.latency_percentile(0.0), Duration::from_micros(100));
        assert_eq!(s.latency_percentile(50.0), Duration::from_micros(300));
        assert_eq!(s.latency_percentile(100.0), Duration::from_micros(1000));
        assert_eq!(s.served, 40);
        assert_eq!(s.padded_rows, 0);
        assert_eq!(s.padded_row_fraction(), 0.0);
    }

    #[test]
    fn stats_track_padding_and_rows() {
        let mut s = ServerStats::default();
        s.record(Duration::from_micros(50), 8, 8); // full
        s.record(Duration::from_micros(50), 3, 8); // tail: 5 padded
        assert_eq!(s.served, 11);
        assert_eq!(s.dispatches, 2);
        assert_eq!(s.padded_rows, 5);
        assert_eq!(s.rows_dispatched, 16);
        assert!((s.padded_row_fraction() - 5.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn stats_merge_sums_counters_and_maxes_high_water() {
        let mut a = ServerStats::default();
        a.record(Duration::from_micros(100), 8, 8);
        a.queue_depth_high_water = 12;
        let mut b = ServerStats::default();
        b.record(Duration::from_micros(300), 3, 8);
        b.record(Duration::from_micros(500), 8, 8);
        b.queue_depth_high_water = 7;
        a.merge(&b);
        assert_eq!(a.served, 19);
        assert_eq!(a.dispatches, 3);
        assert_eq!(a.padded_rows, 5);
        assert_eq!(a.rows_dispatched, 24);
        assert_eq!(a.queue_depth_high_water, 12);
        assert_eq!(a.latency_percentile(100.0), Duration::from_micros(500));
        assert_eq!(a.mean_latency(), Duration::from_micros(300));
    }
}
