//! Concurrent serving engine: a pool of N [`crate::runtime::ExecBackend`]
//! workers drained from one shared request queue with deadline-aware
//! dynamic batching — the AccelTran-Server half of the paper's serving
//! story (Sec. V-E compares against Energon on *sustained request
//! throughput*, not single-batch latency, so keeping every backend
//! instance fed matters as much as per-op sparsity).
//!
//! Pipeline, front to back:
//!
//! 1. **Queue** — [`ServePool::submit`] validates the request's native
//!    token count (`1..=manifest.seq`), stamps it with its arrival time
//!    and an SLO budget (`deadline = arrival + slo`; laxer `batch_slo`
//!    for [`Priority::Batch`] traffic) and pushes it onto the
//!    mutex-guarded per-length-bucket queues shared by all workers
//!    ([`super::batcher::BucketQueues`]).  Admission is bounded:
//!    past `max_queue` pending requests, submits fail fast with
//!    [`SubmitError::QueueFull`] — the backpressure signal the HTTP
//!    front-end turns into 429 + `Retry-After`.
//! 2. **Batcher** — each worker claims work via the same
//!    length-bucketed fill-or-deadline policy as the single-threaded
//!    [`super::batcher::BatchServer`] (dispatch the largest exported
//!    shape the moment any bucket fills it; flush the nearest queued
//!    deadline's bucket the moment that deadline expires, preferring
//!    completely filled shapes and padding rows only up to the
//!    bucket's seq).  Until the dispatch instant a deadline-armed
//!    bucket keeps accepting late arrivals that ride the flush
//!    (topping-off), and within a bucket interactive requests are
//!    claimed ahead of batch-class ones.
//! 3. **Worker pool** — every worker owns a forked runtime
//!    ([`crate::runtime::Runtime::fork`]); the read-only checkpoint is
//!    shared behind one `Arc`, so `classify` calls never contend and
//!    batches from different workers execute genuinely in parallel.
//! 4. **Histograms** — per-request queue / compute / end-to-end
//!    latencies stream into fixed-size log-linear [`LatencyHistogram`]s
//!    folded into one shared live accumulator per dispatched batch, so a
//!    running pool can be observed mid-flight ([`ServePool::snapshot`],
//!    the HTTP `/stats` data source) and [`ServePool::finish`] merely
//!    freezes the totals into the final [`ServeReport`].
//!
//! Responses are retained for the end-of-run collection by default;
//! requests submitted with a completion channel
//! ([`ServePool::submit_with_reply`]) are instead delivered per request
//! the moment their batch completes — the synchronous path the network
//! front-end ([`crate::serve::net`]) rides.
//!
//! **Sim-in-the-loop** ([`SimInLoop`]): each dispatched batch shape is
//! additionally costed by the cycle-accurate engine
//! ([`crate::sim::simulate_with`]) under a measured per-op sparsity
//! trace (or the uniform fallback), so the report carries both the
//! host-measured latency and the modeled-accelerator latency
//! (measured queueing + simulated compute) side by side — the serving
//! analogue of the trace-driven Figs. 17-20 pipeline.  Shapes repeat, so
//! the simulation runs once per distinct batch shape and is cached.
//!
//! **Multi-model serving** ([`ServePool::start_multi`]): the pool can
//! host several named `(checkpoint, task)` runtimes at once
//! ([`ModelEntry`]).  Each model keeps its *own* length-bucketed
//! queues, so a dispatched batch is always claimed from exactly one
//! model's one bucket — a batch never mixes checkpoints — while the
//! worker threads stay shared: any worker serves whichever model the
//! dispatch policy ([`super::batcher`]'s `dispatch_multi`) picks next.
//! Only full batches preempt deadlines, and expired deadlines are
//! served earliest-first across models, so one model's half-filled
//! queues can never delay another model's armed SLO.  Accounting,
//! sim-in-the-loop costing and the `/stats` snapshot all stay per
//! model ([`ModelSnapshot`], [`ModelReport`]).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::coordinator::batcher::{
    assemble_batch, dispatch_multi, BucketQueues, Priority, Request, Response,
    ServerStats, SubmitError, DEFAULT_MAX_QUEUE,
};
use crate::model::TransformerConfig;
use crate::runtime::Runtime;
use crate::sim::scheduler::Policy;
use crate::sim::{simulate_with, AcceleratorConfig, SparsitySource};
use crate::util::json::Json;

// ---------------------------------------------------------------------------
// Latency histogram
// ---------------------------------------------------------------------------

/// Buckets 0..8 are exact (1 µs wide); above that, log-linear groups of
/// 8 sub-buckets per power of two (HdrHistogram's layout at 3
/// significant bits), covering the full `u64` µs range.
const LINEAR_BUCKETS: u64 = 8;
const HIST_BUCKETS: usize = 8 + 61 * 8;

/// Streaming latency histogram: O(1) allocation-free `record`, merges
/// across workers, and quantiles within 12.5% relative error (1 µs
/// exact below 8 µs).
///
/// ```
/// use acceltran::coordinator::LatencyHistogram;
/// let mut h = LatencyHistogram::new();
/// for us in [100u64, 200, 400] {
///     h.record_us(us);
/// }
/// assert_eq!(h.count(), 3);
/// let p50 = h.percentile_us(50.0);
/// assert!((100..=220).contains(&p50));
/// ```
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    buckets: [u64; HIST_BUCKETS],
    count: u64,
    sum_us: u64,
    min_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: [0u64; HIST_BUCKETS],
            count: 0,
            sum_us: 0,
            min_us: u64::MAX,
            max_us: 0,
        }
    }
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram::default()
    }

    fn bucket_index(us: u64) -> usize {
        if us < LINEAR_BUCKETS {
            return us as usize;
        }
        let group = 63 - us.leading_zeros() as usize; // >= 3
        let sub = ((us >> (group - 3)) & 7) as usize;
        8 + (group - 3) * 8 + sub
    }

    /// Representative value (µs) of a bucket: its geometric middle
    /// (exact for the linear and first log-linear groups).
    fn bucket_value(idx: usize) -> u64 {
        if idx < LINEAR_BUCKETS as usize {
            return idx as u64;
        }
        let group = (idx - 8) / 8 + 3;
        let sub = ((idx - 8) % 8) as u64;
        let width = 1u64 << (group - 3);
        (8 + sub) * width + width / 2
    }

    /// Record one latency in microseconds.  O(1), no allocation.
    pub fn record_us(&mut self, us: u64) {
        self.buckets[Self::bucket_index(us)] += 1;
        self.count += 1;
        self.sum_us = self.sum_us.saturating_add(us);
        self.min_us = self.min_us.min(us);
        self.max_us = self.max_us.max(us);
    }

    /// Record one latency as a [`Duration`].
    pub fn record(&mut self, d: Duration) {
        self.record_us(d.as_micros() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact mean in µs (0 when empty).
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_us as f64 / self.count as f64
    }

    /// Exact maximum in µs (0 when empty).
    pub fn max_us(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.max_us
        }
    }

    /// Exact minimum in µs (0 when empty).
    pub fn min_us(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min_us
        }
    }

    /// Nearest-rank percentile (`p` in `0..=100`) in µs, clamped to the
    /// exact observed min/max so p0/p100 are exact.
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let rank = rank.min(self.count);
        let mut seen = 0u64;
        for (idx, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return Self::bucket_value(idx).clamp(self.min_us, self.max_us);
            }
        }
        self.max_us
    }

    /// Fold another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us = self.sum_us.saturating_add(other.sum_us);
        self.min_us = self.min_us.min(other.min_us);
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Summary object for reports: count, mean, p50/p95/p99, min/max.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("mean_us", Json::num(self.mean_us())),
            ("p50_us", Json::num(self.percentile_us(50.0) as f64)),
            ("p95_us", Json::num(self.percentile_us(95.0) as f64)),
            ("p99_us", Json::num(self.percentile_us(99.0) as f64)),
            ("min_us", Json::num(self.min_us() as f64)),
            ("max_us", Json::num(self.max_us() as f64)),
        ])
    }
}

// ---------------------------------------------------------------------------
// Sim-in-the-loop
// ---------------------------------------------------------------------------

/// Cycle-accurate costing of each dispatched batch: the design point,
/// model and sparsity source handed to [`crate::sim::simulate_with`]
/// once per distinct batch shape.
#[derive(Clone, Debug)]
pub struct SimInLoop {
    /// Accelerator design point (its `batch` field is overridden by the
    /// dispatched shape).
    pub accel: AcceleratorConfig,
    /// Model to simulate (the architecture being served).
    pub model: TransformerConfig,
    /// Simulated sequence length for *full-length* dispatches (batches
    /// in the manifest-seq bucket); shorter buckets are simulated at
    /// their own seq.
    pub seq: usize,
    /// Per-op sparsity operating points — pass
    /// [`SparsitySource::Trace`] to cost batches under a measured
    /// capture (the PR-4 trace pipeline), or `Uniform` for a
    /// hypothetical point.
    pub source: SparsitySource,
}

/// Modeled cost of one `(seq, batch)` dispatch shape (one
/// cycle-accurate run).
#[derive(Clone, Copy, Debug)]
pub struct ShapeModel {
    pub seq: usize,
    pub batch: usize,
    pub total_cycles: u64,
    pub latency_us: f64,
    pub throughput_seq_s: f64,
    pub energy_mj_per_seq: f64,
}

/// `(seq, batch)`-keyed memoization of [`SimInLoop`] runs: the
/// simulation is deterministic in the dispatch shape, so each distinct
/// shape is costed exactly once — [`ServePool::start`] pre-warms every
/// batch shape at the full-length bucket (the only one a uniform
/// full-length workload ever dispatches) before the first worker
/// spawns; shorter-bucket shapes on a mixed-length workload are
/// simulated on first miss (pre-warming the full bucket-x-shape cross
/// product would multiply pool-start cost by the bucket count for
/// points a given workload may never dispatch).
struct SimCache {
    spec: SimInLoop,
    shapes: Mutex<HashMap<(usize, usize), ShapeModel>>,
}

impl SimCache {
    /// Simulated seq for a dispatch at `bucket_seq`: the spec's
    /// (possibly overridden) seq for the full-length bucket, the
    /// bucket's own seq otherwise.
    fn sim_seq(&self, bucket_seq: usize, max_seq: usize) -> usize {
        if bucket_seq == max_seq {
            self.spec.seq
        } else {
            bucket_seq
        }
    }

    fn model_for(&self, seq: usize, shape: usize) -> ShapeModel {
        if let Some(m) = self.shapes.lock().unwrap().get(&(seq, shape)) {
            return *m;
        }
        // simulate outside the lock: a concurrent duplicate run returns
        // the identical (deterministic) result
        let mut accel = self.spec.accel.clone();
        accel.batch = shape;
        let r = simulate_with(
            &accel,
            &self.spec.model,
            seq,
            Policy::Staggered,
            &self.spec.source,
        );
        let m = ShapeModel {
            seq,
            batch: shape,
            total_cycles: r.total_cycles,
            latency_us: r.latency_s(&accel) * 1e6,
            throughput_seq_s: r.throughput_seq_s(&accel),
            energy_mj_per_seq: r.energy_mj_per_seq(),
        };
        self.shapes.lock().unwrap().entry((seq, shape)).or_insert(m);
        m
    }

    fn describe(&self) -> String {
        format!(
            "{} x {} @ seq={} ({})",
            self.spec.accel.name,
            self.spec.model.name,
            self.spec.seq,
            self.spec.source.name()
        )
    }
}

// ---------------------------------------------------------------------------
// The worker pool
// ---------------------------------------------------------------------------

/// Which task a registered model serves — selects the backend entry
/// point a dispatched batch executes
/// ([`Runtime::classify_padded`] vs [`Runtime::span_logits_padded`])
/// and the response logit layout.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// Sequence classification: responses carry `classes` logits.
    Classify,
    /// Extractive span: a length-`l` request's response carries `2 * l`
    /// logits — its native-length start logits, then its end logits.
    Span,
}

impl TaskKind {
    pub fn name(self) -> &'static str {
        match self {
            TaskKind::Classify => "classify",
            TaskKind::Span => "span",
        }
    }

    pub fn parse(s: &str) -> Option<TaskKind> {
        match s {
            "classify" => Some(TaskKind::Classify),
            "span" => Some(TaskKind::Span),
            _ => None,
        }
    }
}

/// One model registered with [`ServePool::start_multi`]: a named
/// `(checkpoint, task)` pair served from its own length-bucketed queues
/// by the shared worker threads.
pub struct ModelEntry {
    /// Routing key (unique within a pool; the HTTP front-end resolves
    /// request model names against it).
    pub name: String,
    pub task: TaskKind,
    /// Prototype runtime; each worker forks its own sibling.
    pub runtime: Runtime,
    /// The model's checkpoint (read-only, shared across workers behind
    /// one `Arc`).
    pub params: Vec<f32>,
    /// Optional per-model sim-in-the-loop costing.
    pub sim: Option<SimInLoop>,
}

/// Static description of a registered model ([`ServePool::models`]).
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub task: TaskKind,
    /// Maximum token count a request for this model may carry.
    pub seq: usize,
    pub vocab: usize,
    pub classes: usize,
}

/// Serving-engine knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Worker threads, each with its own forked backend.
    pub workers: usize,
    /// Default per-request SLO budget for interactive traffic: an
    /// under-filled batch flushes as soon as its most urgent queued
    /// deadline expires.
    pub slo: Duration,
    /// SLO budget stamped onto [`Priority::Batch`] submissions — laxer
    /// than `slo`, so throughput traffic waits longer for a full batch
    /// and never drags an interactive flush forward.
    pub batch_slo: Duration,
    /// Admission bound: submits fail with [`SubmitError::QueueFull`]
    /// once this many requests are pending (backpressure; the HTTP
    /// front-end maps it to 429 + `Retry-After`).
    pub max_queue: usize,
    /// Cost each dispatched batch on the cycle-accurate engine too.
    pub sim: Option<SimInLoop>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .clamp(1, 4),
            slo: Duration::from_millis(25),
            batch_slo: Duration::from_millis(100),
            max_queue: DEFAULT_MAX_QUEUE,
            sim: None,
        }
    }
}

/// Idle re-check interval for workers parked on an empty queue (submits
/// wake them immediately; this only bounds staleness after a missed
/// wakeup).
const HOUSEKEEPING: Duration = Duration::from_millis(20);

struct QueueState {
    /// One set of length buckets per registered model (index-aligned
    /// with [`ServePool::models`]); a claim always drains exactly one
    /// model's one bucket.
    queues: Vec<BucketQueues>,
    closed: bool,
    /// High-water mark of the *total* pending count (the shared
    /// admission bound's view).
    high_water_total: u64,
    /// Per-model pending high-water marks.
    high_water: Vec<u64>,
}

impl QueueState {
    fn pending(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }
}

/// Accounting every worker folds into after each dispatched batch (one
/// short lock per *batch*, not per request), so a live observer — the
/// HTTP `/stats` endpoint via [`ServePool::snapshot`] — sees current
/// numbers without waiting for [`ServePool::finish`].
#[derive(Default)]
struct LiveAccounting {
    stats: ServerStats,
    queue_h: LatencyHistogram,
    compute_h: LatencyHistogram,
    total_h: LatencyHistogram,
    modeled_h: LatencyHistogram,
    deadline_misses: u64,
}

struct Shared {
    state: Mutex<QueueState>,
    work: Condvar,
    completed: AtomicU64,
    /// One accounting slot per model (index-aligned with the queues).
    live: Mutex<Vec<LiveAccounting>>,
}

/// The concurrent serving engine: start it over a prototype runtime,
/// submit requests from any thread, then [`ServePool::finish`] to close
/// the queue, drain, and collect the merged [`ServeReport`].
pub struct ServePool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<Result<Vec<Response>>>>,
    next_id: AtomicU64,
    slo: Duration,
    batch_slo: Duration,
    max_queue: usize,
    /// Registered models, in registration order (queue/accounting
    /// indices refer into this).
    models: Vec<ModelInfo>,
    started: Instant,
    backend: String,
    sims: Vec<Option<Arc<SimCache>>>,
}

impl ServePool {
    /// Spawn `cfg.workers` worker threads, each over
    /// [`Runtime::fork`]`(proto)`; the (read-only) `params` buffer is
    /// shared across workers behind one [`Arc`].  Single-model wrapper
    /// of [`ServePool::start_multi`]: the model registers under the
    /// name `"default"` with the classify task and `cfg.sim`.
    pub fn start(proto: &Runtime, params: &[f32], cfg: &ServeConfig) -> Result<ServePool> {
        let entry = ModelEntry {
            name: "default".into(),
            task: TaskKind::Classify,
            runtime: proto.fork().context("forking backend for the serve pool")?,
            params: params.to_vec(),
            sim: cfg.sim.clone(),
        };
        ServePool::start_multi(vec![entry], cfg)
    }

    /// Spawn the pool over several named `(checkpoint, task)` models.
    /// Every worker thread forks a runtime for *every* model, so any
    /// worker can serve whichever model the dispatch policy picks;
    /// each model gets its own length-bucketed queues (a dispatched
    /// batch never mixes models) and its own accounting/sim sections.
    pub fn start_multi(entries: Vec<ModelEntry>, cfg: &ServeConfig) -> Result<ServePool> {
        anyhow::ensure!(!entries.is_empty(), "serve pool needs at least one model");
        for (i, e) in entries.iter().enumerate() {
            anyhow::ensure!(
                !entries[..i].iter().any(|p| p.name == e.name),
                "duplicate serve model name '{}'",
                e.name
            );
        }
        let n_workers = cfg.workers.max(1);
        let n_models = entries.len();
        let infos: Vec<ModelInfo> = entries
            .iter()
            .map(|e| ModelInfo {
                name: e.name.clone(),
                task: e.task,
                seq: e.runtime.manifest.seq,
                vocab: e.runtime.manifest.vocab,
                classes: e.runtime.manifest.classes,
            })
            .collect();
        let backend = entries[0].runtime.backend_name().to_string();
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState {
                queues: entries
                    .iter()
                    .map(|e| BucketQueues::new(e.runtime.manifest.seq))
                    .collect(),
                closed: false,
                high_water_total: 0,
                high_water: vec![0; n_models],
            }),
            work: Condvar::new(),
            completed: AtomicU64::new(0),
            live: Mutex::new((0..n_models).map(|_| LiveAccounting::default()).collect()),
        });
        // Pre-warm each model's modeled-cost cache for every batch shape
        // at the full-length bucket BEFORE any worker starts: a cache
        // miss runs the full cycle-accurate engine (far longer than an
        // SLO), and on the serving path that stall would leak into the
        // queue latencies of every request waiting behind the dispatch.
        // Warming here keeps the uniform full-length serving path
        // lookup-only; shorter buckets (mixed-length traffic) fall back
        // to on-miss simulation, each shape exactly once.
        let mut sims: Vec<Option<Arc<SimCache>>> = Vec::with_capacity(n_models);
        for e in &entries {
            let cache = e.sim.clone().map(|spec| {
                Arc::new(SimCache { spec, shapes: Mutex::new(HashMap::new()) })
            });
            if let Some(cache) = &cache {
                for &shape in crate::coordinator::batcher::BATCH_SHAPES {
                    cache.model_for(cache.spec.seq, shape);
                }
            }
            sims.push(cache);
        }
        let protos: Vec<(Runtime, Arc<Vec<f32>>, TaskKind)> = entries
            .into_iter()
            .map(|e| (e.runtime, Arc::new(e.params), e.task))
            .collect();
        let mut workers = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let mut wmodels = Vec::with_capacity(n_models);
            for (m, (proto, params, task)) in protos.iter().enumerate() {
                wmodels.push(WorkerModel {
                    rt: proto.fork().with_context(|| {
                        format!("forking model {m} for serve worker {w}")
                    })?,
                    params: Arc::clone(params),
                    sim: sims[m].clone(),
                    task: *task,
                });
            }
            let shared = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name(format!("serve-worker-{w}"))
                .spawn(move || worker_loop(wmodels, shared))
                .with_context(|| format!("spawning serve worker {w}"))?;
            workers.push(handle);
        }
        Ok(ServePool {
            shared,
            workers,
            next_id: AtomicU64::new(0),
            slo: cfg.slo,
            batch_slo: cfg.batch_slo,
            max_queue: cfg.max_queue.max(1),
            models: infos,
            started: Instant::now(),
            backend,
            sims,
        })
    }

    /// Maximum token count a request for the *first* model may carry
    /// (its manifest's `seq`; any native length `1..=seq` is accepted
    /// and served in its length bucket).  Multi-model callers use
    /// [`ServePool::models`].
    pub fn seq(&self) -> usize {
        self.models[0].seq
    }

    /// Vocabulary size of the first served model (valid token ids are
    /// `0..vocab`).
    pub fn vocab(&self) -> usize {
        self.models[0].vocab
    }

    /// Logit count per classify request on the first model
    /// (`Response::logits.len()`).
    pub fn classes(&self) -> usize {
        self.models[0].classes
    }

    /// Registered models, in registration order; the index of an entry
    /// is the `model` argument the `submit_model_*` family takes.
    pub fn models(&self) -> &[ModelInfo] {
        &self.models
    }

    /// Resolve a model name to its index.
    pub fn find_model(&self, name: &str) -> Option<usize> {
        self.models.iter().position(|m| m.name == name)
    }

    /// Enqueue a request for the first model under the pool's default
    /// SLO and interactive priority; returns its id.  Thread-safe: any
    /// number of submitters may run against the pool.  Errors (never
    /// panics) on a token count outside `1..=seq` or a queue at its
    /// admission bound.
    pub fn submit(&self, ids: Vec<i32>, tau: f32) -> Result<u64, SubmitError> {
        self.submit_with_slo(ids, tau, self.slo)
    }

    /// Enqueue with an explicit SLO budget (`deadline = now + slo`).
    pub fn submit_with_slo(
        &self,
        ids: Vec<i32>,
        tau: f32,
        slo: Duration,
    ) -> Result<u64, SubmitError> {
        self.enqueue(0, ids, tau, slo, Priority::Interactive, None)
    }

    /// Enqueue under a scheduling class: [`Priority::Batch`] requests
    /// take the pool's laxer `batch_slo` budget and are claimed after
    /// any interactive rows in their bucket.
    pub fn submit_with_priority(
        &self,
        ids: Vec<i32>,
        tau: f32,
        priority: Priority,
    ) -> Result<u64, SubmitError> {
        self.enqueue(0, ids, tau, self.slo_for(priority), priority, None)
    }

    /// [`ServePool::submit_with_priority`] against an explicit
    /// registered model (index into [`ServePool::models`]).
    pub fn submit_model_with_priority(
        &self,
        model: usize,
        ids: Vec<i32>,
        tau: f32,
        priority: Priority,
    ) -> Result<u64, SubmitError> {
        self.enqueue(model, ids, tau, self.slo_for(priority), priority, None)
    }

    /// Enqueue under the default SLO with a per-request completion
    /// channel: the serving worker sends the [`Response`] to `reply` the
    /// moment the batch completes, and the response is *not* retained
    /// for [`ServePool::finish`] — the delivery mode the HTTP front-end
    /// ([`crate::serve::net`]) uses, which keeps a long-lived pool's
    /// memory flat.  A closed receiver is tolerated (the response is
    /// dropped; accounting still records it).
    pub fn submit_with_reply(
        &self,
        ids: Vec<i32>,
        tau: f32,
        reply: mpsc::Sender<Response>,
    ) -> Result<u64, SubmitError> {
        self.enqueue(0, ids, tau, self.slo, Priority::Interactive, Some(reply))
    }

    /// [`ServePool::submit_with_reply`] with an explicit scheduling
    /// class.
    pub fn submit_with_reply_priority(
        &self,
        ids: Vec<i32>,
        tau: f32,
        priority: Priority,
        reply: mpsc::Sender<Response>,
    ) -> Result<u64, SubmitError> {
        self.enqueue(0, ids, tau, self.slo_for(priority), priority, Some(reply))
    }

    /// [`ServePool::submit_with_reply_priority`] against an explicit
    /// registered model — the multi-model HTTP path.
    pub fn submit_model_with_reply_priority(
        &self,
        model: usize,
        ids: Vec<i32>,
        tau: f32,
        priority: Priority,
        reply: mpsc::Sender<Response>,
    ) -> Result<u64, SubmitError> {
        self.enqueue(model, ids, tau, self.slo_for(priority), priority, Some(reply))
    }

    /// Atomically enqueue a multi-request submission (the HTTP batch
    /// endpoint): all rows are admitted or none are, under one lock, so
    /// a client never gets a half-accepted batch when the queue is near
    /// its bound.  Row lengths are validated up front; the first bad
    /// row rejects the whole submission.
    pub fn submit_batch_with_reply(
        &self,
        rows: Vec<(Vec<i32>, f32, Priority)>,
        reply: &mpsc::Sender<Response>,
    ) -> Result<Vec<u64>, SubmitError> {
        self.submit_batch_model_with_reply(0, rows, reply)
    }

    /// [`ServePool::submit_batch_with_reply`] against an explicit
    /// registered model.  All rows route to the same model (a batch
    /// submission cannot span checkpoints).
    pub fn submit_batch_model_with_reply(
        &self,
        model: usize,
        rows: Vec<(Vec<i32>, f32, Priority)>,
        reply: &mpsc::Sender<Response>,
    ) -> Result<Vec<u64>, SubmitError> {
        let max_seq = self.models[model].seq;
        for (ids, _, _) in &rows {
            if ids.is_empty() || ids.len() > max_seq {
                return Err(SubmitError::BadLength { got: ids.len(), max_seq });
            }
        }
        let enqueued_at = Instant::now();
        let mut out = Vec::with_capacity(rows.len());
        {
            let mut st = self.shared.state.lock().unwrap();
            if st.closed {
                // drained pools reject like a full queue: retry elsewhere
                return Err(SubmitError::QueueFull { pending: 0, bound: 0 });
            }
            let pending = st.pending();
            if pending + rows.len() > self.max_queue {
                return Err(SubmitError::QueueFull { pending, bound: self.max_queue });
            }
            for (ids, tau, priority) in rows {
                let id = self.next_id.fetch_add(1, Ordering::Relaxed);
                st.queues[model].push(Request {
                    id,
                    ids,
                    tau,
                    enqueued_at,
                    deadline: enqueued_at + self.slo_for(priority),
                    priority,
                    reply: Some(reply.clone()),
                });
                out.push(id);
            }
            st.high_water[model] =
                st.high_water[model].max(st.queues[model].len() as u64);
            st.high_water_total = st.high_water_total.max(st.pending() as u64);
        }
        self.shared.work.notify_all();
        Ok(out)
    }

    fn slo_for(&self, priority: Priority) -> Duration {
        match priority {
            Priority::Interactive => self.slo,
            Priority::Batch => self.batch_slo,
        }
    }

    fn enqueue(
        &self,
        model: usize,
        ids: Vec<i32>,
        tau: f32,
        slo: Duration,
        priority: Priority,
        reply: Option<mpsc::Sender<Response>>,
    ) -> Result<u64, SubmitError> {
        assert!(model < self.models.len(), "model index {model} out of range");
        let max_seq = self.models[model].seq;
        if ids.is_empty() || ids.len() > max_seq {
            return Err(SubmitError::BadLength { got: ids.len(), max_seq });
        }
        let enqueued_at = Instant::now();
        let id = {
            let mut st = self.shared.state.lock().unwrap();
            let pending = st.pending();
            if pending >= self.max_queue {
                return Err(SubmitError::QueueFull { pending, bound: self.max_queue });
            }
            let id = self.next_id.fetch_add(1, Ordering::Relaxed);
            st.queues[model].push(Request {
                id,
                ids,
                tau,
                enqueued_at,
                deadline: enqueued_at + slo,
                priority,
                reply,
            });
            st.high_water[model] =
                st.high_water[model].max(st.queues[model].len() as u64);
            st.high_water_total = st.high_water_total.max((pending + 1) as u64);
            id
        };
        self.shared.work.notify_one();
        Ok(id)
    }

    /// Requests fully served so far (responses recorded by a worker).
    pub fn completed(&self) -> u64 {
        self.shared.completed.load(Ordering::Relaxed)
    }

    /// Requests currently queued across all models (excludes batches in
    /// flight).
    pub fn pending(&self) -> usize {
        self.shared.state.lock().unwrap().pending()
    }

    /// Requests currently queued for one model.
    pub fn pending_model(&self, model: usize) -> usize {
        self.shared.state.lock().unwrap().queues[model].len()
    }

    /// Admission bound this pool enforces (`ServeConfig::max_queue`).
    pub fn max_queue(&self) -> usize {
        self.max_queue
    }

    /// Live accounting snapshot — current stats and latency histograms
    /// without closing the pool (the `/stats` endpoint's data source).
    /// Cheap relative to a dispatch: two short lock acquisitions and a
    /// fixed-size histogram copy per call.  The top-level fields merge
    /// across models; `models` carries the per-model sections.
    pub fn snapshot(&self) -> PoolSnapshot {
        let (per_pending, per_depths, high_water_total, per_high) = {
            let st = self.shared.state.lock().unwrap();
            let per_pending: Vec<usize> = st.queues.iter().map(|q| q.len()).collect();
            let per_depths: Vec<Vec<(usize, usize)>> = st
                .queues
                .iter()
                .map(|q| q.seqs().iter().copied().zip(q.depths()).collect())
                .collect();
            (per_pending, per_depths, st.high_water_total, st.high_water.clone())
        };
        let live = self.shared.live.lock().unwrap();
        let mut merged = LiveAccounting::default();
        for la in live.iter() {
            merged.stats.merge(&la.stats);
            merged.queue_h.merge(&la.queue_h);
            merged.compute_h.merge(&la.compute_h);
            merged.total_h.merge(&la.total_h);
            merged.deadline_misses += la.deadline_misses;
        }
        merged.stats.queue_depth_high_water = high_water_total;
        // merged bucket view: depths summed per bucket seq across models
        let mut by_seq: std::collections::BTreeMap<usize, usize> =
            std::collections::BTreeMap::new();
        for depths in &per_depths {
            for &(seq, d) in depths {
                *by_seq.entry(seq).or_insert(0) += d;
            }
        }
        let models: Vec<ModelSnapshot> = self
            .models
            .iter()
            .enumerate()
            .map(|(m, info)| {
                let la = &live[m];
                let mut stats = la.stats.clone();
                stats.queue_depth_high_water = per_high[m];
                ModelSnapshot {
                    name: info.name.clone(),
                    task: info.task,
                    seq: info.seq,
                    classes: info.classes,
                    pending: per_pending[m],
                    bucket_depths: per_depths[m].clone(),
                    served: la.stats.served,
                    deadline_misses: la.deadline_misses,
                    stats,
                    total_latency: la.total_h.clone(),
                }
            })
            .collect();
        PoolSnapshot {
            backend: self.backend.clone(),
            workers: self.workers.len(),
            submitted: self.next_id.load(Ordering::Relaxed),
            completed: self.shared.completed.load(Ordering::Relaxed),
            pending: per_pending.iter().sum(),
            bucket_depths: by_seq.into_iter().collect(),
            deadline_misses: merged.deadline_misses,
            queue_latency: merged.queue_h,
            compute_latency: merged.compute_h,
            total_latency: merged.total_h,
            stats: merged.stats,
            models,
            uptime: self.started.elapsed(),
        }
    }

    /// Close the queue, let the workers drain it (closing force-flushes
    /// under-filled tails), join them, and merge their accounting.
    /// Returns the aggregate report plus every *retained* response
    /// (unordered — match by `Response::id`; responses delivered through
    /// [`ServePool::submit_with_reply`] channels are not retained).
    pub fn finish(self) -> Result<(ServeReport, Vec<Response>)> {
        {
            self.shared.state.lock().unwrap().closed = true;
        }
        self.shared.work.notify_all();
        let n_workers = self.workers.len();
        let mut responses = Vec::new();
        let mut first_err: Option<anyhow::Error> = None;
        for handle in self.workers {
            match handle.join() {
                Ok(Ok(out)) => responses.extend(out),
                Ok(Err(e)) => first_err = first_err.or(Some(e)),
                Err(_) => {
                    first_err =
                        first_err.or_else(|| Some(anyhow!("serve worker panicked")))
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e.context("serve worker failed"));
        }
        let wall = self.started.elapsed();
        let live_vec: Vec<LiveAccounting> =
            std::mem::take(&mut *self.shared.live.lock().unwrap());
        let (high_water_total, per_high) = {
            let st = self.shared.state.lock().unwrap();
            (st.high_water_total, st.high_water.clone())
        };
        let mut merged = LiveAccounting::default();
        for la in &live_vec {
            merged.stats.merge(&la.stats);
            merged.queue_h.merge(&la.queue_h);
            merged.compute_h.merge(&la.compute_h);
            merged.total_h.merge(&la.total_h);
            merged.modeled_h.merge(&la.modeled_h);
            merged.deadline_misses += la.deadline_misses;
        }
        merged.stats.queue_depth_high_water = high_water_total;
        let any_sim = self.sims.iter().any(|s| s.is_some());
        let mut modeled_shapes: Vec<ShapeModel> = Vec::new();
        let mut descs: Vec<String> = Vec::new();
        for cache in self.sims.iter().flatten() {
            modeled_shapes.extend(cache.shapes.lock().unwrap().values().copied());
            let d = cache.describe();
            if !descs.contains(&d) {
                descs.push(d);
            }
        }
        modeled_shapes.sort_by_key(|m| (m.seq, m.batch));
        let (modeled_latency, sim_config) = if any_sim {
            (Some(merged.modeled_h), Some(descs.join("; ")))
        } else {
            (None, None)
        };
        let models: Vec<ModelReport> = self
            .models
            .iter()
            .enumerate()
            .map(|(m, info)| {
                let la = &live_vec[m];
                let mut stats = la.stats.clone();
                stats.queue_depth_high_water = per_high[m];
                ModelReport {
                    name: info.name.clone(),
                    task: info.task,
                    requests: la.stats.served,
                    deadline_misses: la.deadline_misses,
                    stats,
                    total_latency: la.total_h.clone(),
                    modeled_latency: self.sims[m]
                        .as_ref()
                        .map(|_| la.modeled_h.clone()),
                }
            })
            .collect();
        let report = ServeReport {
            backend: self.backend,
            workers: n_workers,
            submitted: self.next_id.load(Ordering::Relaxed),
            requests: merged.stats.served,
            wall,
            slo: self.slo,
            deadline_misses: merged.deadline_misses,
            stats: merged.stats,
            queue_latency: merged.queue_h,
            compute_latency: merged.compute_h,
            total_latency: merged.total_h,
            modeled_latency,
            modeled_shapes,
            sim_config,
            models,
        };
        Ok((report, responses))
    }
}

/// Point-in-time view of a running [`ServePool`] from
/// [`ServePool::snapshot`]: counters plus the three host-measured
/// latency histograms as of the last dispatched batch.
#[derive(Clone, Debug)]
pub struct PoolSnapshot {
    /// Backend the pool's workers execute on.
    pub backend: String,
    /// Worker-thread count.
    pub workers: usize,
    /// Requests accepted so far.
    pub submitted: u64,
    /// Requests fully served so far.
    pub completed: u64,
    /// Requests currently queued (excludes batches in flight).
    pub pending: usize,
    /// Per-length-bucket queue depths as `(bucket_seq, depth)`,
    /// ascending by seq.
    pub bucket_depths: Vec<(usize, usize)>,
    /// Served requests whose end-to-end latency exceeded their SLO.
    pub deadline_misses: u64,
    /// Merged dispatch accounting (high-water filled from the queue).
    pub stats: ServerStats,
    /// Submit-to-claim latency histogram.
    pub queue_latency: LatencyHistogram,
    /// Host `classify` wall-time histogram.
    pub compute_latency: LatencyHistogram,
    /// Submit-to-response latency histogram.
    pub total_latency: LatencyHistogram,
    /// Per-model sections (one per registered model, in registration
    /// order); a single-model pool has exactly one.
    pub models: Vec<ModelSnapshot>,
    /// Time since [`ServePool::start`].
    pub uptime: Duration,
}

/// One model's slice of a [`PoolSnapshot`].
#[derive(Clone, Debug)]
pub struct ModelSnapshot {
    pub name: String,
    pub task: TaskKind,
    pub seq: usize,
    pub classes: usize,
    /// Requests currently queued for this model.
    pub pending: usize,
    /// This model's per-length-bucket queue depths as
    /// `(bucket_seq, depth)`, ascending by seq.
    pub bucket_depths: Vec<(usize, usize)>,
    /// Requests served from this model's queues so far.
    pub served: u64,
    pub deadline_misses: u64,
    /// Dispatch accounting for this model only (high-water is the
    /// model's own pending peak).
    pub stats: ServerStats,
    /// Submit-to-response latency histogram for this model's requests.
    pub total_latency: LatencyHistogram,
}

impl ModelSnapshot {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("task", Json::str(self.task.name())),
            ("seq", Json::num(self.seq as f64)),
            ("classes", Json::num(self.classes as f64)),
            ("pending", Json::num(self.pending as f64)),
            ("served", Json::num(self.served as f64)),
            ("deadline_misses", Json::num(self.deadline_misses as f64)),
            ("dispatches", Json::num(self.stats.dispatches as f64)),
            (
                "padded_token_fraction",
                Json::num(self.stats.padded_token_fraction()),
            ),
            (
                "queue_depth_high_water",
                Json::num(self.stats.queue_depth_high_water as f64),
            ),
            (
                "buckets",
                Json::arr(self.bucket_depths.iter().map(|&(seq, depth)| {
                    Json::obj(vec![
                        ("seq", Json::num(seq as f64)),
                        ("depth", Json::num(depth as f64)),
                    ])
                })),
            ),
            (
                "latency_us",
                Json::obj(vec![("total", self.total_latency.to_json())]),
            ),
        ])
    }
}

impl PoolSnapshot {
    /// JSON object mirroring the [`ServeReport`] field names so `/stats`
    /// consumers and report readers share a schema.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("backend", Json::str(self.backend.clone())),
            ("workers", Json::num(self.workers as f64)),
            ("submitted", Json::num(self.submitted as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("pending", Json::num(self.pending as f64)),
            ("deadline_misses", Json::num(self.deadline_misses as f64)),
            ("dispatches", Json::num(self.stats.dispatches as f64)),
            ("rows_dispatched", Json::num(self.stats.rows_dispatched as f64)),
            ("padded_rows", Json::num(self.stats.padded_rows as f64)),
            (
                "padded_row_fraction",
                Json::num(self.stats.padded_row_fraction()),
            ),
            (
                "tokens_dispatched",
                Json::num(self.stats.tokens_dispatched as f64),
            ),
            ("padded_tokens", Json::num(self.stats.padded_tokens as f64)),
            (
                "padded_token_fraction",
                Json::num(self.stats.padded_token_fraction()),
            ),
            (
                "queue_depth_high_water",
                Json::num(self.stats.queue_depth_high_water as f64),
            ),
            (
                "buckets",
                Json::arr(self.bucket_depths.iter().map(|&(seq, depth)| {
                    Json::obj(vec![
                        ("seq", Json::num(seq as f64)),
                        ("depth", Json::num(depth as f64)),
                    ])
                })),
            ),
            ("uptime_s", Json::num(self.uptime.as_secs_f64())),
            (
                "latency_us",
                Json::obj(vec![
                    ("queue", self.queue_latency.to_json()),
                    ("compute", self.compute_latency.to_json()),
                    ("total", self.total_latency.to_json()),
                ]),
            ),
            (
                "models",
                Json::arr(self.models.iter().map(|m| m.to_json())),
            ),
        ])
    }
}

/// One model's per-worker execution state: a forked runtime, the shared
/// checkpoint, the task selecting the entry point, and the model's
/// modeled-cost cache.
struct WorkerModel {
    rt: Runtime,
    params: Arc<Vec<f32>>,
    sim: Option<Arc<SimCache>>,
    task: TaskKind,
}

fn worker_loop(
    mut models: Vec<WorkerModel>,
    shared: Arc<Shared>,
) -> Result<Vec<Response>> {
    let mut retained: Vec<Response> = Vec::new();
    loop {
        // ---- claim a batch under the queue lock ------------------------
        // The claim happens at the dispatch instant, not when the policy
        // first armed a deadline: every same-bucket request that arrived
        // during the wait below is still in the queues here and rides
        // the flush (in-flight topping-off).  The dispatch decision
        // spans every model's queues, but the claim drains exactly one
        // model's one bucket — a batch never mixes checkpoints.
        let picked = {
            let mut st = shared.state.lock().unwrap();
            loop {
                let now = Instant::now();
                let depth_vecs: Vec<Vec<usize>> =
                    st.queues.iter().map(|q| q.depths()).collect();
                let depth_refs: Vec<&[usize]> =
                    depth_vecs.iter().map(|v| v.as_slice()).collect();
                let deadlines: Vec<Option<(Instant, usize)>> =
                    st.queues.iter().map(|q| q.nearest_deadline()).collect();
                let choice = dispatch_multi(&depth_refs, &deadlines, now, st.closed);
                if let Some((model, bucket, shape)) = choice {
                    let bucket_seq = st.queues[model].seqs()[bucket];
                    let reqs = st.queues[model].claim(bucket, shape);
                    if st.queues.iter().any(|q| !q.is_empty()) {
                        // more work remains: wake a sibling
                        shared.work.notify_one();
                    }
                    break Some((model, bucket_seq, shape, reqs));
                }
                if st.closed && st.queues.iter().all(|q| q.is_empty()) {
                    break None;
                }
                // park until the nearest queued deadline across models —
                // submits (which can only bring the nearest deadline
                // *earlier*) notify the condvar, so no shorter polling
                // tick is needed; an empty queue just re-checks every
                // HOUSEKEEPING interval
                let nearest = deadlines.iter().flatten().map(|&(d, _)| d).min();
                let wait = match nearest {
                    Some(d) => d
                        .saturating_duration_since(now)
                        .max(Duration::from_micros(50)),
                    None => HOUSEKEEPING,
                };
                let (guard, _timeout) = shared.work.wait_timeout(st, wait).unwrap();
                st = guard;
            }
        };
        let Some((model, bucket_seq, shape, reqs)) = picked else {
            return Ok(retained);
        };

        // ---- execute off-lock ------------------------------------------
        let wm = &mut models[model];
        let max_seq = wm.rt.manifest.seq;
        let classes = wm.rt.manifest.classes;
        let dequeued = Instant::now();
        let fill = reqs.len();
        let true_tokens: usize = reqs.iter().map(|r| r.ids.len()).sum();
        let (ids, lens, tau) = assemble_batch(&reqs, shape, bucket_seq);
        let t0 = Instant::now();
        let logits = match wm.task {
            TaskKind::Classify => wm.rt.classify_padded(
                shape,
                bucket_seq,
                &lens,
                wm.params.as_slice(),
                &ids,
                tau,
            )?,
            TaskKind::Span => wm.rt.span_logits_padded(
                shape,
                bucket_seq,
                &lens,
                wm.params.as_slice(),
                &ids,
                tau,
            )?,
        };
        let compute = t0.elapsed();
        // stamp completion BEFORE the modeled-cost lookup: a cache miss
        // runs the cycle-accurate simulation, and that modeling overhead
        // must not leak into the host-measured latencies or SLO misses
        let done = Instant::now();
        let modeled = wm
            .sim
            .as_ref()
            .map(|cache| cache.model_for(cache.sim_seq(bucket_seq, max_seq), shape));

        // ---- account ---------------------------------------------------
        // fold this batch into the model's slot of the shared live
        // accounting under one short lock (O(batch) histogram records),
        // then deliver/retain responses off-lock
        let compute_us = compute.as_micros() as u64;
        {
            let mut live = shared.live.lock().unwrap();
            let live = &mut live[model];
            live.stats.record(compute, fill, shape, bucket_seq, true_tokens);
            for r in &reqs {
                let queue_us = dequeued
                    .saturating_duration_since(r.enqueued_at)
                    .as_micros() as u64;
                let total = done.saturating_duration_since(r.enqueued_at);
                live.queue_h.record_us(queue_us);
                live.compute_h.record_us(compute_us);
                live.total_h.record_us(total.as_micros() as u64);
                if let Some(m) = modeled {
                    // modeled end-to-end: measured queueing + simulated
                    // accelerator compute for this batch shape
                    live.modeled_h
                        .record_us(queue_us + m.latency_us.round() as u64);
                }
                if done > r.deadline {
                    live.deadline_misses += 1;
                }
            }
        }
        // completed counts BEFORE replies go out: an observer that saw a
        // response (an HTTP client holding its 200) must never read a
        // `completed` that excludes it
        shared.completed.fetch_add(fill as u64, Ordering::Relaxed);
        for (i, r) in reqs.into_iter().enumerate() {
            let total = done.saturating_duration_since(r.enqueued_at);
            let out = match wm.task {
                TaskKind::Classify => {
                    logits[i * classes..(i + 1) * classes].to_vec()
                }
                TaskKind::Span => {
                    // row i is position-major (start, end) pairs at the
                    // bucket width; the response carries the split-half
                    // native-length layout
                    // [start_0..start_{l-1}, end_0..end_{l-1}]
                    let l = r.ids.len();
                    let row = &logits[i * bucket_seq * 2..(i + 1) * bucket_seq * 2];
                    let mut out = Vec::with_capacity(2 * l);
                    for p in 0..l {
                        out.push(row[p * 2]);
                    }
                    for p in 0..l {
                        out.push(row[p * 2 + 1]);
                    }
                    out
                }
            };
            let resp = Response {
                id: r.id,
                logits: out,
                latency: total,
                batch: shape,
            };
            match r.reply {
                // synchronous delivery (HTTP path); a hung-up receiver
                // just drops the response — accounting already ran
                Some(tx) => {
                    let _ = tx.send(resp);
                }
                None => retained.push(resp),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The report
// ---------------------------------------------------------------------------

/// Aggregate outcome of one serving run: merged worker stats, the three
/// host-measured latency histograms (queue / compute / end-to-end), and
/// — under sim-in-the-loop — the modeled-accelerator histogram plus the
/// per-shape cycle-accurate costs.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub backend: String,
    pub workers: usize,
    /// Requests accepted by [`ServePool::submit`].
    pub submitted: u64,
    /// Requests actually served (== submitted after a clean finish).
    pub requests: u64,
    /// Pool lifetime: start to finish (includes submission time).
    pub wall: Duration,
    pub slo: Duration,
    /// Requests whose end-to-end latency exceeded their SLO budget.
    pub deadline_misses: u64,
    pub stats: ServerStats,
    /// Time from submit to batch claim.
    pub queue_latency: LatencyHistogram,
    /// Host `classify` wall time of the batch each request rode.
    pub compute_latency: LatencyHistogram,
    /// Submit-to-response latency (queue + compute).
    pub total_latency: LatencyHistogram,
    /// Modeled-accelerator end-to-end latency (measured queueing +
    /// simulated batch compute); `None` without [`SimInLoop`].
    pub modeled_latency: Option<LatencyHistogram>,
    /// One cycle-accurate run per dispatchable batch shape (pre-warmed
    /// at pool start).
    pub modeled_shapes: Vec<ShapeModel>,
    /// Human-readable sim-in-the-loop operating point (multi-model
    /// pools join each model's, `; `-separated).
    pub sim_config: Option<String>,
    /// Per-model report sections, in registration order (a single-model
    /// pool has exactly one; its numbers equal the merged top level).
    pub models: Vec<ModelReport>,
}

/// One model's slice of a [`ServeReport`].
#[derive(Clone, Debug)]
pub struct ModelReport {
    pub name: String,
    pub task: TaskKind,
    /// Requests served from this model's queues.
    pub requests: u64,
    pub deadline_misses: u64,
    /// Dispatch accounting for this model only (high-water is the
    /// model's own pending peak).
    pub stats: ServerStats,
    /// Submit-to-response latency histogram for this model's requests.
    pub total_latency: LatencyHistogram,
    /// Modeled-accelerator latency histogram; `None` when the model was
    /// registered without [`SimInLoop`].
    pub modeled_latency: Option<LatencyHistogram>,
}

impl ModelReport {
    pub fn to_json(&self) -> Json {
        let mut latency = vec![("total", self.total_latency.to_json())];
        if let Some(m) = &self.modeled_latency {
            latency.push(("modeled", m.to_json()));
        }
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("task", Json::str(self.task.name())),
            ("requests", Json::num(self.requests as f64)),
            ("deadline_misses", Json::num(self.deadline_misses as f64)),
            ("dispatches", Json::num(self.stats.dispatches as f64)),
            (
                "padded_row_fraction",
                Json::num(self.stats.padded_row_fraction()),
            ),
            (
                "padded_token_fraction",
                Json::num(self.stats.padded_token_fraction()),
            ),
            (
                "queue_depth_high_water",
                Json::num(self.stats.queue_depth_high_water as f64),
            ),
            ("latency_us", Json::obj(latency)),
        ])
    }
}

impl ServeReport {
    /// Served requests per wall-clock second.
    pub fn throughput_rps(&self) -> f64 {
        self.requests as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    pub fn to_json(&self) -> Json {
        let mut latency = vec![
            ("queue", self.queue_latency.to_json()),
            ("compute", self.compute_latency.to_json()),
            ("total", self.total_latency.to_json()),
        ];
        if let Some(m) = &self.modeled_latency {
            latency.push(("modeled", m.to_json()));
        }
        let mut obj = vec![
            ("backend", Json::str(self.backend.clone())),
            ("workers", Json::num(self.workers as f64)),
            ("submitted", Json::num(self.submitted as f64)),
            ("requests", Json::num(self.requests as f64)),
            ("wall_s", Json::num(self.wall.as_secs_f64())),
            ("throughput_rps", Json::num(self.throughput_rps())),
            ("slo_ms", Json::num(self.slo.as_secs_f64() * 1e3)),
            ("deadline_misses", Json::num(self.deadline_misses as f64)),
            ("dispatches", Json::num(self.stats.dispatches as f64)),
            ("padded_rows", Json::num(self.stats.padded_rows as f64)),
            (
                "padded_row_fraction",
                Json::num(self.stats.padded_row_fraction()),
            ),
            (
                "tokens_dispatched",
                Json::num(self.stats.tokens_dispatched as f64),
            ),
            ("padded_tokens", Json::num(self.stats.padded_tokens as f64)),
            (
                "padded_token_fraction",
                Json::num(self.stats.padded_token_fraction()),
            ),
            (
                "queue_depth_high_water",
                Json::num(self.stats.queue_depth_high_water as f64),
            ),
            ("latency_us", Json::obj(latency)),
            (
                "models",
                Json::arr(self.models.iter().map(|m| m.to_json())),
            ),
        ];
        if let Some(cfg) = &self.sim_config {
            obj.push(("sim_config", Json::str(cfg.clone())));
            obj.push((
                "sim_shapes",
                Json::arr(self.modeled_shapes.iter().map(|m| {
                    Json::obj(vec![
                        ("seq", Json::num(m.seq as f64)),
                        ("batch", Json::num(m.batch as f64)),
                        ("total_cycles", Json::num(m.total_cycles as f64)),
                        ("latency_us", Json::num(m.latency_us)),
                        ("throughput_seq_s", Json::num(m.throughput_seq_s)),
                        ("energy_mj_per_seq", Json::num(m.energy_mj_per_seq)),
                    ])
                })),
            ));
        }
        Json::obj(obj)
    }

    /// Write the JSON report to `path`, creating parent directories.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {dir:?}"))?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing serve report to {path:?}"))
    }

    /// Print the human-readable summary the `acceltran serve` transcript
    /// shows.
    pub fn print_summary(&self) {
        println!(
            "served {} requests in {:.3}s ({:.1} req/s) on {} worker(s) \
             ['{}' backend]",
            self.requests,
            self.wall.as_secs_f64(),
            self.throughput_rps(),
            self.workers,
            self.backend,
        );
        println!(
            "  {} dispatches, {} padded rows ({:.1}%), {} padded tokens \
             ({:.1}%), queue high-water {}, {} SLO miss(es) @ {:?}",
            self.stats.dispatches,
            self.stats.padded_rows,
            100.0 * self.stats.padded_row_fraction(),
            self.stats.padded_tokens,
            100.0 * self.stats.padded_token_fraction(),
            self.stats.queue_depth_high_water,
            self.deadline_misses,
            self.slo,
        );
        let line = |name: &str, h: &LatencyHistogram| {
            println!(
                "  {name:<18} p50 {:>8} us  p95 {:>8} us  p99 {:>8} us  \
                 mean {:>9.1} us  max {:>8} us",
                h.percentile_us(50.0),
                h.percentile_us(95.0),
                h.percentile_us(99.0),
                h.mean_us(),
                h.max_us(),
            );
        };
        line("queue latency", &self.queue_latency);
        line("compute latency", &self.compute_latency);
        line("total latency", &self.total_latency);
        if let Some(m) = &self.modeled_latency {
            line("modeled latency", m);
        }
        if self.models.len() > 1 {
            for m in &self.models {
                println!(
                    "  model '{}' [{}]: {} served, {} dispatch(es), \
                     {} SLO miss(es), p50 {} us, p99 {} us",
                    m.name,
                    m.task.name(),
                    m.requests,
                    m.stats.dispatches,
                    m.deadline_misses,
                    m.total_latency.percentile_us(50.0),
                    m.total_latency.percentile_us(99.0),
                );
            }
        }
        if let Some(cfg) = &self.sim_config {
            println!("  sim-in-the-loop: {cfg}");
            for m in &self.modeled_shapes {
                println!(
                    "    seq {:>3} batch {:>2}: {:>10} cycles  {:>10.1} us  \
                     {:>8.1} seq/s  {:.3} mJ/seq",
                    m.seq,
                    m.batch,
                    m.total_cycles,
                    m.latency_us,
                    m.throughput_seq_s,
                    m.energy_mj_per_seq,
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ParamStore;

    // ---- histogram -----------------------------------------------------

    #[test]
    fn histogram_small_values_are_exact() {
        let mut h = LatencyHistogram::new();
        for us in [0u64, 1, 2, 3, 7] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.min_us(), 0);
        assert_eq!(h.max_us(), 7);
        assert_eq!(h.percentile_us(0.0), 0);
        assert_eq!(h.percentile_us(100.0), 7);
        assert_eq!(h.percentile_us(50.0), 2);
    }

    #[test]
    fn histogram_quantile_error_is_bounded() {
        // single-value histograms: the representative must be within
        // 12.5% of the recorded value at any scale
        for v in [9u64, 100, 1_000, 65_537, 10_000_000] {
            let mut h = LatencyHistogram::new();
            h.record_us(v);
            let got = h.percentile_us(50.0);
            let err = (got as f64 - v as f64).abs() / v as f64;
            assert!(err <= 0.125, "v={v} got={got} err={err}");
            assert_eq!(h.percentile_us(100.0), v, "max is exact");
        }
    }

    #[test]
    fn histogram_percentiles_are_monotone() {
        let mut h = LatencyHistogram::new();
        for i in 0..1000u64 {
            h.record_us(i * 37 % 50_000);
        }
        let mut last = 0u64;
        for p in [0.0, 10.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            let v = h.percentile_us(p);
            assert!(v >= last, "p{p}: {v} < {last}");
            last = v;
        }
        // i*37 stays below 50_000 for i < 1000, so the mean is exactly
        // 37 * 999 / 2 (the sum accumulator is exact)
        assert!((h.mean_us() - 18_481.5).abs() < 1.0, "{}", h.mean_us());
    }

    #[test]
    fn histogram_merge_equals_single_stream() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut whole = LatencyHistogram::new();
        for i in 0..500u64 {
            let v = (i * i) % 90_000;
            if i % 2 == 0 {
                a.record_us(v);
            } else {
                b.record_us(v);
            }
            whole.record_us(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.min_us(), whole.min_us());
        assert_eq!(a.max_us(), whole.max_us());
        for p in [50.0, 95.0, 99.0] {
            assert_eq!(a.percentile_us(p), whole.percentile_us(p));
        }
    }

    #[test]
    fn histogram_empty_is_all_zero() {
        let h = LatencyHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.percentile_us(99.0), 0);
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.max_us(), 0);
    }

    // ---- pool ----------------------------------------------------------

    fn micro_runtime() -> Runtime {
        let model = TransformerConfig {
            name: "serve-micro".into(),
            hidden: 32,
            layers: 1,
            heads: 2,
            ff: 64,
            vocab: 64,
            seq: 16,
        };
        Runtime::reference_for(&model, 2).unwrap()
    }

    fn micro_requests(rt: &Runtime, n: usize) -> Vec<Vec<i32>> {
        let seq = rt.manifest.seq;
        let vocab = rt.manifest.vocab as i32;
        (0..n)
            .map(|i| (0..seq).map(|j| ((i * 7 + j * 3) as i32) % vocab).collect())
            .collect()
    }

    #[test]
    fn pool_serves_every_request_across_workers() {
        let rt = micro_runtime();
        let params = ParamStore::init(&rt.manifest, 0).params;
        let cfg = ServeConfig {
            workers: 3,
            slo: Duration::from_millis(5),
            sim: None,
            ..Default::default()
        };
        let pool = ServePool::start(&rt, &params, &cfg).unwrap();
        let reqs = micro_requests(&rt, 70);
        let mut ids = Vec::new();
        for r in reqs {
            ids.push(pool.submit(r, 0.02).unwrap());
        }
        let (report, responses) = pool.finish().unwrap();
        assert_eq!(report.submitted, 70);
        assert_eq!(report.requests, 70);
        assert_eq!(responses.len(), 70);
        let mut got: Vec<u64> = responses.iter().map(|r| r.id).collect();
        got.sort_unstable();
        assert_eq!(got, ids);
        for r in &responses {
            assert_eq!(r.logits.len(), 2);
            assert!(r.logits.iter().all(|v| v.is_finite()));
        }
        // accounting is self-consistent
        let s = &report.stats;
        assert_eq!(s.served, 70);
        assert_eq!(s.rows_dispatched, s.served + s.padded_rows);
        assert!(s.dispatches < 70, "batching must group requests");
        assert_eq!(report.total_latency.count(), 70);
        assert_eq!(report.queue_latency.count(), 70);
        assert!(report.total_latency.max_us() >= report.queue_latency.min_us());
    }

    #[test]
    fn pool_matches_single_threaded_logits() {
        // the same request must classify identically whether it rides
        // the pool or a lone runtime (batch rows are independent and the
        // test pins every request to one tau)
        let mut rt = micro_runtime();
        let params = ParamStore::init(&rt.manifest, 0).params;
        let reqs = micro_requests(&rt, 9);
        let cfg = ServeConfig {
            workers: 2,
            slo: Duration::from_millis(2),
            sim: None,
            ..Default::default()
        };
        let pool = ServePool::start(&rt, &params, &cfg).unwrap();
        for r in &reqs {
            pool.submit(r.clone(), 0.03).unwrap();
        }
        let (_, mut responses) = pool.finish().unwrap();
        responses.sort_by_key(|r| r.id);
        for (i, resp) in responses.iter().enumerate() {
            let solo = rt.classify(1, &params, &reqs[i], 0.03).unwrap();
            for (a, b) in resp.logits.iter().zip(solo.iter()) {
                assert!(
                    (a - b).abs() < 1e-4,
                    "request {i}: pool {a} vs solo {b}"
                );
            }
        }
    }

    #[test]
    fn expired_slo_flushes_an_underfilled_batch_while_open() {
        // 3 requests never fill a shape; the deadline alone must flush
        // them while the pool is still accepting traffic.  The SLO is
        // generous (150 ms, like the BatchServer deadline test) so a
        // scheduler stall between the submits cannot split the flush.
        let rt = micro_runtime();
        let params = ParamStore::init(&rt.manifest, 0).params;
        let cfg = ServeConfig {
            workers: 1,
            slo: Duration::from_millis(150),
            sim: None,
            ..Default::default()
        };
        let pool = ServePool::start(&rt, &params, &cfg).unwrap();
        for r in micro_requests(&rt, 3) {
            pool.submit(r, 0.0).unwrap();
        }
        let t0 = Instant::now();
        while pool.completed() < 3 && t0.elapsed() < Duration::from_secs(10) {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(
            pool.completed(),
            3,
            "deadline must flush an under-filled batch without close/drain"
        );
        let (report, responses) = pool.finish().unwrap();
        assert_eq!(report.requests, 3);
        // 3 requests pad up to the smallest covering shape (8)
        assert_eq!(responses[0].batch, 8);
        assert_eq!(report.stats.padded_rows, 5);
        assert_eq!(report.stats.rows_dispatched, 8);
    }

    #[test]
    fn sim_in_loop_reports_modeled_latencies() {
        let rt = micro_runtime();
        let params = ParamStore::init(&rt.manifest, 0).params;
        // shrunken design point so the per-shape simulation stays fast
        let mut accel = AcceleratorConfig::edge();
        accel.pes = 8;
        accel.act_buffer_bytes = 1 << 20;
        accel.weight_buffer_bytes = 2 << 20;
        accel.mask_buffer_bytes = 1 << 18;
        let model = TransformerConfig {
            name: "serve-micro".into(),
            hidden: 32,
            layers: 1,
            heads: 2,
            ff: 64,
            vocab: 64,
            seq: 16,
        };
        let cfg = ServeConfig {
            workers: 2,
            slo: Duration::from_millis(2),
            sim: Some(SimInLoop {
                accel,
                model,
                seq: 16,
                source: SparsitySource::Uniform(
                    crate::sim::SparsityProfile::paper_default(),
                ),
            }),
            ..Default::default()
        };
        let pool = ServePool::start(&rt, &params, &cfg).unwrap();
        for r in micro_requests(&rt, 40) {
            pool.submit(r, 0.02).unwrap();
        }
        let (report, _) = pool.finish().unwrap();
        assert_eq!(report.requests, 40);
        let modeled = report.modeled_latency.as_ref().expect("modeled histogram");
        assert_eq!(modeled.count(), 40, "every request gets a modeled time");
        assert!(modeled.max_us() > 0);
        assert!(!report.modeled_shapes.is_empty());
        for m in &report.modeled_shapes {
            assert!(m.total_cycles > 0);
            assert!(m.latency_us > 0.0);
        }
        assert!(report.sim_config.as_deref().unwrap_or("").contains("serve-micro"));
        // the JSON report carries the modeled block
        let j = report.to_json();
        assert!(j.path(&["latency_us", "modeled"]).is_some());
        assert!(j.get("sim_shapes").is_some());
    }

    #[test]
    fn concurrent_submitters_keep_stats_consistent() {
        // the satellite contract: queue_depth_high_water and
        // padded_row_fraction stay correct when many threads enqueue
        let rt = micro_runtime();
        let params = ParamStore::init(&rt.manifest, 0).params;
        let cfg = ServeConfig {
            workers: 2,
            slo: Duration::from_millis(3),
            sim: None,
            ..Default::default()
        };
        let pool = ServePool::start(&rt, &params, &cfg).unwrap();
        let reqs = micro_requests(&rt, 96);
        std::thread::scope(|scope| {
            for chunk in reqs.chunks(24) {
                let pool = &pool;
                scope.spawn(move || {
                    for r in chunk {
                        pool.submit(r.clone(), 0.01).unwrap();
                    }
                });
            }
        });
        let (report, responses) = pool.finish().unwrap();
        assert_eq!(report.submitted, 96);
        assert_eq!(report.requests, 96);
        assert_eq!(responses.len(), 96);
        let mut got: Vec<u64> = responses.iter().map(|r| r.id).collect();
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), 96, "no response lost or duplicated");
        let s = &report.stats;
        assert_eq!(s.rows_dispatched, s.served + s.padded_rows);
        let f = s.padded_row_fraction();
        assert!((0.0..1.0).contains(&f), "padded fraction {f}");
        assert!(
            s.queue_depth_high_water >= 1 && s.queue_depth_high_water <= 96,
            "high water {}",
            s.queue_depth_high_water
        );
    }

    #[test]
    fn mixed_length_requests_classify_identically_to_solo_native_runs() {
        // the tentpole contract end to end: variable-length requests ride
        // length-bucketed batches (padded only within their bucket) and
        // still classify BIT-identically to a solo native-length run
        let mut rt = micro_runtime();
        let params = ParamStore::init(&rt.manifest, 0).params;
        let vocab = rt.manifest.vocab as i32;
        let reqs: Vec<Vec<i32>> = (0..30usize)
            .map(|i| {
                let len = 1 + (i * 5) % 16;
                (0..len).map(|j| ((i * 7 + j * 3) as i32) % vocab).collect()
            })
            .collect();
        let cfg = ServeConfig {
            workers: 2,
            slo: Duration::from_millis(2),
            sim: None,
            ..Default::default()
        };
        let pool = ServePool::start(&rt, &params, &cfg).unwrap();
        for r in &reqs {
            pool.submit(r.clone(), 0.02).unwrap();
        }
        let (report, mut responses) = pool.finish().unwrap();
        assert_eq!(report.requests, 30);
        responses.sort_by_key(|r| r.id);
        for (i, resp) in responses.iter().enumerate() {
            let solo = rt.classify(1, &params, &reqs[i], 0.02).unwrap();
            assert_eq!(
                resp.logits, solo,
                "request {i} (len {}) drifted through the bucketed pool",
                reqs[i].len()
            );
        }
        // token accounting is live and self-consistent: every dispatched
        // token is either a true token or a padded one
        let s = &report.stats;
        assert!(s.tokens_dispatched > 0);
        assert!(s.padded_tokens < s.tokens_dispatched);
        let f = s.padded_token_fraction();
        assert!((0.0..1.0).contains(&f), "padded token fraction {f}");
    }

    #[test]
    fn submit_backpressure_rejects_at_the_admission_bound() {
        let rt = micro_runtime();
        let params = ParamStore::init(&rt.manifest, 0).params;
        // zero workers is impossible (start clamps to 1), so use a long
        // SLO and saturate faster than one worker can drain: with the
        // bound at 4 a burst of submits must hit QueueFull
        let cfg = ServeConfig {
            workers: 1,
            slo: Duration::from_secs(5),
            max_queue: 4,
            sim: None,
            ..Default::default()
        };
        let pool = ServePool::start(&rt, &params, &cfg).unwrap();
        assert_eq!(pool.max_queue(), 4);
        // bad lengths reject before touching the queue
        assert_eq!(
            pool.submit(vec![], 0.0),
            Err(SubmitError::BadLength { got: 0, max_seq: 16 })
        );
        assert_eq!(
            pool.submit(vec![0; 17], 0.0),
            Err(SubmitError::BadLength { got: 17, max_seq: 16 })
        );
        // a 4-request burst holds the bucket below the 8-shape and the
        // 5s SLO keeps it parked, so the 5th submit must bounce
        let reqs = micro_requests(&rt, 4);
        let mut rejected = None;
        for r in reqs {
            pool.submit(r, 0.0).unwrap();
        }
        match pool.submit(micro_requests(&rt, 1).remove(0), 0.0) {
            Err(SubmitError::QueueFull { pending, bound }) => {
                rejected = Some((pending, bound));
            }
            Ok(_) => {}
            Err(e) => panic!("unexpected submit error {e}"),
        }
        // the worker may have claimed the burst already (force is off,
        // but a deadline tick could race); only assert when it bounced
        if let Some((pending, bound)) = rejected {
            assert_eq!(bound, 4);
            assert!(pending >= 1, "pending {pending}");
        }
        let (report, _) = pool.finish().unwrap();
        assert!(report.requests >= 4);
    }

    #[test]
    fn batch_priority_takes_the_laxer_slo_and_still_serves() {
        let rt = micro_runtime();
        let params = ParamStore::init(&rt.manifest, 0).params;
        let cfg = ServeConfig {
            workers: 1,
            slo: Duration::from_millis(2),
            batch_slo: Duration::from_millis(40),
            sim: None,
            ..Default::default()
        };
        let pool = ServePool::start(&rt, &params, &cfg).unwrap();
        let reqs = micro_requests(&rt, 6);
        for (i, r) in reqs.into_iter().enumerate() {
            let pri = if i % 2 == 0 { Priority::Interactive } else { Priority::Batch };
            pool.submit_with_priority(r, 0.0, pri).unwrap();
        }
        let (report, responses) = pool.finish().unwrap();
        assert_eq!(report.requests, 6);
        assert_eq!(responses.len(), 6);
    }

    #[test]
    fn single_model_report_carries_one_matching_section() {
        let rt = micro_runtime();
        let params = ParamStore::init(&rt.manifest, 0).params;
        let cfg = ServeConfig {
            workers: 1,
            slo: Duration::from_millis(2),
            sim: None,
            ..Default::default()
        };
        let pool = ServePool::start(&rt, &params, &cfg).unwrap();
        assert_eq!(pool.models().len(), 1);
        assert_eq!(pool.models()[0].name, "default");
        assert_eq!(pool.models()[0].task, TaskKind::Classify);
        assert_eq!(pool.find_model("default"), Some(0));
        assert_eq!(pool.find_model("nope"), None);
        for r in micro_requests(&rt, 10) {
            pool.submit(r, 0.01).unwrap();
        }
        let (report, _) = pool.finish().unwrap();
        assert_eq!(report.models.len(), 1);
        let section = &report.models[0];
        assert_eq!(section.requests, report.requests);
        assert_eq!(section.stats.dispatches, report.stats.dispatches);
        assert_eq!(section.total_latency.count(), report.total_latency.count());
        // the JSON report always carries the models array
        let j = report.to_json();
        assert!(j.get("models").is_some());
    }

    #[test]
    fn multi_model_pool_serves_both_tasks_with_per_model_sections() {
        // classify and span models sharing one pool: interleaved
        // variable-length traffic, every response bit-identical to a
        // solo native-length run on its own checkpoint, and the report
        // splitting cleanly into per-model sections
        let mut rt_c = micro_runtime();
        let mut rt_s = micro_runtime();
        let params_c = ParamStore::init(&rt_c.manifest, 0).params;
        let params_s = ParamStore::init(&rt_s.manifest, 3).params;
        let cfg = ServeConfig {
            workers: 2,
            slo: Duration::from_millis(2),
            sim: None,
            ..Default::default()
        };
        let pool = ServePool::start_multi(
            vec![
                ModelEntry {
                    name: "classify".into(),
                    task: TaskKind::Classify,
                    runtime: rt_c.fork().unwrap(),
                    params: params_c.clone(),
                    sim: None,
                },
                ModelEntry {
                    name: "span".into(),
                    task: TaskKind::Span,
                    runtime: rt_s.fork().unwrap(),
                    params: params_s.clone(),
                    sim: None,
                },
            ],
            &cfg,
        )
        .unwrap();
        assert_eq!(pool.find_model("classify"), Some(0));
        assert_eq!(pool.find_model("span"), Some(1));
        let snap = pool.snapshot();
        assert_eq!(snap.models.len(), 2);
        assert_eq!(snap.models[1].task, TaskKind::Span);
        assert!(snap.to_json().get("models").is_some());
        let vocab = rt_c.manifest.vocab as i32;
        let reqs: Vec<Vec<i32>> = (0..24usize)
            .map(|i| {
                let len = 1 + (i * 5) % 16;
                (0..len).map(|j| ((i * 7 + j * 3) as i32) % vocab).collect()
            })
            .collect();
        let mut owners = Vec::new();
        for (i, r) in reqs.iter().enumerate() {
            let m = i % 2;
            let id = pool
                .submit_model_with_priority(m, r.clone(), 0.02, Priority::Interactive)
                .unwrap();
            owners.push((id, m, i));
        }
        let (report, responses) = pool.finish().unwrap();
        assert_eq!(report.requests, 24);
        assert_eq!(report.models.len(), 2);
        assert_eq!(report.models[0].name, "classify");
        assert_eq!(report.models[1].name, "span");
        assert_eq!(report.models[0].requests, 12);
        assert_eq!(report.models[1].requests, 12);
        assert_eq!(report.models[1].task, TaskKind::Span);
        let served: u64 = report.models.iter().map(|m| m.stats.served).sum();
        assert_eq!(served, report.stats.served);
        for (id, m, i) in owners {
            let resp = responses.iter().find(|r| r.id == id).unwrap();
            let ids = &reqs[i];
            let l = ids.len();
            if m == 0 {
                let solo = rt_c.classify(1, &params_c, ids, 0.02).unwrap();
                assert_eq!(resp.logits, solo, "classify request {i} drifted");
            } else {
                assert_eq!(resp.logits.len(), 2 * l, "span request {i} logit count");
                let solo = rt_s.span_logits(1, &params_s, ids, 0.02).unwrap();
                for p in 0..l {
                    assert_eq!(
                        resp.logits[p],
                        solo[p * 2],
                        "span request {i} start logit {p}"
                    );
                    assert_eq!(
                        resp.logits[l + p],
                        solo[p * 2 + 1],
                        "span request {i} end logit {p}"
                    );
                }
            }
        }
    }

    #[test]
    fn multi_model_rejects_duplicate_names_and_validates_per_model_seq() {
        let rt = micro_runtime();
        let params = ParamStore::init(&rt.manifest, 0).params;
        let cfg = ServeConfig { workers: 1, sim: None, ..Default::default() };
        let mk = |name: &str| ModelEntry {
            name: name.into(),
            task: TaskKind::Classify,
            runtime: rt.fork().unwrap(),
            params: params.clone(),
            sim: None,
        };
        assert!(ServePool::start_multi(vec![mk("a"), mk("a")], &cfg).is_err());
        assert!(ServePool::start_multi(vec![], &cfg).is_err());
        let pool = ServePool::start_multi(vec![mk("a"), mk("b")], &cfg).unwrap();
        // per-model length validation (both models are seq=16 here)
        assert_eq!(
            pool.submit_model_with_priority(1, vec![0; 17], 0.0, Priority::Interactive),
            Err(SubmitError::BadLength { got: 17, max_seq: 16 })
        );
        let (report, _) = pool.finish().unwrap();
        assert_eq!(report.requests, 0);
        assert_eq!(report.models.len(), 2);
    }
}
