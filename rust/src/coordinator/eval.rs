//! Evaluation loops: accuracy / F1 / activation sparsity over `nlp`
//! datasets through the runtime (any `ExecBackend`) — the drivers
//! behind Figs. 11, 12 and 14.

use anyhow::Result;

use crate::nlp::span::{f1_score, span_f1, SpanDataset};
use crate::nlp::Dataset;
use crate::pruning::profile::Curve;
use crate::runtime::Runtime;

/// One evaluation outcome.
#[derive(Clone, Debug)]
pub struct EvalReport {
    pub accuracy: f64,
    pub f1: f64,
    pub activation_sparsity: f64,
    pub examples: usize,
}

/// Argmax over per-example logits.
fn predictions(logits: &[f32], classes: usize) -> Vec<i32> {
    logits
        .chunks(classes)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as i32)
                .unwrap_or(0)
        })
        .collect()
}

/// Evaluate classification accuracy (+F1 on class 1) on `ds` at DynaTran
/// threshold `tau`, batching through the b32 artifact.
pub fn evaluate_accuracy(
    rt: &mut Runtime,
    params: &[f32],
    ds: &Dataset,
    tau: f32,
    max_examples: usize,
) -> Result<EvalReport> {
    evaluate_inner(rt, params, ds, PruneKnob::Tau(tau), max_examples)
}

/// Evaluate under top-k pruning at `keep_frac`.
pub fn evaluate_topk(
    rt: &mut Runtime,
    params: &[f32],
    ds: &Dataset,
    keep_frac: f32,
    max_examples: usize,
) -> Result<EvalReport> {
    evaluate_inner(rt, params, ds, PruneKnob::KeepFrac(keep_frac), max_examples)
}

enum PruneKnob {
    Tau(f32),
    KeepFrac(f32),
}

fn evaluate_inner(
    rt: &mut Runtime,
    params: &[f32],
    ds: &Dataset,
    knob: PruneKnob,
    max_examples: usize,
) -> Result<EvalReport> {
    let classes = rt.manifest.classes;
    let n = ds.examples.len().min(max_examples.max(1));
    let mut preds: Vec<i32> = Vec::with_capacity(n);
    let mut labels: Vec<i32> = Vec::with_capacity(n);
    let batch = 32usize;
    let mut i = 0usize;
    while i < n {
        let fill = batch.min(n - i);
        let mut ids = Vec::with_capacity(batch * ds.seq);
        for b in 0..batch {
            let ex = &ds.examples[(i + b.min(fill - 1)).min(n - 1)];
            ids.extend_from_slice(&ex.ids);
        }
        let logits = match knob {
            PruneKnob::Tau(tau) => rt.classify(batch, params, &ids, tau)?,
            PruneKnob::KeepFrac(k) => rt.classify_topk(params, &ids, k)?,
        };
        let p = predictions(&logits, classes);
        for b in 0..fill {
            preds.push(p[b]);
            labels.push(ds.examples[i + b].label);
        }
        i += fill;
    }
    let correct = preds
        .iter()
        .zip(&labels)
        .filter(|(p, l)| p == l)
        .count();
    // activation sparsity probe on the first 8 examples
    let mut probe_ids = Vec::with_capacity(8 * ds.seq);
    for b in 0..8 {
        probe_ids.extend_from_slice(&ds.examples[b % n].ids);
    }
    let rho = match knob {
        PruneKnob::Tau(tau) => rt.activation_sparsity(params, &probe_ids, tau)? as f64,
        // top-k only sparsifies attention scores; report the dynatran
        // probe at tau=0 (inherent zeros) plus the attention share — the
        // Fig. 11(b) "net activation sparsity" is computed by the bench
        // from keep_frac directly.
        PruneKnob::KeepFrac(_) => rt.activation_sparsity(params, &probe_ids, 0.0)? as f64,
    };
    Ok(EvalReport {
        accuracy: correct as f64 / preds.len() as f64,
        f1: f1_score(&preds, &labels),
        activation_sparsity: rho,
        examples: preds.len(),
    })
}

/// Decode one row's position-major `(start, end)` logit pairs: argmax
/// start and argmax end independently (the standard extractive decode).
/// An inverted pair (`end < start`) scores as "no answer" in
/// [`span_f1`], so no clamping happens here.
fn span_prediction(row: &[f32], seq: usize) -> (usize, usize) {
    let mut best = (0usize, 0usize);
    let (mut smax, mut emax) = (f32::NEG_INFINITY, f32::NEG_INFINITY);
    for p in 0..seq {
        let s = row[p * 2];
        let e = row[p * 2 + 1];
        if s > smax {
            smax = s;
            best.0 = p;
        }
        if e > emax {
            emax = e;
            best.1 = p;
        }
    }
    best
}

/// Evaluate the span task on `ds` at DynaTran threshold `tau`:
/// `accuracy` is exact-match, `f1` is mean token-overlap [`span_f1`]
/// (the Fig. 14(b) metric; both-no-answer rows score 1.0).
pub fn evaluate_span(
    rt: &mut Runtime,
    params: &[f32],
    ds: &SpanDataset,
    tau: f32,
    max_examples: usize,
) -> Result<EvalReport> {
    let seq = ds.seq;
    let n = ds.examples.len().min(max_examples.max(1));
    let mut exact = 0usize;
    let mut f1_sum = 0.0f64;
    let mut scored = 0usize;
    let batch = 32usize;
    let mut i = 0usize;
    while i < n {
        let fill = batch.min(n - i);
        let mut ids = Vec::with_capacity(batch * seq);
        for b in 0..batch {
            let ex = &ds.examples[(i + b.min(fill - 1)).min(n - 1)];
            ids.extend_from_slice(&ex.ids);
        }
        let logits = rt.span_logits(batch, params, &ids, tau)?;
        for b in 0..fill {
            let row = &logits[b * seq * 2..(b + 1) * seq * 2];
            let pred = span_prediction(row, seq);
            let ex = &ds.examples[i + b];
            let gold = (ex.start, ex.end);
            if pred == gold {
                exact += 1;
            }
            f1_sum += span_f1(pred, gold);
            scored += 1;
        }
        i += fill;
    }
    // activation sparsity probe on the first 8 examples
    let mut probe_ids = Vec::with_capacity(8 * seq);
    for b in 0..8 {
        probe_ids.extend_from_slice(&ds.examples[b % n].ids);
    }
    let rho = rt.activation_sparsity(params, &probe_ids, tau)? as f64;
    Ok(EvalReport {
        accuracy: exact as f64 / scored as f64,
        f1: f1_sum / scored as f64,
        activation_sparsity: rho,
        examples: scored,
    })
}

/// Sweep DynaTran thresholds on the span task — the Fig. 14(b)
/// F1-vs-sparsity curve (`y` is F1, not accuracy).
pub fn sweep_dynatran_span(
    rt: &mut Runtime,
    params: &[f32],
    ds: &SpanDataset,
    taus: &[f32],
    max_examples: usize,
) -> Result<Curve> {
    let mut curve = Curve::new("dynatran-span");
    for &tau in taus {
        let r = evaluate_span(rt, params, ds, tau, max_examples)?;
        curve.push(tau as f64, r.activation_sparsity, r.f1);
    }
    Ok(curve)
}

/// Sweep DynaTran thresholds, producing a Fig. 11(a)/12 curve.
pub fn sweep_dynatran(
    rt: &mut Runtime,
    params: &[f32],
    ds: &Dataset,
    taus: &[f32],
    max_examples: usize,
) -> Result<Curve> {
    let mut curve = Curve::new("dynatran");
    for &tau in taus {
        let r = evaluate_accuracy(rt, params, ds, tau, max_examples)?;
        curve.push(tau as f64, r.activation_sparsity, r.accuracy);
    }
    Ok(curve)
}

/// Sweep top-k keep fractions, producing the Fig. 11(b)/12 baseline curve.
pub fn sweep_topk(
    rt: &mut Runtime,
    params: &[f32],
    ds: &Dataset,
    keep_fracs: &[f32],
    max_examples: usize,
) -> Result<Curve> {
    let mut curve = Curve::new("top-k");
    for &k in keep_fracs {
        let r = evaluate_topk(rt, params, ds, k, max_examples)?;
        // net activation sparsity under top-k: the attention-score share
        // of activations is pruned to (1-k); everything else only has
        // inherent zeros (r.activation_sparsity at tau=0).  The attention
        // share for the synth model (h=128, S=64) is ~0.17 of activation
        // elements; compute it from the manifest shape.
        let s = rt.manifest.seq as f64;
        let h = rt.manifest.hidden as f64;
        let heads = rt.manifest.heads as f64;
        // feed-forward width from the layout itself (ffn.b1's length),
        // so non-4h models report the right share; 4h as a fallback.
        let ff = rt
            .manifest
            .param_specs
            .iter()
            .find(|(name, _, _)| name == "layer0.ffn.b1")
            .map(|(_, shape, _)| shape.iter().product::<usize>() as f64)
            .unwrap_or(4.0 * h);
        let per_layer_attn = 2.0 * heads * s * s;
        let per_layer_rest = 8.0 * s * h + s * ff;
        let attn_share = per_layer_attn / (per_layer_attn + per_layer_rest);
        let rho = r.activation_sparsity * (1.0 - attn_share)
            + attn_share * (1.0 - k as f64);
        curve.push(k as f64, rho, r.accuracy);
    }
    Ok(curve)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn predictions_argmax() {
        let logits = [0.1f32, 0.9, 0.8, 0.2, 0.4, 0.6];
        assert_eq!(predictions(&logits, 2), vec![1, 0, 1]);
    }

    #[test]
    fn predictions_handle_single_class_rows() {
        assert_eq!(predictions(&[1.0, 2.0], 1), vec![0, 0]);
    }

    #[test]
    fn span_prediction_decodes_independent_argmaxes() {
        // seq 3: start logits [0.1, 2.0, -1.0], end logits [0.0, 0.5, 3.0]
        let row = [0.1f32, 0.0, 2.0, 0.5, -1.0, 3.0];
        assert_eq!(span_prediction(&row, 3), (1, 2));
        // inverted pairs are passed through (span_f1 treats them as empty)
        let row = [0.1f32, 3.0, 2.0, 0.5, -1.0, 0.0];
        assert_eq!(span_prediction(&row, 3), (1, 0));
    }
}
