//! End-to-end training driver over the runtime's `train_step` entry
//! point (the reference backend's native backprop + AdamW, or the AOT
//! `train_step_b32` artifact under PJRT).
//!
//! The Rust side owns parameters and optimizer state (`ParamStore`),
//! streams synthetic-sentiment batches, invokes the train step, and logs
//! the loss curve — the "train a small transformer through the full
//! stack" validation recorded in EXPERIMENTS.md, and the fine-tune
//! behind the Figs. 11/12/14 accuracy-vs-sparsity curves.

use anyhow::Result;

use crate::nlp::span::SpanDataset;
use crate::nlp::Dataset;
use crate::runtime::{ParamStore, Runtime};

/// Training log: per-step losses and periodic validation accuracies.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    pub losses: Vec<f32>,
    /// (step, accuracy) checkpoints.
    pub val_accuracy: Vec<(usize, f64)>,
}

impl TrainLog {
    /// Mean loss over the first / last `k` steps (loss-curve summary).
    pub fn head_tail_means(&self, k: usize) -> (f32, f32) {
        let k = k.min(self.losses.len()).max(1);
        let head: f32 = self.losses[..k].iter().sum::<f32>() / k as f32;
        let tail: f32 =
            self.losses[self.losses.len() - k..].iter().sum::<f32>() / k as f32;
        (head, tail)
    }
}

/// Train for `steps` AdamW steps at learning rate `lr`, evaluating on
/// `val` every `eval_every` steps (0 = never).  Parameters and optimizer
/// state update in place inside the `ParamStore`; only the scalar loss
/// crosses the backend boundary per step.
#[allow(clippy::too_many_arguments)]
pub fn train(
    rt: &mut Runtime,
    store: &mut ParamStore,
    train_ds: &Dataset,
    val_ds: Option<&Dataset>,
    steps: usize,
    lr: f32,
    eval_every: usize,
    verbose: bool,
) -> Result<TrainLog> {
    let batch = 32usize;
    let batches = train_ds.batches(batch);
    assert!(!batches.is_empty());
    let mut log = TrainLog::default();
    for step in 0..steps {
        let (ids, labels) = &batches[step % batches.len()];
        let loss = rt.train_step(
            &mut store.params,
            &mut store.m,
            &mut store.v,
            store.step,
            ids,
            labels,
            lr,
        )?;
        store.step += 1.0;
        log.losses.push(loss);
        if verbose && (step % 20 == 0 || step + 1 == steps) {
            println!("  step {step:>4}  loss {loss:.4}");
        }
        if eval_every > 0 && val_ds.is_some() && (step + 1) % eval_every == 0 {
            let r = super::eval::evaluate_accuracy(
                rt,
                &store.params,
                val_ds.unwrap(),
                0.0,
                256,
            )?;
            if verbose {
                println!("  step {:>4}  val accuracy {:.4}", step + 1, r.accuracy);
            }
            log.val_accuracy.push((step + 1, r.accuracy));
        }
    }
    Ok(log)
}

/// Span-task counterpart of [`train`]: streams `(ids, starts, ends)`
/// batches through the backend's `span_train_step`, evaluating
/// token-overlap F1 on `val` every `eval_every` steps.  F1 checkpoints
/// land in `TrainLog::val_accuracy` — the field holds whichever scalar
/// metric the task validates with.
#[allow(clippy::too_many_arguments)]
pub fn train_span(
    rt: &mut Runtime,
    store: &mut ParamStore,
    train_ds: &SpanDataset,
    val_ds: Option<&SpanDataset>,
    steps: usize,
    lr: f32,
    eval_every: usize,
    verbose: bool,
) -> Result<TrainLog> {
    let batch = 32usize;
    let batches = train_ds.batches(batch);
    assert!(!batches.is_empty());
    let mut log = TrainLog::default();
    for step in 0..steps {
        let (ids, starts, ends) = &batches[step % batches.len()];
        let loss = rt.span_train_step(
            &mut store.params,
            &mut store.m,
            &mut store.v,
            store.step,
            ids,
            starts,
            ends,
            lr,
        )?;
        store.step += 1.0;
        log.losses.push(loss);
        if verbose && (step % 20 == 0 || step + 1 == steps) {
            println!("  step {step:>4}  span loss {loss:.4}");
        }
        if eval_every > 0 && val_ds.is_some() && (step + 1) % eval_every == 0 {
            let r = super::eval::evaluate_span(
                rt,
                &store.params,
                val_ds.unwrap(),
                0.0,
                256,
            )?;
            if verbose {
                println!("  step {:>4}  val span F1 {:.4}", step + 1, r.f1);
            }
            log.val_accuracy.push((step + 1, r.f1));
        }
    }
    Ok(log)
}

/// Train-once cache: load trained params from `path` if present,
/// otherwise train `steps` on a fresh synthetic-sentiment corpus and
/// save.  The Figs. 11/12/14 bench harnesses share one trained model
/// this way.  `ACCELTRAN_TRAIN_STEPS` overrides `steps` (the CI smoke
/// job uses it to shrink the fine-tune).  A `<path>.meta` sidecar
/// records the steps/backend a checkpoint was trained under, so a
/// reduced smoke checkpoint is never silently reused by a full-size
/// run (or vice versa).
pub fn ensure_trained(
    rt: &mut Runtime,
    path: &std::path::Path,
    steps: usize,
    verbose: bool,
) -> Result<ParamStore> {
    let steps = std::env::var("ACCELTRAN_TRAIN_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(steps);
    let meta_path = path.with_extension("bin.meta");
    let meta = format!("steps={steps} backend={}", rt.backend_name());
    if path.exists() {
        let cached_meta = std::fs::read_to_string(&meta_path).unwrap_or_default();
        if cached_meta.trim() == meta {
            if let Ok(store) = ParamStore::from_file(&rt.manifest, path) {
                if verbose {
                    println!("loaded cached trained params from {path:?} ({meta})");
                }
                return Ok(store);
            }
        } else if verbose {
            println!(
                "retraining: cached checkpoint was '{}', want '{meta}'",
                cached_meta.trim()
            );
        }
    }
    let task = crate::nlp::sentiment::SentimentTask::new(
        rt.manifest.vocab,
        rt.manifest.seq,
        7,
    );
    let train_ds = task.dataset(4096, 1);
    let mut store = ParamStore::init(&rt.manifest, 0);
    if verbose {
        println!(
            "training {} steps on the {} backend for the evaluation benches...",
            steps,
            rt.backend_name()
        );
    }
    train(rt, &mut store, &train_ds, None, steps, 1e-3, 0, verbose)?;
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    store.save(path)?;
    std::fs::write(&meta_path, &meta).ok();
    Ok(store)
}

/// [`ensure_trained`] for the span task (the Fig. 14(b) fine-tune):
/// same caching and `ACCELTRAN_TRAIN_STEPS` override, training on a
/// fresh synthetic span corpus through `span_train_step`.  The meta
/// sidecar carries a `task=span` tag, so a classify checkpoint at the
/// same path is never mistaken for a span one.
pub fn ensure_trained_span(
    rt: &mut Runtime,
    path: &std::path::Path,
    steps: usize,
    verbose: bool,
) -> Result<ParamStore> {
    let steps = std::env::var("ACCELTRAN_TRAIN_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(steps);
    let meta_path = path.with_extension("bin.meta");
    let meta = format!("task=span steps={steps} backend={}", rt.backend_name());
    if path.exists() {
        let cached_meta = std::fs::read_to_string(&meta_path).unwrap_or_default();
        if cached_meta.trim() == meta {
            if let Ok(store) = ParamStore::from_file(&rt.manifest, path) {
                if verbose {
                    println!("loaded cached trained span params from {path:?} ({meta})");
                }
                return Ok(store);
            }
        } else if verbose {
            println!(
                "retraining span: cached checkpoint was '{}', want '{meta}'",
                cached_meta.trim()
            );
        }
    }
    let task = crate::nlp::span::SpanTask::new(rt.manifest.vocab, rt.manifest.seq);
    let train_ds = task.dataset(4096, 1);
    let mut store = ParamStore::init(&rt.manifest, 0);
    if verbose {
        println!(
            "training span head {} steps on the {} backend...",
            steps,
            rt.backend_name()
        );
    }
    train_span(rt, &mut store, &train_ds, None, steps, 1e-3, 0, verbose)?;
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    store.save(path)?;
    std::fs::write(&meta_path, &meta).ok();
    Ok(store)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_tail_means() {
        let log = TrainLog {
            losses: vec![1.0, 0.9, 0.8, 0.3, 0.2, 0.1],
            val_accuracy: vec![],
        };
        let (head, tail) = log.head_tail_means(2);
        assert!((head - 0.95).abs() < 1e-6);
        assert!((tail - 0.15).abs() < 1e-6);
    }

    #[test]
    fn head_tail_handles_short_logs() {
        let log = TrainLog { losses: vec![0.5], val_accuracy: vec![] };
        let (h, t) = log.head_tail_means(10);
        assert_eq!((h, t), (0.5, 0.5));
    }
}
