//! SST-2-like synthetic sentiment task.
//!
//! Generation model: tokens `[2, vocab)` carry polarity weights drawn
//! from a sparse mixture (most tokens neutral, some strongly signed —
//! mirroring sentiment lexica).  A sequence samples a topic-skewed bag of
//! tokens; its label is `sign(sum of polarities + noise)`.  Token 0 is
//! `[CLS]` (the classification position of the L2 model), token 1 is
//! `[PAD]`.

use super::{Dataset, Example};
use crate::util::rng::Rng;

pub const CLS: i32 = 0;
pub const PAD: i32 = 1;

/// Task generator parameters.
#[derive(Clone, Debug)]
pub struct SentimentTask {
    pub vocab: usize,
    pub seq: usize,
    /// Fraction of lexicon tokens that are polar (non-neutral).
    pub polar_fraction: f64,
    /// Label-noise standard deviation on the polarity sum.
    pub noise: f32,
    /// Per-token polarity weights (index = token id).
    polarity: Vec<f32>,
}

impl SentimentTask {
    pub fn new(vocab: usize, seq: usize, seed: u64) -> SentimentTask {
        let mut rng = Rng::new(seed);
        let polar_fraction = 0.3;
        let mut polarity = vec![0.0f32; vocab];
        for p in polarity.iter_mut().skip(2) {
            if rng.chance(polar_fraction) {
                *p = rng.normal() * 1.0;
            }
        }
        SentimentTask { vocab, seq, polar_fraction, noise: 0.5, polarity }
    }

    /// Sample one example.
    pub fn sample(&self, rng: &mut Rng) -> Example {
        let mut ids = Vec::with_capacity(self.seq);
        ids.push(CLS);
        // topic skew: bias token draws toward a per-sequence polarity
        // direction so sequences are separable but overlapping.
        let skew = rng.normal() * 0.8;
        let mut polarity_sum = 0.0f32;
        let content_len = 2 + rng.index(self.seq - 2);
        for _ in 0..content_len.min(self.seq - 1) {
            // rejection-sample a token leaning toward `skew`
            let mut tok = 2 + rng.index(self.vocab - 2);
            for _ in 0..3 {
                let cand = 2 + rng.index(self.vocab - 2);
                if (self.polarity[cand] - skew).abs()
                    < (self.polarity[tok] - skew).abs()
                {
                    tok = cand;
                }
            }
            polarity_sum += self.polarity[tok];
            ids.push(tok as i32);
        }
        while ids.len() < self.seq {
            ids.push(PAD);
        }
        let label = if polarity_sum + rng.normal() * self.noise > 0.0 {
            1
        } else {
            0
        };
        Example { ids, label }
    }

    /// Generate a dataset split of `n` examples.
    pub fn dataset(&self, n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        Dataset {
            examples: (0..n).map(|_| self.sample(&mut rng)).collect(),
            vocab: self.vocab,
            seq: self.seq,
            classes: 2,
        }
    }

    /// Bayes-ish reference accuracy: classify by the true polarity sum
    /// (no noise knowledge).  Upper-bounds what the model can reach.
    pub fn lexicon_accuracy(&self, ds: &Dataset) -> f64 {
        let mut correct = 0usize;
        for ex in &ds.examples {
            let sum: f32 = ex
                .ids
                .iter()
                .filter(|&&t| t >= 2)
                .map(|&t| self.polarity[t as usize])
                .sum();
            let pred = if sum > 0.0 { 1 } else { 0 };
            if pred == ex.label {
                correct += 1;
            }
        }
        correct as f64 / ds.examples.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task() -> SentimentTask {
        SentimentTask::new(1024, 64, 7)
    }

    #[test]
    fn examples_are_well_formed() {
        let ds = task().dataset(200, 1);
        for ex in &ds.examples {
            assert_eq!(ex.ids.len(), 64);
            assert_eq!(ex.ids[0], CLS);
            assert!(ex.ids.iter().all(|&t| (t as usize) < 1024));
            assert!(ex.label == 0 || ex.label == 1);
        }
    }

    #[test]
    fn labels_are_balanced_ish() {
        let ds = task().dataset(2000, 2);
        let pos = ds.examples.iter().filter(|e| e.label == 1).count();
        let frac = pos as f64 / 2000.0;
        assert!((0.3..0.7).contains(&frac), "pos frac {frac}");
    }

    #[test]
    fn task_is_learnable_by_lexicon() {
        // the generating lexicon must beat chance by a wide margin,
        // otherwise no model could learn it.
        let t = task();
        let ds = t.dataset(2000, 3);
        let acc = t.lexicon_accuracy(&ds);
        assert!(acc > 0.75, "lexicon accuracy {acc}");
    }

    #[test]
    fn deterministic_given_seeds() {
        let a = task().dataset(10, 9);
        let b = task().dataset(10, 9);
        for (x, y) in a.examples.iter().zip(&b.examples) {
            assert_eq!(x.ids, y.ids);
            assert_eq!(x.label, y.label);
        }
    }

    #[test]
    fn train_and_val_differ() {
        let t = task();
        let train = t.dataset(10, 1);
        let val = t.dataset(10, 2);
        assert!(train
            .examples
            .iter()
            .zip(&val.examples)
            .any(|(a, b)| a.ids != b.ids));
    }
}
