//! Synthetic NLP tasks standing in for SST-2 and SQuAD-v2 (paper
//! Sec. IV-A; DESIGN.md §Substitutions explains why the originals are
//! gated behind proprietary-scale pretraining corpora).
//!
//! * [`sentiment`] — an SST-2-like binary sentiment task: sequences are
//!   sampled from a lexicon whose tokens carry latent polarity weights;
//!   the label is the sign of the (noisy) polarity sum.  Linear structure
//!   plus token interactions make it learnable-but-not-trivial for a
//!   BERT-Tiny-scale encoder, producing the accuracy-vs-sparsity curve
//!   shapes of Figs. 11/12/14.
//! * [`span`] — a SQuAD-v2-like *extractive* span task: answerable
//!   examples plant a question-named marker at both endpoints of a
//!   short context span, unanswerable ones label the CLS position, and
//!   predictions are scored with token-overlap F1 (the Fig. 14(b)
//!   metric).

pub mod sentiment;
pub mod span;

/// A tokenized example: fixed-length token ids + integer label.
#[derive(Clone, Debug)]
pub struct Example {
    pub ids: Vec<i32>,
    pub label: i32,
}

/// A dataset split.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub examples: Vec<Example>,
    pub vocab: usize,
    pub seq: usize,
    pub classes: usize,
}

impl Dataset {
    /// Iterate fixed-size batches (the trailing partial batch is padded
    /// by repeating examples, matching the fixed-shape AOT artifacts).
    pub fn batches(&self, batch: usize) -> Vec<(Vec<i32>, Vec<i32>)> {
        assert!(batch > 0 && !self.examples.is_empty());
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.examples.len() {
            let mut ids = Vec::with_capacity(batch * self.seq);
            let mut labels = Vec::with_capacity(batch);
            for b in 0..batch {
                let ex = &self.examples[(i + b) % self.examples.len()];
                ids.extend_from_slice(&ex.ids);
                labels.push(ex.label);
            }
            out.push((ids, labels));
            i += batch;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_pad_by_wrapping() {
        let ds = Dataset {
            examples: (0..5)
                .map(|i| Example { ids: vec![i; 4], label: i })
                .collect(),
            vocab: 10,
            seq: 4,
            classes: 2,
        };
        let bs = ds.batches(2);
        assert_eq!(bs.len(), 3);
        let (ids, labels) = &bs[2];
        assert_eq!(ids.len(), 8);
        assert_eq!(labels, &vec![4, 0]); // wrapped
    }
}
