//! SQuAD-v2-like synthetic span task, scored with F1 (Fig. 14(b) axis).
//!
//! Reduced formulation: a "question" token prefix asks about a marker
//! token; the label is whether a valid answer span (marker followed by a
//! content token within a window) appears in the "context" portion.
//! Like SQuAD-v2, a substantial fraction of examples are unanswerable —
//! so accuracy and F1 diverge and F1 is the meaningful metric.

use super::{Dataset, Example};
use crate::util::rng::Rng;

pub const CLS: i32 = 0;
pub const PAD: i32 = 1;
/// Separator between question and context.
pub const SEP: i32 = 2;

#[derive(Clone, Debug)]
pub struct SpanTask {
    pub vocab: usize,
    pub seq: usize,
    /// Tokens `[3, 3+markers)` act as askable markers.
    pub markers: usize,
    /// Fraction of answerable examples.
    pub answerable: f64,
}

impl SpanTask {
    pub fn new(vocab: usize, seq: usize) -> SpanTask {
        assert!(vocab > 64 && seq >= 16);
        SpanTask { vocab, seq, markers: 16, answerable: 0.55 }
    }

    pub fn sample(&self, rng: &mut Rng) -> Example {
        let marker = 3 + rng.index(self.markers) as i32;
        let answerable = rng.chance(self.answerable);
        let mut ids = vec![CLS, marker, SEP];
        let content_start = ids.len();
        while ids.len() < self.seq {
            let tok = (3 + self.markers) as i32
                + rng.index(self.vocab - 3 - self.markers) as i32;
            ids.push(tok);
        }
        if answerable {
            // plant the marker followed by a content token in the context
            let pos = content_start + rng.index(self.seq - content_start - 1);
            ids[pos] = marker;
        } else {
            // ensure the marker does NOT appear in the context
            for t in ids.iter_mut().skip(content_start) {
                if *t == marker {
                    *t += 1;
                }
            }
        }
        Example { ids, label: answerable as i32 }
    }

    pub fn dataset(&self, n: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        Dataset {
            examples: (0..n).map(|_| self.sample(&mut rng)).collect(),
            vocab: self.vocab,
            seq: self.seq,
            classes: 2,
        }
    }
}

/// Binary F1 with class 1 ("answerable") as the positive class.
pub fn f1_score(predictions: &[i32], labels: &[i32]) -> f64 {
    assert_eq!(predictions.len(), labels.len());
    let mut tp = 0.0;
    let mut fp = 0.0;
    let mut fne = 0.0;
    for (&p, &l) in predictions.iter().zip(labels) {
        match (p, l) {
            (1, 1) => tp += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fne += 1.0,
            _ => {}
        }
    }
    if tp == 0.0 {
        return 0.0;
    }
    let precision = tp / (tp + fp);
    let recall = tp / (tp + fne);
    2.0 * precision * recall / (precision + recall)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn answerable_examples_contain_marker_in_context() {
        let t = SpanTask::new(1024, 64);
        let ds = t.dataset(500, 4);
        for ex in &ds.examples {
            let marker = ex.ids[1];
            let in_context = ex.ids[3..].contains(&marker);
            assert_eq!(in_context, ex.label == 1);
        }
    }

    #[test]
    fn f1_perfect_and_degenerate() {
        assert_eq!(f1_score(&[1, 0, 1], &[1, 0, 1]), 1.0);
        assert_eq!(f1_score(&[0, 0, 0], &[1, 1, 0]), 0.0);
    }

    #[test]
    fn f1_balances_precision_recall() {
        // 2 TP, 2 FP, 0 FN: precision .5, recall 1 -> F1 = 2/3
        let f1 = f1_score(&[1, 1, 1, 1], &[1, 1, 0, 0]);
        assert!((f1 - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn answerable_fraction_matches() {
        let t = SpanTask::new(1024, 64);
        let ds = t.dataset(2000, 5);
        let frac = ds.examples.iter().filter(|e| e.label == 1).count() as f64
            / 2000.0;
        assert!((frac - t.answerable).abs() < 0.05, "frac {frac}");
    }
}
