//! SQuAD-v2-like synthetic *extractive* span task (the Fig. 14(b)
//! axis).
//!
//! Positional formulation: every sequence is `[CLS, marker, SEP,
//! content...]` — the "question" names a marker token, and answerable
//! examples plant that marker at the answer-span start and again at its
//! end (spans of 1..=`max_span` context tokens).  The model emits
//! start/end logits over positions and must point both at the planted
//! span.  Like SQuAD-v2 a substantial fraction of examples are
//! unanswerable; those are labelled `(start, end) = (0, 0)` — the CLS
//! position — exactly the no-answer convention of the original
//! benchmark, and the reason token-overlap F1 (not exact accuracy) is
//! the meaningful metric.
//!
//! Content tokens are drawn from `[3 + markers, vocab)`, so a marker
//! can appear in the context only where the task planted it: the task
//! is solvable by attending from the question marker to its context
//! occurrences, which a BERT-Tiny-scale encoder learns in a few hundred
//! AdamW steps.

use crate::util::rng::Rng;

pub const CLS: i32 = 0;
pub const PAD: i32 = 1;
/// Separator between question and context.
pub const SEP: i32 = 2;

/// A tokenized span example: token ids plus the inclusive answer span
/// `[start, end]` in position space.  `(0, 0)` — pointing at CLS — means
/// "no answer" (SQuAD-v2 convention).
#[derive(Clone, Debug, PartialEq)]
pub struct SpanExample {
    pub ids: Vec<i32>,
    pub start: usize,
    pub end: usize,
}

impl SpanExample {
    /// Whether the example carries a real answer span.
    pub fn answerable(&self) -> bool {
        !(self.start == 0 && self.end == 0)
    }
}

/// A span-task dataset split.
#[derive(Clone, Debug)]
pub struct SpanDataset {
    pub examples: Vec<SpanExample>,
    pub vocab: usize,
    pub seq: usize,
}

impl SpanDataset {
    /// Iterate fixed-size `(ids, starts, ends)` batches; the trailing
    /// partial batch is padded by wrapping, matching
    /// [`super::Dataset::batches`].
    pub fn batches(&self, batch: usize) -> Vec<(Vec<i32>, Vec<i32>, Vec<i32>)> {
        assert!(batch > 0 && !self.examples.is_empty());
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.examples.len() {
            let mut ids = Vec::with_capacity(batch * self.seq);
            let mut starts = Vec::with_capacity(batch);
            let mut ends = Vec::with_capacity(batch);
            for b in 0..batch {
                let ex = &self.examples[(i + b) % self.examples.len()];
                ids.extend_from_slice(&ex.ids);
                starts.push(ex.start as i32);
                ends.push(ex.end as i32);
            }
            out.push((ids, starts, ends));
            i += batch;
        }
        out
    }
}

#[derive(Clone, Debug)]
pub struct SpanTask {
    pub vocab: usize,
    pub seq: usize,
    /// Tokens `[3, 3+markers)` act as askable markers.
    pub markers: usize,
    /// Fraction of answerable examples.
    pub answerable: f64,
    /// Longest planted span, in tokens (spans are 1..=`max_span`).
    pub max_span: usize,
}

impl SpanTask {
    pub fn new(vocab: usize, seq: usize) -> SpanTask {
        assert!(vocab > 64 && seq >= 16);
        SpanTask { vocab, seq, markers: 16, answerable: 0.55, max_span: 3 }
    }

    pub fn sample(&self, rng: &mut Rng) -> SpanExample {
        let marker = 3 + rng.index(self.markers) as i32;
        let mut ids = vec![CLS, marker, SEP];
        let content_start = ids.len();
        // content can never collide with a marker: its token range
        // starts above the marker block
        while ids.len() < self.seq {
            let tok = (3 + self.markers) as i32
                + rng.index(self.vocab - 3 - self.markers) as i32;
            ids.push(tok);
        }
        if rng.chance(self.answerable) {
            let span_len = 1 + rng.index(self.max_span);
            let start = content_start
                + rng.index(self.seq - content_start - span_len + 1);
            let end = start + span_len - 1;
            // plant the asked-about marker at both span endpoints (the
            // same cell for a length-1 span)
            ids[start] = marker;
            ids[end] = marker;
            SpanExample { ids, start, end }
        } else {
            SpanExample { ids, start: 0, end: 0 }
        }
    }

    pub fn dataset(&self, n: usize, seed: u64) -> SpanDataset {
        let mut rng = Rng::new(seed);
        SpanDataset {
            examples: (0..n).map(|_| self.sample(&mut rng)).collect(),
            vocab: self.vocab,
            seq: self.seq,
        }
    }
}

/// Token-overlap F1 between a predicted and a gold inclusive span (the
/// SQuAD metric).  Both-no-answer scores 1.0, a one-sided no-answer 0.0,
/// and an inverted prediction (`end < start`) counts as empty.
pub fn span_f1(pred: (usize, usize), gold: (usize, usize)) -> f64 {
    let no_pred = pred == (0, 0) || pred.1 < pred.0;
    let no_gold = gold == (0, 0);
    if no_pred || no_gold {
        return (no_pred == no_gold) as i32 as f64;
    }
    let (ps, pe) = pred;
    let (gs, ge) = gold;
    let lo = ps.max(gs);
    let hi = pe.min(ge);
    if hi < lo {
        return 0.0;
    }
    let overlap = (hi - lo + 1) as f64;
    let precision = overlap / (pe - ps + 1) as f64;
    let recall = overlap / (ge - gs + 1) as f64;
    2.0 * precision * recall / (precision + recall)
}

/// Binary F1 with class 1 as the positive class (the classification
/// tasks' second metric; the span task scores with [`span_f1`]).
pub fn f1_score(predictions: &[i32], labels: &[i32]) -> f64 {
    assert_eq!(predictions.len(), labels.len());
    let mut tp = 0.0;
    let mut fp = 0.0;
    let mut fne = 0.0;
    for (&p, &l) in predictions.iter().zip(labels) {
        match (p, l) {
            (1, 1) => tp += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fne += 1.0,
            _ => {}
        }
    }
    if tp == 0.0 {
        return 0.0;
    }
    let precision = tp / (tp + fp);
    let recall = tp / (tp + fne);
    2.0 * precision * recall / (precision + recall)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn answerable_examples_plant_marker_at_both_endpoints() {
        let t = SpanTask::new(1024, 64);
        let ds = t.dataset(500, 4);
        for ex in &ds.examples {
            let marker = ex.ids[1];
            if ex.answerable() {
                assert!(ex.start >= 3 && ex.end < t.seq);
                assert!(ex.end >= ex.start);
                assert!(ex.end - ex.start < t.max_span);
                assert_eq!(ex.ids[ex.start], marker);
                assert_eq!(ex.ids[ex.end], marker);
                // no stray occurrences outside the planted span
                for (p, &tok) in ex.ids.iter().enumerate().skip(3) {
                    if tok == marker {
                        assert!(
                            (ex.start..=ex.end).contains(&p),
                            "stray marker at {p}"
                        );
                    }
                }
            } else {
                assert_eq!((ex.start, ex.end), (0, 0));
                assert!(
                    !ex.ids[3..].contains(&marker),
                    "unanswerable context contains the marker"
                );
            }
        }
    }

    #[test]
    fn answerable_fraction_matches() {
        let t = SpanTask::new(1024, 64);
        let ds = t.dataset(2000, 5);
        let frac =
            ds.examples.iter().filter(|e| e.answerable()).count() as f64
                / 2000.0;
        assert!((frac - t.answerable).abs() < 0.05, "frac {frac}");
    }

    #[test]
    fn span_batches_wrap() {
        let t = SpanTask::new(1024, 64);
        let ds = t.dataset(5, 9);
        let bs = ds.batches(2);
        assert_eq!(bs.len(), 3);
        let (ids, starts, ends) = &bs[2];
        assert_eq!(ids.len(), 2 * 64);
        assert_eq!(starts.len(), 2);
        // wrapped row repeats example 0
        assert_eq!(&ids[64..], &ds.examples[0].ids[..]);
        assert_eq!(starts[1], ds.examples[0].start as i32);
        assert_eq!(ends[1], ds.examples[0].end as i32);
    }

    #[test]
    fn span_f1_exact_partial_and_no_answer() {
        assert_eq!(span_f1((5, 7), (5, 7)), 1.0);
        assert_eq!(span_f1((0, 0), (0, 0)), 1.0);
        assert_eq!(span_f1((0, 0), (5, 7)), 0.0);
        assert_eq!(span_f1((5, 7), (0, 0)), 0.0);
        assert_eq!(span_f1((4, 9), (10, 12)), 0.0);
        // inverted prediction counts as empty
        assert_eq!(span_f1((9, 4), (5, 7)), 0.0);
        // pred [5,6], gold [6,7]: overlap 1, p=.5, r=.5 -> F1 .5
        assert!((span_f1((5, 6), (6, 7)) - 0.5).abs() < 1e-12);
        // pred [5,7], gold [5,5]: overlap 1, p=1/3, r=1 -> F1 .5
        assert!((span_f1((5, 7), (5, 5)) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn f1_perfect_and_degenerate() {
        assert_eq!(f1_score(&[1, 0, 1], &[1, 0, 1]), 1.0);
        assert_eq!(f1_score(&[0, 0, 0], &[1, 1, 0]), 0.0);
    }

    #[test]
    fn f1_balances_precision_recall() {
        // 2 TP, 2 FP, 0 FN: precision .5, recall 1 -> F1 = 2/3
        let f1 = f1_score(&[1, 1, 1, 1], &[1, 1, 0, 0]);
        assert!((f1 - 2.0 / 3.0).abs() < 1e-12);
    }
}
