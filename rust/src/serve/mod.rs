//! Network-facing serving layer.
//!
//! [`crate::coordinator::serve`] is the in-process serving engine — a
//! worker pool draining a deadline-batched queue.  This module is what
//! puts it on the wire: [`net`] wraps one or more `ServePool`s behind a
//! hand-rolled HTTP/1.1 front-end with a sharded router, graceful
//! drain, and a live `/stats` endpoint (DESIGN.md "Network front-end").
//!
//! The split mirrors the paper's serving framing (Sec. V-E compares
//! AccelTran-Server against Energon on *sustained* request throughput):
//! an accelerator only wins if the host front-end keeps it fed at line
//! rate, so request ingest, validation, and routing live in their own
//! layer that can be hardened and measured independently of the
//! execution pools behind it.

pub mod net;
