//! Minimal HTTP/1.1 wire protocol: bounded request parsing and response
//! writing over any `Read`/`Write` pair (hyper is not vendored; the
//! subset here — request line, headers, `Content-Length` bodies,
//! keep-alive — is what `curl`, browsers, and the in-crate
//! [`super::client`] speak for JSON APIs).
//!
//! Every read is bounded: header bytes and count are capped, bodies are
//! capped *before* allocation, and each request is read under a
//! wall-clock budget ([`RequestTimer`], armed alongside the caller's
//! per-read socket timeout) — so a slow-loris or oversized client costs
//! one connection thread a bounded wait, never a serving worker
//! (DESIGN.md "Network front-end").  The socket timeout alone is not
//! enough: it resets on every successful read, so a peer dripping one
//! byte per interval would otherwise hold a thread for
//! `max_header_bytes × read_timeout`.

use std::io::{BufRead, Write};
use std::time::{Duration, Instant};

/// Hard ceilings for one request (defaults are generous for JSON
/// classify bodies and hostile-input-safe).
#[derive(Clone, Debug)]
pub struct Limits {
    /// Total request-line + header bytes (431 when exceeded).
    pub max_header_bytes: usize,
    /// Header count (431 when exceeded).
    pub max_headers: usize,
    /// `Content-Length` ceiling, checked before the body buffer is
    /// allocated (413 when exceeded).
    pub max_body_bytes: usize,
    /// Socket read timeout the connection handler arms; a peer that
    /// stalls mid-request longer than this gets 408 and the connection
    /// is closed.
    pub read_timeout: Duration,
    /// Wall-clock budget for reading one full request (head + body),
    /// counted from its first byte.  The per-read socket timeout resets
    /// on every successful read, so on its own it lets a peer drip one
    /// byte per interval ~forever; this cap bounds the whole request
    /// (408 when exceeded).
    pub max_request_time: Duration,
}

impl Default for Limits {
    fn default() -> Self {
        Limits {
            max_header_bytes: 8 << 10,
            max_headers: 64,
            max_body_bytes: 1 << 20,
            read_timeout: Duration::from_millis(2000),
            max_request_time: Duration::from_millis(8000),
        }
    }
}

/// Wall-clock budget for one request, shared between the head and body
/// reads.  The clock starts at the request's *first byte* (an idle
/// keep-alive connection waiting for a request is governed by the
/// socket timeout instead), and every subsequent read ticks it; once
/// `max_request_time` has elapsed the request fails with
/// [`RecvError::Timeout`] no matter how steadily the peer drips bytes.
pub struct RequestTimer {
    budget: Duration,
    started: Option<Instant>,
}

impl RequestTimer {
    /// Fresh timer for one request, budgeted by `limits`.
    pub fn new(limits: &Limits) -> RequestTimer {
        RequestTimer { budget: limits.max_request_time, started: None }
    }

    /// Record read progress; fails once the budget is spent.  The first
    /// call starts the clock.
    fn tick(&mut self, mid_request: bool) -> Result<(), RecvError> {
        let started = *self.started.get_or_insert_with(Instant::now);
        if started.elapsed() > self.budget {
            return Err(RecvError::Timeout { mid_request });
        }
        Ok(())
    }
}

/// Why a request could not be read; the connection handler maps each
/// variant to a status code (or a silent close).
#[derive(Debug)]
pub enum RecvError {
    /// Clean EOF before any byte of a new request — the peer ended a
    /// keep-alive session; close silently.
    Closed,
    /// The socket read timed out.  `mid_request` distinguishes an idle
    /// keep-alive connection (close silently) from a peer that stalled
    /// partway through a request (408).
    Timeout {
        /// Whether any bytes of the current request had arrived.
        mid_request: bool,
    },
    /// A limit in [`Limits`] was exceeded; `what` is `"header"` (431)
    /// or `"body"` (413).
    TooLarge {
        /// Which limit tripped.
        what: &'static str,
    },
    /// Not parseable as HTTP/1.x (400).
    Malformed(String),
    /// Parseable but outside the supported subset, e.g. chunked
    /// transfer encoding (501).
    Unsupported(String),
    /// Transport error other than a timeout; close silently.
    Io(std::io::Error),
}

impl std::fmt::Display for RecvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RecvError::Closed => write!(f, "connection closed"),
            RecvError::Timeout { mid_request } => {
                write!(f, "read timeout (mid_request={mid_request})")
            }
            RecvError::TooLarge { what } => write!(f, "{what} too large"),
            RecvError::Malformed(m) => write!(f, "malformed request: {m}"),
            RecvError::Unsupported(m) => write!(f, "unsupported: {m}"),
            RecvError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for RecvError {}

fn map_io(e: std::io::Error, mid_request: bool) -> RecvError {
    match e.kind() {
        // platform-dependent: unix read timeouts surface as WouldBlock,
        // windows as TimedOut
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => {
            RecvError::Timeout { mid_request }
        }
        _ => RecvError::Io(e),
    }
}

/// Request line + headers of one request (header names lowercased at
/// parse time; values trimmed).
#[derive(Clone, Debug)]
pub struct HttpHead {
    /// Verb, as sent (`GET`, `POST`, ...).
    pub method: String,
    /// Request target, e.g. `/v1/classify` (query strings are kept
    /// as-is; the routes this server exposes don't use them).
    pub path: String,
    /// `(lowercased-name, value)` pairs in arrival order.
    pub headers: Vec<(String, String)>,
}

impl HttpHead {
    /// First header with this (lowercase) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }

    /// Declared body length: 0 when absent, `Err` when present but not
    /// a decimal integer — or when the header appears more than once.
    /// Duplicate `Content-Length` headers (even agreeing ones) are a
    /// classic request-smuggling desync vector behind a front proxy
    /// that resolves the conflict differently, so they are rejected
    /// outright, mirroring the JSON layer's duplicate-key rejection.
    pub fn content_length(&self) -> Result<usize, RecvError> {
        let mut found: Option<&str> = None;
        for (n, v) in &self.headers {
            if n == "content-length" {
                if found.is_some() {
                    return Err(RecvError::Malformed(
                        "multiple content-length headers".into(),
                    ));
                }
                found = Some(v);
            }
        }
        match found {
            None => Ok(0),
            Some(v) => v.trim().parse().map_err(|_| {
                RecvError::Malformed(format!("bad content-length '{v}'"))
            }),
        }
    }

    /// Whether the peer asked to end the session after this exchange.
    pub fn wants_close(&self) -> bool {
        self.header("connection")
            .map(|v| v.eq_ignore_ascii_case("close"))
            .unwrap_or(false)
    }

    /// Whether the peer sent `Expect: 100-continue` and is waiting for
    /// the interim response before transmitting the body (curl does
    /// this for larger POST bodies).
    pub fn expects_continue(&self) -> bool {
        self.header("expect")
            .map(|v| v.eq_ignore_ascii_case("100-continue"))
            .unwrap_or(false)
    }
}

/// One `\r\n`-terminated line with the header-byte budget enforced;
/// `budget` is decremented by the bytes consumed.  Every byte ticks
/// `timer`, so a peer dripping header bytes under the socket timeout
/// still runs out of wall clock.
fn read_line(
    r: &mut impl BufRead,
    budget: &mut usize,
    timer: &mut RequestTimer,
    mid_request: bool,
) -> Result<String, RecvError> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let mut byte = [0u8; 1];
        // byte-at-a-time keeps the logic simple and is fine behind a
        // BufReader (the syscall count is unchanged)
        let n = std::io::Read::read(r, &mut byte)
            .map_err(|e| map_io(e, mid_request || !buf.is_empty()))?;
        if n == 0 {
            if buf.is_empty() && !mid_request {
                return Err(RecvError::Closed);
            }
            return Err(RecvError::Malformed("unexpected eof".into()));
        }
        timer.tick(mid_request || !buf.is_empty())?;
        if *budget == 0 {
            return Err(RecvError::TooLarge { what: "header" });
        }
        *budget -= 1;
        if byte[0] == b'\n' {
            if buf.last() == Some(&b'\r') {
                buf.pop();
            }
            return String::from_utf8(buf)
                .map_err(|_| RecvError::Malformed("non-utf8 header".into()));
        }
        buf.push(byte[0]);
    }
}

/// Read one request head (request line + headers) within `limits`.
/// [`RecvError::Closed`] means the peer cleanly ended the keep-alive
/// session before starting a request.
pub fn read_head(
    r: &mut impl BufRead,
    limits: &Limits,
    timer: &mut RequestTimer,
) -> Result<HttpHead, RecvError> {
    let mut budget = limits.max_header_bytes;
    // tolerate stray blank line(s) between pipelined requests
    let mut line = read_line(r, &mut budget, timer, false)?;
    while line.is_empty() {
        line = read_line(r, &mut budget, timer, false)?;
    }
    let mut parts = line.split_whitespace();
    let (method, path, version) =
        match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(p), Some(v), None) => {
                (m.to_string(), p.to_string(), v)
            }
            _ => {
                return Err(RecvError::Malformed(format!(
                    "bad request line '{}'",
                    line.chars().take(80).collect::<String>()
                )))
            }
        };
    if !version.starts_with("HTTP/1.") {
        return Err(RecvError::Malformed(format!("bad version '{version}'")));
    }
    let mut headers = Vec::new();
    loop {
        let line = read_line(r, &mut budget, timer, true)?;
        if line.is_empty() {
            break;
        }
        if headers.len() >= limits.max_headers {
            return Err(RecvError::TooLarge { what: "header" });
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(RecvError::Malformed(format!(
                "bad header line '{}'",
                line.chars().take(80).collect::<String>()
            )));
        };
        headers.push((
            name.trim().to_ascii_lowercase(),
            value.trim().to_string(),
        ));
    }
    Ok(HttpHead { method, path, headers })
}

/// Validate `head`'s body declaration against `limits` without reading
/// anything: rejects transfer encodings this server does not speak and
/// a `Content-Length` past the cap, returning the declared length.
/// Shared by [`read_body`] and the connection handler's
/// `Expect: 100-continue` path — an oversized body must be refused
/// *before* the interim `100 Continue` invites the peer to transmit it.
pub fn check_body_limits(
    head: &HttpHead,
    limits: &Limits,
) -> Result<usize, RecvError> {
    if let Some(te) = head.header("transfer-encoding") {
        return Err(RecvError::Unsupported(format!(
            "transfer-encoding '{te}' (send Content-Length)"
        )));
    }
    let len = head.content_length()?;
    if len > limits.max_body_bytes {
        return Err(RecvError::TooLarge { what: "body" });
    }
    Ok(len)
}

/// Chunk size for body reads — small enough that the request timer is
/// ticked often, large enough that a full-size body costs few reads.
const BODY_CHUNK: usize = 64 << 10;

/// Read the request body declared by `head` within `limits`.  Checks
/// the length cap *before* allocating, rejects transfer encodings this
/// server does not speak, and ticks `timer` between chunks so a
/// dripped body runs out of wall clock.
pub fn read_body(
    r: &mut impl BufRead,
    head: &HttpHead,
    limits: &Limits,
    timer: &mut RequestTimer,
) -> Result<Vec<u8>, RecvError> {
    let len = check_body_limits(head, limits)?;
    let mut body = vec![0u8; len];
    let mut off = 0;
    while off < len {
        let end = (off + BODY_CHUNK).min(len);
        match std::io::Read::read(r, &mut body[off..end]) {
            Ok(0) => {
                return Err(RecvError::Malformed("body truncated".into()))
            }
            Ok(n) => off += n,
            Err(e) => return Err(map_io(e, true)),
        }
        timer.tick(true)?;
    }
    Ok(body)
}

/// Canonical reason phrase for the status codes this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        100 => "Continue",
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Write one response with an explicit `Content-Length` (the only
/// framing this server uses) and flush it.
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    write_response_with(w, status, content_type, &[], body, keep_alive)
}

/// Like [`write_response`] but with extra response headers (name,
/// value) inserted before the blank line — the 429 path uses this for
/// `Retry-After`.
pub fn write_response_with(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, String)],
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {status} {}\r\nServer: acceltran\r\nContent-Type: \
         {content_type}\r\nContent-Length: {}\r\nConnection: {}\r\n",
        reason(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    )?;
    for (name, value) in extra_headers {
        write!(w, "{name}: {value}\r\n")?;
    }
    w.write_all(b"\r\n")?;
    w.write_all(body)?;
    w.flush()
}

/// Write the interim `100 Continue` response (no headers, no body).
pub fn write_continue(w: &mut impl Write) -> std::io::Result<()> {
    w.write_all(b"HTTP/1.1 100 Continue\r\n\r\n")?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn timer() -> RequestTimer {
        RequestTimer::new(&Limits::default())
    }

    fn head_of(text: &str) -> Result<HttpHead, RecvError> {
        read_head(
            &mut Cursor::new(text.as_bytes()),
            &Limits::default(),
            &mut timer(),
        )
    }

    #[test]
    fn parses_request_line_and_headers() {
        let h = head_of(
            "POST /v1/classify HTTP/1.1\r\nHost: x\r\nContent-Length: 5\r\n\
             Content-Type: application/json\r\n\r\nhello",
        )
        .unwrap();
        assert_eq!(h.method, "POST");
        assert_eq!(h.path, "/v1/classify");
        assert_eq!(h.header("host"), Some("x"));
        assert_eq!(h.content_length().unwrap(), 5);
        assert!(!h.wants_close());
        assert!(!h.expects_continue());
    }

    #[test]
    fn header_names_are_case_insensitive_values_trimmed() {
        let h = head_of("GET / HTTP/1.1\r\nCONNECTION:   close  \r\n\r\n")
            .unwrap();
        assert!(h.wants_close());
        assert_eq!(h.header("connection"), Some("close"));
    }

    #[test]
    fn body_reads_exactly_content_length() {
        let text = "POST / HTTP/1.1\r\nContent-Length: 4\r\n\r\nabcdEXTRA";
        let mut r = Cursor::new(text.as_bytes());
        let limits = Limits::default();
        let h = read_head(&mut r, &limits, &mut timer()).unwrap();
        let body = read_body(&mut r, &h, &limits, &mut timer()).unwrap();
        assert_eq!(body, b"abcd");
        // the EXTRA bytes stay buffered for the next (pipelined) request
        let mut rest = Vec::new();
        std::io::Read::read_to_end(&mut r, &mut rest).unwrap();
        assert_eq!(rest, b"EXTRA");
    }

    #[test]
    fn clean_eof_is_closed_partial_is_malformed() {
        assert!(matches!(head_of(""), Err(RecvError::Closed)));
        assert!(matches!(
            head_of("GET / HT"),
            Err(RecvError::Malformed(_))
        ));
        assert!(matches!(
            head_of("GET / HTTP/1.1\r\nHost: x"),
            Err(RecvError::Malformed(_))
        ));
    }

    #[test]
    fn oversized_header_is_rejected() {
        let mut limits = Limits::default();
        limits.max_header_bytes = 64;
        let long = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(100));
        let got = read_head(&mut Cursor::new(long.as_bytes()), &limits, &mut timer());
        assert!(matches!(got, Err(RecvError::TooLarge { what: "header" })));
        // header *count* cap too
        let mut limits = Limits::default();
        limits.max_headers = 2;
        let many = "GET / HTTP/1.1\r\na: 1\r\nb: 2\r\nc: 3\r\n\r\n";
        let got = read_head(&mut Cursor::new(many.as_bytes()), &limits, &mut timer());
        assert!(matches!(got, Err(RecvError::TooLarge { what: "header" })));
    }

    #[test]
    fn oversized_body_rejected_before_allocation() {
        let limits = Limits { max_body_bytes: 8, ..Limits::default() };
        // content-length lies far past the cap; read_body must refuse
        // without trying to allocate or read it
        let text = "POST / HTTP/1.1\r\nContent-Length: 999999999999\r\n\r\n";
        let mut r = Cursor::new(text.as_bytes());
        let h = read_head(&mut r, &limits, &mut timer()).unwrap();
        let got = read_body(&mut r, &h, &limits, &mut timer());
        assert!(matches!(got, Err(RecvError::TooLarge { what: "body" })));
    }

    #[test]
    fn chunked_encoding_is_unsupported() {
        let text = "POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n";
        let mut r = Cursor::new(text.as_bytes());
        let h = read_head(&mut r, &Limits::default(), &mut timer()).unwrap();
        assert!(matches!(
            read_body(&mut r, &h, &Limits::default(), &mut timer()),
            Err(RecvError::Unsupported(_))
        ));
    }

    #[test]
    fn truncated_body_is_malformed() {
        let text = "POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc";
        let mut r = Cursor::new(text.as_bytes());
        let h = read_head(&mut r, &Limits::default(), &mut timer()).unwrap();
        assert!(matches!(
            read_body(&mut r, &h, &Limits::default(), &mut timer()),
            Err(RecvError::Malformed(_))
        ));
    }

    #[test]
    fn bad_content_length_is_malformed() {
        let h = head_of("POST / HTTP/1.1\r\nContent-Length: ten\r\n\r\n")
            .unwrap();
        assert!(matches!(
            h.content_length(),
            Err(RecvError::Malformed(_))
        ));
    }

    #[test]
    fn pipelined_heads_parse_back_to_back() {
        let two = "GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\n\r\n";
        let mut r = Cursor::new(two.as_bytes());
        let limits = Limits::default();
        assert_eq!(read_head(&mut r, &limits, &mut timer()).unwrap().path, "/a");
        assert_eq!(read_head(&mut r, &limits, &mut timer()).unwrap().path, "/b");
        assert!(matches!(
            read_head(&mut r, &limits, &mut timer()),
            Err(RecvError::Closed)
        ));
    }

    #[test]
    fn duplicate_content_length_is_rejected() {
        // differing values: the textbook smuggling desync
        let h = head_of(
            "POST / HTTP/1.1\r\nContent-Length: 4\r\nContent-Length: 9\r\n\r\n",
        )
        .unwrap();
        assert!(matches!(h.content_length(), Err(RecvError::Malformed(_))));
        // agreeing duplicates are rejected too — a front proxy may
        // collapse or reorder them differently than we would
        let h = head_of(
            "POST / HTTP/1.1\r\nContent-Length: 4\r\nContent-Length: 4\r\n\r\n",
        )
        .unwrap();
        assert!(matches!(h.content_length(), Err(RecvError::Malformed(_))));
        // ...and read_body refuses the request without reading a byte
        let text = "POST / HTTP/1.1\r\nContent-Length: 4\r\n\
                    Content-Length: 4\r\n\r\nabcd";
        let mut r = Cursor::new(text.as_bytes());
        let h = read_head(&mut r, &Limits::default(), &mut timer()).unwrap();
        assert!(matches!(
            read_body(&mut r, &h, &Limits::default(), &mut timer()),
            Err(RecvError::Malformed(_))
        ));
    }

    #[test]
    fn check_body_limits_refuses_before_reading() {
        let limits = Limits { max_body_bytes: 8, ..Limits::default() };
        let h = head_of("POST / HTTP/1.1\r\nContent-Length: 9\r\n\r\n")
            .unwrap();
        assert!(matches!(
            check_body_limits(&h, &limits),
            Err(RecvError::TooLarge { what: "body" })
        ));
        let h = head_of(
            "POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n",
        )
        .unwrap();
        assert!(matches!(
            check_body_limits(&h, &limits),
            Err(RecvError::Unsupported(_))
        ));
        let h = head_of("POST / HTTP/1.1\r\nContent-Length: 8\r\n\r\n")
            .unwrap();
        assert_eq!(check_body_limits(&h, &limits).unwrap(), 8);
    }

    /// Yields one byte per read with a fixed delay — a loopback
    /// slow-loris that never trips a per-read socket timeout.
    struct DripReader {
        data: Vec<u8>,
        pos: usize,
        delay: Duration,
    }

    impl std::io::Read for DripReader {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if self.pos >= self.data.len() {
                return Ok(0);
            }
            std::thread::sleep(self.delay);
            buf[0] = self.data[self.pos];
            self.pos += 1;
            Ok(1)
        }
    }

    #[test]
    fn slow_loris_drip_hits_wall_clock_deadline() {
        // each 2ms byte-read succeeds, so a per-read timeout would
        // never fire — the request timer must cut the drip off as a
        // mid-request timeout (408), not let it run to completion
        let limits = Limits {
            max_request_time: Duration::from_millis(20),
            ..Limits::default()
        };
        let drip = DripReader {
            data: format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(512))
                .into_bytes(),
            pos: 0,
            delay: Duration::from_millis(2),
        };
        let mut r = std::io::BufReader::new(drip);
        let mut t = RequestTimer::new(&limits);
        let got = read_head(&mut r, &limits, &mut t);
        assert!(
            matches!(got, Err(RecvError::Timeout { mid_request: true })),
            "{got:?}"
        );
    }

    #[test]
    fn dripped_body_hits_wall_clock_deadline() {
        let limits = Limits {
            max_request_time: Duration::from_millis(20),
            ..Limits::default()
        };
        // head arrives instantly (and starts the shared clock); only
        // the body drips
        let head = "POST / HTTP/1.1\r\nContent-Length: 512\r\n\r\n";
        let mut t = RequestTimer::new(&limits);
        let h = read_head(
            &mut Cursor::new(head.as_bytes()),
            &limits,
            &mut t,
        )
        .unwrap();
        let drip = DripReader {
            data: vec![b'x'; 512],
            pos: 0,
            delay: Duration::from_millis(2),
        };
        let mut r = std::io::BufReader::new(drip);
        let got = read_body(&mut r, &h, &limits, &mut t);
        assert!(
            matches!(got, Err(RecvError::Timeout { mid_request: true })),
            "{got:?}"
        );
    }

    #[test]
    fn response_writer_is_parseable() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "application/json", b"{}", true)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: 2\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(text.ends_with("\r\n\r\n{}"));
        let mut out = Vec::new();
        write_response(&mut out, 503, "application/json", b"x", false)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"));
        assert!(text.contains("Connection: close\r\n"));
    }

    #[test]
    fn extra_headers_land_before_the_blank_line() {
        let mut out = Vec::new();
        write_response_with(
            &mut out,
            429,
            "application/json",
            &[("Retry-After", "1".to_string())],
            b"{}",
            true,
        )
        .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("Retry-After: 1\r\n"));
        // headers end exactly once, body follows
        assert!(text.ends_with("\r\n\r\n{}"), "{text}");
        assert_eq!(text.matches("\r\n\r\n").count(), 1, "{text}");
    }
}
