//! Minimal HTTP/1.1 client for loopback use: the hermetic end-to-end
//! tests, the `http_serve` load generator, and the transport-overhead
//! bench.  Speaks exactly the subset the server emits —
//! `Content-Length`-framed responses over a keep-alive connection — and
//! connects only to explicitly-given addresses.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// One keep-alive connection to a server.
pub struct HttpClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// A parsed response: status code, headers (lowercased names), body.
#[derive(Debug)]
pub struct HttpResponse {
    /// Status code from the response line.
    pub status: u16,
    /// `(lowercased-name, value)` pairs in arrival order.
    pub headers: Vec<(String, String)>,
    /// Response body bytes.
    pub body: Vec<u8>,
}

impl HttpResponse {
    /// Body parsed as JSON.
    pub fn json(&self) -> Result<Json> {
        let text = std::str::from_utf8(&self.body)
            .context("response body is not UTF-8")?;
        Json::parse(text)
            .map_err(|e| anyhow!("response body is not JSON: {e}"))
    }
}

impl HttpClient {
    /// Connect with a 10s read timeout (tests and benches must fail,
    /// not hang, when the server wedges).
    pub fn connect(addr: impl ToSocketAddrs) -> Result<HttpClient> {
        let stream = TcpStream::connect(addr).context("connecting")?;
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .context("setting client read timeout")?;
        let _ = stream.set_nodelay(true);
        let reader =
            BufReader::new(stream.try_clone().context("cloning stream")?);
        Ok(HttpClient { reader, writer: stream })
    }

    /// Write raw bytes on the connection without reading anything back
    /// — the fuzz and pipelining tests use this to send hostile or
    /// back-to-back payloads.
    pub fn send_raw(&mut self, bytes: &[u8]) -> Result<()> {
        self.writer.write_all(bytes).context("writing request")?;
        self.writer.flush().context("flushing request")
    }

    /// Read one `Content-Length`-framed response off the connection.
    pub fn read_response(&mut self) -> Result<HttpResponse> {
        let status_line = self.read_line()?;
        let mut parts = status_line.split_whitespace();
        let version = parts.next().unwrap_or("");
        if !version.starts_with("HTTP/1.") {
            bail!("bad response line '{status_line}'");
        }
        let status: u16 = parts
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow!("bad status in '{status_line}'"))?;
        let mut headers = Vec::new();
        loop {
            let line = self.read_line()?;
            if line.is_empty() {
                break;
            }
            let (name, value) = line
                .split_once(':')
                .ok_or_else(|| anyhow!("bad response header '{line}'"))?;
            headers.push((
                name.trim().to_ascii_lowercase(),
                value.trim().to_string(),
            ));
        }
        let len: usize = headers
            .iter()
            .find(|(n, _)| n == "content-length")
            .map(|(_, v)| v.parse().context("bad content-length"))
            .transpose()?
            .unwrap_or(0);
        let mut body = vec![0u8; len];
        std::io::Read::read_exact(&mut self.reader, &mut body)
            .context("reading response body")?;
        // interim 1xx responses (100 Continue) precede the real one
        if (100..200).contains(&status) {
            return self.read_response();
        }
        Ok(HttpResponse { status, headers, body })
    }

    fn read_line(&mut self) -> Result<String> {
        let mut line = String::new();
        let n = self
            .reader
            .read_line(&mut line)
            .context("reading response line")?;
        if n == 0 {
            bail!("connection closed by server");
        }
        while line.ends_with('\n') || line.ends_with('\r') {
            line.pop();
        }
        Ok(line)
    }

    /// Send one request and read its response.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&[u8]>,
    ) -> Result<HttpResponse> {
        let body = body.unwrap_or(b"");
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: acceltran\r\nContent-Type: \
             application/json\r\nContent-Length: {}\r\n\r\n",
            body.len()
        );
        self.writer.write_all(head.as_bytes()).context("writing head")?;
        self.writer.write_all(body).context("writing body")?;
        self.writer.flush().context("flushing")?;
        self.read_response()
    }

    /// `GET path`, expecting a JSON body.
    pub fn get(&mut self, path: &str) -> Result<(u16, Json)> {
        let resp = self.request("GET", path, None)?;
        let json = resp.json()?;
        Ok((resp.status, json))
    }

    /// `POST path` with a JSON body, expecting a JSON response.
    pub fn post_json(&mut self, path: &str, body: &Json) -> Result<(u16, Json)> {
        let text = body.to_string_compact();
        let resp = self.request("POST", path, Some(text.as_bytes()))?;
        let json = resp.json()?;
        Ok((resp.status, json))
    }
}
