//! `serve::net` — the HTTP/JSON front-end over the worker-pool serving
//! engine: a hand-rolled HTTP/1.1 server (`std::net` only; the
//! anyhow-only dependency policy holds) exposing
//!
//! * `POST /v1/classify` — single or batched token-id classification
//!   (rows may be any length `1..=seq`; an optional `"priority"` of
//!   `"interactive"` or `"batch"` picks the SLO class) with typed
//!   validation errors (4xx JSON bodies; a malformed or hostile body
//!   never reaches a pool) and bounded-queue admission control (429 +
//!   `Retry-After` when a pool is at its depth bound),
//! * `POST /v1/span` — extractive span prediction over the same wire
//!   shape: the response carries split-half `[start..., end...]` logits
//!   over the row's native length plus the decoded argmax `start` /
//!   `end` positions,
//! * `GET /stats` — live serving state: per-pool and merged latency
//!   histogram percentiles, queue high-water, per-bucket depths,
//!   padded-row and padded-token fractions, 429 shed count, per-model
//!   rollups, and the process-wide block-sparse GEMM
//!   effectual-tile/MAC counters,
//! * `GET /healthz` — liveness plus the registered models (name, task,
//!   shape) a client needs to build valid requests.
//!
//! A server hosts one or more named `(checkpoint, task)` models
//! ([`NetServer::start_multi`]); each request routes to the first model
//! of its endpoint's task, or to an explicit `"model": "name"` body
//! field.  Every pool shard hosts the full registry and a dispatched
//! batch never mixes models.
//!
//! Layering, front to back:
//!
//! 1. [`http`] — wire protocol: bounded request parsing (header/body
//!    caps, per-connection read/write timeouts, a wall-clock budget
//!    per request) and response writing.
//! 2. [`api`] — typed decode of classify/span bodies against the
//!    resolved model's shape (`seq`, `vocab`), with structured
//!    [`api::ApiError`]s.
//! 3. [`router`] — shards accepted requests across N independent
//!    [`crate::coordinator::ServePool`]s by power-of-two-choices on
//!    queue depth.
//! 4. [`server`] — the accept loop, connection threads, and the
//!    graceful-drain state machine (SIGTERM / ctrl-c → stop accepting,
//!    flush in-flight work, report).
//! 5. [`stats`] — counters and the `/stats` document assembly.
//! 6. [`client`] — a minimal loopback HTTP client for the hermetic
//!    tests, the `http_serve` example, and the transport-overhead
//!    bench; it connects only to explicitly-given addresses (no
//!    redirects, no name resolution beyond `ToSocketAddrs`).

pub mod api;
pub mod client;
pub mod http;
pub mod router;
pub mod server;
pub mod stats;

pub use api::{ApiError, ClassifyItem, ClassifyRequest, ModelShape};
pub use client::{HttpClient, HttpResponse};
pub use http::{HttpHead, Limits, RecvError};
pub use router::Router;
pub use server::{
    drain_requested, install_drain_signals, NetConfig, NetReport, NetServer,
};
pub use stats::NetCounters;
