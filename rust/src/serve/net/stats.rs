//! Front-end counters and `/stats` document assembly.
//!
//! [`NetCounters`] tracks what the HTTP layer itself did (connections,
//! requests by outcome class); the pools' serving state comes from
//! [`crate::coordinator::PoolSnapshot`]s, and the block-sparse GEMM
//! counters from the process-wide
//! [`crate::runtime::tensor::gemm_stats_snapshot`] accumulator.  All of
//! it is relaxed atomics and short lock holds — scraping `/stats` never
//! stalls a serving worker.

use crate::coordinator::{LatencyHistogram, PoolSnapshot};
use crate::runtime::tensor::gemm_stats_snapshot;
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Process-lifetime HTTP-layer counters (monotonic, relaxed atomics).
#[derive(Debug, Default)]
pub struct NetCounters {
    /// TCP connections accepted.
    pub connections: AtomicU64,
    /// HTTP requests fully read (any outcome).
    pub http_requests: AtomicU64,
    /// 2xx responses.
    pub ok: AtomicU64,
    /// 4xx responses (validation, routing, size limits, backpressure).
    pub client_errors: AtomicU64,
    /// 429s specifically: admission-control rejections (a pool queue at
    /// its depth bound).  Also counted in `client_errors`; broken out
    /// because load-shedding is an operational signal, not a client
    /// bug.
    pub rejected_429: AtomicU64,
    /// 5xx responses other than drain rejections.
    pub server_errors: AtomicU64,
    /// 503s sent because the server was draining.
    pub drained_rejects: AtomicU64,
    /// Connections dropped for stalling mid-request (408 sent).
    pub timeouts: AtomicU64,
}

impl NetCounters {
    /// Bump the outcome-class counter for a response status.
    pub fn record_status(&self, status: u16) {
        if status == 429 {
            self.rejected_429.fetch_add(1, Ordering::Relaxed);
        }
        let c = match status {
            200..=299 => &self.ok,
            400..=499 => &self.client_errors,
            _ => &self.server_errors,
        };
        c.fetch_add(1, Ordering::Relaxed);
    }

    /// JSON object for the `/stats` `server` section.
    pub fn to_json(&self) -> Json {
        let get = |c: &AtomicU64| Json::num(c.load(Ordering::Relaxed) as f64);
        Json::obj(vec![
            ("connections", get(&self.connections)),
            ("http_requests", get(&self.http_requests)),
            ("ok", get(&self.ok)),
            ("client_errors", get(&self.client_errors)),
            ("rejected_429", get(&self.rejected_429)),
            ("server_errors", get(&self.server_errors)),
            ("drained_rejects", get(&self.drained_rejects)),
            ("timeouts", get(&self.timeouts)),
        ])
    }
}

/// Assemble the `/stats` response body.
///
/// Shape (field names match [`crate::coordinator::ServeReport`] where
/// the concepts overlap, so report readers and live scrapers share a
/// schema):
///
/// ```json
/// {
///   "state": "accepting" | "draining",
///   "listen": "127.0.0.1:8080",
///   "uptime_s": 12.3,
///   "server": { "connections": .., "ok": .., ... },
///   "pools": [ { per-shard PoolSnapshot }, ... ],
///   "merged": { "completed": .., "pending": ..,
///               "padded_row_fraction": ..,
///               "queue_depth_high_water": ..,
///               "latency_us": { "queue": .., "compute": .., "total": .. } },
///   "models": [ { "name": .., "task": .., "served": .., "pending": ..,
///                 "deadline_misses": .., "padded_token_fraction": ..,
///                 "latency_us": { "total": .. } }, ... ],
///   "gemm": { "tiles": .., "effectual_mac_fraction": .., ... }
/// }
/// ```
///
/// `models` merges each registered model's section across the pool
/// shards (every shard hosts the same registry), so a scraper can read
/// per-model health without summing shards itself.
pub fn stats_json(
    state: &str,
    listen: &str,
    uptime: Duration,
    counters: &NetCounters,
    pools: &[PoolSnapshot],
) -> Json {
    let mut queue_h = LatencyHistogram::new();
    let mut compute_h = LatencyHistogram::new();
    let mut total_h = LatencyHistogram::new();
    let mut completed = 0u64;
    let mut submitted = 0u64;
    let mut pending = 0usize;
    let mut deadline_misses = 0u64;
    let mut rows = 0u64;
    let mut padded = 0u64;
    let mut tokens = 0u64;
    let mut padded_tokens = 0u64;
    let mut high_water = 0u64;
    for p in pools {
        queue_h.merge(&p.queue_latency);
        compute_h.merge(&p.compute_latency);
        total_h.merge(&p.total_latency);
        completed += p.completed;
        submitted += p.submitted;
        pending += p.pending;
        deadline_misses += p.deadline_misses;
        rows += p.stats.rows_dispatched;
        padded += p.stats.padded_rows;
        tokens += p.stats.tokens_dispatched;
        padded_tokens += p.stats.padded_tokens;
        high_water = high_water.max(p.stats.queue_depth_high_water);
    }
    let padded_frac =
        if rows == 0 { 0.0 } else { padded as f64 / rows as f64 };
    let padded_token_frac =
        if tokens == 0 { 0.0 } else { padded_tokens as f64 / tokens as f64 };
    // merged per-model sections: shard 0's registry gives the order;
    // every shard hosts the same models so index i matches across pools
    let n_models = pools.first().map(|p| p.models.len()).unwrap_or(0);
    let mut model_sections = Vec::with_capacity(n_models);
    for i in 0..n_models {
        let m0 = &pools[0].models[i];
        let mut served = 0u64;
        let mut m_pending = 0usize;
        let mut misses = 0u64;
        let mut m_tokens = 0u64;
        let mut m_padded_tokens = 0u64;
        let mut m_total = LatencyHistogram::new();
        for p in pools {
            if let Some(m) = p.models.get(i) {
                served += m.served;
                m_pending += m.pending;
                misses += m.deadline_misses;
                m_tokens += m.stats.tokens_dispatched;
                m_padded_tokens += m.stats.padded_tokens;
                m_total.merge(&m.total_latency);
            }
        }
        let m_pad_frac = if m_tokens == 0 {
            0.0
        } else {
            m_padded_tokens as f64 / m_tokens as f64
        };
        model_sections.push(Json::obj(vec![
            ("name", Json::str(m0.name.clone())),
            ("task", Json::str(m0.task.name())),
            ("seq", Json::num(m0.seq as f64)),
            ("classes", Json::num(m0.classes as f64)),
            ("served", Json::num(served as f64)),
            ("pending", Json::num(m_pending as f64)),
            ("deadline_misses", Json::num(misses as f64)),
            ("padded_token_fraction", Json::num(m_pad_frac)),
            (
                "latency_us",
                Json::obj(vec![("total", m_total.to_json())]),
            ),
        ]));
    }
    let gemm = gemm_stats_snapshot();
    Json::obj(vec![
        ("state", Json::str(state)),
        ("listen", Json::str(listen)),
        ("uptime_s", Json::num(uptime.as_secs_f64())),
        ("server", counters.to_json()),
        (
            "pools",
            Json::arr(pools.iter().map(|p| p.to_json())),
        ),
        (
            "merged",
            Json::obj(vec![
                ("submitted", Json::num(submitted as f64)),
                ("completed", Json::num(completed as f64)),
                ("pending", Json::num(pending as f64)),
                ("deadline_misses", Json::num(deadline_misses as f64)),
                ("rows_dispatched", Json::num(rows as f64)),
                ("padded_rows", Json::num(padded as f64)),
                ("padded_row_fraction", Json::num(padded_frac)),
                ("tokens_dispatched", Json::num(tokens as f64)),
                ("padded_tokens", Json::num(padded_tokens as f64)),
                ("padded_token_fraction", Json::num(padded_token_frac)),
                (
                    "queue_depth_high_water",
                    Json::num(high_water as f64),
                ),
                (
                    "latency_us",
                    Json::obj(vec![
                        ("queue", queue_h.to_json()),
                        ("compute", compute_h.to_json()),
                        ("total", total_h.to_json()),
                    ]),
                ),
            ]),
        ),
        ("models", Json::arr(model_sections)),
        (
            "gemm",
            Json::obj(vec![
                ("tiles", Json::num(gemm.tiles as f64)),
                ("zero_tiles", Json::num(gemm.zero_tiles as f64)),
                ("macs", Json::num(gemm.macs as f64)),
                (
                    "tile_skipped_macs",
                    Json::num(gemm.tile_skipped_macs as f64),
                ),
                (
                    "effectual_tile_fraction",
                    Json::num(gemm.effectual_tile_fraction()),
                ),
                (
                    "effectual_mac_fraction",
                    Json::num(gemm.effectual_mac_fraction()),
                ),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_status_classifies() {
        let c = NetCounters::default();
        c.record_status(200);
        c.record_status(201);
        c.record_status(400);
        c.record_status(413);
        c.record_status(429);
        c.record_status(500);
        assert_eq!(c.ok.load(Ordering::Relaxed), 2);
        // 429 lands in client_errors AND the dedicated shed counter
        assert_eq!(c.client_errors.load(Ordering::Relaxed), 3);
        assert_eq!(c.rejected_429.load(Ordering::Relaxed), 1);
        assert_eq!(c.server_errors.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn stats_json_empty_pools_is_well_formed() {
        let c = NetCounters::default();
        c.connections.fetch_add(3, Ordering::Relaxed);
        let j = stats_json(
            "accepting",
            "127.0.0.1:0",
            Duration::from_millis(1500),
            &c,
            &[],
        );
        assert_eq!(
            j.get("state").and_then(|v| v.as_str()),
            Some("accepting")
        );
        assert_eq!(
            j.path(&["server", "connections"]).and_then(|v| v.as_f64()),
            Some(3.0)
        );
        assert_eq!(
            j.path(&["merged", "completed"]).and_then(|v| v.as_f64()),
            Some(0.0)
        );
        assert_eq!(
            j.path(&["merged", "padded_row_fraction"])
                .and_then(|v| v.as_f64()),
            Some(0.0)
        );
        assert_eq!(
            j.path(&["merged", "padded_token_fraction"])
                .and_then(|v| v.as_f64()),
            Some(0.0)
        );
        assert_eq!(
            j.path(&["server", "rejected_429"]).and_then(|v| v.as_f64()),
            Some(0.0)
        );
        // must serialize and re-parse cleanly (non-finite would break)
        let text = j.to_string_compact();
        assert!(Json::parse(&text).is_ok(), "{text}");
        // the per-model rollup is always present (empty with no pools)
        assert!(
            matches!(j.get("models"), Some(Json::Arr(a)) if a.is_empty()),
            "{text}"
        );
    }
}
