//! Typed decode + validation of `POST /v1/classify` and `POST
//! /v1/span` bodies (the two endpoints share one wire shape).
//!
//! Every way a request can be wrong maps to a *specific* [`ApiError`]
//! with a machine-readable `code` and a 4xx status, serialized as
//! `{"error":{"code":..,"message":..}}` — a malformed or hostile body
//! is answered at this layer and never reaches a
//! [`crate::coordinator::ServePool`].
//!
//! Two body shapes are accepted:
//!
//! ```json
//! {"ids": [1, 2, ...], "tau": 0.04}          // single request
//! {"requests": [{"ids": [...], "tau": 0.1},  // batched: served by ONE
//!               {"ids": [...]}]}             // pool so they co-batch
//! ```
//!
//! `tau` (the DynaTran activation-pruning threshold) and `priority`
//! (`"interactive"` | `"batch"`) are optional and per-item; `ids` may
//! carry any *native* length `1..=seq` (the engine buckets and pads it
//! — requests are no longer forced to the manifest's full sequence
//! length) with every id in `[0, vocab)` — shape errors caught here
//! would otherwise reach a worker thread deep in the embedding gather.
//!
//! On a multi-model server an optional top-level `"model": "name"`
//! field routes the request to an explicit registered model.  Because
//! the shape to validate against depends on the resolved model, the
//! server decodes in two phases: [`parse_body`] (UTF-8 + JSON + split
//! out `model`), then [`decode_value`] against the resolved model's
//! [`ModelShape`].  [`decode_classify`] composes both for
//! single-model callers and keeps the strict historical contract
//! (`model` is an unknown field there).

use crate::coordinator::Priority;
use crate::util::json::Json;

/// A structured request failure: HTTP status, stable machine-readable
/// code, and a human message.
#[derive(Debug, Clone, PartialEq)]
pub struct ApiError {
    /// HTTP status to answer with (400, 404, 405, 408, 413, 431, 503...).
    pub status: u16,
    /// Stable snake_case identifier for programmatic handling.
    pub code: &'static str,
    /// Human-readable detail (safe to echo: derived from our own
    /// validation, never raw client bytes beyond short excerpts).
    pub message: String,
}

impl ApiError {
    /// Construct a 400 with the given code.
    pub fn bad_request(code: &'static str, message: impl Into<String>) -> ApiError {
        ApiError { status: 400, code, message: message.into() }
    }

    /// The `{"error":{...}}` response body.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "error",
            Json::obj(vec![
                ("code", Json::str(self.code)),
                ("message", Json::str(self.message.clone())),
                ("status", Json::num(self.status as f64)),
            ]),
        )])
    }
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} {}: {}", self.status, self.code, self.message)
    }
}

impl std::error::Error for ApiError {}

/// One validated classify item: a native-length token-id row plus its
/// pruning threshold and scheduling class.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassifyItem {
    /// Token ids, `1..=seq` long, each in `[0, vocab)`.
    pub ids: Vec<i32>,
    /// DynaTran pruning threshold in `[0, 1]`.
    pub tau: f32,
    /// Scheduling class (defaults to interactive).
    pub priority: Priority,
}

/// A validated classify request body.
#[derive(Debug, Clone, PartialEq)]
pub enum ClassifyRequest {
    /// `{"ids": [...]}` — one row.
    Single(ClassifyItem),
    /// `{"requests": [...]}` — 1..=max_batch rows, routed to one pool
    /// so the batcher can co-schedule them.
    Batch(Vec<ClassifyItem>),
}

impl ClassifyRequest {
    /// Number of rows this request will submit.
    pub fn len(&self) -> usize {
        match self {
            ClassifyRequest::Single(_) => 1,
            ClassifyRequest::Batch(items) => items.len(),
        }
    }

    /// Always false — validation rejects empty batches.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Model-shape context the decoder validates against.
#[derive(Debug, Clone, Copy)]
pub struct ModelShape {
    /// Maximum length of an `ids` array (any native length `1..=seq`
    /// is accepted and served in its length bucket).
    pub seq: usize,
    /// Exclusive upper bound on token ids.
    pub vocab: usize,
}

fn item_from(
    obj: &Json,
    shape: ModelShape,
    default_tau: f32,
    at: &str,
    top_level: bool,
) -> Result<ClassifyItem, ApiError> {
    let map = obj.as_obj().ok_or_else(|| {
        ApiError::bad_request("bad_type", format!("{at} must be an object"))
    })?;
    for key in map.keys() {
        // "model" is the routing field [`parse_body`] already consumed;
        // it is only legal at the top level of the body.
        if key != "ids" && key != "tau" && key != "priority"
            && !(top_level && key == "model")
        {
            return Err(ApiError::bad_request(
                "unknown_field",
                format!("{at} has unknown field '{key}'"),
            ));
        }
    }
    let ids_json = obj.get("ids").ok_or_else(|| {
        ApiError::bad_request("missing_field", format!("{at} is missing 'ids'"))
    })?;
    let arr = ids_json.as_arr().ok_or_else(|| {
        ApiError::bad_request("bad_type", format!("{at}.ids must be an array"))
    })?;
    if arr.is_empty() || arr.len() > shape.seq {
        return Err(ApiError::bad_request(
            "bad_shape",
            format!(
                "{at}.ids must have between 1 and {} token ids (the served \
                 model's maximum sequence length), got {}",
                shape.seq,
                arr.len()
            ),
        ));
    }
    let mut ids = Vec::with_capacity(arr.len());
    for (i, v) in arr.iter().enumerate() {
        let id = v.as_i64().ok_or_else(|| {
            ApiError::bad_request(
                "bad_type",
                format!("{at}.ids[{i}] must be an integer"),
            )
        })?;
        if id < 0 || id >= shape.vocab as i64 {
            return Err(ApiError::bad_request(
                "bad_token_id",
                format!(
                    "{at}.ids[{i}] = {id} outside [0, {})",
                    shape.vocab
                ),
            ));
        }
        ids.push(id as i32);
    }
    let tau = match obj.get("tau") {
        None => default_tau,
        Some(v) => {
            let t = v.as_f64().ok_or_else(|| {
                ApiError::bad_request(
                    "bad_type",
                    format!("{at}.tau must be a number"),
                )
            })?;
            if !t.is_finite() || !(0.0..=1.0).contains(&t) {
                return Err(ApiError::bad_request(
                    "bad_tau",
                    format!("{at}.tau must be a finite number in [0, 1], got {t}"),
                ));
            }
            t as f32
        }
    };
    let priority = match obj.get("priority") {
        None => Priority::Interactive,
        Some(v) => {
            let s = v.as_str().ok_or_else(|| {
                ApiError::bad_request(
                    "bad_type",
                    format!("{at}.priority must be a string"),
                )
            })?;
            Priority::parse(s).ok_or_else(|| {
                ApiError::bad_request(
                    "bad_priority",
                    format!(
                        "{at}.priority must be 'interactive' or 'batch', \
                         got '{s}'"
                    ),
                )
            })?
        }
    };
    Ok(ClassifyItem { ids, tau, priority })
}

/// Phase one of the multi-model decode: UTF-8 + JSON + extract the
/// optional top-level `"model"` routing field (the caller resolves the
/// name to a registered model, then finishes with [`decode_value`]
/// against that model's shape).
pub fn parse_body(body: &[u8]) -> Result<(Json, Option<String>), ApiError> {
    let text = std::str::from_utf8(body).map_err(|_| {
        ApiError::bad_request("bad_encoding", "body is not valid UTF-8")
    })?;
    let root = Json::parse(text).map_err(|e| {
        ApiError::bad_request("bad_json", format!("body is not valid JSON: {e}"))
    })?;
    let map = root.as_obj().ok_or_else(|| {
        ApiError::bad_request("bad_type", "body must be a JSON object")
    })?;
    let model = match map.get("model") {
        None => None,
        Some(v) => Some(
            v.as_str()
                .ok_or_else(|| {
                    ApiError::bad_request("bad_type", "'model' must be a string")
                })?
                .to_string(),
        ),
    };
    Ok((root, model))
}

/// Phase two: validate a parsed body against the resolved model shape.
///
/// `max_batch` caps `requests` length; exceeding it is 413 (the client
/// should split the batch), everything else wrong is 400.
pub fn decode_value(
    root: &Json,
    shape: ModelShape,
    default_tau: f32,
    max_batch: usize,
) -> Result<ClassifyRequest, ApiError> {
    let map = root.as_obj().ok_or_else(|| {
        ApiError::bad_request("bad_type", "body must be a JSON object")
    })?;
    let has_ids = map.contains_key("ids");
    let has_requests = map.contains_key("requests");
    match (has_ids, has_requests) {
        (true, true) => Err(ApiError::bad_request(
            "ambiguous_body",
            "body must have either 'ids' (single) or 'requests' (batch), not both",
        )),
        (true, false) => item_from(root, shape, default_tau, "request", true)
            .map(ClassifyRequest::Single),
        (false, true) => {
            for key in map.keys() {
                if key != "requests" && key != "model" {
                    return Err(ApiError::bad_request(
                        "unknown_field",
                        format!("body has unknown field '{key}'"),
                    ));
                }
            }
            let arr = map["requests"].as_arr().ok_or_else(|| {
                ApiError::bad_request("bad_type", "'requests' must be an array")
            })?;
            if arr.is_empty() {
                return Err(ApiError::bad_request(
                    "empty_batch",
                    "'requests' must not be empty",
                ));
            }
            if arr.len() > max_batch {
                return Err(ApiError {
                    status: 413,
                    code: "batch_too_large",
                    message: format!(
                        "'requests' has {} items, max is {max_batch}; \
                         split the batch",
                        arr.len()
                    ),
                });
            }
            let mut items = Vec::with_capacity(arr.len());
            for (i, v) in arr.iter().enumerate() {
                items.push(item_from(
                    v,
                    shape,
                    default_tau,
                    &format!("requests[{i}]"),
                    false,
                )?);
            }
            Ok(ClassifyRequest::Batch(items))
        }
        (false, false) => Err(ApiError::bad_request(
            "missing_field",
            "body must have 'ids' (single) or 'requests' (batch)",
        )),
    }
}

/// Decode and validate a classify body against the served model shape —
/// the strict single-model entry point: a `model` routing field is an
/// unknown field here, exactly as before multi-model serving existed.
pub fn decode_classify(
    body: &[u8],
    shape: ModelShape,
    default_tau: f32,
    max_batch: usize,
) -> Result<ClassifyRequest, ApiError> {
    let (root, model) = parse_body(body)?;
    if model.is_some() {
        return Err(ApiError::bad_request(
            "unknown_field",
            "request has unknown field 'model'",
        ));
    }
    decode_value(&root, shape, default_tau, max_batch)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SHAPE: ModelShape = ModelShape { seq: 4, vocab: 100 };

    fn decode(body: &str) -> Result<ClassifyRequest, ApiError> {
        decode_classify(body.as_bytes(), SHAPE, 0.04, 8)
    }

    #[test]
    fn single_request_with_default_tau() {
        let got = decode(r#"{"ids": [1, 2, 3, 4]}"#).unwrap();
        match got {
            ClassifyRequest::Single(item) => {
                assert_eq!(item.ids, vec![1, 2, 3, 4]);
                assert!((item.tau - 0.04).abs() < 1e-6);
            }
            other => panic!("expected Single, got {other:?}"),
        }
    }

    #[test]
    fn single_request_with_explicit_tau() {
        let got = decode(r#"{"ids": [0, 0, 99, 1], "tau": 0.5}"#).unwrap();
        match got {
            ClassifyRequest::Single(item) => assert_eq!(item.tau, 0.5),
            other => panic!("expected Single, got {other:?}"),
        }
    }

    #[test]
    fn batch_request_round_trips() {
        let got = decode(
            r#"{"requests": [{"ids": [1,2,3,4]}, {"ids": [4,3,2,1], "tau": 0.1}]}"#,
        )
        .unwrap();
        match got {
            ClassifyRequest::Batch(items) => {
                assert_eq!(items.len(), 2);
                assert_eq!(items[1].ids, vec![4, 3, 2, 1]);
                assert!((items[1].tau - 0.1).abs() < 1e-6);
            }
            other => panic!("expected Batch, got {other:?}"),
        }
    }

    #[test]
    fn wrong_length_is_bad_shape() {
        // new rule: any native length 1..=seq is legal; empty and
        // over-long arrays are not
        let e = decode(r#"{"ids": []}"#).unwrap_err();
        assert_eq!((e.status, e.code), (400, "bad_shape"));
        let e = decode(r#"{"ids": [1, 2, 3, 4, 5]}"#).unwrap_err();
        assert_eq!(e.code, "bad_shape");
    }

    #[test]
    fn shorter_than_seq_is_accepted_at_native_length() {
        let got = decode(r#"{"ids": [7]}"#).unwrap();
        match got {
            ClassifyRequest::Single(item) => {
                assert_eq!(item.ids, vec![7]);
                assert_eq!(item.priority, Priority::Interactive);
            }
            other => panic!("expected Single, got {other:?}"),
        }
        let got = decode(r#"{"ids": [1, 2, 3]}"#).unwrap();
        match got {
            ClassifyRequest::Single(item) => assert_eq!(item.ids, vec![1, 2, 3]),
            other => panic!("expected Single, got {other:?}"),
        }
    }

    #[test]
    fn priority_field_parses_and_rejects_junk() {
        let got = decode(r#"{"ids": [1, 2], "priority": "batch"}"#).unwrap();
        match got {
            ClassifyRequest::Single(item) => {
                assert_eq!(item.priority, Priority::Batch);
            }
            other => panic!("expected Single, got {other:?}"),
        }
        let e = decode(r#"{"ids": [1, 2], "priority": "urgent"}"#).unwrap_err();
        assert_eq!((e.status, e.code), (400, "bad_priority"));
        let e = decode(r#"{"ids": [1, 2], "priority": 3}"#).unwrap_err();
        assert_eq!(e.code, "bad_type");
    }

    #[test]
    fn out_of_vocab_and_negative_ids_rejected() {
        let e = decode(r#"{"ids": [1, 2, 3, 100]}"#).unwrap_err();
        assert_eq!((e.status, e.code), (400, "bad_token_id"));
        let e = decode(r#"{"ids": [-1, 2, 3, 4]}"#).unwrap_err();
        assert_eq!(e.code, "bad_token_id");
    }

    #[test]
    fn non_integer_ids_rejected() {
        let e = decode(r#"{"ids": [1.5, 2, 3, 4]}"#).unwrap_err();
        assert_eq!((e.status, e.code), (400, "bad_type"));
        let e = decode(r#"{"ids": ["a", 2, 3, 4]}"#).unwrap_err();
        assert_eq!(e.code, "bad_type");
    }

    #[test]
    fn bad_tau_rejected() {
        for body in [
            r#"{"ids": [1,2,3,4], "tau": -0.1}"#,
            r#"{"ids": [1,2,3,4], "tau": 1.5}"#,
            r#"{"ids": [1,2,3,4], "tau": "hot"}"#,
        ] {
            let e = decode(body).unwrap_err();
            assert_eq!(e.status, 400, "{body}");
        }
    }

    #[test]
    fn malformed_json_and_encoding() {
        let e = decode(r#"{"ids": [1, 2"#).unwrap_err();
        assert_eq!((e.status, e.code), (400, "bad_json"));
        let e = decode("not json at all").unwrap_err();
        assert_eq!(e.code, "bad_json");
        let e = decode_classify(&[0xff, 0xfe], SHAPE, 0.04, 8).unwrap_err();
        assert_eq!(e.code, "bad_encoding");
        let e = decode(r#"[1, 2, 3]"#).unwrap_err();
        assert_eq!(e.code, "bad_type");
    }

    #[test]
    fn unknown_and_ambiguous_fields_rejected() {
        let e = decode(r#"{"ids": [1,2,3,4], "temperature": 1}"#).unwrap_err();
        assert_eq!(e.code, "unknown_field");
        let e = decode(r#"{"ids": [1,2,3,4], "requests": []}"#).unwrap_err();
        assert_eq!(e.code, "ambiguous_body");
        let e = decode(r#"{}"#).unwrap_err();
        assert_eq!(e.code, "missing_field");
    }

    #[test]
    fn batch_limits() {
        let e = decode(r#"{"requests": []}"#).unwrap_err();
        assert_eq!((e.status, e.code), (400, "empty_batch"));
        let items: Vec<String> =
            (0..9).map(|_| r#"{"ids": [1,2,3,4]}"#.to_string()).collect();
        let body = format!(r#"{{"requests": [{}]}}"#, items.join(","));
        let e = decode(&body).unwrap_err();
        assert_eq!((e.status, e.code), (413, "batch_too_large"));
    }

    #[test]
    fn model_field_routes_in_two_phase_but_is_unknown_in_classic_decode() {
        // two-phase: "model" is split out and the remaining body decodes
        let (root, model) =
            parse_body(br#"{"ids": [1, 2], "model": "span-a"}"#).unwrap();
        assert_eq!(model.as_deref(), Some("span-a"));
        let got = decode_value(&root, SHAPE, 0.04, 8).unwrap();
        match got {
            ClassifyRequest::Single(item) => assert_eq!(item.ids, vec![1, 2]),
            other => panic!("expected Single, got {other:?}"),
        }
        // batch form carries it at top level too
        let (root, model) = parse_body(
            br#"{"model": "m0", "requests": [{"ids": [1]}, {"ids": [2, 3]}]}"#,
        )
        .unwrap();
        assert_eq!(model.as_deref(), Some("m0"));
        assert_eq!(decode_value(&root, SHAPE, 0.04, 8).unwrap().len(), 2);
        // but never inside a batch item
        let (root, _) =
            parse_body(br#"{"requests": [{"ids": [1], "model": "x"}]}"#).unwrap();
        let e = decode_value(&root, SHAPE, 0.04, 8).unwrap_err();
        assert_eq!(e.code, "unknown_field");
        // non-string model is a type error
        let e = parse_body(br#"{"ids": [1], "model": 3}"#).unwrap_err();
        assert_eq!((e.status, e.code), (400, "bad_type"));
        // the classic single-model decoder still rejects it
        let e = decode(r#"{"ids": [1, 2], "model": "span-a"}"#).unwrap_err();
        assert_eq!(e.code, "unknown_field");
    }

    #[test]
    fn error_json_shape() {
        let e = ApiError::bad_request("bad_shape", "nope");
        let j = e.to_json();
        assert_eq!(
            j.path(&["error", "code"]).and_then(|v| v.as_str()),
            Some("bad_shape")
        );
        assert_eq!(
            j.path(&["error", "status"]).and_then(|v| v.as_f64()),
            Some(400.0)
        );
    }
}
