//! The HTTP server proper: accept loop, connection threads, request
//! routing, and the graceful-drain state machine.
//!
//! # Drain state machine
//!
//! ```text
//! accepting ──(SIGTERM / ctrl-c / NetServer::shutdown)──▶ draining
//!   draining: listener closed (new connects refused by the OS),
//!             in-flight connections answered; new classify bodies
//!             get 503 {"error":{"code":"draining"}} + Connection: close
//!   then:     connection threads joined (bounded by the socket
//!             read/write timeouts and the per-request budget),
//!             pools drained via Router::finish (every accepted request
//!             is served — force-flushed tails included),
//!             NetReport assembled and returned
//! ```
//!
//! The ordering is what makes drain *lossless*: a classify request is
//! either rejected with 503 before it touches a pool, or it was
//! enqueued — and [`crate::coordinator::ServePool::finish`] guarantees
//! an enqueued request is served.  There is no window where an accepted
//! request can be dropped.
//!
//! # Hardening
//!
//! Connection threads arm [`Limits::read_timeout`] on the socket for
//! both reads *and* writes, so a stalled peer costs one thread a
//! bounded wait (408 mid-request, silent close when idle) and a peer
//! that stops reading responses is dropped instead of blocking
//! `write_all` forever — which is what keeps the drain join bounded.
//! A per-request wall-clock budget ([`Limits::max_request_time`])
//! bounds byte-dripping slow-loris clients that would otherwise reset
//! the socket timeout on every byte; header/body caps bound memory per
//! connection; oversized bodies are refused before `100 Continue`
//! invites them; reply waits are capped ([`REPLY_WAIT`] → 504).
//! Serving workers never block on the network: they hand responses to
//! a channel and move to the next batch.

use super::api::{self, ApiError, ClassifyRequest, ModelShape};
use super::http::{self, HttpHead, Limits, RecvError};
use super::router::Router;
use super::stats::{stats_json, NetCounters};
use crate::coordinator::{
    ModelEntry, Priority, Response, ServeConfig, ServeReport, ServePool,
    SubmitError, TaskKind,
};
use crate::runtime::Runtime;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Ceiling on one request's wait for its pool reply.  Far above any
/// sane SLO — it only trips if a pool wedges, in which case the client
/// gets 504 instead of a hung connection.
const REPLY_WAIT: Duration = Duration::from_secs(30);

/// Poll interval of the non-blocking accept loop (a connect is picked
/// up at most this much late; drain is noticed just as fast).
const ACCEPT_POLL: Duration = Duration::from_millis(5);

// ---------------------------------------------------------------------------
// Drain signals (SIGTERM / ctrl-c)
// ---------------------------------------------------------------------------

static DRAIN_REQUESTED: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" fn on_drain_signal(_sig: i32) {
    // async-signal-safe: a single atomic store, nothing else
    DRAIN_REQUESTED.store(true, Ordering::SeqCst);
}

/// Route SIGINT (ctrl-c) and SIGTERM into [`drain_requested`] instead
/// of process death, so `acceltran serve --listen` drains gracefully.
/// Uses the libc `signal(2)` entry point directly (no signal-handling
/// crate is vendored); a no-op on non-unix targets.
pub fn install_drain_signals() {
    #[cfg(unix)]
    unsafe {
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        let h = on_drain_signal as extern "C" fn(i32) as usize;
        signal(2, h); // SIGINT
        signal(15, h); // SIGTERM
    }
}

/// Whether a drain signal has arrived since process start.
pub fn drain_requested() -> bool {
    DRAIN_REQUESTED.load(Ordering::SeqCst)
}

// ---------------------------------------------------------------------------
// Config / report
// ---------------------------------------------------------------------------

/// Front-end knobs (the serving engine's own knobs ride in `serve`).
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Bind address, e.g. `127.0.0.1:8080`; port 0 picks a free port
    /// (the bound address is [`NetServer::addr`]).
    pub listen: String,
    /// Pool shards; each gets `serve.workers` workers over its own
    /// forked backends.
    pub pools: usize,
    /// Per-shard serving-engine config.
    pub serve: ServeConfig,
    /// Wire-protocol limits and the per-connection read timeout.
    pub limits: Limits,
    /// `tau` used when a classify body omits it.
    pub default_tau: f32,
    /// Max items in a `{"requests": [...]}` batch (413 beyond).
    pub max_batch: usize,
    /// Honor SIGTERM / ctrl-c as drain triggers (off in tests, which
    /// drive [`NetServer::shutdown`] directly).
    pub drain_on_signal: bool,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            listen: "127.0.0.1:0".to_string(),
            pools: 2,
            serve: ServeConfig::default(),
            limits: Limits::default(),
            default_tau: 0.04,
            max_batch: 32,
            drain_on_signal: false,
        }
    }
}

/// What a drained server hands back: front-end counters plus each pool
/// shard's final [`ServeReport`].
#[derive(Debug)]
pub struct NetReport {
    /// Address the server was bound to.
    pub listen: String,
    /// Start-to-drain wall time.
    pub uptime: Duration,
    /// TCP connections accepted.
    pub connections: u64,
    /// HTTP requests fully read.
    pub http_requests: u64,
    /// 2xx responses.
    pub ok: u64,
    /// 4xx responses.
    pub client_errors: u64,
    /// 429 admission-control rejections (also counted in
    /// `client_errors`).
    pub rejected_429: u64,
    /// 5xx responses other than drain rejections.
    pub server_errors: u64,
    /// 503s sent while draining.
    pub drained_rejects: u64,
    /// Mid-request read timeouts (408s).
    pub timeouts: u64,
    /// Final per-shard serving reports, in shard order.
    pub pool_reports: Vec<ServeReport>,
}

impl NetReport {
    /// Total classify requests served across shards.
    pub fn requests_served(&self) -> u64 {
        self.pool_reports.iter().map(|r| r.requests).sum()
    }

    /// JSON document (`server` section + per-shard reports).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("listen", Json::str(self.listen.clone())),
            ("uptime_s", Json::num(self.uptime.as_secs_f64())),
            (
                "server",
                Json::obj(vec![
                    ("connections", Json::num(self.connections as f64)),
                    ("http_requests", Json::num(self.http_requests as f64)),
                    ("ok", Json::num(self.ok as f64)),
                    ("client_errors", Json::num(self.client_errors as f64)),
                    ("rejected_429", Json::num(self.rejected_429 as f64)),
                    ("server_errors", Json::num(self.server_errors as f64)),
                    (
                        "drained_rejects",
                        Json::num(self.drained_rejects as f64),
                    ),
                    ("timeouts", Json::num(self.timeouts as f64)),
                ]),
            ),
            (
                "pools",
                Json::arr(self.pool_reports.iter().map(|r| r.to_json())),
            ),
        ])
    }

    /// Write the JSON document, creating parent directories.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// One-screen summary to stdout.
    pub fn print_summary(&self) {
        println!(
            "net: {} up {:.1}s — {} conns, {} http reqs ({} ok / {} 4xx \
             [{} shed] / {} 5xx / {} drain-rejected / {} timeouts)",
            self.listen,
            self.uptime.as_secs_f64(),
            self.connections,
            self.http_requests,
            self.ok,
            self.client_errors,
            self.rejected_429,
            self.server_errors,
            self.drained_rejects,
            self.timeouts,
        );
        for (i, r) in self.pool_reports.iter().enumerate() {
            println!(
                "  pool {i}: {} served on {} worker(s), p50 {}us p99 {}us \
                 total, {} deadline misses",
                r.requests,
                r.workers,
                r.total_latency.percentile_us(50.0),
                r.total_latency.percentile_us(99.0),
                r.deadline_misses,
            );
        }
    }
}

// ---------------------------------------------------------------------------
// The server
// ---------------------------------------------------------------------------

/// Everything connection threads share.  Lives in one [`Arc`] so the
/// accept loop, connection threads, and [`NetServer`] see the same
/// state; reclaimed with `Arc::try_unwrap` once every thread has been
/// joined (which is what lets [`NetServer::shutdown`] consume the
/// router and drain the pools).
struct Ctx {
    router: Router,
    counters: NetCounters,
    limits: Limits,
    default_tau: f32,
    max_batch: usize,
    draining: AtomicBool,
    started: Instant,
    listen: String,
}

impl Ctx {
    fn state_str(&self) -> &'static str {
        if self.draining.load(Ordering::SeqCst) {
            "draining"
        } else {
            "accepting"
        }
    }
}

/// A running HTTP front-end.  Construct with [`NetServer::start`],
/// stop with [`NetServer::shutdown`] (or let a drain signal trigger it
/// via [`NetServer::run_until_drained`]).
pub struct NetServer {
    addr: SocketAddr,
    ctx: Arc<Ctx>,
    accept: JoinHandle<Result<()>>,
    drain_on_signal: bool,
}

impl NetServer {
    /// Bind `cfg.listen`, start `cfg.pools` pool shards forked from
    /// `proto`, and begin accepting.  Single-model: the shards host one
    /// classify model named `"default"`.
    pub fn start(proto: &Runtime, params: &[f32], cfg: &NetConfig) -> Result<NetServer> {
        let mut pools = Vec::with_capacity(cfg.pools.max(1));
        for i in 0..cfg.pools.max(1) {
            pools.push(
                ServePool::start(proto, params, &cfg.serve)
                    .with_context(|| format!("starting pool shard {i}"))?,
            );
        }
        Self::start_with_pools(pools, cfg)
    }

    /// Multi-model start: every shard hosts the same registry of named
    /// `(checkpoint, task)` models, so `/v1/classify` and `/v1/span`
    /// route by task (or an explicit `"model"` body field) on any
    /// shard.  `entries` seeds one shard; the others run fresh forks of
    /// the same runtimes over their own copies of the parameters.
    pub fn start_multi(
        entries: Vec<ModelEntry>,
        cfg: &NetConfig,
    ) -> Result<NetServer> {
        let shards = cfg.pools.max(1);
        let mut per_shard: Vec<Vec<ModelEntry>> = Vec::with_capacity(shards);
        for _ in 1..shards {
            let mut forked = Vec::with_capacity(entries.len());
            for e in &entries {
                forked.push(ModelEntry {
                    name: e.name.clone(),
                    task: e.task,
                    runtime: e.runtime.fork()?,
                    params: e.params.clone(),
                    sim: e.sim.clone(),
                });
            }
            per_shard.push(forked);
        }
        per_shard.push(entries);
        let mut pools = Vec::with_capacity(shards);
        for (i, shard_entries) in per_shard.into_iter().enumerate() {
            pools.push(
                ServePool::start_multi(shard_entries, &cfg.serve)
                    .with_context(|| format!("starting pool shard {i}"))?,
            );
        }
        Self::start_with_pools(pools, cfg)
    }

    /// Shared tail of [`NetServer::start`] / [`NetServer::start_multi`]:
    /// bind, wrap the shards in a router, spawn the accept loop.
    fn start_with_pools(pools: Vec<ServePool>, cfg: &NetConfig) -> Result<NetServer> {
        let listener = TcpListener::bind(&cfg.listen)
            .with_context(|| format!("binding {}", cfg.listen))?;
        let addr = listener.local_addr().context("reading bound address")?;
        listener
            .set_nonblocking(true)
            .context("setting listener non-blocking")?;
        let ctx = Arc::new(Ctx {
            router: Router::new(pools),
            counters: NetCounters::default(),
            limits: cfg.limits.clone(),
            default_tau: cfg.default_tau,
            max_batch: cfg.max_batch,
            draining: AtomicBool::new(false),
            started: Instant::now(),
            listen: addr.to_string(),
        });
        let accept_ctx = Arc::clone(&ctx);
        let drain_on_signal = cfg.drain_on_signal;
        let accept = std::thread::Builder::new()
            .name("net-accept".to_string())
            .spawn(move || accept_loop(listener, accept_ctx, drain_on_signal))
            .context("spawning accept thread")?;
        Ok(NetServer { addr, ctx, accept, drain_on_signal })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Classify requests served so far across shards.
    pub fn completed(&self) -> u64 {
        self.ctx.router.completed_total()
    }

    /// Begin draining (idempotent; the accept loop notices within one
    /// poll interval).
    pub fn begin_drain(&self) {
        self.ctx.draining.store(true, Ordering::SeqCst);
    }

    /// Drain and reclaim: stop accepting, join every connection
    /// thread, flush the pools, and return the final [`NetReport`].
    pub fn shutdown(self) -> Result<NetReport> {
        self.begin_drain();
        match self.accept.join() {
            Ok(res) => res.context("accept loop failed")?,
            Err(_) => return Err(anyhow!("accept loop panicked")),
        }
        // every connection thread has been joined by the accept loop,
        // so this Arc is the last one standing
        let ctx = Arc::try_unwrap(self.ctx)
            .map_err(|_| anyhow!("context still shared after join"))?;
        let uptime = ctx.started.elapsed();
        let c = &ctx.counters;
        let load = |a: &std::sync::atomic::AtomicU64| a.load(Ordering::Relaxed);
        let (connections, http_requests, ok, client_errors) = (
            load(&c.connections),
            load(&c.http_requests),
            load(&c.ok),
            load(&c.client_errors),
        );
        let (rejected_429, server_errors, drained_rejects, timeouts) = (
            load(&c.rejected_429),
            load(&c.server_errors),
            load(&c.drained_rejects),
            load(&c.timeouts),
        );
        let listen = ctx.listen.clone();
        let pool_reports = ctx.router.finish()?;
        Ok(NetReport {
            listen,
            uptime,
            connections,
            http_requests,
            ok,
            client_errors,
            rejected_429,
            server_errors,
            drained_rejects,
            timeouts,
            pool_reports,
        })
    }

    /// Serve until a drain trigger fires (a signal when
    /// `drain_on_signal`, or [`NetServer::begin_drain`] from another
    /// handle), then drain and report.
    pub fn run_until_drained(self) -> Result<NetReport> {
        while !self.ctx.draining.load(Ordering::SeqCst)
            && !(self.drain_on_signal && drain_requested())
        {
            std::thread::sleep(Duration::from_millis(50));
        }
        self.shutdown()
    }
}

fn accept_loop(
    listener: TcpListener,
    ctx: Arc<Ctx>,
    drain_on_signal: bool,
) -> Result<()> {
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    loop {
        if ctx.draining.load(Ordering::SeqCst) {
            break;
        }
        if drain_on_signal && drain_requested() {
            ctx.draining.store(true, Ordering::SeqCst);
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                ctx.counters.connections.fetch_add(1, Ordering::Relaxed);
                let conn_ctx = Arc::clone(&ctx);
                match std::thread::Builder::new()
                    .name("net-conn".to_string())
                    .spawn(move || handle_connection(stream, conn_ctx))
                {
                    Ok(h) => conns.push(h),
                    Err(_) => {
                        // thread exhaustion: shed this connection
                        // rather than kill the server
                        ctx.counters
                            .server_errors
                            .fetch_add(1, Ordering::Relaxed);
                    }
                }
                // reap finished handlers so the vec stays bounded by
                // the number of LIVE connections
                conns.retain(|h| !h.is_finished());
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e).context("accept failed"),
        }
    }
    // draining: the listener drops here (OS refuses new connects);
    // join every live connection — bounded because idle keep-alive
    // reads give up after the read timeout, dripped requests exhaust
    // the per-request budget, and stalled response writes hit the
    // write timeout
    drop(listener);
    for h in conns {
        let _ = h.join();
    }
    Ok(())
}

/// Outcome of serving one request on a connection: the response has
/// been written; `keep` says whether the session may continue.
struct Served {
    keep: bool,
}

fn handle_connection(stream: TcpStream, ctx: Arc<Ctx>) {
    if stream.set_read_timeout(Some(ctx.limits.read_timeout)).is_err() {
        return;
    }
    // a peer that sends requests but stops reading responses would
    // otherwise block write_all forever once its receive window fills,
    // wedging this thread — and with it the drain join — indefinitely;
    // a timed-out write is treated as a dead connection (silent close),
    // keeping drain bounded
    if stream.set_write_timeout(Some(ctx.limits.read_timeout)).is_err() {
        return;
    }
    let _ = stream.set_nodelay(true);
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = BufWriter::new(stream);
    loop {
        // per-request wall-clock budget: the socket timeout resets on
        // every successful read, so on its own a byte-dripping peer
        // could hold this thread for hours
        let mut timer = http::RequestTimer::new(&ctx.limits);
        let head =
            match http::read_head(&mut reader, &ctx.limits, &mut timer) {
                Ok(h) => h,
                Err(e) => {
                    recv_error_response(&mut writer, &ctx, e);
                    return;
                }
            };
        // curl waits for this before sending larger bodies — but an
        // oversized or unsupported body declaration is refused *here*,
        // before the interim response invites the peer to transmit it
        if head.expects_continue() {
            if let Err(e) = http::check_body_limits(&head, &ctx.limits) {
                recv_error_response(&mut writer, &ctx, e);
                return;
            }
            if http::write_continue(&mut writer).is_err() {
                return;
            }
        }
        let body = match http::read_body(
            &mut reader,
            &head,
            &ctx.limits,
            &mut timer,
        ) {
            Ok(b) => b,
            Err(e) => {
                // over-cap body: consume (bounded) what the peer already
                // sent before answering — closing a socket with unread
                // bytes raises an RST that can destroy the in-flight 413
                if let RecvError::TooLarge { .. } = e {
                    let len =
                        head.content_length().unwrap_or(0).min(256 << 10);
                    drain_bytes(&mut reader, len);
                }
                recv_error_response(&mut writer, &ctx, e);
                return;
            }
        };
        ctx.counters.http_requests.fetch_add(1, Ordering::Relaxed);
        let served = serve_request(&mut writer, &ctx, &head, &body);
        match served {
            Ok(Served { keep: true }) => continue,
            _ => return,
        }
    }
}

/// Read and discard up to `n` bytes (stops early on EOF / timeout);
/// bounded cleanup so the TCP close after an error is clean.
fn drain_bytes(r: &mut impl std::io::Read, mut n: usize) {
    let mut sink = [0u8; 4096];
    while n > 0 {
        let want = n.min(sink.len());
        match r.read(&mut sink[..want]) {
            Ok(0) | Err(_) => break,
            Ok(got) => n -= got,
        }
    }
}

/// Answer a protocol-level receive failure (write a status when the
/// peer can still be talked to; stay silent on close/idle/transport
/// errors).  The connection always ends after this.
fn recv_error_response(
    writer: &mut impl std::io::Write,
    ctx: &Ctx,
    err: RecvError,
) {
    let status = match err {
        RecvError::Closed | RecvError::Io(_) => return,
        RecvError::Timeout { mid_request: false } => return,
        RecvError::Timeout { mid_request: true } => {
            ctx.counters.timeouts.fetch_add(1, Ordering::Relaxed);
            408
        }
        RecvError::TooLarge { what: "body" } => 413,
        RecvError::TooLarge { .. } => 431,
        RecvError::Malformed(_) => 400,
        RecvError::Unsupported(_) => 501,
    };
    let api_err = ApiError {
        status,
        code: match status {
            408 => "timeout",
            413 => "too_large",
            431 => "headers_too_large",
            501 => "unsupported",
            _ => "malformed",
        },
        message: err.to_string(),
    };
    write_json(writer, ctx, status, &api_err.to_json(), false);
}

/// Serialize and send one JSON response, recording the outcome class.
/// Admission-control rejections (429) carry `Retry-After: 1` so
/// well-behaved clients back off instead of hot-looping.
fn write_json(
    writer: &mut impl std::io::Write,
    ctx: &Ctx,
    status: u16,
    body: &Json,
    keep: bool,
) -> bool {
    ctx.counters.record_status(status);
    let text = body.to_string_compact();
    let retry = [("Retry-After", String::from("1"))];
    let extra: &[(&str, String)] =
        if status == 429 { &retry } else { &[] };
    http::write_response_with(
        writer,
        status,
        "application/json",
        extra,
        text.as_bytes(),
        keep,
    )
    .is_ok()
}

/// Route one fully-read request and write its response.
fn serve_request(
    writer: &mut impl std::io::Write,
    ctx: &Ctx,
    head: &HttpHead,
    body: &[u8],
) -> Result<Served, ()> {
    let keep = !head.wants_close();
    let (status, doc, keep) = match (head.method.as_str(), head.path.as_str())
    {
        ("GET", "/healthz") => (
            200,
            Json::obj(vec![
                ("status", Json::str("ok")),
                ("state", Json::str(ctx.state_str())),
                (
                    // back-compat: the first registered model's shape
                    "model",
                    Json::obj(vec![
                        ("seq", Json::num(ctx.router.seq() as f64)),
                        ("vocab", Json::num(ctx.router.vocab() as f64)),
                        ("classes", Json::num(ctx.router.classes() as f64)),
                    ]),
                ),
                (
                    "models",
                    Json::arr(ctx.router.models().iter().map(|m| {
                        Json::obj(vec![
                            ("name", Json::str(m.name.clone())),
                            ("task", Json::str(m.task.name())),
                            ("seq", Json::num(m.seq as f64)),
                            ("vocab", Json::num(m.vocab as f64)),
                            ("classes", Json::num(m.classes as f64)),
                        ])
                    })),
                ),
                ("pools", Json::num(ctx.router.len() as f64)),
            ]),
            keep,
        ),
        ("GET", "/stats") => (
            200,
            stats_json(
                ctx.state_str(),
                &ctx.listen,
                ctx.started.elapsed(),
                &ctx.counters,
                &ctx.router.snapshots(),
            ),
            keep,
        ),
        ("POST", "/v1/classify") | ("POST", "/v1/span") => {
            if ctx.draining.load(Ordering::SeqCst) {
                ctx.counters.drained_rejects.fetch_add(1, Ordering::Relaxed);
                let e = ApiError {
                    status: 503,
                    code: "draining",
                    message: "server is draining; retry elsewhere".into(),
                };
                // drain rejections close the connection so clients
                // re-resolve instead of hammering a dying server
                (503, e.to_json(), false)
            } else {
                let task = if head.path == "/v1/span" {
                    TaskKind::Span
                } else {
                    TaskKind::Classify
                };
                match infer(ctx, body, task) {
                    Ok(doc) => (200, doc, keep),
                    Err(e) => (e.status, e.to_json(), keep),
                }
            }
        }
        ("POST", "/healthz") | ("POST", "/stats")
        | (
            "GET" | "PUT" | "DELETE" | "HEAD" | "PATCH",
            "/v1/classify" | "/v1/span",
        ) => {
            let e = ApiError {
                status: 405,
                code: "method_not_allowed",
                message: format!("{} not allowed on {}", head.method, head.path),
            };
            (405, e.to_json(), keep)
        }
        _ => {
            let e = ApiError {
                status: 404,
                code: "not_found",
                message: format!("no route for {}", head.path),
            };
            (404, e.to_json(), keep)
        }
    };
    if write_json(writer, ctx, status, &doc, keep) && keep {
        Ok(Served { keep: true })
    } else {
        Err(())
    }
}

fn response_json(r: &Response, shard: usize) -> Json {
    Json::obj(vec![
        ("id", Json::num(r.id as f64)),
        ("pool", Json::num(shard as f64)),
        ("batch", Json::num(r.batch as f64)),
        ("latency_us", Json::num(r.latency.as_micros() as f64)),
        ("logits", Json::arr(r.logits.iter().map(|&l| Json::num(l as f64)))),
    ])
}

/// Span responses carry the raw split-half logits (`[start_0..start_l,
/// end_0..end_l]` over the row's native length) plus the decoded
/// extractive answer: independent argmax `start` / `end` positions
/// (`end < start` means "no answer", matching the eval decode).
fn span_response_json(r: &Response, shard: usize) -> Json {
    let l = r.logits.len() / 2;
    let argmax = |s: &[f32]| {
        s.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    };
    Json::obj(vec![
        ("id", Json::num(r.id as f64)),
        ("pool", Json::num(shard as f64)),
        ("batch", Json::num(r.batch as f64)),
        ("latency_us", Json::num(r.latency.as_micros() as f64)),
        ("start", Json::num(argmax(&r.logits[..l]) as f64)),
        ("end", Json::num(argmax(&r.logits[l..]) as f64)),
        ("logits", Json::arr(r.logits.iter().map(|&l| Json::num(l as f64)))),
    ])
}

fn task_response_json(task: TaskKind, r: &Response, shard: usize) -> Json {
    match task {
        TaskKind::Classify => response_json(r, shard),
        TaskKind::Span => span_response_json(r, shard),
    }
}

/// Map a pool admission failure to its HTTP shape.  `BadLength` is
/// defensive — the API layer validates lengths before submit — but
/// `QueueFull` is the normal load-shedding path: 429 plus a
/// `Retry-After` header (added by `write_json`).
fn submit_error(e: SubmitError) -> ApiError {
    match e {
        SubmitError::BadLength { got, max_seq } => ApiError {
            status: 400,
            code: "bad_shape",
            message: format!(
                "request has {got} token ids, want between 1 and {max_seq}"
            ),
        },
        SubmitError::QueueFull { pending, bound } => ApiError {
            status: 429,
            code: "queue_full",
            message: format!(
                "pool queue at its admission bound ({pending} pending, \
                 bound {bound}); retry after the Retry-After interval"
            ),
        },
    }
}

/// Resolve which registered model an inference request targets: the
/// explicit `"model"` body field when present (404 on an unknown name,
/// 400 when the named model serves the other task), otherwise the
/// first registered model of the endpoint's task (404 when none is).
fn resolve_model(
    ctx: &Ctx,
    task: TaskKind,
    name: Option<String>,
) -> Result<usize, ApiError> {
    let models = ctx.router.models();
    match name {
        Some(name) => {
            let idx = ctx.router.find_model(&name).ok_or_else(|| ApiError {
                status: 404,
                code: "model_not_found",
                message: format!("no model named '{name}' is registered"),
            })?;
            if models[idx].task != task {
                return Err(ApiError::bad_request(
                    "task_mismatch",
                    format!(
                        "model '{name}' serves the {} task, not {}",
                        models[idx].task.name(),
                        task.name()
                    ),
                ));
            }
            Ok(idx)
        }
        None => models
            .iter()
            .position(|m| m.task == task)
            .ok_or_else(|| ApiError {
                status: 404,
                code: "no_model_for_task",
                message: format!("no {} model is registered", task.name()),
            }),
    }
}

/// Decode, validate, route to a pool shard, and wait for the replies —
/// shared by `/v1/classify` and `/v1/span` (same wire shape; the model
/// registry and response serializer differ by task).
fn infer(ctx: &Ctx, body: &[u8], task: TaskKind) -> Result<Json, ApiError> {
    let (root, name) = api::parse_body(body)?;
    let model = resolve_model(ctx, task, name)?;
    let info = &ctx.router.models()[model];
    let shape = ModelShape { seq: info.seq, vocab: info.vocab };
    let req = api::decode_value(&root, shape, ctx.default_tau, ctx.max_batch)?;
    let wedged = || ApiError {
        status: 504,
        code: "reply_timeout",
        message: format!(
            "pool did not answer within {REPLY_WAIT:?}; server may be wedged"
        ),
    };
    match req {
        ClassifyRequest::Single(item) => {
            let (tx, rx) = mpsc::channel();
            let (shard, _id) = ctx
                .router
                .submit_model(model, item.ids, item.tau, item.priority, tx)
                .map_err(submit_error)?;
            let resp = rx.recv_timeout(REPLY_WAIT).map_err(|_| wedged())?;
            Ok(task_response_json(task, &resp, shard))
        }
        ClassifyRequest::Batch(items) => {
            let n = items.len();
            let rows: Vec<(Vec<i32>, f32, Priority)> = items
                .into_iter()
                .map(|i| (i.ids, i.tau, i.priority))
                .collect();
            let (tx, rx) = mpsc::channel();
            let (shard, ids) = ctx
                .router
                .submit_batch_model(model, rows, tx)
                .map_err(submit_error)?;
            let mut by_id: Vec<Option<Response>> = (0..n).map(|_| None).collect();
            for _ in 0..n {
                let resp = rx.recv_timeout(REPLY_WAIT).map_err(|_| wedged())?;
                if let Some(slot) = ids.iter().position(|&id| id == resp.id) {
                    by_id[slot] = Some(resp);
                }
            }
            let responses: Vec<Json> = by_id
                .into_iter()
                .map(|r| {
                    r.map(|r| task_response_json(task, &r, shard)).ok_or_else(
                        || ApiError {
                            status: 500,
                            code: "missing_reply",
                            message: "a batch row produced no response".into(),
                        },
                    )
                })
                .collect::<Result<_, _>>()?;
            Ok(Json::obj(vec![("responses", Json::arr(responses))]))
        }
    }
}
