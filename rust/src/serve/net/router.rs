//! Sharded routing across N independent [`ServePool`]s.
//!
//! Each pool owns its queue, workers, and runtime clones; sharding
//! multiplies serving capacity without any cross-pool locking on the
//! hot path.  Placement is *power-of-two-choices*: hash a tick to pick
//! two distinct candidate pools, then enqueue on the one with the
//! shorter queue.  P2C gets most of the benefit of a global
//! least-loaded scan at the cost of two `pending()` reads, and avoids
//! the thundering-herd of pure least-loaded when many connection
//! threads route concurrently (they sample different candidate pairs).
//!
//! A batched request (`{"requests": [...]}`) is placed once and all its
//! rows go to the same pool, so the pool's deadline batcher can
//! co-schedule them into one dispatch.

use crate::coordinator::{
    ModelInfo, PoolSnapshot, Priority, Response, ServeReport, ServePool,
    SubmitError,
};
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;

/// Same mix as `util::rng` — a cheap stateless hash from tick to
/// candidate pair (kept private there; four lines to re-derive).
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Pure placement decision: index of the pool to enqueue on, given the
/// current queue depths and a routing seed.  Separated from [`Router`]
/// so the policy is unit-testable without spinning up pools.
///
/// With one pool it returns 0; otherwise it derives two *distinct*
/// candidates from the seed and returns the one with the smaller depth
/// (first candidate wins ties).
pub fn p2c_pick(depths: &[usize], seed: u64) -> usize {
    let n = depths.len();
    assert!(n > 0, "p2c_pick over zero pools");
    if n == 1 {
        return 0;
    }
    let h = splitmix64(seed);
    let a = (h % n as u64) as usize;
    // map the second draw into the remaining n-1 slots so a != b
    let mut b = ((h >> 32) % (n as u64 - 1)) as usize;
    if b >= a {
        b += 1;
    }
    if depths[b] < depths[a] {
        b
    } else {
        a
    }
}

/// Owns the pool shards and places every accepted request.
pub struct Router {
    pools: Vec<ServePool>,
    tick: AtomicU64,
}

impl Router {
    /// Wrap already-started pools.  Panics on an empty set (a router
    /// with nothing behind it is a config bug, not a runtime state).
    pub fn new(pools: Vec<ServePool>) -> Router {
        assert!(!pools.is_empty(), "Router needs at least one ServePool");
        Router { pools, tick: AtomicU64::new(0) }
    }

    /// Number of pool shards.
    pub fn len(&self) -> usize {
        self.pools.len()
    }

    /// Always false — construction rejects an empty pool set.
    pub fn is_empty(&self) -> bool {
        self.pools.is_empty()
    }

    /// Maximum sequence length a request row may carry (identical
    /// across shards: they are clones of one runtime).
    pub fn seq(&self) -> usize {
        self.pools[0].seq()
    }

    /// Vocabulary bound for token-id validation.
    pub fn vocab(&self) -> usize {
        self.pools[0].vocab()
    }

    /// Logits per served row.
    pub fn classes(&self) -> usize {
        self.pools[0].classes()
    }

    /// Registered models, in registration order (identical across
    /// shards: every shard hosts the same registry).
    pub fn models(&self) -> &[ModelInfo] {
        self.pools[0].models()
    }

    /// Resolve a model name to its registry index.
    pub fn find_model(&self, name: &str) -> Option<usize> {
        self.pools[0].find_model(name)
    }

    /// Pick a shard by power-of-two-choices on current queue depth.
    pub fn pick(&self) -> usize {
        let seed = self.tick.fetch_add(1, Ordering::Relaxed);
        let depths: Vec<usize> =
            self.pools.iter().map(|p| p.pending()).collect();
        p2c_pick(&depths, seed)
    }

    /// Place one request: pick a shard and enqueue with a reply
    /// channel.  Returns `(shard, request_id)`, or the shard's
    /// [`SubmitError`] (bad length / queue at its admission bound) —
    /// the server layer maps `QueueFull` to 429.
    pub fn submit(
        &self,
        ids: Vec<i32>,
        tau: f32,
        priority: Priority,
        reply: mpsc::Sender<Response>,
    ) -> Result<(usize, u64), SubmitError> {
        self.submit_model(0, ids, tau, priority, reply)
    }

    /// [`Router::submit`] addressed to a specific registered model:
    /// shard choice is still P2C over total shard depth, but the row
    /// lands in that model's own queues (a batch never mixes models).
    pub fn submit_model(
        &self,
        model: usize,
        ids: Vec<i32>,
        tau: f32,
        priority: Priority,
        reply: mpsc::Sender<Response>,
    ) -> Result<(usize, u64), SubmitError> {
        let shard = self.pick();
        let id = self.pools[shard]
            .submit_model_with_reply_priority(model, ids, tau, priority, reply)?;
        Ok((shard, id))
    }

    /// Place a multi-row request on ONE shard so the rows can share a
    /// dispatch.  Admission is all-or-nothing on that shard
    /// ([`ServePool::submit_batch_with_reply`]): a near-full queue
    /// rejects the whole batch rather than accepting a prefix.  Returns
    /// the shard and the per-row request ids.
    pub fn submit_batch(
        &self,
        rows: Vec<(Vec<i32>, f32, Priority)>,
        reply: mpsc::Sender<Response>,
    ) -> Result<(usize, Vec<u64>), SubmitError> {
        self.submit_batch_model(0, rows, reply)
    }

    /// [`Router::submit_batch`] addressed to a specific model.
    pub fn submit_batch_model(
        &self,
        model: usize,
        rows: Vec<(Vec<i32>, f32, Priority)>,
        reply: mpsc::Sender<Response>,
    ) -> Result<(usize, Vec<u64>), SubmitError> {
        let shard = self.pick();
        let ids =
            self.pools[shard].submit_batch_model_with_reply(model, rows, &reply)?;
        Ok((shard, ids))
    }

    /// Live snapshot of every shard, in shard order.
    pub fn snapshots(&self) -> Vec<PoolSnapshot> {
        self.pools.iter().map(|p| p.snapshot()).collect()
    }

    /// Requests currently queued across all shards.
    pub fn pending_total(&self) -> usize {
        self.pools.iter().map(|p| p.pending()).sum()
    }

    /// Requests fully served across all shards.
    pub fn completed_total(&self) -> u64 {
        self.pools.iter().map(|p| p.completed()).sum()
    }

    /// Drain every shard: close the queues, let the workers flush
    /// in-flight and queued work, join them.  Returns each shard's
    /// final report, in shard order (retained responses are dropped —
    /// the HTTP path delivers every response through its reply channel,
    /// so there are none on a pure network workload).
    pub fn finish(self) -> Result<Vec<ServeReport>> {
        let mut reports = Vec::with_capacity(self.pools.len());
        for pool in self.pools {
            let (report, _retained) = pool.finish()?;
            reports.push(report);
        }
        Ok(reports)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_pool_always_zero() {
        for seed in 0..64 {
            assert_eq!(p2c_pick(&[17], seed), 0);
        }
    }

    #[test]
    fn candidates_are_distinct_and_in_range() {
        // with depth pattern [0, MAX, MAX, ...], picking pool 0 is only
        // possible when 0 is among the candidates; picking any other
        // pool means both candidates were non-zero — either way the
        // result must be in range, and over many seeds pool 0 must be
        // chosen whenever it is sampled (it is strictly shallower)
        for n in 2..6 {
            let mut depths = vec![usize::MAX; n];
            depths[0] = 0;
            let mut zero_picks = 0;
            for seed in 0..512 {
                let got = p2c_pick(&depths, seed);
                assert!(got < n);
                if got == 0 {
                    zero_picks += 1;
                }
            }
            // pool 0 is in the candidate pair with prob 2/n; it must
            // win every time it is sampled
            assert!(
                zero_picks > 512 / n,
                "n={n}: pool 0 picked only {zero_picks}/512"
            );
        }
    }

    #[test]
    fn prefers_shorter_queue() {
        // one deep pool among shallow ones: the deep pool should only
        // be picked when BOTH candidates land on it — impossible since
        // candidates are distinct — so it is never picked
        let depths = [0usize, 1000, 0, 0];
        for seed in 0..512 {
            assert_ne!(p2c_pick(&depths, seed), 1);
        }
    }

    #[test]
    fn spreads_over_equal_queues() {
        // equal depths: ties go to the first candidate, which is
        // uniform-ish over pools; every pool should get some traffic
        let depths = [5usize; 4];
        let mut hits = [0usize; 4];
        for seed in 0..1024 {
            hits[p2c_pick(&depths, seed)] += 1;
        }
        for (i, &h) in hits.iter().enumerate() {
            assert!(h > 100, "pool {i} starved: {hits:?}");
        }
    }
}
