//! # AccelTran — sparsity-aware accelerator simulation for dynamic
//! transformer inference
//!
//! Rust reproduction of *AccelTran: A Sparsity-Aware Accelerator for
//! Dynamic Inference with Transformers* (Tuli & Jha, IEEE TCAD 2023),
//! built as the L3 layer of a three-layer Rust + JAX + Pallas stack:
//!
//! * [`sim`] — the paper's contribution: a cycle-accurate simulator of the
//!   AccelTran ASIC (PEs, MAC lanes, softmax/layer-norm modules, DynaTran
//!   pruning, binary-mask sparsity pipeline, buffers, LP-DDR3 /
//!   monolithic-3D-RRAM main memory, smart stagger scheduling, 24 tiled
//!   dataflows, 14nm area/energy models).
//! * [`runtime`] — the functional inference/training path behind the
//!   pluggable `ExecBackend` trait: a pure-Rust reference executor that
//!   runs the encoder natively (forward, sparsity probe, backprop +
//!   AdamW; the hermetic default), and the PJRT backend that executes
//!   the AOT HLO artifacts from `python/compile/aot.py` (gated on real
//!   xla bindings — see DESIGN.md §Substitutions).
//! * [`coordinator`] — the serving and experiment layer tying the
//!   functional model (runtime) and the timing model (sim) together:
//!   dynamic batcher, the concurrent worker-pool serving engine
//!   ([`coordinator::serve`]) with deadline-aware batching, streaming
//!   latency histograms and sim-in-the-loop batch costing, plus the
//!   evaluation / training / trace-capture drivers.
//! * [`serve`] — the network-facing layer over the coordinator's
//!   serving engine: a hand-rolled HTTP/1.1 front-end
//!   ([`serve::net`]) with typed request validation, a sharded
//!   power-of-two-choices router over N worker pools, graceful drain
//!   on SIGTERM/ctrl-c, and a live `/stats` endpoint.
//! * [`model`] — transformer architecture descriptions (Table I op
//!   inventory, Fig. 1 memory analytics) shared by sim and runtime.
//! * [`pruning`] — host-side DynaTran / top-k / magnitude pruning over f32
//!   tensors for the Fig. 11–14 profiling curves and the Fig. 13
//!   throughput comparison.
//! * [`nlp`] — synthetic sentiment + span tasks standing in for SST-2 /
//!   SQuAD (see DESIGN.md §Substitutions).
//! * [`trace`] — measured-sparsity traces: the interchange format that
//!   feeds real per-op activation sparsities captured by a runtime
//!   backend into the cycle-accurate simulator (DESIGN.md "Measured vs
//!   assumed sparsity").
//! * [`util`] — zero-dependency substrates (PRNG, JSON, CLI, property
//!   testing, tables, bench timing) built from scratch for this image.

pub mod coordinator;
pub mod model;
pub mod nlp;
pub mod pruning;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod trace;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
