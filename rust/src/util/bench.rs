//! Bench timing harness (criterion is not vendored in this image).
//!
//! Used by every `benches/*.rs` target: warms up, runs a fixed wall-clock
//! budget of iterations, and reports min/median/mean in the same units
//! criterion would.  Results also feed the EXPERIMENTS.md §Perf log.

use std::time::{Duration, Instant};

/// Timing summary of one benchmark.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
}

impl Sample {
    /// Iterations per second based on the median.
    pub fn per_sec(&self) -> f64 {
        1.0 / self.median.as_secs_f64()
    }
}

impl std::fmt::Display for Sample {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} median {:>12?}  mean {:>12?}  ({} iters)",
            self.name, self.median, self.mean, self.iters
        )
    }
}

/// Run `f` repeatedly for roughly `budget` after `warmup` iterations and
/// return per-iteration statistics.  `f` should return a value that the
/// harness black-boxes to keep the optimizer honest.
pub fn bench<T, F: FnMut() -> T>(name: &str, warmup: usize, budget: Duration, mut f: F) -> Sample {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || times.is_empty() {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
        if times.len() >= 10_000 {
            break;
        }
    }
    times.sort_unstable();
    let min = times[0];
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<Duration>() / times.len() as u32;
    Sample { name: name.to_string(), iters: times.len(), min, median, mean }
}

/// Convenience: bench with the default 3-iteration warmup and 1s budget.
pub fn quick<T, F: FnMut() -> T>(name: &str, f: F) -> Sample {
    bench(name, 3, Duration::from_secs(1), f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_times() {
        let s = bench("spin", 1, Duration::from_millis(50), || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(s.iters > 0);
        assert!(s.min <= s.median && s.median >= Duration::ZERO);
        assert!(s.per_sec() > 0.0);
    }
}
