//! Tiny command-line argument parser (clap is not vendored).
//!
//! Supports the subcommand + `--flag value` / `--flag` / positional style
//! used by the `acceltran` binary and the bench harnesses.

use std::collections::BTreeMap;

/// Parsed arguments: a subcommand, `--key value` options, bare `--switch`
/// flags, and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

/// Environment-variable override with a default — the bench harnesses'
/// problem-size knobs (`ACCELTRAN_TRAIN_STEPS`, `ACCELTRAN_EVAL_EXAMPLES`).
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    /// The first non-flag token becomes the subcommand when
    /// `with_subcommand` is set.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I, with_subcommand: bool) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else if with_subcommand && out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse the process arguments.
    pub fn from_env(with_subcommand: bool) -> Args {
        Args::parse(std::env::args().skip(1), with_subcommand)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got '{v}'")))
            .unwrap_or(default)
    }

    /// Millisecond flag as a [`std::time::Duration`] (`--read-timeout-ms
    /// 2000` style knobs on the serve subcommand).
    pub fn get_duration_ms(&self, key: &str, default_ms: u64) -> std::time::Duration {
        std::time::Duration::from_millis(self.get_u64(key, default_ms))
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from), true)
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("simulate --model bert-tiny --pes 64 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.get("model"), Some("bert-tiny"));
        assert_eq!(a.get_usize("pes", 0), 64);
        assert!(a.has("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse("run --tau=0.05");
        assert_eq!(a.get_f64("tau", 0.0), 0.05);
    }

    #[test]
    fn positionals() {
        let a = parse("serve req1 req2");
        assert_eq!(a.positional, vec!["req1", "req2"]);
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_usize("n", 3), 3);
        assert!(!a.has("quiet"));
    }

    #[test]
    fn duration_helper() {
        let a = parse("serve --read-timeout-ms 250");
        assert_eq!(
            a.get_duration_ms("read-timeout-ms", 2000),
            std::time::Duration::from_millis(250)
        );
        assert_eq!(
            a.get_duration_ms("slo-ms", 25),
            std::time::Duration::from_millis(25)
        );
    }

    #[test]
    fn switch_before_option() {
        let a = parse("cmd --fast --n 4");
        assert!(a.has("fast"));
        assert_eq!(a.get_usize("n", 0), 4);
    }
}
