//! Minimal JSON parser + writer (serde is not vendored in this image).
//!
//! Parses the subset of JSON emitted by `python/compile/aot.py` /
//! `goldens.py` (which is, in fact, all of JSON minus exotic number forms)
//! and serializes experiment reports.  Numbers are held as `f64`;
//! integer-valued access helpers round-trip exactly for |n| < 2^53.
//!
//! Since the HTTP front-end ([`crate::serve::net`]) made untrusted
//! bytes a real input class, the parser also *rejects* duplicate object
//! keys (rather than silently picking one — a classic smuggling vector)
//! and the writer serializes non-finite `f64` as `null` so emitted
//! documents are always valid JSON.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style multi-level access.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Exactly-integer-valued number within `i64` range; `None` for
    /// fractional values, non-finite values, other types, or |n| ≥ 2^53
    /// (past which `f64` stops round-tripping integers) — the strict
    /// accessor typed request decoding (token ids) wants.
    pub fn as_i64(&self) -> Option<i64> {
        let n = self.as_f64()?;
        if n.is_finite() && n.fract() == 0.0 && n.abs() < 9e15 {
            Some(n as i64)
        } else {
            None
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Shape-like array of usize.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|j| j.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    // -- construction helpers ----------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // -- serialization -----------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity literal; emitting `NaN`
                    // would produce invalid JSON, so serialize as null
                    // (the same choice serde_json's default makes)
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !items.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !map.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs unsupported (not emitted by our
                            // python side); map lone surrogates to U+FFFD.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-decode UTF-8: back up and take the full char.
                    self.i -= 1;
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                    let _ = c;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            // RFC 8259 leaves duplicate-key behavior undefined; with the
            // HTTP layer feeding adversarial bodies in here, silently
            // keeping one of the two values is a smuggling vector —
            // reject instead
            if map.insert(key.clone(), val).is_some() {
                return Err(self.err(&format!("duplicate object key '{key}'")));
            }
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.path(&["a"]).unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let j = Json::obj(vec![
            ("name", Json::str("acceltran")),
            ("pi", Json::num(3.25)),
            ("shape", Json::arr([Json::num(8.0), Json::num(64.0)])),
            ("ok", Json::Bool(true)),
        ]);
        for text in [j.to_string_pretty(), j.to_string_compact()] {
            assert_eq!(Json::parse(&text).unwrap(), j);
        }
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::num(64.0).to_string_compact(), "64");
        assert_eq!(Json::num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn escapes_in_strings() {
        let j = Json::str("a\"b\\c\nd");
        let text = j.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let j = Json::parse(&text).unwrap();
            assert!(j.path(&["model", "param_count"]).unwrap().as_usize().unwrap() > 0);
        }
    }

    #[test]
    fn rejects_duplicate_object_keys() {
        // adversarial-input regression: two values under one key must
        // not silently resolve to either
        let err = Json::parse(r#"{"a": 1, "a": 2}"#).unwrap_err();
        assert!(err.msg.contains("duplicate object key 'a'"), "{err}");
        // nested objects are checked too
        assert!(Json::parse(r#"{"x": {"b": 1, "b": 1}}"#).is_err());
        // distinct keys still parse
        assert!(Json::parse(r#"{"a": 1, "b": 2}"#).is_ok());
    }

    #[test]
    fn non_finite_serializes_as_null() {
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let j = Json::obj(vec![("v", Json::num(v))]);
            let text = j.to_string_compact();
            assert_eq!(text, r#"{"v":null}"#, "{v}");
            // and the output round-trips as valid JSON
            assert_eq!(
                Json::parse(&text).unwrap().get("v"),
                Some(&Json::Null)
            );
        }
    }

    #[test]
    fn i64_and_bool_accessors() {
        assert_eq!(Json::parse("42").unwrap().as_i64(), Some(42));
        assert_eq!(Json::parse("-3").unwrap().as_i64(), Some(-3));
        assert_eq!(Json::parse("1.5").unwrap().as_i64(), None);
        assert_eq!(Json::parse("1e300").unwrap().as_i64(), None);
        assert_eq!(Json::parse("\"7\"").unwrap().as_i64(), None);
        assert_eq!(Json::parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(Json::parse("1").unwrap().as_bool(), None);
    }

    #[test]
    fn usize_vec_helper() {
        let j = Json::parse("[8, 64]").unwrap();
        assert_eq!(j.as_usize_vec().unwrap(), vec![8, 64]);
        assert!(Json::parse("[8, \"x\"]").unwrap().as_usize_vec().is_none());
    }
}
