//! Zero-dependency substrates used across the crate.
//!
//! The build pulls in only `anyhow` (registry) and the in-tree `xla`
//! path crate, so the usual ecosystem crates (rand, serde, clap,
//! criterion, proptest) are reimplemented here at the scale this
//! project needs — each one small, tested, and documented.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod table;
