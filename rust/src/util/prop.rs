//! Minimal property-based testing harness (proptest is not vendored).
//!
//! `check(seed, cases, |g| { ... })` runs a closure over `cases` randomized
//! inputs drawn from a [`Gen`]; on failure it reports the case index and
//! the per-case seed so the exact input can be replayed with
//! `Gen::replay`.

use super::rng::Rng;

/// Randomized input source handed to each property case.
pub struct Gen {
    rng: Rng,
    /// Per-case seed, printed on failure for replay.
    pub case_seed: u64,
}

impl Gen {
    /// Rebuild the generator a failing case reported.
    pub fn replay(case_seed: u64) -> Gen {
        Gen { rng: Rng::new(case_seed), case_seed }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.index(hi - lo + 1)
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_range(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        self.rng.normal_vec(n, std)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Case-count knob: returns `ACCELTRAN_PROPTEST_CASES` when set (CI runs
/// property suites at elevated counts), else `default`.  Zero or
/// unparsable values fall back to the default.
pub fn cases(default: usize) -> usize {
    if let Ok(v) = std::env::var("ACCELTRAN_PROPTEST_CASES") {
        if let Ok(n) = v.parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    default
}

/// Run `cases` property checks.  The property panics (e.g. via `assert!`)
/// to signal failure; this wrapper enriches the panic with replay info.
pub fn check<F: FnMut(&mut Gen)>(seed: u64, cases: usize, mut property: F) {
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let case_seed = root.next_u64();
        let mut g = Gen::replay(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut g)
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property failed on case {case}/{cases} (replay with \
                 Gen::replay({case_seed})): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check(1, 50, |g| {
            let x = g.usize_in(0, 10);
            assert!(x <= 10);
            n += 1;
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_case() {
        check(2, 100, |g| {
            let x = g.usize_in(0, 100);
            assert!(x < 90, "x={x}");
        });
    }

    #[test]
    fn replay_reproduces_input() {
        let mut seed_and_val = None;
        check(3, 5, |g| {
            if seed_and_val.is_none() {
                seed_and_val = Some((g.case_seed, g.u64()));
            }
        });
        let (seed, val) = seed_and_val.unwrap();
        let mut g = Gen::replay(seed);
        assert_eq!(g.u64(), val);
    }
}
