//! Deterministic pseudo-random number generation (SplitMix64 +
//! xoshiro256**), replacing the `rand` crate.
//!
//! Everything in this repo that samples (parameter init, synthetic
//! datasets, property tests, workload generators) goes through [`Rng`],
//! so every experiment is reproducible from a single `u64` seed.

/// xoshiro256** seeded via SplitMix64 (Blackman & Vigna's recommended
/// seeding procedure).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a seed; distinct seeds give independent
    /// streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child stream (for per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; callers draw in bulk anyway).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Vector of standard normals scaled by `std`.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments_are_sane() {
        let mut r = Rng::new(5);
        let xs: Vec<f32> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
