//! ASCII table printer for paper-style result tables.

/// Column-aligned table with a header row, printed in the same row/column
//  style the paper's tables use.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(header: I) -> Table {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let sep = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncols {
                s.push(' ');
                s.push_str(&cells[i]);
                s.push_str(&" ".repeat(widths[i] - cells[i].len() + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a number with engineering suffixes (K/M/G/T) like the paper's
/// "330K x" style annotations.
pub fn eng(v: f64) -> String {
    let (div, suffix) = match v.abs() {
        x if x >= 1e12 => (1e12, "T"),
        x if x >= 1e9 => (1e9, "G"),
        x if x >= 1e6 => (1e6, "M"),
        x if x >= 1e3 => (1e3, "K"),
        _ => (1.0, ""),
    };
    let scaled = v / div;
    if scaled.abs() >= 100.0 || scaled.fract() == 0.0 {
        format!("{scaled:.0}{suffix}")
    } else if scaled.abs() >= 10.0 {
        format!("{scaled:.1}{suffix}")
    } else {
        format!("{scaled:.2}{suffix}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(["name", "value"]);
        t.row(["edge", "55.12"]).row(["server-long-name", "1950.95"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(s.contains("server-long-name"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        Table::new(["a", "b"]).row(["only-one"]);
    }

    #[test]
    fn eng_suffixes() {
        assert_eq!(eng(330_578.0), "331K");
        assert_eq!(eng(5.73), "5.73");
        assert_eq!(eng(93_300.0), "93.3K");
        assert_eq!(eng(372.74e12), "373T");
    }
}
