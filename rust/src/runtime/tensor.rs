//! Host-tensor math layer for the pure-Rust reference executor
//! (`runtime::backend::reference`).
//!
//! Row-major f32 matrices as flat slices, shapes passed explicitly.  The
//! three GEMM variants cover forward (`matmul`), input gradients
//! (`matmul_nt`, x · Wᵀ), and weight gradients (`matmul_tn`, Xᵀ · dY)
//! without ever materializing a transpose.  `matmul` and `matmul_tn`
//! (the row-broadcast forms) skip zero multiplicands in their inner
//! accumulation — the software mirror of the accelerator's
//! ineffectual-MAC skipping, and the reason DynaTran-pruned inference
//! speeds up on this backend too; `matmul_nt` is a dense dot-product
//! loop, where a per-element branch would defeat vectorization for no
//! row-level reuse.
//!
//! All three GEMMs split their output across scoped threads for large
//! problems (`matmul`/`matmul_nt` by input rows, `matmul_tn` by output
//! rows); chunking never splits a single output element's accumulation,
//! so results are bitwise identical to the single-threaded loops.

/// Problems below this many MACs stay single-threaded (thread spawn
/// overhead dominates under ~1e6 MACs on commodity cores).
const PAR_THRESHOLD: usize = 1 << 21;

/// Worker count for row-parallel GEMMs: `ACCELTRAN_THREADS` if set,
/// otherwise available parallelism capped at 8.
fn worker_count() -> usize {
    if let Ok(v) = std::env::var("ACCELTRAN_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
}

fn row_chunk(rows: usize, workers: usize) -> usize {
    let per = (rows + workers - 1) / workers;
    per.max(1)
}

/// `out = x · w` for row-major `x: m x k`, `w: k x n`.
pub fn matmul(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * k, "matmul: x shape");
    assert_eq!(w.len(), k * n, "matmul: w shape");
    let mut out = vec![0.0f32; m * n];
    let workers = if m * k * n >= PAR_THRESHOLD { worker_count() } else { 1 };
    if workers <= 1 || m < 2 * workers {
        matmul_rows(x, w, &mut out, k, n);
    } else {
        let per = row_chunk(m, workers);
        std::thread::scope(|scope| {
            for (xc, oc) in x.chunks(per * k).zip(out.chunks_mut(per * n)) {
                scope.spawn(move || matmul_rows(xc, w, oc, k, n));
            }
        });
    }
    out
}

/// Row-major kernel: `out[i, :] += x[i, kk] * w[kk, :]`, skipping zero
/// `x` entries (ineffectual-MAC elision on pruned activations).
fn matmul_rows(x: &[f32], w: &[f32], out: &mut [f32], k: usize, n: usize) {
    for (xr, or) in x.chunks_exact(k).zip(out.chunks_exact_mut(n)) {
        for (kk, &a) in xr.iter().enumerate() {
            if a != 0.0 {
                let wr = &w[kk * n..kk * n + n];
                for (o, &b) in or.iter_mut().zip(wr) {
                    *o += a * b;
                }
            }
        }
    }
}

/// `out = x · wᵀ` for `x: m x n`, `w: k x n`; result is `m x k`.
/// (Backward pass: `dX = dY · Wᵀ`; also attention scores `Q · Kᵀ`.)
pub fn matmul_nt(x: &[f32], w: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * n, "matmul_nt: x shape");
    assert_eq!(w.len(), k * n, "matmul_nt: w shape");
    let mut out = vec![0.0f32; m * k];
    let workers = if m * n * k >= PAR_THRESHOLD { worker_count() } else { 1 };
    if workers <= 1 || m < 2 * workers {
        matmul_nt_rows(x, w, &mut out, n, k);
    } else {
        let per = row_chunk(m, workers);
        std::thread::scope(|scope| {
            for (xc, oc) in x.chunks(per * n).zip(out.chunks_mut(per * k)) {
                scope.spawn(move || matmul_nt_rows(xc, w, oc, n, k));
            }
        });
    }
    out
}

fn matmul_nt_rows(x: &[f32], w: &[f32], out: &mut [f32], n: usize, k: usize) {
    for (xr, or) in x.chunks_exact(n).zip(out.chunks_exact_mut(k)) {
        for (kk, o) in or.iter_mut().enumerate() {
            let wr = &w[kk * n..kk * n + n];
            let mut acc = 0.0f32;
            for (&a, &b) in xr.iter().zip(wr) {
                acc += a * b;
            }
            *o = acc;
        }
    }
}

/// `out = xᵀ · y` for `x: m x k`, `y: m x n`; result is `k x n`.
/// (Backward pass: `dW = Xᵀ · dY`.)
pub fn matmul_tn(x: &[f32], y: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * k, "matmul_tn: x shape");
    assert_eq!(y.len(), m * n, "matmul_tn: y shape");
    let mut out = vec![0.0f32; k * n];
    let workers = if m * k * n >= PAR_THRESHOLD { worker_count() } else { 1 };
    if workers <= 1 || k < 2 * workers {
        matmul_tn_cols(x, y, &mut out, m, k, n, 0, k);
    } else {
        let per = row_chunk(k, workers);
        std::thread::scope(|scope| {
            for (ci, oc) in out.chunks_mut(per * n).enumerate() {
                let k0 = ci * per;
                let kc = oc.len() / n;
                scope.spawn(move || matmul_tn_cols(x, y, oc, m, k, n, k0, kc));
            }
        });
    }
    out
}

/// Accumulate `out[kk - k0, :] += x[i, kk] * y[i, :]` over all rows `i`
/// for `kk` in `[k0, k0 + kc)`.
fn matmul_tn_cols(
    x: &[f32],
    y: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    k0: usize,
    kc: usize,
) {
    for i in 0..m {
        let xr = &x[i * k + k0..i * k + k0 + kc];
        let yr = &y[i * n..i * n + n];
        for (kk, &a) in xr.iter().enumerate() {
            if a != 0.0 {
                let or = &mut out[kk * n..kk * n + n];
                for (o, &b) in or.iter_mut().zip(yr) {
                    *o += a * b;
                }
            }
        }
    }
}

/// `x[i, :] += bias` for every row of `x: m x n`.
pub fn add_bias(x: &mut [f32], bias: &[f32]) {
    for row in x.chunks_exact_mut(bias.len()) {
        for (v, &b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// Column sums of `x: m x n` (bias gradients).
pub fn col_sums(x: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    for row in x.chunks_exact(n) {
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
    out
}

/// Numerically-stable softmax over each length-`n` row, in place.
pub fn softmax_rows(x: &mut [f32], n: usize) {
    for row in x.chunks_exact_mut(n) {
        let mut max = f32::NEG_INFINITY;
        for &v in row.iter() {
            max = max.max(v);
        }
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Softmax backward over rows: given probabilities `p` and upstream
/// `dp`, returns `dA` where `dA = p ∘ (dp − Σ_j dp_j p_j)` per row.
pub fn softmax_backward_rows(p: &[f32], dp: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; p.len()];
    for ((pr, dpr), or) in
        p.chunks_exact(n).zip(dp.chunks_exact(n)).zip(out.chunks_exact_mut(n))
    {
        let mut dot = 0.0f32;
        for (&pv, &dv) in pr.iter().zip(dpr) {
            dot += pv * dv;
        }
        for ((o, &pv), &dv) in or.iter_mut().zip(pr).zip(dpr) {
            *o = pv * (dv - dot);
        }
    }
    out
}

pub const LN_EPS: f32 = 1e-5;

/// Layer-norm forward over length-`n` rows.  Writes `gamma ∘ norm + beta`
/// into `out`, and (for the backward pass) the normalized rows into
/// `norm` and per-row `1/sqrt(var + eps)` into `inv_std`.
pub fn layernorm_rows(
    x: &[f32],
    gamma: &[f32],
    beta: &[f32],
    n: usize,
    out: &mut [f32],
    norm: &mut [f32],
    inv_std: &mut [f32],
) {
    for (i, (xr, (or, nr))) in x
        .chunks_exact(n)
        .zip(out.chunks_exact_mut(n).zip(norm.chunks_exact_mut(n)))
        .enumerate()
    {
        let mut mean = 0.0f32;
        for &v in xr.iter() {
            mean += v;
        }
        mean /= n as f32;
        let mut var = 0.0f32;
        for &v in xr.iter() {
            let d = v - mean;
            var += d * d;
        }
        var /= n as f32;
        let istd = 1.0 / (var + LN_EPS).sqrt();
        inv_std[i] = istd;
        for (j, &v) in xr.iter().enumerate() {
            let nv = (v - mean) * istd;
            nr[j] = nv;
            or[j] = nv * gamma[j] + beta[j];
        }
    }
}

/// Layer-norm backward.  Inputs are the cached `norm`/`inv_std` from the
/// forward pass; returns `dx` and accumulates `dgamma`/`dbeta`.
pub fn layernorm_backward_rows(
    dy: &[f32],
    norm: &[f32],
    inv_std: &[f32],
    gamma: &[f32],
    n: usize,
    dgamma: &mut [f32],
    dbeta: &mut [f32],
) -> Vec<f32> {
    let mut dx = vec![0.0f32; dy.len()];
    for (i, ((dyr, nr), dxr)) in dy
        .chunks_exact(n)
        .zip(norm.chunks_exact(n))
        .zip(dx.chunks_exact_mut(n))
        .enumerate()
    {
        let mut m1 = 0.0f32; // mean of dnorm
        let mut m2 = 0.0f32; // mean of dnorm ∘ norm
        for (j, (&dv, &nv)) in dyr.iter().zip(nr).enumerate() {
            dgamma[j] += dv * nv;
            dbeta[j] += dv;
            let dn = dv * gamma[j];
            m1 += dn;
            m2 += dn * nv;
        }
        m1 /= n as f32;
        m2 /= n as f32;
        let istd = inv_std[i];
        for (j, ((dxv, &dv), &nv)) in
            dxr.iter_mut().zip(dyr).zip(nr).enumerate()
        {
            let dn = dv * gamma[j];
            *dxv = istd * (dn - m1 - nv * m2);
        }
    }
    dx
}

/// Abramowitz & Stegun 7.1.26 rational approximation of `erf`
/// (max absolute error 1.5e-7 — well inside f32 noise for this model).
pub fn erf(x: f32) -> f32 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let poly = t
        * (0.254_829_592
            + t * (-0.284_496_736
                + t * (1.421_413_741 + t * (-1.453_152_027 + t * 1.061_405_429))));
    sign * (1.0 - poly * (-x * x).exp())
}

/// Standard normal CDF `Φ(x)`.
fn phi_cdf(x: f32) -> f32 {
    0.5 * (1.0 + erf(x * std::f32::consts::FRAC_1_SQRT_2))
}

/// Exact (erf-based) GeLU: `x · Φ(x)` — matches the Python reference
/// oracle (`jax.nn.gelu(approximate=False)`), not the tanh approximation.
pub fn gelu(x: f32) -> f32 {
    x * phi_cdf(x)
}

/// GeLU derivative: `Φ(x) + x · φ(x)`.
pub fn gelu_derivative(x: f32) -> f32 {
    const INV_SQRT_2PI: f32 = 0.398_942_28;
    phi_cdf(x) + x * INV_SQRT_2PI * (-0.5 * x * x).exp()
}

/// Fraction of exactly-zero elements.
pub fn zero_fraction(x: &[f32]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().filter(|&&v| v == 0.0).count() as f64 / x.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(got: &[f32], want: &[f32], tol: f32) {
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            assert!((g - w).abs() <= tol, "[{i}]: got {g}, want {w}");
        }
    }

    #[test]
    fn matmul_small_known() {
        // [1 2; 3 4] x [5 6; 7 8] = [19 22; 43 50]
        let x = [1.0, 2.0, 3.0, 4.0];
        let w = [5.0, 6.0, 7.0, 8.0];
        assert_close(&matmul(&x, &w, 2, 2, 2), &[19.0, 22.0, 43.0, 50.0], 1e-6);
    }

    #[test]
    fn matmul_variants_agree_with_explicit_transposes() {
        let mut rng = crate::util::rng::Rng::new(3);
        let (m, k, n) = (7, 5, 6);
        let x = rng.normal_vec(m * k, 1.0);
        let w = rng.normal_vec(k * n, 1.0);
        let y = matmul(&x, &w, m, k, n);

        // nt: y · wᵀ should equal matmul against the materialized wᵀ.
        let mut wt = vec![0.0f32; n * k];
        for kk in 0..k {
            for j in 0..n {
                wt[j * k + kk] = w[kk * n + j];
            }
        }
        assert_close(&matmul_nt(&y, &w, m, n, k), &matmul(&y, &wt, m, n, k), 1e-4);

        // tn: xᵀ · y should equal matmul against the materialized xᵀ.
        let mut xt = vec![0.0f32; k * m];
        for i in 0..m {
            for kk in 0..k {
                xt[kk * m + i] = x[i * k + kk];
            }
        }
        assert_close(&matmul_tn(&x, &y, m, k, n), &matmul(&xt, &y, k, m, n), 1e-4);
    }

    #[test]
    fn parallel_matmul_matches_serial() {
        let mut rng = crate::util::rng::Rng::new(4);
        // Large enough to cross PAR_THRESHOLD: 256 * 128 * 128 = 4.2M MACs.
        let (m, k, n) = (256, 128, 128);
        let x = rng.normal_vec(m * k, 1.0);
        let w = rng.normal_vec(k * n, 1.0);
        let par = matmul(&x, &w, m, k, n);
        let mut serial = vec![0.0f32; m * n];
        matmul_rows(&x, &w, &mut serial, k, n);
        assert_eq!(par, serial, "row-chunked parallel GEMM must be bitwise exact");
    }

    #[test]
    fn softmax_rows_normalize() {
        let mut x = vec![1.0f32, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut x, 3);
        for row in x.chunks(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            assert!(row.windows(2).all(|w| w[0] < w[1]), "monotone inputs stay ordered");
        }
    }

    #[test]
    fn softmax_backward_matches_finite_difference() {
        let a = [0.3f32, -0.7, 1.1, 0.2];
        let dp = [0.5f32, -0.2, 0.1, 0.4];
        let n = a.len();
        let p = {
            let mut p = a.to_vec();
            softmax_rows(&mut p, n);
            p
        };
        let da = softmax_backward_rows(&p, &dp, n);
        let eps = 1e-3f32;
        for j in 0..n {
            let mut ap = a.to_vec();
            ap[j] += eps;
            softmax_rows(&mut ap, n);
            let mut am = a.to_vec();
            am[j] -= eps;
            softmax_rows(&mut am, n);
            let mut fd = 0.0f32;
            for t in 0..n {
                fd += dp[t] * (ap[t] - am[t]) / (2.0 * eps);
            }
            assert!((da[j] - fd).abs() < 1e-3, "j={j}: analytic {} fd {fd}", da[j]);
        }
    }

    #[test]
    fn layernorm_rows_are_normalized() {
        let x = [1.0f32, 2.0, 3.0, 4.0, 10.0, 10.0, 10.0, 14.0];
        let gamma = [1.0f32; 4];
        let beta = [0.0f32; 4];
        let mut out = vec![0.0f32; 8];
        let mut norm = vec![0.0f32; 8];
        let mut inv_std = vec![0.0f32; 2];
        layernorm_rows(&x, &gamma, &beta, 4, &mut out, &mut norm, &mut inv_std);
        for row in out.chunks(4) {
            let mean: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-5, "mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "var {var}");
        }
        assert_eq!(out, norm, "identity affine leaves norm unchanged");
    }

    #[test]
    fn layernorm_backward_matches_finite_difference() {
        let x = [0.5f32, -1.0, 2.0, 0.1, 0.4, 1.5];
        let n = 3;
        let gamma = [1.2f32, 0.8, -0.5];
        let beta = [0.1f32, 0.0, -0.2];
        let dy = [0.3f32, -0.6, 0.9, 0.2, 0.5, -0.4];
        let fwd = |x: &[f32]| {
            let mut out = vec![0.0f32; x.len()];
            let mut norm = vec![0.0f32; x.len()];
            let mut istd = vec![0.0f32; x.len() / n];
            layernorm_rows(x, &gamma, &beta, n, &mut out, &mut norm, &mut istd);
            (out, norm, istd)
        };
        let (_, norm, istd) = fwd(&x);
        let mut dg = vec![0.0f32; n];
        let mut db = vec![0.0f32; n];
        let dx = layernorm_backward_rows(&dy, &norm, &istd, &gamma, n, &mut dg, &mut db);
        let eps = 1e-3f32;
        for j in 0..x.len() {
            let mut xp = x.to_vec();
            xp[j] += eps;
            let mut xm = x.to_vec();
            xm[j] -= eps;
            let (yp, _, _) = fwd(&xp);
            let (ym, _, _) = fwd(&xm);
            let mut fd = 0.0f32;
            for t in 0..x.len() {
                fd += dy[t] * (yp[t] - ym[t]) / (2.0 * eps);
            }
            assert!((dx[j] - fd).abs() < 2e-3, "j={j}: analytic {} fd {fd}", dx[j]);
        }
        // dbeta is just the column sum of dy
        assert_close(&db, &col_sums(&dy, n), 1e-6);
    }

    #[test]
    fn erf_reference_values() {
        // erf(0)=0, erf(1)=0.8427008, erf(-1)=-erf(1), erf(2)=0.9953223
        assert!(erf(0.0).abs() < 1e-7);
        assert!((erf(1.0) - 0.842_700_8).abs() < 1e-5);
        assert!((erf(-1.0) + 0.842_700_8).abs() < 1e-5);
        assert!((erf(2.0) - 0.995_322_3).abs() < 1e-5);
    }

    #[test]
    fn gelu_reference_values_and_derivative() {
        // gelu(0)=0; gelu(1)=0.8413447; gelu(-1)=-0.15865525 (erf-based).
        assert!(gelu(0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841_344_7).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158_655_25).abs() < 1e-4);
        // derivative vs central difference
        for &x in &[-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let eps = 1e-3;
            let fd = (gelu(x + eps) - gelu(x - eps)) / (2.0 * eps);
            assert!((gelu_derivative(x) - fd).abs() < 1e-3, "x={x}");
        }
    }

    #[test]
    fn bias_and_colsum_roundtrip() {
        let mut x = vec![0.0f32; 6];
        add_bias(&mut x, &[1.0, 2.0, 3.0]);
        assert_eq!(x, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
        assert_eq!(col_sums(&x, 3), vec![2.0, 4.0, 6.0]);
    }

    #[test]
    fn zero_fraction_counts() {
        assert_eq!(zero_fraction(&[0.0, 1.0, 0.0, 2.0]), 0.5);
        assert_eq!(zero_fraction(&[]), 0.0);
    }
}
