//! Host-tensor math layer for the pure-Rust reference executor
//! (`runtime::backend::reference`).
//!
//! Row-major f32 matrices as flat slices, shapes passed explicitly.  The
//! three GEMM variants cover forward (`matmul`), input gradients
//! (`matmul_nt`, x · Wᵀ), and weight gradients (`matmul_tn`, Xᵀ · dY)
//! without ever materializing a transpose.
//!
//! # Host microkernel (DESIGN.md "Host microkernel")
//!
//! Since the block-sparse GEMM rewrite the hot path is a cache-blocked,
//! autovectorizable microkernel instead of the original scalar loops:
//! the streamed operand is packed once per call into `KC x NR` panels,
//! the broadcast operand into `MR x KC` tiles, and a branchless
//! register-tile inner loop accumulates `MR x NR` outputs over each
//! depth block.  On top of the dense tiling sits *block-granular*
//! sparsity — the software mirror of AccelTran's ineffectual-tile
//! skipping: while packing the broadcast operand, a per-tile zero bitmap
//! is built (one `all-zero?` bit per `MR x KC` tile), and fully-zero
//! tiles are skipped for every output panel they would have touched.
//! DynaTran-pruned activations (`pruning::dynatran_prune_inplace`
//! upstream) therefore skip whole tiles — pruned-token rows, collapsed
//! attention columns — instead of paying a per-element branch per MAC.
//! A [`BlockSparsity`] summary (effectual-tile and effectual-MAC
//! fractions) is returned by the `_ex` variants and aggregated into a
//! process-wide accumulator ([`gemm_stats_snapshot`]) so benches,
//! serving sweeps, and trace captures can report both numbers.
//!
//! Determinism contract: every kernel accumulates each output element in
//! strictly ascending reduction order with plain (non-FMA-contracted)
//! f32 mul-adds, macro-tile threading splits only whole `MR`-aligned
//! row groups, and skipped contributions are exact `±0.0` products — so
//! tiled, scalar, serial, and row-chunk-parallel runs are all *bitwise
//! identical* for finite inputs (pinned by `tests/gemm_oracle.rs` and
//! `tests/determinism.rs`).  Problems under [`TILE_THRESHOLD`] MACs take
//! the original scalar path, where packing overhead would dominate.

/// Rows per register tile of the broadcast operand (the A side).
pub const GEMM_MR: usize = 4;
/// Columns per packed panel of the streamed operand (the B side); the
/// inner loop keeps an `MR x NR` f32 accumulator block in registers.
pub const GEMM_NR: usize = 16;
/// Depth (reduction) block: one `MR x KC` A-tile and `KC x NR` B-panel
/// pair stays resident in L1 while the microkernel runs.
pub const GEMM_KC: usize = 128;
/// Column macro-block: B panels are packed `NC` columns at a time so the
/// packed working set stays inside L2.
pub const GEMM_NC: usize = 256;

/// Problems below this many MACs stay single-threaded (thread spawn
/// overhead dominates under ~1e6 MACs on commodity cores).
const PAR_THRESHOLD: usize = 1 << 21;

/// Problems below this many MACs skip the tiled path entirely: packing
/// costs more than it saves on tiny matrices (micro tests, per-head
/// attention at toy sequence lengths).
const TILE_THRESHOLD: usize = 1 << 14;

/// Worker count for row-parallel GEMMs: `ACCELTRAN_THREADS` if set,
/// otherwise available parallelism capped at 8.
fn worker_count() -> usize {
    if let Ok(v) = std::env::var("ACCELTRAN_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
}

fn row_chunk(rows: usize, workers: usize) -> usize {
    let per = (rows + workers - 1) / workers;
    per.max(1)
}

// ---------------------------------------------------------------------------
// Block-sparsity accounting
// ---------------------------------------------------------------------------

/// Block-granular sparsity summary of one (or many, when aggregated)
/// tiled GEMM calls, over the *broadcast* operand — the activation side
/// on the forward path.  `effectual_tile_fraction` is the hardware-tile
/// analogue of the paper's effectual-MAC fraction: the share of
/// `GEMM_MR x GEMM_KC` tiles that contained at least one nonzero and
/// therefore had to be computed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BlockSparsity {
    /// `MR x KC` tiles examined in the broadcast operand.
    pub tiles: u64,
    /// Tiles that were entirely zero and skipped for every output panel.
    pub zero_tiles: u64,
    /// Dense MAC count of the call(s): `rows * depth * cols`.
    pub macs: u64,
    /// MACs elided by whole-tile skipping (`<= macs`).
    pub tile_skipped_macs: u64,
    /// Elements examined in the broadcast operand (`rows * depth`).
    pub elems: u64,
    /// Exactly-zero elements among them (element-granular sparsity).
    pub zero_elems: u64,
}

impl BlockSparsity {
    /// Fraction of tiles that had to be computed (1.0 when no tiles were
    /// examined — an empty accumulator reads as fully dense).
    pub fn effectual_tile_fraction(&self) -> f64 {
        if self.tiles == 0 {
            1.0
        } else {
            1.0 - self.zero_tiles as f64 / self.tiles as f64
        }
    }

    /// Element-granular effectual-MAC fraction: the share of MACs whose
    /// broadcast-operand element was nonzero (the paper's rho axis,
    /// measured on the host kernel's inputs).
    pub fn effectual_mac_fraction(&self) -> f64 {
        if self.elems == 0 {
            1.0
        } else {
            1.0 - self.zero_elems as f64 / self.elems as f64
        }
    }

    /// Fraction of the dense MAC count actually elided by tile skipping
    /// (what the block-granular path saved, as opposed to what element
    /// granularity *could* have saved).
    pub fn tile_skipped_mac_fraction(&self) -> f64 {
        if self.macs == 0 {
            0.0
        } else {
            self.tile_skipped_macs as f64 / self.macs as f64
        }
    }

    /// Fold another summary into this one (chunk merge, call aggregate).
    pub fn absorb(&mut self, other: &BlockSparsity) {
        self.tiles += other.tiles;
        self.zero_tiles += other.zero_tiles;
        self.macs += other.macs;
        self.tile_skipped_macs += other.tile_skipped_macs;
        self.elems += other.elems;
        self.zero_elems += other.zero_elems;
    }
}

mod gemm_counters {
    use std::sync::atomic::AtomicU64;

    pub(super) static TILES: AtomicU64 = AtomicU64::new(0);
    pub(super) static ZERO_TILES: AtomicU64 = AtomicU64::new(0);
    pub(super) static MACS: AtomicU64 = AtomicU64::new(0);
    pub(super) static TILE_SKIPPED_MACS: AtomicU64 = AtomicU64::new(0);
    pub(super) static ELEMS: AtomicU64 = AtomicU64::new(0);
    pub(super) static ZERO_ELEMS: AtomicU64 = AtomicU64::new(0);
}

/// Reset the process-wide tiled-GEMM accumulator (scope a measurement:
/// reset, run the workload, [`gemm_stats_snapshot`]).
pub fn gemm_stats_reset() {
    use std::sync::atomic::Ordering::Relaxed;
    gemm_counters::TILES.store(0, Relaxed);
    gemm_counters::ZERO_TILES.store(0, Relaxed);
    gemm_counters::MACS.store(0, Relaxed);
    gemm_counters::TILE_SKIPPED_MACS.store(0, Relaxed);
    gemm_counters::ELEMS.store(0, Relaxed);
    gemm_counters::ZERO_ELEMS.store(0, Relaxed);
}

/// Aggregate [`BlockSparsity`] over every tiled GEMM call in the process
/// since the last [`gemm_stats_reset`].  Scalar-path (sub-threshold)
/// calls do not contribute; the accumulator describes the tiled hot
/// path that serving and capture run on.
pub fn gemm_stats_snapshot() -> BlockSparsity {
    use std::sync::atomic::Ordering::Relaxed;
    BlockSparsity {
        tiles: gemm_counters::TILES.load(Relaxed),
        zero_tiles: gemm_counters::ZERO_TILES.load(Relaxed),
        macs: gemm_counters::MACS.load(Relaxed),
        tile_skipped_macs: gemm_counters::TILE_SKIPPED_MACS.load(Relaxed),
        elems: gemm_counters::ELEMS.load(Relaxed),
        zero_elems: gemm_counters::ZERO_ELEMS.load(Relaxed),
    }
}

fn gemm_stats_add(s: &BlockSparsity) {
    use std::sync::atomic::Ordering::Relaxed;
    gemm_counters::TILES.fetch_add(s.tiles, Relaxed);
    gemm_counters::ZERO_TILES.fetch_add(s.zero_tiles, Relaxed);
    gemm_counters::MACS.fetch_add(s.macs, Relaxed);
    gemm_counters::TILE_SKIPPED_MACS.fetch_add(s.tile_skipped_macs, Relaxed);
    gemm_counters::ELEMS.fetch_add(s.elems, Relaxed);
    gemm_counters::ZERO_ELEMS.fetch_add(s.zero_elems, Relaxed);
}

// ---------------------------------------------------------------------------
// Blocked microkernel
// ---------------------------------------------------------------------------

/// One GEMM operand viewed through its logical indices: `at(r, c)` reads
/// logical element `(r, c)` regardless of whether the stored matrix is
/// the logical one (`trans = false`, row-major with leading dimension
/// `ld`) or its transpose (`trans = true` — the `matmul_nt` B side and
/// `matmul_tn` A side, which never materialize the transpose).
#[derive(Clone, Copy)]
struct OperandView<'a> {
    data: &'a [f32],
    ld: usize,
    trans: bool,
}

impl OperandView<'_> {
    #[inline(always)]
    fn at(&self, r: usize, c: usize) -> f32 {
        if self.trans {
            self.data[c * self.ld + r]
        } else {
            self.data[r * self.ld + c]
        }
    }
}

/// The register-tile inner loop: accumulate an `mr x nrr` corner of a
/// full `GEMM_MR x GEMM_NR` accumulator block over one depth block.
///
/// `at` is a packed A tile (`pl x GEMM_MR`, depth-major), `bp` a packed
/// B panel (`pl x GEMM_NR`, depth-major, zero-padded past `nrr`), `c`
/// the output tile's top-left element with row stride `ldc`.  The
/// accumulator is *loaded from* `c` and stored back, so calls over
/// successive depth blocks extend one strictly-ascending-k summation
/// per element — bitwise identical to the scalar loops.  The compute
/// loop is branchless and fixed-shape (`GEMM_MR x GEMM_NR`); padded
/// lanes compute on zeros and are never stored.
#[inline]
fn microkernel(
    at: &[f32],
    bp: &[f32],
    c: &mut [f32],
    ldc: usize,
    pl: usize,
    mr: usize,
    nrr: usize,
) {
    let mut acc = [[0.0f32; GEMM_NR]; GEMM_MR];
    for i in 0..mr {
        for j in 0..nrr {
            acc[i][j] = c[i * ldc + j];
        }
    }
    for pp in 0..pl {
        let av = &at[pp * GEMM_MR..pp * GEMM_MR + GEMM_MR];
        let bv = &bp[pp * GEMM_NR..pp * GEMM_NR + GEMM_NR];
        for i in 0..GEMM_MR {
            let ai = av[i];
            for j in 0..GEMM_NR {
                acc[i][j] += ai * bv[j];
            }
        }
    }
    for i in 0..mr {
        for j in 0..nrr {
            c[i * ldc + j] = acc[i][j];
        }
    }
}

/// Pack the streamed operand into `KC x NR` panels, grouped by
/// `(depth block, column macro-block)`.  Returns the packed buffer and
/// the flat offset of each `(pc, jc)` group (`pc * num_jc + jc` order);
/// within a group, panel `jr` starts at `offset + jr * pl * GEMM_NR`.
/// Ragged edges are zero-padded to full `NR` width so the microkernel's
/// inner loop never branches on column bounds.
fn pack_b(b: &OperandView, depth: usize, cols: usize) -> (Vec<f32>, Vec<usize>) {
    let num_pc = (depth + GEMM_KC - 1) / GEMM_KC;
    let num_jc = (cols + GEMM_NC - 1) / GEMM_NC;
    let mut offs = Vec::with_capacity(num_pc * num_jc);
    let mut total = 0usize;
    for pc in 0..num_pc {
        let pl = (depth - pc * GEMM_KC).min(GEMM_KC);
        for jc in 0..num_jc {
            let ncl = (cols - jc * GEMM_NC).min(GEMM_NC);
            let panels = (ncl + GEMM_NR - 1) / GEMM_NR;
            offs.push(total);
            total += pl * panels * GEMM_NR;
        }
    }
    let mut buf = vec![0.0f32; total];
    let mut group = 0usize;
    for pc in 0..num_pc {
        let p0 = pc * GEMM_KC;
        let pl = (depth - p0).min(GEMM_KC);
        for jc in 0..num_jc {
            let j0 = jc * GEMM_NC;
            let ncl = (cols - j0).min(GEMM_NC);
            let panels = (ncl + GEMM_NR - 1) / GEMM_NR;
            let base = offs[group];
            group += 1;
            for jr in 0..panels {
                let jj0 = j0 + jr * GEMM_NR;
                let nrr = (ncl - jr * GEMM_NR).min(GEMM_NR);
                let pbase = base + jr * pl * GEMM_NR;
                for pp in 0..pl {
                    let row = pbase + pp * GEMM_NR;
                    for jj in 0..nrr {
                        buf[row + jj] = b.at(p0 + pp, jj0 + jj);
                    }
                }
            }
        }
    }
    (buf, offs)
}

/// Compute one chunk of output rows (`r0 .. r0 + rows_c`): pack the
/// chunk's A tiles per depth block (building the zero-tile bitmap and
/// the element-sparsity counts as a side effect of the same pass), then
/// sweep column macro-blocks, skipping fully-zero tiles outright.
#[allow(clippy::too_many_arguments)]
fn gemm_chunk(
    a: &OperandView,
    bbuf: &[f32],
    boffs: &[usize],
    out: &mut [f32],
    r0: usize,
    rows_c: usize,
    cols: usize,
    depth: usize,
    stats: &mut BlockSparsity,
) {
    let num_jc = (cols + GEMM_NC - 1) / GEMM_NC;
    let ntiles = (rows_c + GEMM_MR - 1) / GEMM_MR;
    let mut apack = vec![0.0f32; ntiles * GEMM_KC * GEMM_MR];
    let mut tile_zero = vec![false; ntiles];
    for (pc, p0) in (0..depth).step_by(GEMM_KC).enumerate() {
        let pl = (depth - p0).min(GEMM_KC);
        for t in 0..ntiles {
            let i0 = t * GEMM_MR;
            let mr = (rows_c - i0).min(GEMM_MR);
            let base = t * pl * GEMM_MR;
            let mut any = false;
            let mut zeros = 0usize;
            for pp in 0..pl {
                let dst = base + pp * GEMM_MR;
                for i in 0..GEMM_MR {
                    let v = if i < mr { a.at(r0 + i0 + i, p0 + pp) } else { 0.0 };
                    zeros += (i < mr && v == 0.0) as usize;
                    any |= v != 0.0;
                    apack[dst + i] = v;
                }
            }
            tile_zero[t] = !any;
            stats.tiles += 1;
            stats.elems += (mr * pl) as u64;
            stats.zero_elems += zeros as u64;
            if !any {
                stats.zero_tiles += 1;
                stats.tile_skipped_macs += (mr * pl * cols) as u64;
            }
        }
        for jc in 0..num_jc {
            let j0 = jc * GEMM_NC;
            let ncl = (cols - j0).min(GEMM_NC);
            let panels = (ncl + GEMM_NR - 1) / GEMM_NR;
            let block = boffs[pc * num_jc + jc];
            for t in 0..ntiles {
                if tile_zero[t] {
                    continue;
                }
                let i0 = t * GEMM_MR;
                let mr = (rows_c - i0).min(GEMM_MR);
                let at = &apack[t * pl * GEMM_MR..(t + 1) * pl * GEMM_MR];
                for jr in 0..panels {
                    let nrr = (ncl - jr * GEMM_NR).min(GEMM_NR);
                    let bp = &bbuf[block + jr * pl * GEMM_NR..][..pl * GEMM_NR];
                    let c0 = i0 * cols + j0 + jr * GEMM_NR;
                    microkernel(at, bp, &mut out[c0..], cols, pl, mr, nrr);
                }
            }
        }
    }
}

/// Blocked GEMM driver shared by all three variants: pack B once, then
/// split output rows across scoped threads in `GEMM_MR`-aligned chunks
/// (alignment keeps the tile partition — and therefore the
/// [`BlockSparsity`] counts — independent of the worker count; the
/// *results* are bitwise worker-count-independent regardless, because
/// chunking never splits an output element's accumulation).
fn gemm_blocked(
    a: OperandView,
    b: OperandView,
    rows: usize,
    cols: usize,
    depth: usize,
    force_workers: Option<usize>,
) -> (Vec<f32>, BlockSparsity) {
    let mut out = vec![0.0f32; rows * cols];
    if rows == 0 || cols == 0 || depth == 0 {
        return (out, BlockSparsity::default());
    }
    let (bbuf, boffs) = pack_b(&b, depth, cols);
    let mut stats = BlockSparsity {
        macs: rows as u64 * cols as u64 * depth as u64,
        ..BlockSparsity::default()
    };
    let workers = force_workers
        .unwrap_or_else(|| if rows * cols * depth >= PAR_THRESHOLD { worker_count() } else { 1 })
        .max(1);
    let per = {
        let rough = row_chunk(rows, workers);
        ((rough + GEMM_MR - 1) / GEMM_MR) * GEMM_MR
    };
    if per >= rows {
        gemm_chunk(&a, &bbuf, &boffs, &mut out, 0, rows, cols, depth, &mut stats);
    } else {
        let nchunks = (rows + per - 1) / per;
        let mut slots = vec![BlockSparsity::default(); nchunks];
        std::thread::scope(|scope| {
            for (ci, (oc, slot)) in out.chunks_mut(per * cols).zip(slots.iter_mut()).enumerate() {
                let a = &a;
                let bbuf = &bbuf;
                let boffs = &boffs;
                scope.spawn(move || {
                    let rows_c = oc.len() / cols;
                    gemm_chunk(a, bbuf, boffs, oc, ci * per, rows_c, cols, depth, slot);
                });
            }
        });
        for s in &slots {
            stats.absorb(s);
        }
    }
    gemm_stats_add(&stats);
    (out, stats)
}

// ---------------------------------------------------------------------------
// Public GEMM API
// ---------------------------------------------------------------------------

/// `out = x · w` for row-major `x: m x k`, `w: k x n`.  Dispatches to
/// the blocked microkernel above [`TILE_THRESHOLD`] MACs, the scalar
/// loops below it; both produce bitwise-identical results.
pub fn matmul(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * k, "matmul: x shape");
    assert_eq!(w.len(), k * n, "matmul: w shape");
    if m * k * n < TILE_THRESHOLD {
        return matmul_scalar(x, w, m, k, n);
    }
    matmul_ex(x, w, m, k, n).0
}

/// [`matmul`] through the blocked kernel unconditionally, returning the
/// call's [`BlockSparsity`] summary alongside the product.
pub fn matmul_ex(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> (Vec<f32>, BlockSparsity) {
    assert_eq!(x.len(), m * k, "matmul: x shape");
    assert_eq!(w.len(), k * n, "matmul: w shape");
    gemm_blocked(
        OperandView { data: x, ld: k, trans: false },
        OperandView { data: w, ld: n, trans: false },
        m,
        n,
        k,
        None,
    )
}

/// [`matmul_ex`] with a forced worker count (determinism tests pin
/// serial vs parallel without racing on `ACCELTRAN_THREADS`).
#[doc(hidden)]
pub fn matmul_ex_threads(
    x: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
    workers: usize,
) -> (Vec<f32>, BlockSparsity) {
    assert_eq!(x.len(), m * k, "matmul: x shape");
    assert_eq!(w.len(), k * n, "matmul: w shape");
    gemm_blocked(
        OperandView { data: x, ld: k, trans: false },
        OperandView { data: w, ld: n, trans: false },
        m,
        n,
        k,
        Some(workers),
    )
}

/// `out = x · wᵀ` for `x: m x n`, `w: k x n`; result is `m x k`.
/// (Backward pass: `dX = dY · Wᵀ`; also attention scores `Q · Kᵀ`.)
pub fn matmul_nt(x: &[f32], w: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * n, "matmul_nt: x shape");
    assert_eq!(w.len(), k * n, "matmul_nt: w shape");
    if m * n * k < TILE_THRESHOLD {
        return matmul_nt_scalar(x, w, m, n, k);
    }
    matmul_nt_ex(x, w, m, n, k).0
}

/// [`matmul_nt`] through the blocked kernel unconditionally, with the
/// call's [`BlockSparsity`] summary.
pub fn matmul_nt_ex(
    x: &[f32],
    w: &[f32],
    m: usize,
    n: usize,
    k: usize,
) -> (Vec<f32>, BlockSparsity) {
    assert_eq!(x.len(), m * n, "matmul_nt: x shape");
    assert_eq!(w.len(), k * n, "matmul_nt: w shape");
    gemm_blocked(
        OperandView { data: x, ld: n, trans: false },
        OperandView { data: w, ld: n, trans: true },
        m,
        k,
        n,
        None,
    )
}

/// [`matmul_nt_ex`] with a forced worker count (determinism tests).
#[doc(hidden)]
pub fn matmul_nt_ex_threads(
    x: &[f32],
    w: &[f32],
    m: usize,
    n: usize,
    k: usize,
    workers: usize,
) -> (Vec<f32>, BlockSparsity) {
    assert_eq!(x.len(), m * n, "matmul_nt: x shape");
    assert_eq!(w.len(), k * n, "matmul_nt: w shape");
    gemm_blocked(
        OperandView { data: x, ld: n, trans: false },
        OperandView { data: w, ld: n, trans: true },
        m,
        k,
        n,
        Some(workers),
    )
}

/// `out = xᵀ · y` for `x: m x k`, `y: m x n`; result is `k x n`.
/// (Backward pass: `dW = Xᵀ · dY`.)
pub fn matmul_tn(x: &[f32], y: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * k, "matmul_tn: x shape");
    assert_eq!(y.len(), m * n, "matmul_tn: y shape");
    if m * k * n < TILE_THRESHOLD {
        return matmul_tn_scalar(x, y, m, k, n);
    }
    matmul_tn_ex(x, y, m, k, n).0
}

/// [`matmul_tn`] through the blocked kernel unconditionally, with the
/// call's [`BlockSparsity`] summary (the broadcast operand here is
/// `xᵀ`, so tile sparsity tracks zero *columns* of `x`).
pub fn matmul_tn_ex(
    x: &[f32],
    y: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> (Vec<f32>, BlockSparsity) {
    assert_eq!(x.len(), m * k, "matmul_tn: x shape");
    assert_eq!(y.len(), m * n, "matmul_tn: y shape");
    gemm_blocked(
        OperandView { data: x, ld: k, trans: true },
        OperandView { data: y, ld: n, trans: false },
        k,
        n,
        m,
        None,
    )
}

/// [`matmul_tn_ex`] with a forced worker count (determinism tests).
#[doc(hidden)]
pub fn matmul_tn_ex_threads(
    x: &[f32],
    y: &[f32],
    m: usize,
    k: usize,
    n: usize,
    workers: usize,
) -> (Vec<f32>, BlockSparsity) {
    assert_eq!(x.len(), m * k, "matmul_tn: x shape");
    assert_eq!(y.len(), m * n, "matmul_tn: y shape");
    gemm_blocked(
        OperandView { data: x, ld: k, trans: true },
        OperandView { data: y, ld: n, trans: false },
        k,
        n,
        m,
        Some(workers),
    )
}

// ---------------------------------------------------------------------------
// Scalar reference kernels (the pre-rewrite implementation, kept as the
// bitwise baseline for tests and the "pre" row of BENCH_gemm.json)
// ---------------------------------------------------------------------------

/// The original scalar `matmul` (per-element zero skip + row-chunk
/// threading).  Bitwise identical to the blocked kernel for finite
/// inputs; kept public as the property-test baseline and the "pre"
/// kernel in `benches/perf_hotpath.rs`.
pub fn matmul_scalar(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * k, "matmul: x shape");
    assert_eq!(w.len(), k * n, "matmul: w shape");
    let mut out = vec![0.0f32; m * n];
    if m == 0 || k == 0 || n == 0 {
        return out;
    }
    let workers = if m * k * n >= PAR_THRESHOLD { worker_count() } else { 1 };
    if workers <= 1 || m < 2 * workers {
        matmul_rows(x, w, &mut out, k, n);
    } else {
        let per = row_chunk(m, workers);
        std::thread::scope(|scope| {
            for (xc, oc) in x.chunks(per * k).zip(out.chunks_mut(per * n)) {
                scope.spawn(move || matmul_rows(xc, w, oc, k, n));
            }
        });
    }
    out
}

/// Row-major kernel: `out[i, :] += x[i, kk] * w[kk, :]`, skipping zero
/// `x` entries (ineffectual-MAC elision on pruned activations).
fn matmul_rows(x: &[f32], w: &[f32], out: &mut [f32], k: usize, n: usize) {
    for (xr, or) in x.chunks_exact(k).zip(out.chunks_exact_mut(n)) {
        for (kk, &a) in xr.iter().enumerate() {
            if a != 0.0 {
                let wr = &w[kk * n..kk * n + n];
                for (o, &b) in or.iter_mut().zip(wr) {
                    *o += a * b;
                }
            }
        }
    }
}

/// The original scalar `matmul_nt` (dense dot-product loop).
pub fn matmul_nt_scalar(x: &[f32], w: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * n, "matmul_nt: x shape");
    assert_eq!(w.len(), k * n, "matmul_nt: w shape");
    let mut out = vec![0.0f32; m * k];
    if m == 0 || n == 0 || k == 0 {
        return out;
    }
    let workers = if m * n * k >= PAR_THRESHOLD { worker_count() } else { 1 };
    if workers <= 1 || m < 2 * workers {
        matmul_nt_rows(x, w, &mut out, n, k);
    } else {
        let per = row_chunk(m, workers);
        std::thread::scope(|scope| {
            for (xc, oc) in x.chunks(per * n).zip(out.chunks_mut(per * k)) {
                scope.spawn(move || matmul_nt_rows(xc, w, oc, n, k));
            }
        });
    }
    out
}

fn matmul_nt_rows(x: &[f32], w: &[f32], out: &mut [f32], n: usize, k: usize) {
    for (xr, or) in x.chunks_exact(n).zip(out.chunks_exact_mut(k)) {
        for (kk, o) in or.iter_mut().enumerate() {
            let wr = &w[kk * n..kk * n + n];
            let mut acc = 0.0f32;
            for (&a, &b) in xr.iter().zip(wr) {
                acc += a * b;
            }
            *o = acc;
        }
    }
}

/// The original scalar `matmul_tn` (per-element zero skip, output rows
/// split across threads).
pub fn matmul_tn_scalar(x: &[f32], y: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * k, "matmul_tn: x shape");
    assert_eq!(y.len(), m * n, "matmul_tn: y shape");
    let mut out = vec![0.0f32; k * n];
    if m == 0 || k == 0 || n == 0 {
        return out;
    }
    let workers = if m * k * n >= PAR_THRESHOLD { worker_count() } else { 1 };
    if workers <= 1 || k < 2 * workers {
        matmul_tn_cols(x, y, &mut out, m, k, n, 0, k);
    } else {
        let per = row_chunk(k, workers);
        std::thread::scope(|scope| {
            for (ci, oc) in out.chunks_mut(per * n).enumerate() {
                let k0 = ci * per;
                let kc = oc.len() / n;
                scope.spawn(move || matmul_tn_cols(x, y, oc, m, k, n, k0, kc));
            }
        });
    }
    out
}

/// Accumulate `out[kk - k0, :] += x[i, kk] * y[i, :]` over all rows `i`
/// for `kk` in `[k0, k0 + kc)`.
fn matmul_tn_cols(
    x: &[f32],
    y: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    k0: usize,
    kc: usize,
) {
    for i in 0..m {
        let xr = &x[i * k + k0..i * k + k0 + kc];
        let yr = &y[i * n..i * n + n];
        for (kk, &a) in xr.iter().enumerate() {
            if a != 0.0 {
                let or = &mut out[kk * n..kk * n + n];
                for (o, &b) in or.iter_mut().zip(yr) {
                    *o += a * b;
                }
            }
        }
    }
}

/// `x[i, :] += bias` for every row of `x: m x n`.
pub fn add_bias(x: &mut [f32], bias: &[f32]) {
    for row in x.chunks_exact_mut(bias.len()) {
        for (v, &b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// Column sums of `x: m x n` (bias gradients).
pub fn col_sums(x: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n];
    for row in x.chunks_exact(n) {
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
    out
}

/// Numerically-stable softmax over each length-`n` row, in place.
pub fn softmax_rows(x: &mut [f32], n: usize) {
    for row in x.chunks_exact_mut(n) {
        let mut max = f32::NEG_INFINITY;
        for &v in row.iter() {
            max = max.max(v);
        }
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Softmax backward over rows: given probabilities `p` and upstream
/// `dp`, returns `dA` where `dA = p ∘ (dp − Σ_j dp_j p_j)` per row.
pub fn softmax_backward_rows(p: &[f32], dp: &[f32], n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; p.len()];
    for ((pr, dpr), or) in
        p.chunks_exact(n).zip(dp.chunks_exact(n)).zip(out.chunks_exact_mut(n))
    {
        let mut dot = 0.0f32;
        for (&pv, &dv) in pr.iter().zip(dpr) {
            dot += pv * dv;
        }
        for ((o, &pv), &dv) in or.iter_mut().zip(pr).zip(dpr) {
            *o = pv * (dv - dot);
        }
    }
    out
}

pub const LN_EPS: f32 = 1e-5;

/// Layer-norm forward over length-`n` rows.  Writes `gamma ∘ norm + beta`
/// into `out`, and (for the backward pass) the normalized rows into
/// `norm` and per-row `1/sqrt(var + eps)` into `inv_std`.
pub fn layernorm_rows(
    x: &[f32],
    gamma: &[f32],
    beta: &[f32],
    n: usize,
    out: &mut [f32],
    norm: &mut [f32],
    inv_std: &mut [f32],
) {
    for (i, (xr, (or, nr))) in x
        .chunks_exact(n)
        .zip(out.chunks_exact_mut(n).zip(norm.chunks_exact_mut(n)))
        .enumerate()
    {
        let mut mean = 0.0f32;
        for &v in xr.iter() {
            mean += v;
        }
        mean /= n as f32;
        let mut var = 0.0f32;
        for &v in xr.iter() {
            let d = v - mean;
            var += d * d;
        }
        var /= n as f32;
        let istd = 1.0 / (var + LN_EPS).sqrt();
        inv_std[i] = istd;
        for (j, &v) in xr.iter().enumerate() {
            let nv = (v - mean) * istd;
            nr[j] = nv;
            or[j] = nv * gamma[j] + beta[j];
        }
    }
}

/// Layer-norm backward.  Inputs are the cached `norm`/`inv_std` from the
/// forward pass; returns `dx` and accumulates `dgamma`/`dbeta`.
pub fn layernorm_backward_rows(
    dy: &[f32],
    norm: &[f32],
    inv_std: &[f32],
    gamma: &[f32],
    n: usize,
    dgamma: &mut [f32],
    dbeta: &mut [f32],
) -> Vec<f32> {
    let mut dx = vec![0.0f32; dy.len()];
    for (i, ((dyr, nr), dxr)) in dy
        .chunks_exact(n)
        .zip(norm.chunks_exact(n))
        .zip(dx.chunks_exact_mut(n))
        .enumerate()
    {
        let mut m1 = 0.0f32; // mean of dnorm
        let mut m2 = 0.0f32; // mean of dnorm ∘ norm
        for (j, (&dv, &nv)) in dyr.iter().zip(nr).enumerate() {
            dgamma[j] += dv * nv;
            dbeta[j] += dv;
            let dn = dv * gamma[j];
            m1 += dn;
            m2 += dn * nv;
        }
        m1 /= n as f32;
        m2 /= n as f32;
        let istd = inv_std[i];
        for (j, ((dxv, &dv), &nv)) in
            dxr.iter_mut().zip(dyr).zip(nr).enumerate()
        {
            let dn = dv * gamma[j];
            *dxv = istd * (dn - m1 - nv * m2);
        }
    }
    dx
}

/// Abramowitz & Stegun 7.1.26 rational approximation of `erf`
/// (max absolute error 1.5e-7 — well inside f32 noise for this model).
pub fn erf(x: f32) -> f32 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.327_591_1 * x);
    let poly = t
        * (0.254_829_592
            + t * (-0.284_496_736
                + t * (1.421_413_741 + t * (-1.453_152_027 + t * 1.061_405_429))));
    sign * (1.0 - poly * (-x * x).exp())
}

/// Standard normal CDF `Φ(x)`.
fn phi_cdf(x: f32) -> f32 {
    0.5 * (1.0 + erf(x * std::f32::consts::FRAC_1_SQRT_2))
}

/// Exact (erf-based) GeLU: `x · Φ(x)` — matches the Python reference
/// oracle (`jax.nn.gelu(approximate=False)`), not the tanh approximation.
pub fn gelu(x: f32) -> f32 {
    x * phi_cdf(x)
}

/// GeLU derivative: `Φ(x) + x · φ(x)`.
pub fn gelu_derivative(x: f32) -> f32 {
    const INV_SQRT_2PI: f32 = 0.398_942_28;
    phi_cdf(x) + x * INV_SQRT_2PI * (-0.5 * x * x).exp()
}

/// Fraction of exactly-zero elements.
pub fn zero_fraction(x: &[f32]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().filter(|&&v| v == 0.0).count() as f64 / x.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(got: &[f32], want: &[f32], tol: f32) {
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            assert!((g - w).abs() <= tol, "[{i}]: got {g}, want {w}");
        }
    }

    #[test]
    fn matmul_small_known() {
        // [1 2; 3 4] x [5 6; 7 8] = [19 22; 43 50]
        let x = [1.0, 2.0, 3.0, 4.0];
        let w = [5.0, 6.0, 7.0, 8.0];
        assert_close(&matmul(&x, &w, 2, 2, 2), &[19.0, 22.0, 43.0, 50.0], 1e-6);
        let (blocked, stats) = matmul_ex(&x, &w, 2, 2, 2);
        assert_close(&blocked, &[19.0, 22.0, 43.0, 50.0], 1e-6);
        assert_eq!(stats.tiles, 1);
        assert_eq!(stats.zero_tiles, 0);
        assert_eq!(stats.macs, 8);
    }

    #[test]
    fn matmul_variants_agree_with_explicit_transposes() {
        let mut rng = crate::util::rng::Rng::new(3);
        let (m, k, n) = (7, 5, 6);
        let x = rng.normal_vec(m * k, 1.0);
        let w = rng.normal_vec(k * n, 1.0);
        let y = matmul(&x, &w, m, k, n);

        // nt: y · wᵀ should equal matmul against the materialized wᵀ.
        let mut wt = vec![0.0f32; n * k];
        for kk in 0..k {
            for j in 0..n {
                wt[j * k + kk] = w[kk * n + j];
            }
        }
        assert_close(&matmul_nt(&y, &w, m, n, k), &matmul(&y, &wt, m, n, k), 1e-4);
        assert_close(&matmul_nt_ex(&y, &w, m, n, k).0, &matmul(&y, &wt, m, n, k), 1e-4);

        // tn: xᵀ · y should equal matmul against the materialized xᵀ.
        let mut xt = vec![0.0f32; k * m];
        for i in 0..m {
            for kk in 0..k {
                xt[kk * m + i] = x[i * k + kk];
            }
        }
        assert_close(&matmul_tn(&x, &y, m, k, n), &matmul(&xt, &y, k, m, n), 1e-4);
        assert_close(&matmul_tn_ex(&x, &y, m, k, n).0, &matmul(&xt, &y, k, m, n), 1e-4);
    }

    #[test]
    fn parallel_matmul_matches_serial() {
        let mut rng = crate::util::rng::Rng::new(4);
        // Large enough to cross PAR_THRESHOLD: 256 * 128 * 128 = 4.2M MACs.
        let (m, k, n) = (256, 128, 128);
        let x = rng.normal_vec(m * k, 1.0);
        let w = rng.normal_vec(k * n, 1.0);
        let par = matmul(&x, &w, m, k, n);
        let mut serial = vec![0.0f32; m * n];
        matmul_rows(&x, &w, &mut serial, k, n);
        assert_eq!(par, serial, "row-chunked parallel GEMM must be bitwise exact");
    }

    #[test]
    fn blocked_matches_scalar_bitwise_across_block_edges() {
        // shapes straddling MR/NR/KC/NC boundaries on purpose
        let mut rng = crate::util::rng::Rng::new(41);
        for &(m, k, n) in &[(1, 1, 1), (5, 3, 17), (4, 128, 16), (9, 129, 31), (33, 260, 19)] {
            let x = rng.normal_vec(m * k, 1.0);
            let w = rng.normal_vec(k * n, 1.0);
            let scalar = matmul_scalar(&x, &w, m, k, n);
            let (blocked, stats) = matmul_ex(&x, &w, m, k, n);
            assert_eq!(blocked, scalar, "({m},{k},{n})");
            assert_eq!(stats.macs, (m * k * n) as u64);
            assert_eq!(stats.elems, (m * k) as u64);
        }
    }

    #[test]
    fn zero_tiles_are_skipped_and_counted() {
        // rows [0, 8) zeroed: with MR = 4 that is the first two row tiles
        // of every depth block
        let (m, k, n) = (12, 200, 24);
        let mut rng = crate::util::rng::Rng::new(42);
        let mut x = rng.normal_vec(m * k, 1.0);
        for v in x[..8 * k].iter_mut() {
            *v = 0.0;
        }
        let w = rng.normal_vec(k * n, 1.0);
        let scalar = matmul_scalar(&x, &w, m, k, n);
        let (blocked, stats) = matmul_ex(&x, &w, m, k, n);
        assert_eq!(blocked, scalar, "tile skipping must not change the result");
        // 3 row tiles x 2 depth blocks (200 = 128 + 72); tiles over rows
        // 0-3 and 4-7 are zero in both depth blocks
        assert_eq!(stats.tiles, 6);
        assert_eq!(stats.zero_tiles, 4);
        assert_eq!(stats.tile_skipped_macs, (8 * k * n) as u64);
        assert!((stats.effectual_tile_fraction() - 1.0 / 3.0).abs() < 1e-12);
        assert!(stats.effectual_mac_fraction() < 0.4);
    }

    #[test]
    fn stats_accumulator_aggregates_calls() {
        // Delta-based and >=, not ==: the accumulator is process-global
        // and other tests in this binary run concurrently (none of them
        // reset it, so counters only grow under our feet).
        let mut rng = crate::util::rng::Rng::new(43);
        let x = rng.normal_vec(8 * 40, 1.0);
        let w = rng.normal_vec(40 * 8, 1.0);
        let before = gemm_stats_snapshot();
        let (_, a) = matmul_ex(&x, &w, 8, 40, 8);
        let (_, b) = matmul_ex(&x, &w, 8, 40, 8);
        let after = gemm_stats_snapshot();
        assert!(after.tiles >= before.tiles + a.tiles + b.tiles);
        assert!(after.macs >= before.macs + a.macs + b.macs);
        assert!(after.elems >= before.elems + a.elems + b.elems);
        assert_eq!(a.macs, 8 * 40 * 8);
        assert_eq!(a, b, "identical calls produce identical summaries");
    }

    #[test]
    fn degenerate_dims_return_zeros() {
        assert!(matmul(&[], &[], 0, 0, 0).is_empty());
        assert_eq!(matmul(&[], &[], 3, 0, 2), vec![0.0; 6]);
        assert_eq!(matmul_ex(&[], &[], 3, 0, 2).0, vec![0.0; 6]);
        assert_eq!(matmul_nt_ex(&[], &[1.0, 2.0], 0, 2, 1).0, Vec::<f32>::new());
        assert_eq!(matmul_tn_ex(&[], &[], 0, 2, 3).0, vec![0.0; 6]);
    }

    #[test]
    fn softmax_rows_normalize() {
        let mut x = vec![1.0f32, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut x, 3);
        for row in x.chunks(3) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            assert!(row.windows(2).all(|w| w[0] < w[1]), "monotone inputs stay ordered");
        }
    }

    #[test]
    fn softmax_backward_matches_finite_difference() {
        let a = [0.3f32, -0.7, 1.1, 0.2];
        let dp = [0.5f32, -0.2, 0.1, 0.4];
        let n = a.len();
        let p = {
            let mut p = a.to_vec();
            softmax_rows(&mut p, n);
            p
        };
        let da = softmax_backward_rows(&p, &dp, n);
        let eps = 1e-3f32;
        for j in 0..n {
            let mut ap = a.to_vec();
            ap[j] += eps;
            softmax_rows(&mut ap, n);
            let mut am = a.to_vec();
            am[j] -= eps;
            softmax_rows(&mut am, n);
            let mut fd = 0.0f32;
            for t in 0..n {
                fd += dp[t] * (ap[t] - am[t]) / (2.0 * eps);
            }
            assert!((da[j] - fd).abs() < 1e-3, "j={j}: analytic {} fd {fd}", da[j]);
        }
    }

    #[test]
    fn layernorm_rows_are_normalized() {
        let x = [1.0f32, 2.0, 3.0, 4.0, 10.0, 10.0, 10.0, 14.0];
        let gamma = [1.0f32; 4];
        let beta = [0.0f32; 4];
        let mut out = vec![0.0f32; 8];
        let mut norm = vec![0.0f32; 8];
        let mut inv_std = vec![0.0f32; 2];
        layernorm_rows(&x, &gamma, &beta, 4, &mut out, &mut norm, &mut inv_std);
        for row in out.chunks(4) {
            let mean: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-5, "mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "var {var}");
        }
        assert_eq!(out, norm, "identity affine leaves norm unchanged");
    }

    #[test]
    fn layernorm_backward_matches_finite_difference() {
        let x = [0.5f32, -1.0, 2.0, 0.1, 0.4, 1.5];
        let n = 3;
        let gamma = [1.2f32, 0.8, -0.5];
        let beta = [0.1f32, 0.0, -0.2];
        let dy = [0.3f32, -0.6, 0.9, 0.2, 0.5, -0.4];
        let fwd = |x: &[f32]| {
            let mut out = vec![0.0f32; x.len()];
            let mut norm = vec![0.0f32; x.len()];
            let mut istd = vec![0.0f32; x.len() / n];
            layernorm_rows(x, &gamma, &beta, n, &mut out, &mut norm, &mut istd);
            (out, norm, istd)
        };
        let (_, norm, istd) = fwd(&x);
        let mut dg = vec![0.0f32; n];
        let mut db = vec![0.0f32; n];
        let dx = layernorm_backward_rows(&dy, &norm, &istd, &gamma, n, &mut dg, &mut db);
        let eps = 1e-3f32;
        for j in 0..x.len() {
            let mut xp = x.to_vec();
            xp[j] += eps;
            let mut xm = x.to_vec();
            xm[j] -= eps;
            let (yp, _, _) = fwd(&xp);
            let (ym, _, _) = fwd(&xm);
            let mut fd = 0.0f32;
            for t in 0..x.len() {
                fd += dy[t] * (yp[t] - ym[t]) / (2.0 * eps);
            }
            assert!((dx[j] - fd).abs() < 2e-3, "j={j}: analytic {} fd {fd}", dx[j]);
        }
        // dbeta is just the column sum of dy
        assert_close(&db, &col_sums(&dy, n), 1e-6);
    }

    #[test]
    fn erf_reference_values() {
        // erf(0)=0, erf(1)=0.8427008, erf(-1)=-erf(1), erf(2)=0.9953223
        assert!(erf(0.0).abs() < 1e-7);
        assert!((erf(1.0) - 0.842_700_8).abs() < 1e-5);
        assert!((erf(-1.0) + 0.842_700_8).abs() < 1e-5);
        assert!((erf(2.0) - 0.995_322_3).abs() < 1e-5);
    }

    #[test]
    fn gelu_reference_values_and_derivative() {
        // gelu(0)=0; gelu(1)=0.8413447; gelu(-1)=-0.15865525 (erf-based).
        assert!(gelu(0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841_344_7).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158_655_25).abs() < 1e-4);
        // derivative vs central difference
        for &x in &[-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let eps = 1e-3;
            let fd = (gelu(x + eps) - gelu(x - eps)) / (2.0 * eps);
            assert!((gelu_derivative(x) - fd).abs() < 1e-3, "x={x}");
        }
    }

    #[test]
    fn bias_and_colsum_roundtrip() {
        let mut x = vec![0.0f32; 6];
        add_bias(&mut x, &[1.0, 2.0, 3.0]);
        assert_eq!(x, vec![1.0, 2.0, 3.0, 1.0, 2.0, 3.0]);
        assert_eq!(col_sums(&x, 3), vec![2.0, 4.0, 6.0]);
    }

    #[test]
    fn zero_fraction_counts() {
        assert_eq!(zero_fraction(&[0.0, 1.0, 0.0, 2.0]), 0.5);
        assert_eq!(zero_fraction(&[]), 0.0);
    }
}
