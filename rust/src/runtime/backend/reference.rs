//! The pure-Rust reference executor: runs the BERT-Tiny-shaped encoder
//! natively on host tensors — no Python, no artifacts, no native XLA.
//!
//! Semantics mirror `python/compile/model.py` exactly (same op order,
//! same flat-parameter layout from `manifest.param_specs`, same DynaTran
//! hook placement on every activation matrix, same quantile-threshold
//! top-k baseline, same AdamW update).  Numerics are f32 like the AOT
//! artifacts; the only deliberate approximation is the erf inside GeLU
//! (Abramowitz–Stegun rational form, |err| < 1.5e-7) — see DESIGN.md
//! §Substitutions "Reference executor vs PJRT" for the full bit-exactness
//! inventory.
//!
//! This backend is what turns the serving/accuracy half of the repo into
//! real workloads: the Figs. 11/12/14 sweeps, the serving batcher, and
//! `train_step` fine-tuning all execute here by default when PJRT
//! artifacts are absent.
//!
//! Every GEMM here (QKV/output projections, attention scores and
//! context, both FFN layers, and all of backprop's `matmul_tn` /
//! `matmul_nt` gradients) routes through the block-sparse tiled
//! microkernel in `runtime::tensor` (DESIGN.md "Host microkernel"); the
//! dispatch is shape-based and bitwise-transparent, so this file only
//! ever calls the plain `matmul*` entry points.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::runtime::artifacts::Manifest;
use crate::runtime::backend::ExecBackend;
use crate::runtime::tensor as t;
use crate::trace::{ActHook, HookRecord};

const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

/// Model shape, extracted from the manifest once at construction.
#[derive(Clone, Copy, Debug)]
struct Shape {
    vocab: usize,
    seq: usize,
    hidden: usize,
    layers: usize,
    heads: usize,
    head_dim: usize,
    ff: usize,
    classes: usize,
}

/// Pruning mode of one inference forward pass (mirrors `model.py`
/// PRUNE_DYNATRAN / PRUNE_TOPK; training runs its own unpruned forward
/// in `loss_and_grads`, like the Python `PRUNE_NONE` path).
#[derive(Clone, Copy, Debug)]
enum Prune {
    /// DynaTran magnitude threshold on every activation matrix.
    DynaTran(f32),
    /// SpAtten-style top-k on attention scores only (keep fraction).
    TopK(f32),
}

pub struct ReferenceBackend {
    shape: Shape,
    param_count: usize,
    /// Parameter name -> (offset, len) into the flat buffer.
    offsets: HashMap<String, (usize, usize)>,
}

impl ReferenceBackend {
    /// Build an executor over the manifest's parameter layout.  Errors if
    /// the layout is missing any tensor the encoder needs or disagrees
    /// with the declared model shape.
    pub fn new(manifest: &Manifest) -> Result<ReferenceBackend> {
        if manifest.heads == 0 || manifest.hidden % manifest.heads != 0 {
            bail!(
                "reference backend: hidden {} not divisible by heads {}",
                manifest.hidden,
                manifest.heads
            );
        }
        let mut offsets = HashMap::new();
        let mut off = 0usize;
        for (name, shape, _std) in &manifest.param_specs {
            let len: usize = shape.iter().product();
            offsets.insert(name.clone(), (off, len));
            off += len;
        }
        if off != manifest.param_count {
            bail!(
                "reference backend: param specs cover {off} f32s but manifest \
                 declares {}",
                manifest.param_count
            );
        }
        let mut required =
            vec!["embed.word".to_string(), "embed.pos".into(), "cls.w".into(), "cls.b".into()];
        for layer in 0..manifest.layers {
            for suffix in [
                "attn.wq", "attn.bq", "attn.wk", "attn.bk", "attn.wv", "attn.bv", "attn.wo",
                "attn.bo", "ln1.gamma", "ln1.beta", "ffn.w1", "ffn.b1", "ffn.w2", "ffn.b2",
                "ln2.gamma", "ln2.beta",
            ] {
                required.push(format!("layer{layer}.{suffix}"));
            }
        }
        for name in &required {
            if !offsets.contains_key(name.as_str()) {
                bail!("reference backend: manifest params missing '{name}'");
            }
        }
        let h = manifest.hidden;
        let ff = if manifest.layers > 0 { offsets["layer0.ffn.b1"].1 } else { 4 * h };
        let shape = Shape {
            vocab: manifest.vocab,
            seq: manifest.seq,
            hidden: h,
            layers: manifest.layers,
            heads: manifest.heads,
            head_dim: h / manifest.heads,
            ff,
            classes: manifest.classes,
        };
        let expect = [
            ("embed.word", shape.vocab * h),
            ("embed.pos", shape.seq * h),
            ("cls.w", h * shape.classes),
            ("cls.b", shape.classes),
        ];
        for (name, want) in expect {
            let got = offsets[name].1;
            if got != want {
                bail!("reference backend: '{name}' has {got} elements, want {want}");
            }
        }
        Ok(ReferenceBackend { shape, param_count: off, offsets })
    }

    /// Slice the flat buffer for a named parameter (validated in `new`).
    fn p<'a>(&self, params: &'a [f32], name: &str) -> &'a [f32] {
        let &(off, len) = self
            .offsets
            .get(name)
            .unwrap_or_else(|| panic!("unvalidated parameter '{name}'"));
        &params[off..off + len]
    }

    /// Validate a `(batch, seq)` request: any `1 <= seq <= manifest.seq`
    /// is legal (the positional table is sliced), and per-row true
    /// lengths, when given, must satisfy `1 <= len <= seq`.
    fn check_inputs(
        &self,
        params: &[f32],
        ids: &[i32],
        batch: usize,
        seq: usize,
        lens: Option<&[usize]>,
    ) -> Result<()> {
        if params.len() != self.param_count {
            bail!(
                "params buffer has {} f32s, manifest layout wants {}",
                params.len(),
                self.param_count
            );
        }
        if seq == 0 || seq > self.shape.seq {
            bail!("seq {seq} outside [1, {}]", self.shape.seq);
        }
        if batch == 0 || ids.len() != batch * seq {
            bail!("ids length {} != batch {batch} * seq {seq}", ids.len());
        }
        if let Some(lens) = lens {
            if lens.len() != batch {
                bail!("lens has {} entries for batch {batch}", lens.len());
            }
            for &l in lens {
                if l == 0 || l > seq {
                    bail!("row length {l} outside [1, {seq}]");
                }
            }
        }
        for &id in ids {
            if id < 0 || id as usize >= self.shape.vocab {
                bail!("token id {id} outside vocab [0, {})", self.shape.vocab);
            }
        }
        Ok(())
    }

    /// Derive `seq` from a `(batch, ids)` pair: the row width is
    /// `ids.len() / batch`, and any width up to the manifest's `seq` is
    /// accepted (variable-length requests run at their native length).
    fn derive_seq(&self, ids: &[i32], batch: usize) -> Result<usize> {
        if batch == 0 || ids.len() % batch != 0 {
            bail!("ids length {} is not a multiple of batch {batch}", ids.len());
        }
        Ok(ids.len() / batch)
    }

    /// Run the encoder stack at row width `seq` (any `1..=manifest.seq`;
    /// the positional table is sliced) with per-row true lengths `lens`;
    /// returns the `(batch * seq, hidden)` hidden states.
    ///
    /// Attention is masked per row: scores, softmax, and context for
    /// batch row `b` span only its first `lens[b]` positions, so a
    /// row's logits are bit-identical whether it runs at `seq = len` or
    /// padded wider (every other op is row- or element-wise, and the
    /// tiled GEMM accumulates each output element in a fixed k-order
    /// regardless of the batch dimension — pinned by
    /// `tests/gemm_oracle.rs`).  Padding positions never reach a real
    /// row: their context stays exactly 0.0 and their residual garbage
    /// is confined to their own rows.
    ///
    /// When `stats` is set, the zero-fraction of every pruned
    /// activation matrix is recorded as a labelled [`HookRecord`]
    /// (layer + hook identity — the measured-sparsity trace cells),
    /// matching `model.py::activation_sparsity` hook-for-hook.
    /// Recording only *reads* the matrices, so a traced forward is
    /// bitwise identical to an untraced one.
    fn encode(
        &self,
        params: &[f32],
        ids: &[i32],
        batch: usize,
        seq: usize,
        lens: &[usize],
        mode: Prune,
        mut stats: Option<&mut Vec<HookRecord>>,
    ) -> Vec<f32> {
        let Shape { hidden: h, layers, heads: nh, head_dim: hd, ff, .. } = self.shape;
        let bs = batch * seq;
        let scale = 1.0 / (hd as f32).sqrt();

        // Ragged score-buffer layout: one `lens[b] x lens[b]` block per
        // `(batch row, head)`, b-major then head-major.  When every row
        // runs at the full width this is byte-identical to the old
        // `(batch * heads * seq, seq)` matrix, so the fixed-length path
        // (and its pruning-hook statistics) is unchanged.
        let mut blk_off = Vec::with_capacity(batch * nh);
        let mut att_elems = 0usize;
        for &l in lens {
            for _ in 0..nh {
                blk_off.push(att_elems);
                att_elems += l * l;
            }
        }

        // M-OP-0: word + position embeddings.
        let word = self.p(params, "embed.word");
        let pos = self.p(params, "embed.pos");
        let mut hidden = vec![0.0f32; bs * h];
        for (row, dst) in hidden.chunks_exact_mut(h).enumerate() {
            let id = ids[row] as usize;
            let s = row % seq;
            let wrow = &word[id * h..id * h + h];
            let prow = &pos[s * h..s * h + h];
            for j in 0..h {
                dst[j] = wrow[j] + prow[j];
            }
        }

        for layer in 0..layers {
            let name = |s: &str| format!("layer{layer}.{s}");
            let mut x2 = hidden;
            prune_hook(&mut x2, mode, layer, ActHook::Input, &mut stats);

            // C-OP-1..3: QKV projections.
            let mut q = t::matmul(&x2, self.p(params, &name("attn.wq")), bs, h, h);
            t::add_bias(&mut q, self.p(params, &name("attn.bq")));
            prune_hook(&mut q, mode, layer, ActHook::Q, &mut stats);
            let mut k = t::matmul(&x2, self.p(params, &name("attn.wk")), bs, h, h);
            t::add_bias(&mut k, self.p(params, &name("attn.bk")));
            prune_hook(&mut k, mode, layer, ActHook::K, &mut stats);
            let mut v = t::matmul(&x2, self.p(params, &name("attn.wv")), bs, h, h);
            t::add_bias(&mut v, self.p(params, &name("attn.bv")));
            prune_hook(&mut v, mode, layer, ActHook::V, &mut stats);

            // C-OP-4: attention scores, all heads folded into one ragged
            // buffer so the pruning hook sees every real score (and, at
            // uniform lengths, exactly the (batch * heads * seq, seq)
            // matrix the Python model prunes).
            let mut att = vec![0.0f32; att_elems];
            for b in 0..batch {
                let l = lens[b];
                for head in 0..nh {
                    let qh = gather_head(&q, b, head, l, seq, h, hd);
                    let kh = gather_head(&k, b, head, l, seq, h, hd);
                    let mut a = t::matmul_nt(&qh, &kh, l, hd, l);
                    for val in a.iter_mut() {
                        *val *= scale;
                    }
                    let blk = blk_off[b * nh + head];
                    att[blk..blk + l * l].copy_from_slice(&a);
                }
            }
            match mode {
                Prune::TopK(keep_frac) => {
                    for b in 0..batch {
                        let l = lens[b];
                        for head in 0..nh {
                            let blk = blk_off[b * nh + head];
                            topk_rows_quantile(&mut att[blk..blk + l * l], l, keep_frac);
                        }
                    }
                }
                _ => prune_hook(&mut att, mode, layer, ActHook::Scores, &mut stats),
            }

            // C-OP-5..6: softmax + probabilities x values.  Padding
            // positions get no context at all (pcat rows stay 0.0).
            let mut pcat = vec![0.0f32; bs * h];
            for b in 0..batch {
                let l = lens[b];
                for head in 0..nh {
                    let blk = blk_off[b * nh + head];
                    t::softmax_rows(&mut att[blk..blk + l * l], l);
                    let vh = gather_head(&v, b, head, l, seq, h, hd);
                    let o = t::matmul(&att[blk..blk + l * l], &vh, l, l, hd);
                    scatter_head(&mut pcat, &o, b, head, l, seq, h, hd);
                }
            }
            prune_hook(&mut pcat, mode, layer, ActHook::Context, &mut stats);

            // C-OP-7: output projection.
            let mut mha = t::matmul(&pcat, self.p(params, &name("attn.wo")), bs, h, h);
            t::add_bias(&mut mha, self.p(params, &name("attn.bo")));
            prune_hook(&mut mha, mode, layer, ActHook::ProjOut, &mut stats);

            // C-OP-8: residual + layer-norm.
            let mut r1 = mha;
            for (rv, &xv) in r1.iter_mut().zip(&x2) {
                *rv += xv;
            }
            let mut x_ln1 = vec![0.0f32; bs * h];
            let mut norm1 = vec![0.0f32; bs * h];
            let mut istd1 = vec![0.0f32; bs];
            t::layernorm_rows(
                &r1,
                self.p(params, &name("ln1.gamma")),
                self.p(params, &name("ln1.beta")),
                h,
                &mut x_ln1,
                &mut norm1,
                &mut istd1,
            );

            // C-OP-9..10: feed-forward with GeLU.
            let mut xp = x_ln1.clone();
            prune_hook(&mut xp, mode, layer, ActHook::FfnIn, &mut stats);
            let mut f1 = t::matmul(&xp, self.p(params, &name("ffn.w1")), bs, h, ff);
            t::add_bias(&mut f1, self.p(params, &name("ffn.b1")));
            for val in f1.iter_mut() {
                *val = t::gelu(*val);
            }
            prune_hook(&mut f1, mode, layer, ActHook::Gelu, &mut stats);
            let mut f2 = t::matmul(&f1, self.p(params, &name("ffn.w2")), bs, ff, h);
            t::add_bias(&mut f2, self.p(params, &name("ffn.b2")));
            prune_hook(&mut f2, mode, layer, ActHook::FfnOut, &mut stats);

            // C-OP-11: second residual (from the *unpruned* x_ln1) + norm.
            let mut r2 = f2;
            for (rv, &xv) in r2.iter_mut().zip(&x_ln1) {
                *rv += xv;
            }
            let mut out = vec![0.0f32; bs * h];
            let mut norm2 = vec![0.0f32; bs * h];
            let mut istd2 = vec![0.0f32; bs];
            t::layernorm_rows(
                &r2,
                self.p(params, &name("ln2.gamma")),
                self.p(params, &name("ln2.beta")),
                h,
                &mut out,
                &mut norm2,
                &mut istd2,
            );
            hidden = out;
        }
        hidden
    }

    /// Logits from the `[CLS]` (position-0) hidden state.  `stats`
    /// threads the optional trace-capture recorder through; it never
    /// affects the computed logits.
    fn classify_mode(
        &self,
        params: &[f32],
        ids: &[i32],
        batch: usize,
        seq: usize,
        lens: &[usize],
        mode: Prune,
        stats: Option<&mut Vec<HookRecord>>,
    ) -> Vec<f32> {
        let Shape { hidden: h, classes, .. } = self.shape;
        let hidden = self.encode(params, ids, batch, seq, lens, mode, stats);
        let mut pooled = vec![0.0f32; batch * h];
        for b in 0..batch {
            pooled[b * h..b * h + h].copy_from_slice(&hidden[b * seq * h..b * seq * h + h]);
        }
        let mut logits = t::matmul(&pooled, self.p(params, "cls.w"), batch, h, classes);
        t::add_bias(&mut logits, self.p(params, "cls.b"));
        logits
    }

    /// Unpruned full-width forward with cached intermediates — the
    /// shared training forward for both the classify and the span head
    /// (mirrors the Python `PRUNE_NONE` path).  Returns the per-layer
    /// caches and the final `(batch * seq, hidden)` states.
    fn forward_caches(
        &self,
        params: &[f32],
        ids: &[i32],
        batch: usize,
    ) -> (Vec<LayerCache>, Vec<f32>) {
        let Shape { seq, hidden: h, layers, heads: nh, head_dim: hd, ff, .. } = self.shape;
        let bs = batch * seq;
        let scale = 1.0 / (hd as f32).sqrt();

        let word = self.p(params, "embed.word");
        let pos = self.p(params, "embed.pos");
        let mut hidden = vec![0.0f32; bs * h];
        for (row, dst) in hidden.chunks_exact_mut(h).enumerate() {
            let id = ids[row] as usize;
            let s = row % seq;
            for j in 0..h {
                dst[j] = word[id * h + j] + pos[s * h + j];
            }
        }

        let mut caches: Vec<LayerCache> = Vec::with_capacity(layers);
        for layer in 0..layers {
            let name = |s: &str| format!("layer{layer}.{s}");
            let x2 = hidden;

            let mut q = t::matmul(&x2, self.p(params, &name("attn.wq")), bs, h, h);
            t::add_bias(&mut q, self.p(params, &name("attn.bq")));
            let mut k = t::matmul(&x2, self.p(params, &name("attn.wk")), bs, h, h);
            t::add_bias(&mut k, self.p(params, &name("attn.bk")));
            let mut v = t::matmul(&x2, self.p(params, &name("attn.wv")), bs, h, h);
            t::add_bias(&mut v, self.p(params, &name("attn.bv")));

            let mut probs = vec![0.0f32; batch * nh * seq * seq];
            let mut pcat = vec![0.0f32; bs * h];
            for b in 0..batch {
                for head in 0..nh {
                    let qh = gather_head(&q, b, head, seq, seq, h, hd);
                    let kh = gather_head(&k, b, head, seq, seq, h, hd);
                    let mut a = t::matmul_nt(&qh, &kh, seq, hd, seq);
                    for val in a.iter_mut() {
                        *val *= scale;
                    }
                    t::softmax_rows(&mut a, seq);
                    let vh = gather_head(&v, b, head, seq, seq, h, hd);
                    let o = t::matmul(&a, &vh, seq, seq, hd);
                    scatter_head(&mut pcat, &o, b, head, seq, seq, h, hd);
                    let blk = (b * nh + head) * seq * seq;
                    probs[blk..blk + seq * seq].copy_from_slice(&a);
                }
            }

            let mut mha = t::matmul(&pcat, self.p(params, &name("attn.wo")), bs, h, h);
            t::add_bias(&mut mha, self.p(params, &name("attn.bo")));
            let mut r1 = mha;
            for (rv, &xv) in r1.iter_mut().zip(&x2) {
                *rv += xv;
            }
            let mut x_ln1 = vec![0.0f32; bs * h];
            let mut norm1 = vec![0.0f32; bs * h];
            let mut istd1 = vec![0.0f32; bs];
            t::layernorm_rows(
                &r1,
                self.p(params, &name("ln1.gamma")),
                self.p(params, &name("ln1.beta")),
                h,
                &mut x_ln1,
                &mut norm1,
                &mut istd1,
            );

            let mut u = t::matmul(&x_ln1, self.p(params, &name("ffn.w1")), bs, h, ff);
            t::add_bias(&mut u, self.p(params, &name("ffn.b1")));
            let mut f1 = u.clone();
            for val in f1.iter_mut() {
                *val = t::gelu(*val);
            }
            let mut f2 = t::matmul(&f1, self.p(params, &name("ffn.w2")), bs, ff, h);
            t::add_bias(&mut f2, self.p(params, &name("ffn.b2")));
            let mut r2 = f2;
            for (rv, &xv) in r2.iter_mut().zip(&x_ln1) {
                *rv += xv;
            }
            let mut out = vec![0.0f32; bs * h];
            let mut norm2 = vec![0.0f32; bs * h];
            let mut istd2 = vec![0.0f32; bs];
            t::layernorm_rows(
                &r2,
                self.p(params, &name("ln2.gamma")),
                self.p(params, &name("ln2.beta")),
                h,
                &mut out,
                &mut norm2,
                &mut istd2,
            );
            hidden = out;
            caches.push(LayerCache {
                x2,
                q,
                k,
                v,
                probs,
                pcat,
                norm1,
                istd1,
                x_ln1,
                u,
                f1,
                norm2,
                istd2,
            });
        }
        (caches, hidden)
    }

    /// Forward pass with cached intermediates, then analytic backprop of
    /// the mean cross-entropy loss at the `[CLS]` position.  Training
    /// always runs unpruned, like the `train_step_b32` artifact.
    /// Returns `(loss, grads)` with `grads` in flat `param_specs`
    /// layout.
    fn loss_and_grads(
        &self,
        params: &[f32],
        ids: &[i32],
        labels: &[i32],
        batch: usize,
    ) -> Result<(f32, Vec<f32>)> {
        let Shape { seq, hidden: h, classes, .. } = self.shape;
        let bs = batch * seq;
        for &l in labels {
            if l < 0 || l as usize >= classes {
                bail!("label {l} outside [0, {classes})");
            }
        }
        let (caches, hidden) = self.forward_caches(params, ids, batch);
        let mut pooled = vec![0.0f32; batch * h];
        for b in 0..batch {
            pooled[b * h..b * h + h].copy_from_slice(&hidden[b * seq * h..b * seq * h + h]);
        }
        let mut logits = t::matmul(&pooled, self.p(params, "cls.w"), batch, h, classes);
        t::add_bias(&mut logits, self.p(params, "cls.b"));

        // ---- loss: mean softmax cross-entropy -----------------------
        let mut loss = 0.0f32;
        let mut dlogits = logits.clone();
        t::softmax_rows(&mut dlogits, classes);
        for b in 0..batch {
            let row = &logits[b * classes..(b + 1) * classes];
            let max = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
            let sumexp: f32 = row.iter().map(|&v| (v - max).exp()).sum();
            let logz = max + sumexp.ln();
            loss += logz - row[labels[b] as usize];
            dlogits[b * classes + labels[b] as usize] -= 1.0;
        }
        loss /= batch as f32;
        let inv_b = 1.0 / batch as f32;
        for d in dlogits.iter_mut() {
            *d *= inv_b;
        }

        // ---- backward -----------------------------------------------
        let mut grads = vec![0.0f32; self.param_count];
        let dcls_w = t::matmul_tn(&pooled, &dlogits, batch, h, classes);
        acc(&mut grads, &self.offsets, "cls.w", &dcls_w);
        acc(&mut grads, &self.offsets, "cls.b", &t::col_sums(&dlogits, classes));
        let dpooled = t::matmul_nt(&dlogits, self.p(params, "cls.w"), batch, classes, h);
        // the classify head reads only the CLS position, so the encoder
        // gradient is seeded there alone
        let mut dhidden = vec![0.0f32; bs * h];
        for b in 0..batch {
            dhidden[b * seq * h..b * seq * h + h].copy_from_slice(&dpooled[b * h..b * h + h]);
        }
        self.encoder_backward(params, ids, batch, &caches, dhidden, &mut grads);
        Ok((loss, grads))
    }

    /// Span objective: loss + analytic gradients.  Per batch row the
    /// loss is the mean of two softmax cross-entropies over *positions*
    /// — a start pointer and an end pointer from the shared per-position
    /// `cls` head — averaged over the batch.  Unanswerable rows label
    /// both pointers with position 0 (CLS), the SQuAD-v2 convention
    /// `nlp::span` datasets use.
    fn span_loss_and_grads(
        &self,
        params: &[f32],
        ids: &[i32],
        starts: &[i32],
        ends: &[i32],
        batch: usize,
    ) -> Result<(f32, Vec<f32>)> {
        let Shape { seq, hidden: h, classes, .. } = self.shape;
        if classes != 2 {
            bail!("span head reuses the 2-class cls layout, manifest has {classes} classes");
        }
        if starts.len() != batch || ends.len() != batch {
            bail!(
                "starts/ends must have one entry per batch row ({} / {} for batch {batch})",
                starts.len(),
                ends.len()
            );
        }
        for (&s, &e) in starts.iter().zip(ends) {
            if s < 0 || e < s || e as usize >= seq {
                bail!("span ({s}, {e}) outside 0 <= start <= end < {seq}");
            }
        }
        let bs = batch * seq;
        let (caches, hidden) = self.forward_caches(params, ids, batch);
        let mut logits = t::matmul(&hidden, self.p(params, "cls.w"), bs, h, 2);
        t::add_bias(&mut logits, self.p(params, "cls.b"));

        // ---- loss: mean over rows of (CE_start + CE_end) / 2, each a
        // softmax over the row's positions within one logit column -----
        let mut loss = 0.0f32;
        let mut dlogits = vec![0.0f32; bs * 2];
        let inv = 1.0 / (2.0 * batch as f32);
        for b in 0..batch {
            for col in 0..2usize {
                let target = if col == 0 { starts[b] } else { ends[b] } as usize;
                let at = |p: usize| logits[(b * seq + p) * 2 + col];
                let mut max = f32::NEG_INFINITY;
                for p in 0..seq {
                    max = max.max(at(p));
                }
                let mut sumexp = 0.0f32;
                for p in 0..seq {
                    sumexp += (at(p) - max).exp();
                }
                let logz = max + sumexp.ln();
                loss += 0.5 * (logz - at(target));
                for p in 0..seq {
                    let mut d = (at(p) - logz).exp();
                    if p == target {
                        d -= 1.0;
                    }
                    dlogits[(b * seq + p) * 2 + col] = d * inv;
                }
            }
        }
        loss /= batch as f32;

        // ---- backward: the span head reads EVERY position, so the
        // encoder gradient is dense over positions (unlike the
        // CLS-pooled classify head) -----------------------------------
        let mut grads = vec![0.0f32; self.param_count];
        let dcls_w = t::matmul_tn(&hidden, &dlogits, bs, h, 2);
        acc(&mut grads, &self.offsets, "cls.w", &dcls_w);
        acc(&mut grads, &self.offsets, "cls.b", &t::col_sums(&dlogits, 2));
        let dhidden = t::matmul_nt(&dlogits, self.p(params, "cls.w"), bs, 2, h);
        self.encoder_backward(params, ids, batch, &caches, dhidden, &mut grads);
        Ok((loss, grads))
    }

    /// Per-position span logits from the shared `cls` head: the
    /// `(batch * seq, hidden)` encoder output through one `[h, 2]`
    /// matmul — `(start, end)` pairs, position-major.
    fn span_mode(
        &self,
        params: &[f32],
        ids: &[i32],
        batch: usize,
        seq: usize,
        lens: &[usize],
        mode: Prune,
        stats: Option<&mut Vec<HookRecord>>,
    ) -> Vec<f32> {
        let h = self.shape.hidden;
        let hidden = self.encode(params, ids, batch, seq, lens, mode, stats);
        let mut logits = t::matmul(&hidden, self.p(params, "cls.w"), batch * seq, h, 2);
        t::add_bias(&mut logits, self.p(params, "cls.b"));
        logits
    }

    /// Backprop a gradient at the final hidden states (`dhidden`,
    /// `(batch * seq, hidden)`, however the head seeded it) through the
    /// encoder stack and the embeddings, accumulating parameter
    /// gradients into `grads`.
    fn encoder_backward(
        &self,
        params: &[f32],
        ids: &[i32],
        batch: usize,
        caches: &[LayerCache],
        mut dhidden: Vec<f32>,
        grads: &mut [f32],
    ) {
        let Shape { seq, hidden: h, layers, heads: nh, head_dim: hd, ff, .. } = self.shape;
        let bs = batch * seq;
        let scale = 1.0 / (hd as f32).sqrt();

        for layer in (0..layers).rev() {
            let name = |s: &str| format!("layer{layer}.{s}");
            let c = &caches[layer];

            // LN2 backward.
            let mut dg2 = vec![0.0f32; h];
            let mut db2 = vec![0.0f32; h];
            let dr2 = t::layernorm_backward_rows(
                &dhidden,
                &c.norm2,
                &c.istd2,
                self.p(params, &name("ln2.gamma")),
                h,
                &mut dg2,
                &mut db2,
            );
            acc(grads, &self.offsets, &name("ln2.gamma"), &dg2);
            acc(grads, &self.offsets, &name("ln2.beta"), &db2);

            // FFN backward; dr2 feeds both f2 and the x_ln1 residual.
            let df2 = &dr2;
            let mut dxln1 = dr2.clone();
            let dw2 = t::matmul_tn(&c.f1, df2, bs, ff, h);
            acc(grads, &self.offsets, &name("ffn.w2"), &dw2);
            acc(grads, &self.offsets, &name("ffn.b2"), &t::col_sums(df2, h));
            let mut du = t::matmul_nt(df2, self.p(params, &name("ffn.w2")), bs, h, ff);
            for (dv, &uv) in du.iter_mut().zip(&c.u) {
                *dv *= t::gelu_derivative(uv);
            }
            let dw1 = t::matmul_tn(&c.x_ln1, &du, bs, h, ff);
            acc(grads, &self.offsets, &name("ffn.w1"), &dw1);
            acc(grads, &self.offsets, &name("ffn.b1"), &t::col_sums(&du, ff));
            let dx_ffn = t::matmul_nt(&du, self.p(params, &name("ffn.w1")), bs, ff, h);
            for (a, &b) in dxln1.iter_mut().zip(&dx_ffn) {
                *a += b;
            }

            // LN1 backward.
            let mut dg1 = vec![0.0f32; h];
            let mut db1 = vec![0.0f32; h];
            let dr1 = t::layernorm_backward_rows(
                &dxln1,
                &c.norm1,
                &c.istd1,
                self.p(params, &name("ln1.gamma")),
                h,
                &mut dg1,
                &mut db1,
            );
            acc(grads, &self.offsets, &name("ln1.gamma"), &dg1);
            acc(grads, &self.offsets, &name("ln1.beta"), &db1);

            // Output projection backward; dr1 feeds mha and the x2 residual.
            let dmha = &dr1;
            let mut dx2 = dr1.clone();
            let dwo = t::matmul_tn(&c.pcat, dmha, bs, h, h);
            acc(grads, &self.offsets, &name("attn.wo"), &dwo);
            acc(grads, &self.offsets, &name("attn.bo"), &t::col_sums(dmha, h));
            let dpcat = t::matmul_nt(dmha, self.p(params, &name("attn.wo")), bs, h, h);

            // Attention backward, head by head.
            let mut dq = vec![0.0f32; bs * h];
            let mut dk = vec![0.0f32; bs * h];
            let mut dv = vec![0.0f32; bs * h];
            for b in 0..batch {
                for head in 0..nh {
                    let do_h = gather_head(&dpcat, b, head, seq, seq, h, hd);
                    let blk = (b * nh + head) * seq * seq;
                    let p_blk = &c.probs[blk..blk + seq * seq];
                    let qh = gather_head(&c.q, b, head, seq, seq, h, hd);
                    let kh = gather_head(&c.k, b, head, seq, seq, h, hd);
                    let vh = gather_head(&c.v, b, head, seq, seq, h, hd);
                    let dp = t::matmul_nt(&do_h, &vh, seq, hd, seq);
                    let dvh = t::matmul_tn(p_blk, &do_h, seq, seq, hd);
                    let mut da = t::softmax_backward_rows(p_blk, &dp, seq);
                    for val in da.iter_mut() {
                        *val *= scale;
                    }
                    let dqh = t::matmul(&da, &kh, seq, seq, hd);
                    let dkh = t::matmul_tn(&da, &qh, seq, seq, hd);
                    scatter_head_add(&mut dq, &dqh, b, head, seq, seq, h, hd);
                    scatter_head_add(&mut dk, &dkh, b, head, seq, seq, h, hd);
                    scatter_head_add(&mut dv, &dvh, b, head, seq, seq, h, hd);
                }
            }

            // QKV projection backward.
            let dwq = t::matmul_tn(&c.x2, &dq, bs, h, h);
            acc(grads, &self.offsets, &name("attn.wq"), &dwq);
            acc(grads, &self.offsets, &name("attn.bq"), &t::col_sums(&dq, h));
            let dxq = t::matmul_nt(&dq, self.p(params, &name("attn.wq")), bs, h, h);
            let dwk = t::matmul_tn(&c.x2, &dk, bs, h, h);
            acc(grads, &self.offsets, &name("attn.wk"), &dwk);
            acc(grads, &self.offsets, &name("attn.bk"), &t::col_sums(&dk, h));
            let dxk = t::matmul_nt(&dk, self.p(params, &name("attn.wk")), bs, h, h);
            let dwv = t::matmul_tn(&c.x2, &dv, bs, h, h);
            acc(grads, &self.offsets, &name("attn.wv"), &dwv);
            acc(grads, &self.offsets, &name("attn.bv"), &t::col_sums(&dv, h));
            let dxv = t::matmul_nt(&dv, self.p(params, &name("attn.wv")), bs, h, h);
            for i in 0..bs * h {
                dx2[i] += dxq[i] + dxk[i] + dxv[i];
            }
            dhidden = dx2;
        }

        // Embedding backward.
        let (woff, _) = self.offsets["embed.word"];
        let (poff, _) = self.offsets["embed.pos"];
        for (row, drow) in dhidden.chunks_exact(h).enumerate() {
            let id = ids[row] as usize;
            let s = row % seq;
            for (j, &d) in drow.iter().enumerate() {
                grads[woff + id * h + j] += d;
                grads[poff + s * h + j] += d;
            }
        }
    }
}

/// Cached per-layer intermediates of a training forward pass.
struct LayerCache {
    x2: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Post-softmax attention probabilities, (batch*heads*seq, seq).
    probs: Vec<f32>,
    pcat: Vec<f32>,
    norm1: Vec<f32>,
    istd1: Vec<f32>,
    x_ln1: Vec<f32>,
    /// Pre-GeLU feed-forward activations.
    u: Vec<f32>,
    f1: Vec<f32>,
    norm2: Vec<f32>,
    istd2: Vec<f32>,
}

/// Accumulate a named parameter's gradient block into the flat buffer.
fn acc(grads: &mut [f32], offsets: &HashMap<String, (usize, usize)>, name: &str, vals: &[f32]) {
    let (off, len) = offsets[name];
    debug_assert_eq!(len, vals.len(), "grad size for {name}");
    for (g, &v) in grads[off..off + len].iter_mut().zip(vals) {
        *g += v;
    }
}

/// One AdamW update over the flat buffers — shared by both heads' train
/// steps (`step` is the pre-increment counter for bias correction).
fn adamw_update(
    params: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    grads: &[f32],
    step: f32,
    lr: f32,
) {
    let tstep = step + 1.0;
    let bc1 = 1.0 - ADAM_B1.powf(tstep);
    let bc2 = 1.0 - ADAM_B2.powf(tstep);
    for i in 0..params.len() {
        let g = grads[i];
        m[i] = ADAM_B1 * m[i] + (1.0 - ADAM_B1) * g;
        v[i] = ADAM_B2 * v[i] + (1.0 - ADAM_B2) * g * g;
        let mhat = m[i] / bc1;
        let vhat = v[i] / bc2;
        params[i] -= lr * mhat / (vhat.sqrt() + ADAM_EPS);
    }
}

impl ExecBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    /// Forking is a fresh construction over the same manifest: the
    /// executor holds only the (immutable) shape and parameter-offset
    /// table, so siblings are fully independent.
    fn fork(&self, manifest: &Manifest) -> Result<Box<dyn ExecBackend>> {
        Ok(Box::new(ReferenceBackend::new(manifest)?))
    }

    fn classify(
        &mut self,
        batch: usize,
        params: &[f32],
        ids: &[i32],
        tau: f32,
    ) -> Result<Vec<f32>> {
        let seq = self.derive_seq(ids, batch)?;
        self.check_inputs(params, ids, batch, seq, None)?;
        let lens = vec![seq; batch];
        Ok(self.classify_mode(params, ids, batch, seq, &lens, Prune::DynaTran(tau), None))
    }

    fn classify_padded(
        &mut self,
        batch: usize,
        seq: usize,
        lens: &[usize],
        params: &[f32],
        ids: &[i32],
        tau: f32,
    ) -> Result<Vec<f32>> {
        self.check_inputs(params, ids, batch, seq, Some(lens))?;
        Ok(self.classify_mode(params, ids, batch, seq, lens, Prune::DynaTran(tau), None))
    }

    fn classify_topk(&mut self, params: &[f32], ids: &[i32], keep_frac: f32) -> Result<Vec<f32>> {
        let seq = self.shape.seq;
        if ids.is_empty() || ids.len() % seq != 0 {
            bail!("ids length {} is not a multiple of seq {seq}", ids.len());
        }
        let batch = ids.len() / seq;
        self.check_inputs(params, ids, batch, seq, None)?;
        let lens = vec![seq; batch];
        Ok(self.classify_mode(params, ids, batch, seq, &lens, Prune::TopK(keep_frac), None))
    }

    fn classify_traced(
        &mut self,
        batch: usize,
        params: &[f32],
        ids: &[i32],
        tau: f32,
    ) -> Result<(Vec<f32>, Vec<HookRecord>)> {
        let seq = self.derive_seq(ids, batch)?;
        self.check_inputs(params, ids, batch, seq, None)?;
        let lens = vec![seq; batch];
        let mut records = Vec::new();
        let logits = self.classify_mode(
            params,
            ids,
            batch,
            seq,
            &lens,
            Prune::DynaTran(tau),
            Some(&mut records),
        );
        Ok((logits, records))
    }

    fn activation_sparsity(&mut self, params: &[f32], ids: &[i32], tau: f32) -> Result<f32> {
        let seq = self.shape.seq;
        if ids.is_empty() || ids.len() % seq != 0 {
            bail!("ids length {} is not a multiple of seq {seq}", ids.len());
        }
        let batch = ids.len() / seq;
        self.check_inputs(params, ids, batch, seq, None)?;
        let lens = vec![seq; batch];
        let mut stats = Vec::new();
        self.encode(params, ids, batch, seq, &lens, Prune::DynaTran(tau), Some(&mut stats));
        if stats.is_empty() {
            return Ok(0.0);
        }
        // unweighted mean over the per-matrix fractions (the Figs. 11/12
        // rho axis — same statistic as before hooks carried identities)
        Ok((stats.iter().map(|r| r.zero_frac).sum::<f64>() / stats.len() as f64) as f32)
    }

    fn train_step(
        &mut self,
        params: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        step: f32,
        ids: &[i32],
        labels: &[i32],
        lr: f32,
    ) -> Result<f32> {
        let batch = labels.len();
        // training always runs at the manifest's full seq (the AOT
        // train_step artifacts export exactly that shape)
        self.check_inputs(params, ids, batch, self.shape.seq, None)?;
        if m.len() != params.len() || v.len() != params.len() {
            bail!("optimizer state length mismatch");
        }
        let (loss, grads) = self.loss_and_grads(params, ids, labels, batch)?;
        adamw_update(params, m, v, &grads, step, lr);
        Ok(loss)
    }

    fn span_logits(
        &mut self,
        batch: usize,
        params: &[f32],
        ids: &[i32],
        tau: f32,
    ) -> Result<Vec<f32>> {
        if self.shape.classes != 2 {
            bail!(
                "span head reuses the 2-class cls layout, manifest has {} classes",
                self.shape.classes
            );
        }
        let seq = self.derive_seq(ids, batch)?;
        self.check_inputs(params, ids, batch, seq, None)?;
        let lens = vec![seq; batch];
        Ok(self.span_mode(params, ids, batch, seq, &lens, Prune::DynaTran(tau), None))
    }

    fn span_logits_padded(
        &mut self,
        batch: usize,
        seq: usize,
        lens: &[usize],
        params: &[f32],
        ids: &[i32],
        tau: f32,
    ) -> Result<Vec<f32>> {
        if self.shape.classes != 2 {
            bail!(
                "span head reuses the 2-class cls layout, manifest has {} classes",
                self.shape.classes
            );
        }
        self.check_inputs(params, ids, batch, seq, Some(lens))?;
        Ok(self.span_mode(params, ids, batch, seq, lens, Prune::DynaTran(tau), None))
    }

    fn span_loss_grads(
        &mut self,
        batch: usize,
        params: &[f32],
        ids: &[i32],
        starts: &[i32],
        ends: &[i32],
    ) -> Result<(f32, Vec<f32>)> {
        // span training runs at the manifest's full seq, like train_step
        self.check_inputs(params, ids, batch, self.shape.seq, None)?;
        self.span_loss_and_grads(params, ids, starts, ends, batch)
    }

    fn span_train_step(
        &mut self,
        params: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        step: f32,
        ids: &[i32],
        starts: &[i32],
        ends: &[i32],
        lr: f32,
    ) -> Result<f32> {
        let batch = starts.len();
        self.check_inputs(params, ids, batch, self.shape.seq, None)?;
        if m.len() != params.len() || v.len() != params.len() {
            bail!("optimizer state length mismatch");
        }
        let (loss, grads) = self.span_loss_and_grads(params, ids, starts, ends, batch)?;
        adamw_update(params, m, v, &grads, step, lr);
        Ok(loss)
    }

    fn dynatran_prune(&mut self, x: &[f32], tau: f32) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut pruned = x.to_vec();
        let mut mask = vec![0.0f32; x.len()];
        for (p, msk) in pruned.iter_mut().zip(mask.iter_mut()) {
            if p.abs() < tau {
                *p = 0.0;
                *msk = 1.0;
            }
        }
        Ok((pruned, mask))
    }
}

/// DynaTran hook on one activation matrix: threshold in place (DynaTran
/// mode only), then record its zero-fraction — labelled with the
/// `(layer, hook)` identity the sparsity trace aggregates by — when
/// profiling.  Recording reads the matrix; it never modifies it.
fn prune_hook(
    x: &mut [f32],
    mode: Prune,
    layer: usize,
    hook: ActHook,
    stats: &mut Option<&mut Vec<HookRecord>>,
) {
    if let Prune::DynaTran(tau) = mode {
        if tau > 0.0 {
            // Shared branchless DynaTran primitive — one definition of
            // "pruned to zero" for the hooks, the benches, and the
            // tile-bitmap handoff (`pruning::dynatran_prune_tiled`), so
            // the zeros the blocked GEMM skips downstream are exactly
            // the zeros recorded here.
            crate::pruning::dynatran_prune_inplace(x, tau);
        }
        if let Some(st) = stats.as_mut() {
            st.push(HookRecord {
                layer,
                hook,
                zero_frac: t::zero_fraction(x),
                elems: x.len(),
            });
        }
    }
}

/// SpAtten-style top-k on each length-`n` row, expressed as the
/// `(1 - keep_frac)` quantile threshold of `|row|` with linear
/// interpolation — the same formulation as
/// `python/compile/kernels/ref.py::topk_keep_fraction`.
fn topk_rows_quantile(x: &mut [f32], n: usize, keep_frac: f32) {
    let q = (1.0 - keep_frac).clamp(0.0, 1.0);
    let mut mags: Vec<f32> = Vec::with_capacity(n);
    for row in x.chunks_exact_mut(n) {
        mags.clear();
        mags.extend(row.iter().map(|v| v.abs()));
        mags.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = q * (n - 1) as f32;
        let lo = pos.floor() as usize;
        let hi = (lo + 1).min(n - 1);
        let frac = pos - lo as f32;
        let thr = mags[lo] + (mags[hi] - mags[lo]) * frac;
        for v in row.iter_mut() {
            if v.abs() < thr {
                *v = 0.0;
            }
        }
    }
}

/// Copy the first `len` positions of head `head`, batch row `b`, out of
/// a `(batch * seq, hidden)` matrix into a contiguous `(len, head_dim)`
/// block.  `len` is the attended row length; `seq` is the storage
/// stride (`len == seq` for fixed-length rows).
fn gather_head(
    src: &[f32],
    b: usize,
    head: usize,
    len: usize,
    seq: usize,
    h: usize,
    hd: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; len * hd];
    for s in 0..len {
        let from = (b * seq + s) * h + head * hd;
        out[s * hd..s * hd + hd].copy_from_slice(&src[from..from + hd]);
    }
    out
}

/// Write a contiguous `(len, head_dim)` block back into the first `len`
/// positions of head `head`, batch row `b`, of a `(batch * seq, hidden)`
/// matrix.
fn scatter_head(
    dst: &mut [f32],
    blk: &[f32],
    b: usize,
    head: usize,
    len: usize,
    seq: usize,
    h: usize,
    hd: usize,
) {
    for s in 0..len {
        let to = (b * seq + s) * h + head * hd;
        dst[to..to + hd].copy_from_slice(&blk[s * hd..s * hd + hd]);
    }
}

/// Accumulating variant of [`scatter_head`] for gradients.
fn scatter_head_add(
    dst: &mut [f32],
    blk: &[f32],
    b: usize,
    head: usize,
    len: usize,
    seq: usize,
    h: usize,
    hd: usize,
) {
    for s in 0..len {
        let to = (b * seq + s) * h + head * hd;
        for (d, &v) in dst[to..to + hd].iter_mut().zip(&blk[s * hd..s * hd + hd]) {
            *d += v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TransformerConfig;
    use crate::runtime::ParamStore;
    use crate::util::rng::Rng;

    /// A micro encoder small enough for debug-mode tests and finite
    /// differences: h=8, 1 layer, 2 heads, ff=16, vocab=12, seq=4.
    fn micro_manifest() -> Manifest {
        let model = TransformerConfig {
            name: "micro".into(),
            hidden: 8,
            layers: 1,
            heads: 2,
            ff: 16,
            vocab: 12,
            seq: 4,
        };
        Manifest::synthetic(&model, 2)
    }

    fn micro_backend() -> ReferenceBackend {
        ReferenceBackend::new(&micro_manifest()).unwrap()
    }

    fn micro_ids(batch: usize, seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        (0..batch * 4).map(|_| rng.index(12) as i32).collect()
    }

    #[test]
    fn classify_is_deterministic_and_well_shaped() {
        let manifest = micro_manifest();
        let mut be = micro_backend();
        let params = ParamStore::init(&manifest, 1).params;
        let ids = micro_ids(3, 7);
        let a = be.classify(3, &params, &ids, 0.0).unwrap();
        let b = be.classify(3, &params, &ids, 0.0).unwrap();
        assert_eq!(a.len(), 3 * 2);
        assert!(a.iter().all(|v| v.is_finite()));
        assert_eq!(a, b);
    }

    #[test]
    fn tau_zero_matches_topk_keep_all() {
        // Both identity points run the exact same unpruned forward.
        let manifest = micro_manifest();
        let mut be = micro_backend();
        let params = ParamStore::init(&manifest, 2).params;
        let ids = micro_ids(2, 3);
        let dyna = be.classify(2, &params, &ids, 0.0).unwrap();
        let topk = be.classify_topk(&params, &ids, 1.0).unwrap();
        for (d, t) in dyna.iter().zip(&topk) {
            assert!((d - t).abs() < 1e-6, "tau=0 {d} vs keep=1 {t}");
        }
    }

    #[test]
    fn absurd_tau_collapses_to_bias_only_prediction() {
        let manifest = micro_manifest();
        let mut be = micro_backend();
        let params = ParamStore::init(&manifest, 3).params;
        let ids = micro_ids(4, 5);
        let base = be.classify(4, &params, &ids, 0.0).unwrap();
        let nuked = be.classify(4, &params, &ids, 1e9).unwrap();
        assert_ne!(base, nuked);
        let first = &nuked[..2];
        for row in nuked.chunks(2) {
            assert!((row[0] - first[0]).abs() < 1e-6);
            assert!((row[1] - first[1]).abs() < 1e-6);
        }
    }

    #[test]
    fn activation_sparsity_grows_with_tau() {
        let manifest = micro_manifest();
        let mut be = micro_backend();
        let params = ParamStore::init(&manifest, 4).params;
        let ids = micro_ids(2, 9);
        let lo = be.activation_sparsity(&params, &ids, 0.0).unwrap();
        let hi = be.activation_sparsity(&params, &ids, 1e3).unwrap();
        assert!((0.0..=1.0).contains(&lo));
        assert!(hi > 0.9, "everything pruned at huge tau, got {hi}");
        assert!(hi >= lo);
    }

    #[test]
    fn traced_classify_matches_plain_and_labels_every_hook() {
        let manifest = micro_manifest();
        let mut be = micro_backend();
        let params = ParamStore::init(&manifest, 6).params;
        let ids = micro_ids(2, 21);
        let plain = be.classify(2, &params, &ids, 0.05).unwrap();
        let (traced, records) = be.classify_traced(2, &params, &ids, 0.05).unwrap();
        assert_eq!(plain, traced, "capture must not perturb logits");
        // one record per (layer, hook): 1 layer x 10 hooks
        assert_eq!(records.len(), 10);
        for (rec, hook) in records.iter().zip(ActHook::ALL) {
            assert_eq!(rec.hook, hook, "hook order contract");
            assert_eq!(rec.layer, 0);
            assert!((0.0..=1.0).contains(&rec.zero_frac));
            assert!(rec.elems > 0);
        }
    }

    #[test]
    fn prune_kernel_matches_definition() {
        let mut be = micro_backend();
        let (pruned, mask) = be.dynatran_prune(&[0.5, -0.05, 0.2, -0.9, 0.0], 0.25).unwrap();
        assert_eq!(pruned, vec![0.5, 0.0, 0.0, -0.9, 0.0]);
        assert_eq!(mask, vec![0.0, 1.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn analytic_gradients_match_finite_differences() {
        // The load-bearing test for the training path: central-difference
        // the loss wrt one parameter from every spec group and compare to
        // backprop.  Catches any transpose/sign/residual-routing mistake.
        let manifest = micro_manifest();
        let be = micro_backend();
        let params = ParamStore::init(&manifest, 5).params;
        let ids = micro_ids(2, 11);
        let labels = vec![0, 1];
        let (loss, grads) = be.loss_and_grads(&params, &ids, &labels, 2).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert!(grads.iter().any(|&g| g.abs() > 1e-6), "gradients are all ~zero");

        let loss_at = |p: &[f32]| be.loss_and_grads(p, &ids, &labels, 2).unwrap().0;
        let eps = 5e-3f32;
        let mut off = 0usize;
        for (name, shape, _std) in &manifest.param_specs {
            let len: usize = shape.iter().product();
            // middle element of each parameter tensor
            let idx = off + len / 2;
            let mut pp = params.clone();
            pp[idx] += eps;
            let mut pm = params.clone();
            pm[idx] -= eps;
            let fd = (loss_at(&pp) - loss_at(&pm)) / (2.0 * eps);
            let got = grads[idx];
            assert!(
                (got - fd).abs() <= 1.5e-3 + 0.08 * fd.abs(),
                "{name}[{idx}]: analytic {got} vs finite-difference {fd}"
            );
            off += len;
        }
    }

    #[test]
    fn adamw_training_reduces_loss_on_micro_task() {
        let manifest = micro_manifest();
        let mut be = micro_backend();
        let mut store = ParamStore::init(&manifest, 0);
        let mut rng = Rng::new(13);
        let batch = 8;
        let mut losses = Vec::new();
        for step in 0..40 {
            // a linearly-separable toy rule: label = token 0 present
            let mut ids = Vec::with_capacity(batch * 4);
            let mut labels = Vec::with_capacity(batch);
            for _ in 0..batch {
                let pos = rng.chance(0.5);
                for s in 0..4 {
                    let tok = if pos && s == 1 { 0 } else { 2 + rng.index(10) as i32 };
                    ids.push(tok);
                }
                labels.push(pos as i32);
            }
            let loss = be
                .train_step(
                    &mut store.params,
                    &mut store.m,
                    &mut store.v,
                    step as f32,
                    &ids,
                    &labels,
                    5e-3,
                )
                .unwrap();
            assert!(loss.is_finite(), "step {step} loss {loss}");
            losses.push(loss);
        }
        let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
        let tail: f32 = losses[35..].iter().sum::<f32>() / 5.0;
        assert!(tail < head, "loss did not decrease: head {head:.4} tail {tail:.4}");
    }

    #[test]
    fn rejects_bad_inputs() {
        let manifest = micro_manifest();
        let mut be = micro_backend();
        let params = ParamStore::init(&manifest, 1).params;
        // wrong ids length
        assert!(be.classify(2, &params, &[0, 1, 2], 0.0).is_err());
        // out-of-vocab token
        assert!(be.classify(1, &params, &[0, 1, 2, 99], 0.0).is_err());
        // wrong param buffer size
        assert!(be.classify(1, &params[..10], &[0, 1, 2, 3], 0.0).is_err());
    }

    #[test]
    fn span_logits_agree_with_classify_at_cls() {
        // Both heads are the same [h, 2] matmul over hidden states; the
        // span pair at position 0 must equal the classify logits (the
        // tiled GEMM accumulates each output element in a fixed k-order
        // regardless of the row count — tests/gemm_oracle.rs).
        let manifest = micro_manifest();
        let mut be = micro_backend();
        let params = ParamStore::init(&manifest, 1).params;
        let ids = micro_ids(3, 7);
        let cls = be.classify(3, &params, &ids, 0.05).unwrap();
        let span = be.span_logits(3, &params, &ids, 0.05).unwrap();
        assert_eq!(span.len(), 3 * 4 * 2);
        assert!(span.iter().all(|v| v.is_finite()));
        for b in 0..3 {
            assert_eq!(span[b * 4 * 2], cls[b * 2], "row {b} start@CLS");
            assert_eq!(span[b * 4 * 2 + 1], cls[b * 2 + 1], "row {b} end@CLS");
        }
    }

    #[test]
    fn span_padded_rows_match_native_length_runs() {
        // The serving contract: a padded row's logit pairs at its true
        // positions are bit-identical to running the row alone at its
        // native length.
        let manifest = micro_manifest();
        let mut be = micro_backend();
        let params = ParamStore::init(&manifest, 2).params;
        let ids = vec![0, 5, 6, 7, 0, 8, 1, 1];
        let lens = vec![4usize, 2];
        let padded = be.span_logits_padded(2, 4, &lens, &params, &ids, 0.0).unwrap();
        assert_eq!(padded.len(), 2 * 4 * 2);
        for (b, &l) in lens.iter().enumerate() {
            let solo = be.span_logits(1, &params, &ids[b * 4..b * 4 + l], 0.0).unwrap();
            assert_eq!(
                &padded[b * 4 * 2..b * 4 * 2 + l * 2],
                &solo[..],
                "row {b} at len {l}"
            );
        }
    }

    #[test]
    fn span_analytic_gradients_match_finite_differences() {
        // Same harness as the classify FD test, over the span objective:
        // start/end softmax-CE over positions, gradient seeded densely.
        let manifest = micro_manifest();
        let mut be = micro_backend();
        let params = ParamStore::init(&manifest, 8).params;
        let ids = micro_ids(2, 15);
        let starts = vec![1, 0];
        let ends = vec![2, 0];
        let (loss, grads) = be.span_loss_grads(2, &params, &ids, &starts, &ends).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert!(grads.iter().any(|&g| g.abs() > 1e-6), "gradients are all ~zero");

        let eps = 5e-3f32;
        let mut off = 0usize;
        for (name, shape, _std) in &manifest.param_specs {
            let len: usize = shape.iter().product();
            let idx = off + len / 2;
            let mut pp = params.clone();
            pp[idx] += eps;
            let mut pm = params.clone();
            pm[idx] -= eps;
            let lp = be.span_loss_grads(2, &pp, &ids, &starts, &ends).unwrap().0;
            let lm = be.span_loss_grads(2, &pm, &ids, &starts, &ends).unwrap().0;
            let fd = (lp - lm) / (2.0 * eps);
            let got = grads[idx];
            assert!(
                (got - fd).abs() <= 1.5e-3 + 0.08 * fd.abs(),
                "{name}[{idx}]: analytic {got} vs finite-difference {fd}"
            );
            off += len;
        }
    }

    #[test]
    fn span_adamw_training_reduces_loss_on_micro_task() {
        let manifest = micro_manifest();
        let mut be = micro_backend();
        let mut store = ParamStore::init(&manifest, 0);
        let mut rng = Rng::new(17);
        let batch = 8;
        let mut losses = Vec::new();
        for step in 0..40 {
            // toy span rule: answerable rows plant marker token 3 at
            // position 3 (start = end = 3), the rest point at CLS
            let mut ids = Vec::with_capacity(batch * 4);
            let mut starts = Vec::with_capacity(batch);
            let mut ends = Vec::with_capacity(batch);
            for _ in 0..batch {
                let pos = rng.chance(0.5);
                ids.push(0);
                ids.push(3);
                ids.push(2);
                ids.push(if pos { 3 } else { 4 + rng.index(8) as i32 });
                let target = if pos { 3 } else { 0 };
                starts.push(target);
                ends.push(target);
            }
            let loss = be
                .span_train_step(
                    &mut store.params,
                    &mut store.m,
                    &mut store.v,
                    step as f32,
                    &ids,
                    &starts,
                    &ends,
                    5e-3,
                )
                .unwrap();
            assert!(loss.is_finite(), "step {step} loss {loss}");
            losses.push(loss);
        }
        let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
        let tail: f32 = losses[35..].iter().sum::<f32>() / 5.0;
        assert!(tail < head, "span loss did not decrease: head {head:.4} tail {tail:.4}");
    }

    #[test]
    fn span_rejects_bad_labels_and_non_binary_heads() {
        let manifest = micro_manifest();
        let mut be = micro_backend();
        let params = ParamStore::init(&manifest, 1).params;
        let ids = micro_ids(2, 3);
        // inverted span
        assert!(be.span_loss_grads(2, &params, &ids, &[2, 0], &[1, 0]).is_err());
        // end past the sequence
        assert!(be.span_loss_grads(2, &params, &ids, &[1, 0], &[4, 0]).is_err());
        // label-count mismatch
        assert!(be.span_loss_grads(2, &params, &ids, &[1], &[1]).is_err());
        // a 3-class head has no span layout to reuse
        let model = TransformerConfig {
            name: "micro3".into(),
            hidden: 8,
            layers: 1,
            heads: 2,
            ff: 16,
            vocab: 12,
            seq: 4,
        };
        let m3 = Manifest::synthetic(&model, 3);
        let mut be3 = ReferenceBackend::new(&m3).unwrap();
        let p3 = ParamStore::init(&m3, 1).params;
        assert!(be3.span_logits(2, &p3, &ids, 0.0).is_err());
        assert!(be3.span_loss_grads(2, &p3, &ids, &[1, 0], &[1, 0]).is_err());
    }
}
