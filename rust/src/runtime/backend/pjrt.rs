//! The PJRT/HLO execution backend: compiles the AOT artifacts exported
//! by `python/compile/aot.py` through the PJRT CPU client and dispatches
//! the five entry points to the fixed-shape executables.
//!
//! Still gated on native bindings: the in-tree `xla` crate is a stub
//! whose `compile` errors (DESIGN.md §Substitutions), so this backend
//! constructs fine (manifest-only flows work) but execution reports the
//! missing native library until real xla-rs bindings are swapped in.
//! Host tensors cross the trait boundary as flat slices; literals are
//! built here, immediately before dispatch.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::runtime::artifacts::Manifest;
use crate::runtime::backend::ExecBackend;

/// PJRT client + lazily compiled executables over one artifact manifest.
pub struct PjrtBackend {
    pub client: xla::PjRtClient,
    manifest: Manifest,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtBackend {
    /// Create a backend over `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<PjrtBackend> {
        Self::from_manifest(Manifest::load(dir)?)
    }

    pub fn from_manifest(manifest: Manifest) -> Result<PjrtBackend> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(PjrtBackend { client, manifest, compiled: HashMap::new() })
    }

    /// Compile (once) and return the executable for `name`.
    ///
    /// HLO *text* is the interchange format: jax >= 0.5 serialized protos
    /// carry 64-bit instruction ids that xla_extension 0.5.1 rejects; the
    /// text parser reassigns ids (see python/compile/aot.py).
    pub fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.compiled.contains_key(name) {
            let art = self
                .manifest
                .artifacts
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
            let path = self.manifest.dir.join(&art.file);
            let path_str = path
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?;
            let proto = xla::HloModuleProto::from_text_file(path_str)
                .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.compiled.insert(name.to_string(), exe);
        }
        Ok(&self.compiled[name])
    }

    /// Execute artifact `name` on literal inputs; returns the tuple
    /// elements as literals (lowering always uses return_tuple=True).
    pub fn execute(&mut self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let expected = self
            .manifest
            .artifacts
            .get(name)
            .map(|a| a.args.len())
            .unwrap_or(0);
        if expected != args.len() {
            bail!(
                "artifact '{name}' expects {expected} args, got {}",
                args.len()
            );
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        result
            .to_tuple()
            .map_err(|e| anyhow!("untupling {name} result: {e:?}"))
    }

    fn ids_literal(&self, ids: &[i32], batch: usize) -> Result<xla::Literal> {
        let seq = self.manifest.seq;
        xla::Literal::vec1(ids)
            .reshape(&[batch as i64, seq as i64])
            .map_err(|e| anyhow!("reshape ids: {e:?}"))
    }
}

impl ExecBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    /// Fork for the serving worker pool: a fresh PJRT client over the
    /// same manifest.  Each worker compiles its own executables (the
    /// compiled cache is per-instance), trading one-time compile work
    /// for contention-free dispatch.
    fn fork(&self, manifest: &Manifest) -> Result<Box<dyn ExecBackend>> {
        Ok(Box::new(PjrtBackend::from_manifest(manifest.clone())?))
    }

    /// `classify_b{B}`: logits for a batch of token ids at DynaTran
    /// threshold `tau`.
    fn classify(
        &mut self,
        batch: usize,
        params: &[f32],
        ids: &[i32],
        tau: f32,
    ) -> Result<Vec<f32>> {
        let seq = self.manifest.seq;
        if ids.len() != batch * seq {
            bail!("ids length {} != batch {batch} * seq {seq}", ids.len());
        }
        let name = format!("classify_b{batch}");
        let ids_lit = self.ids_literal(ids, batch)?;
        let out = self.execute(
            &name,
            &[xla::Literal::vec1(params), ids_lit, xla::Literal::scalar(tau)],
        )?;
        out[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits to_vec: {e:?}"))
    }

    /// `classify_topk_b32`: logits under top-k pruning at `keep_frac`.
    fn classify_topk(&mut self, params: &[f32], ids: &[i32], keep_frac: f32) -> Result<Vec<f32>> {
        let batch = ids.len() / self.manifest.seq;
        let ids_lit = self.ids_literal(ids, batch)?;
        let out = self.execute(
            "classify_topk_b32",
            &[xla::Literal::vec1(params), ids_lit, xla::Literal::scalar(keep_frac)],
        )?;
        out[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits to_vec: {e:?}"))
    }

    /// `act_sparsity_b8`: mean post-DynaTran activation sparsity at tau.
    fn activation_sparsity(&mut self, params: &[f32], ids: &[i32], tau: f32) -> Result<f32> {
        let batch = ids.len() / self.manifest.seq;
        let ids_lit = self.ids_literal(ids, batch)?;
        let out = self.execute(
            "act_sparsity_b8",
            &[xla::Literal::vec1(params), ids_lit, xla::Literal::scalar(tau)],
        )?;
        out[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("sparsity to_vec: {e:?}"))?
            .first()
            .copied()
            .ok_or_else(|| anyhow!("empty sparsity result"))
    }

    /// `train_step_b32`: one AdamW step.  The updated `(params, m, v)`
    /// buffers are copied back into the caller's slices.
    fn train_step(
        &mut self,
        params: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        step: f32,
        ids: &[i32],
        labels: &[i32],
        lr: f32,
    ) -> Result<f32> {
        let seq = self.manifest.seq;
        let batch = labels.len();
        if ids.len() != batch * seq {
            bail!("ids length {} != batch {batch} * seq {seq}", ids.len());
        }
        let ids_lit = self.ids_literal(ids, batch)?;
        let out = self.execute(
            "train_step_b32",
            &[
                xla::Literal::vec1(params),
                xla::Literal::vec1(m),
                xla::Literal::vec1(v),
                xla::Literal::scalar(step),
                ids_lit,
                xla::Literal::vec1(labels),
                xla::Literal::scalar(lr),
            ],
        )?;
        if out.len() != 4 {
            bail!("train_step returned {} outputs, want 4", out.len());
        }
        let p2 = out[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("params to_vec: {e:?}"))?;
        let m2 = out[1].to_vec::<f32>().map_err(|e| anyhow!("m to_vec: {e:?}"))?;
        let v2 = out[2].to_vec::<f32>().map_err(|e| anyhow!("v to_vec: {e:?}"))?;
        if p2.len() != params.len() || m2.len() != m.len() || v2.len() != v.len() {
            bail!("train_step output sizes disagree with inputs");
        }
        params.copy_from_slice(&p2);
        m.copy_from_slice(&m2);
        v.copy_from_slice(&v2);
        let loss = out[3]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("loss to_vec: {e:?}"))?[0];
        Ok(loss)
    }

    /// `dynatran_prune_256x256`: the standalone L1 Pallas kernel.
    fn dynatran_prune(&mut self, x: &[f32], tau: f32) -> Result<(Vec<f32>, Vec<f32>)> {
        if x.len() != 256 * 256 {
            bail!("prune artifact is fixed at 256x256");
        }
        let x_lit = xla::Literal::vec1(x)
            .reshape(&[256, 256])
            .map_err(|e| anyhow!("reshape x: {e:?}"))?;
        let out = self.execute(
            "dynatran_prune_256x256",
            &[x_lit, xla::Literal::scalar(tau)],
        )?;
        let pruned = out[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("pruned to_vec: {e:?}"))?;
        let mask = out[1]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("mask to_vec: {e:?}"))?;
        Ok((pruned, mask))
    }
}
