//! Flat-parameter ownership: initialization, optimizer state, and
//! persistence for the model parameters the Rust coordinator feeds the
//! execution backends.
//!
//! The layout contract comes from the manifest (`param_specs` — parsed
//! from `manifest.json` for the PJRT backend, built by
//! `Manifest::synthetic` for the reference backend):
//! parameters are concatenated in spec order into one f32 vector; specs
//! with `init_std > 0` draw `N(0, std^2)`, `init_std == 0` are zeros
//! (biases), `init_std < 0` are ones (layer-norm gains).  Matches
//! `python/compile/model.py::init_params` semantics (not bit-for-bit —
//! the RNGs differ — but distributionally, which is all training needs).

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::artifacts::Manifest;
use crate::util::rng::Rng;

/// Owned model parameters + AdamW state.
pub struct ParamStore {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: f32,
}

impl ParamStore {
    /// Initialize from manifest specs with the given seed.
    pub fn init(manifest: &Manifest, seed: u64) -> ParamStore {
        let mut rng = Rng::new(seed);
        let mut params = Vec::with_capacity(manifest.param_count);
        for (_name, shape, init_std) in &manifest.param_specs {
            let n: usize = shape.iter().product();
            if *init_std < 0.0 {
                params.extend(std::iter::repeat(1.0f32).take(n));
            } else if *init_std == 0.0 {
                params.extend(std::iter::repeat(0.0f32).take(n));
            } else {
                params.extend(rng.normal_vec(n, *init_std as f32));
            }
        }
        assert_eq!(
            params.len(),
            manifest.param_count,
            "spec layout disagrees with param_count"
        );
        let zeros = vec![0.0f32; params.len()];
        ParamStore { params, m: zeros.clone(), v: zeros, step: 0.0 }
    }

    /// Load raw little-endian f32 params from disk (e.g. a golden file or
    /// a previously saved checkpoint).
    pub fn from_file(manifest: &Manifest, path: impl AsRef<Path>) -> Result<ParamStore> {
        let params = read_f32(path.as_ref())?;
        if params.len() != manifest.param_count {
            bail!(
                "param file has {} f32s, manifest wants {}",
                params.len(),
                manifest.param_count
            );
        }
        let zeros = vec![0.0f32; params.len()];
        Ok(ParamStore { params, m: zeros.clone(), v: zeros, step: 0.0 })
    }

    /// Save params as raw little-endian f32.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        write_f32(path.as_ref(), &self.params)
    }
}

/// Read a raw little-endian f32 binary file.
pub fn read_f32(path: &Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    if bytes.len() % 4 != 0 {
        bail!("{path:?}: length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Read a raw little-endian i32 binary file.
pub fn read_i32(path: &Path) -> Result<Vec<i32>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    if bytes.len() % 4 != 0 {
        bail!("{path:?}: length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write raw little-endian f32.
pub fn write_f32(path: &Path, data: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, bytes).with_context(|| format!("writing {path:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest() -> Manifest {
        Manifest {
            dir: std::path::PathBuf::from("."),
            model_name: "m".into(),
            vocab: 4,
            seq: 2,
            hidden: 2,
            layers: 1,
            heads: 1,
            classes: 2,
            param_count: 4 * 2 + 2 + 2,
            param_specs: vec![
                ("embed".into(), vec![4, 2], 0.02),
                ("gamma".into(), vec![2], -1.0),
                ("bias".into(), vec![2], 0.0),
            ],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn init_respects_spec_kinds() {
        let ps = ParamStore::init(&fake_manifest(), 1);
        assert_eq!(ps.params.len(), 12);
        // embed: normal(0, .02) -> nonzero, small
        assert!(ps.params[..8].iter().any(|&v| v != 0.0));
        assert!(ps.params[..8].iter().all(|&v| v.abs() < 0.2));
        // gamma: ones
        assert_eq!(&ps.params[8..10], &[1.0, 1.0]);
        // bias: zeros
        assert_eq!(&ps.params[10..12], &[0.0, 0.0]);
        assert!(ps.m.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn init_is_seed_deterministic() {
        let a = ParamStore::init(&fake_manifest(), 7);
        let b = ParamStore::init(&fake_manifest(), 7);
        let c = ParamStore::init(&fake_manifest(), 8);
        assert_eq!(a.params, b.params);
        assert_ne!(a.params, c.params);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("acceltran_params_{}.bin", std::process::id()));
        let manifest = fake_manifest();
        let ps = ParamStore::init(&manifest, 3);
        ps.save(&path).unwrap();
        let loaded = ParamStore::from_file(&manifest, &path).unwrap();
        assert_eq!(ps.params, loaded.params);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wrong_size_file_rejected() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("acceltran_bad_{}.bin", std::process::id()));
        std::fs::write(&path, [0u8; 8]).unwrap();
        assert!(ParamStore::from_file(&fake_manifest(), &path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
