//! Artifact manifest + compiled-executable registry.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// One AOT artifact as described by `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub file: String,
    pub signature: String,
    /// (shape, dtype) per argument; dtype is "float32" or "int32".
    pub args: Vec<(Vec<usize>, String)>,
}

/// Model metadata + artifact index parsed from `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model_name: String,
    pub vocab: usize,
    pub seq: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub classes: usize,
    pub param_count: usize,
    /// (name, shape, init_std) in flat-buffer order; init_std < 0 means
    /// init-to-one (layer-norm gains), 0 means zeros (biases).
    pub param_specs: Vec<(String, Vec<usize>, f64)>,
    pub artifacts: HashMap<String, Artifact>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        let model = j.get("model").ok_or_else(|| anyhow!("missing model"))?;
        let get = |k: &str| -> Result<usize> {
            model
                .get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest model.{k} missing"))
        };
        let mut param_specs = Vec::new();
        for p in j
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing params"))?
        {
            param_specs.push((
                p.get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("param name"))?
                    .to_string(),
                p.get("shape")
                    .and_then(Json::as_usize_vec)
                    .ok_or_else(|| anyhow!("param shape"))?,
                p.get("init_std")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("param init_std"))?,
            ));
        }
        let mut artifacts = HashMap::new();
        for (name, a) in j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("missing artifacts"))?
        {
            let args = a
                .get("args")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact args"))?
                .iter()
                .map(|arg| -> Result<(Vec<usize>, String)> {
                    Ok((
                        arg.get("shape")
                            .and_then(Json::as_usize_vec)
                            .ok_or_else(|| anyhow!("arg shape"))?,
                        arg.get("dtype")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("arg dtype"))?
                            .to_string(),
                    ))
                })
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                Artifact {
                    name: name.clone(),
                    file: a
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("artifact file"))?
                        .to_string(),
                    signature: a
                        .get("signature")
                        .and_then(Json::as_str)
                        .unwrap_or("")
                        .to_string(),
                    args,
                },
            );
        }
        Ok(Manifest {
            dir,
            model_name: model
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("?")
                .to_string(),
            vocab: get("vocab")?,
            seq: get("seq")?,
            hidden: get("hidden")?,
            layers: get("layers")?,
            heads: get("heads")?,
            classes: get("classes")?,
            param_count: get("param_count")?,
            param_specs,
            artifacts,
        })
    }

    /// Default artifact directory: `$ACCELTRAN_ARTIFACTS` or
    /// `<crate>/artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("ACCELTRAN_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| {
                PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
            })
    }
}

/// The PJRT runtime: one CPU client + lazily compiled executables.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Create a runtime over the default artifact directory.
    pub fn load_default() -> Result<Runtime> {
        Self::load(Manifest::default_dir())
    }

    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime { client, manifest, compiled: HashMap::new() })
    }

    /// Compile (once) and return the executable for `name`.
    ///
    /// HLO *text* is the interchange format: jax >= 0.5 serialized protos
    /// carry 64-bit instruction ids that xla_extension 0.5.1 rejects; the
    /// text parser reassigns ids (see python/compile/aot.py).
    pub fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.compiled.contains_key(name) {
            let art = self
                .manifest
                .artifacts
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
            let path = self.manifest.dir.join(&art.file);
            let path_str = path
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?;
            let proto = xla::HloModuleProto::from_text_file(path_str)
                .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.compiled.insert(name.to_string(), exe);
        }
        Ok(&self.compiled[name])
    }

    /// Execute artifact `name` on literal inputs; returns the tuple
    /// elements as literals (lowering always uses return_tuple=True).
    pub fn execute(&mut self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let expected = self
            .manifest
            .artifacts
            .get(name)
            .map(|a| a.args.len())
            .unwrap_or(0);
        if expected != args.len() {
            bail!(
                "artifact '{name}' expects {expected} args, got {}",
                args.len()
            );
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        result
            .to_tuple()
            .map_err(|e| anyhow!("untupling {name} result: {e:?}"))
    }

    // ---- typed convenience wrappers ------------------------------------

    /// `classify_b{B}`: logits for a batch of token ids at DynaTran
    /// threshold `tau`.  `ids` is row-major `[batch * seq]`.
    pub fn classify(
        &mut self,
        batch: usize,
        params: &xla::Literal,
        ids: &[i32],
        tau: f32,
    ) -> Result<Vec<f32>> {
        let seq = self.manifest.seq;
        if ids.len() != batch * seq {
            bail!("ids length {} != batch {batch} * seq {seq}", ids.len());
        }
        let name = format!("classify_b{batch}");
        let ids_lit = xla::Literal::vec1(ids)
            .reshape(&[batch as i64, seq as i64])
            .map_err(|e| anyhow!("reshape ids: {e:?}"))?;
        let tau_lit = xla::Literal::scalar(tau);
        let out = self.execute(&name, &[params.clone(), ids_lit, tau_lit])?;
        out[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits to_vec: {e:?}"))
    }

    /// `classify_topk_b32`: logits under top-k pruning at `keep_frac`.
    pub fn classify_topk(
        &mut self,
        params: &xla::Literal,
        ids: &[i32],
        keep_frac: f32,
    ) -> Result<Vec<f32>> {
        let seq = self.manifest.seq;
        let batch = ids.len() / seq;
        let ids_lit = xla::Literal::vec1(ids)
            .reshape(&[batch as i64, seq as i64])
            .map_err(|e| anyhow!("reshape ids: {e:?}"))?;
        let out = self.execute(
            "classify_topk_b32",
            &[params.clone(), ids_lit, xla::Literal::scalar(keep_frac)],
        )?;
        out[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits to_vec: {e:?}"))
    }

    /// `act_sparsity_b8`: mean post-DynaTran activation sparsity at tau.
    pub fn activation_sparsity(
        &mut self,
        params: &xla::Literal,
        ids: &[i32],
        tau: f32,
    ) -> Result<f32> {
        let seq = self.manifest.seq;
        let ids_lit = xla::Literal::vec1(ids)
            .reshape(&[(ids.len() / seq) as i64, seq as i64])
            .map_err(|e| anyhow!("reshape ids: {e:?}"))?;
        let out = self.execute(
            "act_sparsity_b8",
            &[params.clone(), ids_lit, xla::Literal::scalar(tau)],
        )?;
        out[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("sparsity to_vec: {e:?}"))?
            .first()
            .copied()
            .ok_or_else(|| anyhow!("empty sparsity result"))
    }

    /// `train_step_b32`: one AdamW step.  Returns
    /// `(params', m', v', loss)` as literals (params stay as literals so
    /// the training loop avoids host round-trips of the full buffer).
    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &mut self,
        params: xla::Literal,
        m: xla::Literal,
        v: xla::Literal,
        step: f32,
        ids: &[i32],
        labels: &[i32],
        lr: f32,
    ) -> Result<(xla::Literal, xla::Literal, xla::Literal, f32)> {
        let seq = self.manifest.seq;
        let batch = labels.len();
        if ids.len() != batch * seq {
            bail!("ids length {} != batch {batch} * seq {seq}", ids.len());
        }
        let ids_lit = xla::Literal::vec1(ids)
            .reshape(&[batch as i64, seq as i64])
            .map_err(|e| anyhow!("reshape ids: {e:?}"))?;
        let labels_lit = xla::Literal::vec1(labels);
        let mut out = self.execute(
            "train_step_b32",
            &[
                params,
                m,
                v,
                xla::Literal::scalar(step),
                ids_lit,
                labels_lit,
                xla::Literal::scalar(lr),
            ],
        )?;
        if out.len() != 4 {
            bail!("train_step returned {} outputs, want 4", out.len());
        }
        let loss = out[3]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("loss to_vec: {e:?}"))?[0];
        let v2 = out.remove(2);
        let m2 = out.remove(1);
        let p2 = out.remove(0);
        Ok((p2, m2, v2, loss))
    }

    /// `dynatran_prune_256x256`: the standalone L1 Pallas kernel.
    pub fn dynatran_prune(
        &mut self,
        x: &[f32],
        tau: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        if x.len() != 256 * 256 {
            bail!("prune artifact is fixed at 256x256");
        }
        let x_lit = xla::Literal::vec1(x)
            .reshape(&[256, 256])
            .map_err(|e| anyhow!("reshape x: {e:?}"))?;
        let out = self.execute(
            "dynatran_prune_256x256",
            &[x_lit, xla::Literal::scalar(tau)],
        )?;
        let pruned = out[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("pruned to_vec: {e:?}"))?;
        let mask = out[1]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("mask to_vec: {e:?}"))?;
        Ok((pruned, mask))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full integration tests (needing artifacts/) live in
    // rust/tests/runtime_integration.rs; here we test manifest parsing
    // against a synthetic manifest.

    #[test]
    fn parses_synthetic_manifest() {
        let dir = std::env::temp_dir().join(format!(
            "acceltran_manifest_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"{
          "model": {"name": "m", "vocab": 16, "seq": 4, "hidden": 8,
                    "layers": 1, "heads": 2, "ff": 16, "classes": 2,
                    "param_count": 100},
          "params": [{"name": "embed.word", "shape": [16, 8],
                      "init_std": 0.02}],
          "artifacts": {"classify_b1": {"file": "classify_b1.hlo.txt",
             "signature": "sig",
             "args": [{"shape": [100], "dtype": "float32"},
                      {"shape": [1, 4], "dtype": "int32"},
                      {"shape": [], "dtype": "float32"}],
             "hlo_bytes": 3}}
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.param_count, 100);
        assert_eq!(m.vocab, 16);
        assert_eq!(m.param_specs.len(), 1);
        let a = &m.artifacts["classify_b1"];
        assert_eq!(a.args.len(), 3);
        assert_eq!(a.args[1].0, vec![1, 4]);
        assert_eq!(a.args[1].1, "int32");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_a_clear_error() {
        let err = Manifest::load("/nonexistent/dir").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
