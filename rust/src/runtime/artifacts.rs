//! Artifact manifest: the model-shape + flat-parameter-layout contract
//! shared by every execution backend.
//!
//! For the PJRT backend the manifest is parsed from the
//! `artifacts/manifest.json` that `python/compile/aot.py` exports (and
//! additionally indexes the HLO artifacts).  For the pure-Rust reference
//! backend, [`Manifest::synthetic`] builds the same layout directly from
//! a [`TransformerConfig`], mirroring `python/compile/model.py::
//! param_specs` name for name — so `ParamStore` buffers and checkpoint
//! files are interchangeable between backends.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::model::TransformerConfig;
use crate::util::json::Json;

/// One AOT artifact as described by `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub file: String,
    pub signature: String,
    /// (shape, dtype) per argument; dtype is "float32" or "int32".
    pub args: Vec<(Vec<usize>, String)>,
}

/// Model metadata + artifact index parsed from `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model_name: String,
    pub vocab: usize,
    pub seq: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub classes: usize,
    pub param_count: usize,
    /// (name, shape, init_std) in flat-buffer order; init_std < 0 means
    /// init-to-one (layer-norm gains), 0 means zeros (biases).
    pub param_specs: Vec<(String, Vec<usize>, f64)>,
    pub artifacts: HashMap<String, Artifact>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        let model = j.get("model").ok_or_else(|| anyhow!("missing model"))?;
        let get = |k: &str| -> Result<usize> {
            model
                .get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("manifest model.{k} missing"))
        };
        let mut param_specs = Vec::new();
        for p in j
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing params"))?
        {
            param_specs.push((
                p.get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("param name"))?
                    .to_string(),
                p.get("shape")
                    .and_then(Json::as_usize_vec)
                    .ok_or_else(|| anyhow!("param shape"))?,
                p.get("init_std")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("param init_std"))?,
            ));
        }
        let mut artifacts = HashMap::new();
        for (name, a) in j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("missing artifacts"))?
        {
            let args = a
                .get("args")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact args"))?
                .iter()
                .map(|arg| -> Result<(Vec<usize>, String)> {
                    Ok((
                        arg.get("shape")
                            .and_then(Json::as_usize_vec)
                            .ok_or_else(|| anyhow!("arg shape"))?,
                        arg.get("dtype")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("arg dtype"))?
                            .to_string(),
                    ))
                })
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                Artifact {
                    name: name.clone(),
                    file: a
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("artifact file"))?
                        .to_string(),
                    signature: a
                        .get("signature")
                        .and_then(Json::as_str)
                        .unwrap_or("")
                        .to_string(),
                    args,
                },
            );
        }
        Ok(Manifest {
            dir,
            model_name: model
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("?")
                .to_string(),
            vocab: get("vocab")?,
            seq: get("seq")?,
            hidden: get("hidden")?,
            layers: get("layers")?,
            heads: get("heads")?,
            classes: get("classes")?,
            param_count: get("param_count")?,
            param_specs,
            artifacts,
        })
    }

    /// Default artifact directory: `$ACCELTRAN_ARTIFACTS` or
    /// `<crate>/artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("ACCELTRAN_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| {
                PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
            })
    }

    /// Build the manifest for a model shape without any AOT artifacts —
    /// the reference backend's layout contract.  The spec list mirrors
    /// `python/compile/model.py::param_specs` exactly: embeddings, then
    /// per layer QKV/output projections with biases, two layer-norms and
    /// the two feed-forward matrices, then the classifier head.
    /// `init_std` conventions match `ParamStore::init`: negative = ones
    /// (layer-norm gains), zero = zeros (biases).
    pub fn synthetic(model: &TransformerConfig, classes: usize) -> Manifest {
        let h = model.hidden;
        let f = model.ff;
        let std = 0.02;
        let mut specs: Vec<(String, Vec<usize>, f64)> = vec![
            ("embed.word".into(), vec![model.vocab, h], std),
            ("embed.pos".into(), vec![model.seq, h], std),
        ];
        for layer in 0..model.layers {
            let p = format!("layer{layer}");
            specs.push((format!("{p}.attn.wq"), vec![h, h], std));
            specs.push((format!("{p}.attn.bq"), vec![h], 0.0));
            specs.push((format!("{p}.attn.wk"), vec![h, h], std));
            specs.push((format!("{p}.attn.bk"), vec![h], 0.0));
            specs.push((format!("{p}.attn.wv"), vec![h, h], std));
            specs.push((format!("{p}.attn.bv"), vec![h], 0.0));
            specs.push((format!("{p}.attn.wo"), vec![h, h], std));
            specs.push((format!("{p}.attn.bo"), vec![h], 0.0));
            specs.push((format!("{p}.ln1.gamma"), vec![h], -1.0));
            specs.push((format!("{p}.ln1.beta"), vec![h], 0.0));
            specs.push((format!("{p}.ffn.w1"), vec![h, f], std));
            specs.push((format!("{p}.ffn.b1"), vec![f], 0.0));
            specs.push((format!("{p}.ffn.w2"), vec![f, h], std));
            specs.push((format!("{p}.ffn.b2"), vec![h], 0.0));
            specs.push((format!("{p}.ln2.gamma"), vec![h], -1.0));
            specs.push((format!("{p}.ln2.beta"), vec![h], 0.0));
        }
        specs.push(("cls.w".into(), vec![h, classes], std));
        specs.push(("cls.b".into(), vec![classes], 0.0));
        let param_count = specs.iter().map(|(_, s, _)| s.iter().product::<usize>()).sum();
        Manifest {
            dir: PathBuf::new(),
            model_name: model.name.clone(),
            vocab: model.vocab,
            seq: model.seq,
            hidden: h,
            layers: model.layers,
            heads: model.heads,
            classes,
            param_count,
            param_specs: specs,
            artifacts: HashMap::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full integration tests (needing artifacts/) live in
    // rust/tests/runtime_integration.rs; here we test manifest parsing
    // against a synthetic manifest.

    #[test]
    fn parses_synthetic_manifest() {
        let dir = std::env::temp_dir().join(format!(
            "acceltran_manifest_test_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let manifest = r#"{
          "model": {"name": "m", "vocab": 16, "seq": 4, "hidden": 8,
                    "layers": 1, "heads": 2, "ff": 16, "classes": 2,
                    "param_count": 100},
          "params": [{"name": "embed.word", "shape": [16, 8],
                      "init_std": 0.02}],
          "artifacts": {"classify_b1": {"file": "classify_b1.hlo.txt",
             "signature": "sig",
             "args": [{"shape": [100], "dtype": "float32"},
                      {"shape": [1, 4], "dtype": "int32"},
                      {"shape": [], "dtype": "float32"}],
             "hlo_bytes": 3}}
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.param_count, 100);
        assert_eq!(m.vocab, 16);
        assert_eq!(m.param_specs.len(), 1);
        let a = &m.artifacts["classify_b1"];
        assert_eq!(a.args.len(), 3);
        assert_eq!(a.args[1].0, vec![1, 4]);
        assert_eq!(a.args[1].1, "int32");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_a_clear_error() {
        let err = Manifest::load("/nonexistent/dir").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn synthetic_manifest_matches_aot_layout() {
        // The default AOT export (bert-tiny-synth, vocab 1024, seq 64,
        // 2 classes) has 536,066 parameters; the synthetic layout must
        // agree so checkpoints are interchangeable between backends.
        let model = TransformerConfig::bert_tiny_synth(1024, 64);
        let m = Manifest::synthetic(&model, 2);
        assert_eq!(m.param_count, 536_066);
        assert_eq!(m.param_specs.len(), 2 + 2 * 16 + 2);
        assert_eq!(m.param_specs[0].0, "embed.word");
        assert_eq!(m.param_specs[0].1, vec![1024, 128]);
        let (name, shape, std) = &m.param_specs[2 + 8];
        assert_eq!(name, "layer0.ln1.gamma");
        assert_eq!(shape, &vec![128]);
        assert!(*std < 0.0, "layer-norm gains init to one");
        assert_eq!(m.param_specs.last().unwrap().0, "cls.b");
        assert!(m.artifacts.is_empty());
    }
}
