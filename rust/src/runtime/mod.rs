//! The functional half of the stack (the simulator is the timing half):
//! classification forward passes with runtime DynaTran tau / top-k
//! keep-fraction knobs, activation-sparsity probes, AdamW training
//! steps, and the standalone DynaTran prune kernel.
//!
//! [`Runtime`] is a thin dispatcher over a pluggable [`ExecBackend`]:
//!
//! * the **reference backend** (`backend::reference`) executes the
//!   encoder natively in Rust — hermetic, always available, and the
//!   default when no AOT artifacts are present;
//! * the **PJRT backend** (`backend::pjrt`) compiles and runs the HLO
//!   text artifacts from `python/compile/aot.py` (gated on real xla
//!   bindings — DESIGN.md §Substitutions).
//!
//! Selection: `Runtime::load_default()` honours `ACCELTRAN_BACKEND`
//! (`reference` | `pjrt`); unset, it uses PJRT when
//! `artifacts/manifest.json` exists and falls back to the reference
//! executor otherwise — which is what lets every example, bench and the
//! serving coordinator run end-to-end out of the box.

pub mod artifacts;
pub mod backend;
pub mod params;
pub mod tensor;

use std::path::Path;

use anyhow::{bail, Result};

pub use artifacts::{Artifact, Manifest};
pub use backend::pjrt::PjrtBackend;
pub use backend::reference::ReferenceBackend;
pub use backend::ExecBackend;
pub use params::ParamStore;

use crate::model::TransformerConfig;

/// The functional runtime: one manifest (model shape + parameter
/// layout) plus the execution backend that honours it.
pub struct Runtime {
    pub manifest: Manifest,
    backend: Box<dyn ExecBackend>,
}

impl Runtime {
    /// Wrap an explicit backend (the constructor everything else
    /// funnels through).
    pub fn with_backend(manifest: Manifest, backend: Box<dyn ExecBackend>) -> Runtime {
        Runtime { manifest, backend }
    }

    /// Pure-Rust reference runtime over the default synthetic model
    /// (BERT-Tiny shape, vocab 1024, seq 64, 2 classes — the same shape
    /// `python/compile/aot.py` exports).
    pub fn reference() -> Runtime {
        Self::reference_for(&TransformerConfig::bert_tiny_synth(1024, 64), 2)
            .expect("the default synthetic shape is self-consistent")
    }

    /// Pure-Rust reference runtime for an arbitrary encoder shape.
    /// Errors when the shape is inconsistent (e.g. `hidden` not
    /// divisible by `heads`).
    pub fn reference_for(model: &TransformerConfig, classes: usize) -> Result<Runtime> {
        let manifest = Manifest::synthetic(model, classes);
        let backend = ReferenceBackend::new(&manifest)?;
        Ok(Runtime::with_backend(manifest, Box::new(backend)))
    }

    /// PJRT runtime over `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let manifest = Manifest::load(&dir)?;
        let backend = PjrtBackend::from_manifest(manifest.clone())?;
        Ok(Runtime::with_backend(manifest, Box::new(backend)))
    }

    /// Default runtime: `$ACCELTRAN_BACKEND` picks explicitly
    /// (`reference` | `pjrt`); unset, PJRT when artifacts exist,
    /// otherwise the reference executor.
    pub fn load_default() -> Result<Runtime> {
        let dir = Manifest::default_dir();
        match std::env::var("ACCELTRAN_BACKEND").unwrap_or_default().as_str() {
            "pjrt" => Self::load(dir),
            "reference" | "ref" => Ok(Self::reference()),
            "" => {
                if dir.join("manifest.json").exists() {
                    Self::load(dir)
                } else {
                    Ok(Self::reference())
                }
            }
            other => bail!("ACCELTRAN_BACKEND must be 'pjrt' or 'reference', got '{other}'"),
        }
    }

    /// Which backend this runtime dispatches to ("reference" / "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// An independent sibling runtime over the same manifest and backend
    /// kind — the worker-pool constructor (`coordinator::serve` forks
    /// one runtime per worker thread; `ExecBackend: Send` is what lets
    /// the fork move across the spawn).  Parameters are *not* part of a
    /// runtime (they cross the call boundary as slices), so forks share
    /// nothing mutable.
    pub fn fork(&self) -> Result<Runtime> {
        Ok(Runtime {
            manifest: self.manifest.clone(),
            backend: self.backend.fork(&self.manifest)?,
        })
    }

    // ---- the five typed entry points -------------------------------

    /// Classification logits for a batch at DynaTran threshold `tau`.
    /// `ids` is row-major `[batch * seq]` for any row width
    /// `1 <= seq <= manifest.seq` (the width is derived as
    /// `ids.len() / batch`; shorter requests run at their native
    /// length); logits come back `[batch * classes]`.
    pub fn classify(
        &mut self,
        batch: usize,
        params: &[f32],
        ids: &[i32],
        tau: f32,
    ) -> Result<Vec<f32>> {
        self.backend.classify(batch, params, ids, tau)
    }

    /// Classification logits for a length-bucketed batch: rows are
    /// stored `[batch * seq]` with row `b`'s true token count in
    /// `lens[b]` (`1 <= len <= seq <= manifest.seq`; the row tail past
    /// `len` is padding the attention mask ignores).  Row `b`'s logits
    /// are bit-identical to classifying its first `lens[b]` tokens alone
    /// — the dynamic batcher relies on this to pad only within a length
    /// bucket (pinned by `rust/tests/varlen_conformance.rs`).
    #[allow(clippy::too_many_arguments)]
    pub fn classify_padded(
        &mut self,
        batch: usize,
        seq: usize,
        lens: &[usize],
        params: &[f32],
        ids: &[i32],
        tau: f32,
    ) -> Result<Vec<f32>> {
        self.backend.classify_padded(batch, seq, lens, params, ids, tau)
    }

    /// Classification logits plus the forward pass's per-activation
    /// sparsity observations (measured-sparsity trace capture).  Logits
    /// are bitwise identical to [`Runtime::classify`]; backends without
    /// a traced path return no observations.
    pub fn classify_traced(
        &mut self,
        batch: usize,
        params: &[f32],
        ids: &[i32],
        tau: f32,
    ) -> Result<(Vec<f32>, Vec<crate::trace::HookRecord>)> {
        self.backend.classify_traced(batch, params, ids, tau)
    }

    /// Span-extraction logits: `(start, end)` logit pairs per position,
    /// row-major `[batch * seq * 2]` (see
    /// [`ExecBackend::span_logits`]).  The span head reuses the `cls`
    /// parameter layout, so any 2-class checkpoint loads for either
    /// task.
    pub fn span_logits(
        &mut self,
        batch: usize,
        params: &[f32],
        ids: &[i32],
        tau: f32,
    ) -> Result<Vec<f32>> {
        self.backend.span_logits(batch, params, ids, tau)
    }

    /// Span logits for a length-bucketed batch — the serving path
    /// (same `lens` contract as [`Runtime::classify_padded`]).
    #[allow(clippy::too_many_arguments)]
    pub fn span_logits_padded(
        &mut self,
        batch: usize,
        seq: usize,
        lens: &[usize],
        params: &[f32],
        ids: &[i32],
        tau: f32,
    ) -> Result<Vec<f32>> {
        self.backend.span_logits_padded(batch, seq, lens, params, ids, tau)
    }

    /// Loss + flat analytic gradients of the span objective (the
    /// finite-difference conformance surface; see
    /// [`ExecBackend::span_loss_grads`]).
    pub fn span_loss_grads(
        &mut self,
        batch: usize,
        params: &[f32],
        ids: &[i32],
        starts: &[i32],
        ends: &[i32],
    ) -> Result<(f32, Vec<f32>)> {
        self.backend.span_loss_grads(batch, params, ids, starts, ends)
    }

    /// One AdamW step on the span objective, in place; returns the loss.
    #[allow(clippy::too_many_arguments)]
    pub fn span_train_step(
        &mut self,
        params: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        step: f32,
        ids: &[i32],
        starts: &[i32],
        ends: &[i32],
        lr: f32,
    ) -> Result<f32> {
        self.backend
            .span_train_step(params, m, v, step, ids, starts, ends, lr)
    }

    /// Logits under SpAtten-style top-k attention pruning at `keep_frac`.
    pub fn classify_topk(
        &mut self,
        params: &[f32],
        ids: &[i32],
        keep_frac: f32,
    ) -> Result<Vec<f32>> {
        self.backend.classify_topk(params, ids, keep_frac)
    }

    /// Mean post-DynaTran activation sparsity over a forward pass at
    /// `tau` (the rho axis of Figs. 11/12).
    pub fn activation_sparsity(&mut self, params: &[f32], ids: &[i32], tau: f32) -> Result<f32> {
        self.backend.activation_sparsity(params, ids, tau)
    }

    /// One AdamW step over the flat buffers, in place; returns the loss.
    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &mut self,
        params: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        step: f32,
        ids: &[i32],
        labels: &[i32],
        lr: f32,
    ) -> Result<f32> {
        self.backend.train_step(params, m, v, step, ids, labels, lr)
    }

    /// The standalone DynaTran prune kernel: `(pruned, mask)` with
    /// mask = 1.0 at pruned positions.
    pub fn dynatran_prune(&mut self, x: &[f32], tau: f32) -> Result<(Vec<f32>, Vec<f32>)> {
        self.backend.dynatran_prune(x, tau)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_runtime_is_always_available() {
        let mut rt = Runtime::reference();
        assert_eq!(rt.backend_name(), "reference");
        assert_eq!(rt.manifest.param_count, 536_066);
        let params = ParamStore::init(&rt.manifest, 0);
        let ids: Vec<i32> = (0..rt.manifest.seq).map(|i| (i % 512) as i32).collect();
        let logits = rt.classify(1, &params.params, &ids, 0.0).unwrap();
        assert_eq!(logits.len(), rt.manifest.classes);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn load_default_falls_back_to_reference_without_artifacts() {
        // Tier-1 runs without artifacts; the fallback is what un-gates
        // the examples and benches.  (Skip under ACCELTRAN_BACKEND=pjrt
        // or a checked-out artifacts/ dir.)
        if std::env::var_os("ACCELTRAN_BACKEND").is_some()
            || Manifest::default_dir().join("manifest.json").exists()
        {
            return;
        }
        let rt = Runtime::load_default().unwrap();
        assert_eq!(rt.backend_name(), "reference");
    }

    #[test]
    fn fork_produces_an_equivalent_independent_runtime() {
        let mut rt = Runtime::reference();
        let mut forked = rt.fork().unwrap();
        assert_eq!(forked.backend_name(), "reference");
        assert_eq!(forked.manifest.param_count, rt.manifest.param_count);
        let params = ParamStore::init(&rt.manifest, 0).params;
        let ids: Vec<i32> = (0..rt.manifest.seq).map(|i| (i % 512) as i32).collect();
        let a = rt.classify(1, &params, &ids, 0.02).unwrap();
        let b = forked.classify(1, &params, &ids, 0.02).unwrap();
        assert_eq!(a, b, "fork must be functionally identical");
        // runtimes are Send: the worker pool moves forks into threads
        fn assert_send<T: Send>(_: &T) {}
        assert_send(&forked);
    }

    #[test]
    fn reference_runtime_scales_to_custom_shapes() {
        let model = TransformerConfig {
            name: "micro".into(),
            hidden: 16,
            layers: 1,
            heads: 2,
            ff: 32,
            vocab: 32,
            seq: 8,
        };
        let mut rt = Runtime::reference_for(&model, 3).unwrap();
        assert_eq!(rt.manifest.classes, 3);
        let params = ParamStore::init(&rt.manifest, 1);
        let ids: Vec<i32> = (0..2 * 8).map(|i| (i % 32) as i32).collect();
        let logits = rt.classify(2, &params.params, &ids, 0.0).unwrap();
        assert_eq!(logits.len(), 2 * 3);
    }
}
