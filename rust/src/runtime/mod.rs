//! PJRT runtime: loads the AOT-compiled HLO text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the *functional* half of the stack (the simulator is the
//! timing half): classification forward passes (with the DynaTran tau or
//! top-k keep-fraction as runtime scalars), activation-sparsity probes,
//! AdamW training steps, and the standalone Pallas DynaTran kernel.
//! Python never runs here — artifacts are compiled once at build time
//! (`make artifacts`) and this module is pure Rust + PJRT.

pub mod artifacts;
pub mod params;

pub use artifacts::{Artifact, Manifest, Runtime};
pub use params::ParamStore;
