//! Pluggable execution backends for the functional half of the stack.
//!
//! [`ExecBackend`] abstracts the five artifact entry points over typed
//! *host* tensors (flat `f32`/`i32` slices), so the coordinator layer —
//! batcher, eval sweeps, trainer — is written once against the trait and
//! runs identically on:
//!
//! * [`reference::ReferenceBackend`] — a pure-Rust executor that runs the
//!   BERT-Tiny-shaped encoder natively (forward, activation-sparsity
//!   probe, backprop + AdamW).  Hermetic: no artifacts, no native XLA.
//!   The default whenever PJRT artifacts are absent.
//! * [`pjrt::PjrtBackend`] — the original AOT-HLO path: compiles the
//!   `python/compile/aot.py` artifacts through the PJRT client (the
//!   in-tree `xla` crate is a stub unless real bindings are swapped in —
//!   DESIGN.md §Substitutions).
//!
//! `runtime::Runtime` is a thin dispatcher over a boxed backend; see
//! DESIGN.md §Substitutions "Reference executor vs PJRT" for what is
//! bit-exact between the two and what is approximate.

use anyhow::{bail, Result};

use crate::runtime::artifacts::Manifest;
use crate::trace::HookRecord;

pub mod pjrt;
pub mod reference;

/// One execution backend: the five typed entry points the artifacts
/// export, over host tensors.
///
/// Shape contract (from the backend's manifest): `ids` is row-major
/// `[batch * seq]`, `params`/`m`/`v` are the flat parameter buffer of
/// `manifest.param_count` f32s in `param_specs` order, logits come back
/// row-major `[batch * classes]`.
///
/// `Send` is a supertrait: the serving worker pool
/// (`coordinator::serve`) moves one forked backend instance into each
/// worker thread.  Both in-tree backends are plain owned data and
/// satisfy it automatically; a future backend wrapping a non-`Send`
/// native handle should construct that handle lazily inside
/// [`ExecBackend::fork`]'s result instead of sharing it.
pub trait ExecBackend: Send {
    /// Short stable name for logs and bench labels ("reference", "pjrt").
    fn name(&self) -> &'static str;

    /// Classification logits for a batch at DynaTran threshold `tau`.
    fn classify(
        &mut self,
        batch: usize,
        params: &[f32],
        ids: &[i32],
        tau: f32,
    ) -> Result<Vec<f32>>;

    /// Classification logits for a length-bucketed batch: `ids` is
    /// row-major `[batch * seq]` for any `1 <= seq <= manifest.seq`, and
    /// `lens[b]` is row `b`'s true token count (`1 <= len <= seq`; the
    /// tail of the row is padding the attention mask must ignore).
    ///
    /// Contract: row `b`'s logits are bit-identical to classifying its
    /// first `lens[b]` tokens alone (pinned by
    /// `rust/tests/varlen_conformance.rs`).  The default covers backends
    /// without a masked path: uniform full-length batches delegate to
    /// [`ExecBackend::classify`] (identical by the contract), ragged
    /// ones are refused.
    fn classify_padded(
        &mut self,
        batch: usize,
        seq: usize,
        lens: &[usize],
        params: &[f32],
        ids: &[i32],
        tau: f32,
    ) -> Result<Vec<f32>> {
        if lens.len() == batch && lens.iter().all(|&l| l == seq) {
            return self.classify(batch, params, ids, tau);
        }
        bail!(
            "backend '{}' does not support ragged (length-masked) batches",
            self.name()
        )
    }

    /// Span-extraction logits: the classification head applied
    /// *per-position* — for every batch row and position a
    /// `(start, end)` logit pair, row-major `[batch * seq * 2]`
    /// (position-major within a row: `[p0_start, p0_end, p1_start,
    /// ...]`).  Requires `manifest.classes == 2`: the span head reuses
    /// the `cls.w`/`cls.b` layout, so classify and span checkpoints are
    /// interchangeable at the `ParamStore` level.  The default refuses,
    /// for backends without a span path (PJRT's AOT graph pools at CLS).
    fn span_logits(
        &mut self,
        batch: usize,
        params: &[f32],
        ids: &[i32],
        tau: f32,
    ) -> Result<Vec<f32>> {
        let _ = (batch, params, ids, tau);
        bail!("backend '{}' does not support span extraction", self.name())
    }

    /// Span logits for a length-bucketed batch (the serving path):
    /// same `ids`/`lens` contract as [`ExecBackend::classify_padded`].
    /// Row `b`'s logit pairs at positions `0..lens[b]` are bit-identical
    /// to running its first `lens[b]` tokens alone; pairs past the row's
    /// true length are unspecified (the caller slices them off).  The
    /// default covers uniform full-length batches only.
    fn span_logits_padded(
        &mut self,
        batch: usize,
        seq: usize,
        lens: &[usize],
        params: &[f32],
        ids: &[i32],
        tau: f32,
    ) -> Result<Vec<f32>> {
        if lens.len() == batch && lens.iter().all(|&l| l == seq) {
            return self.span_logits(batch, params, ids, tau);
        }
        bail!(
            "backend '{}' does not support ragged (length-masked) span batches",
            self.name()
        )
    }

    /// Loss and flat analytic gradients of the span objective: mean over
    /// rows of `(CE_start + CE_end) / 2`, each a softmax cross-entropy
    /// over positions (`starts`/`ends` are inclusive position labels,
    /// `(0, 0)` = no answer).  Gradients come back in `param_specs`
    /// order, `manifest.param_count` long — the surface the external
    /// finite-difference conformance check drives.  Default refuses.
    fn span_loss_grads(
        &mut self,
        batch: usize,
        params: &[f32],
        ids: &[i32],
        starts: &[i32],
        ends: &[i32],
    ) -> Result<(f32, Vec<f32>)> {
        let _ = (batch, params, ids, starts, ends);
        bail!("backend '{}' does not support span training", self.name())
    }

    /// One AdamW step on the span objective (batch inferred from
    /// `starts.len()`); same buffer contract as
    /// [`ExecBackend::train_step`].  Default refuses.
    #[allow(clippy::too_many_arguments)]
    fn span_train_step(
        &mut self,
        params: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        step: f32,
        ids: &[i32],
        starts: &[i32],
        ends: &[i32],
        lr: f32,
    ) -> Result<f32> {
        let _ = (params, m, v, step, ids, starts, ends, lr);
        bail!("backend '{}' does not support span training", self.name())
    }

    /// Classification logits under SpAtten-style top-k attention pruning
    /// at `keep_frac` (batch inferred from `ids.len()`).
    fn classify_topk(
        &mut self,
        params: &[f32],
        ids: &[i32],
        keep_frac: f32,
    ) -> Result<Vec<f32>>;

    /// Mean post-DynaTran activation sparsity over a forward pass.
    fn activation_sparsity(
        &mut self,
        params: &[f32],
        ids: &[i32],
        tau: f32,
    ) -> Result<f32>;

    /// One AdamW step (batch inferred from `labels.len()`); updates
    /// `params`/`m`/`v` in place and returns the scalar loss.  `step` is
    /// the pre-increment step counter for bias correction.
    #[allow(clippy::too_many_arguments)]
    fn train_step(
        &mut self,
        params: &mut [f32],
        m: &mut [f32],
        v: &mut [f32],
        step: f32,
        ids: &[i32],
        labels: &[i32],
        lr: f32,
    ) -> Result<f32>;

    /// The standalone DynaTran prune kernel: returns `(pruned, mask)`
    /// with mask = 1.0 at pruned positions (paper Sec. III-B6).
    fn dynatran_prune(&mut self, x: &[f32], tau: f32) -> Result<(Vec<f32>, Vec<f32>)>;

    /// Classification logits *plus* the per-activation sparsity
    /// observations of the forward pass — the measured-sparsity capture
    /// path that feeds `trace::SparsityTrace` / `sim::SparsitySource`.
    ///
    /// Contract: capture must not perturb inference — the logits are
    /// bitwise identical to [`ExecBackend::classify`] on the same inputs
    /// (pinned by `rust/tests/backend_conformance.rs`).  The default
    /// implementation is for backends without a traced path (PJRT): it
    /// runs plain `classify` and reports no observations.
    fn classify_traced(
        &mut self,
        batch: usize,
        params: &[f32],
        ids: &[i32],
        tau: f32,
    ) -> Result<(Vec<f32>, Vec<HookRecord>)> {
        Ok((self.classify(batch, params, ids, tau)?, Vec::new()))
    }

    /// Build an independent sibling of this backend over `manifest` —
    /// the worker-pool entry point (`coordinator::serve` forks one
    /// backend per worker so classify calls never contend on `&mut
    /// self`).  Backends are stateless with respect to parameters
    /// (buffers cross the trait boundary per call), so a fork is a
    /// fresh construction, not a copy of any mutable state.  The
    /// default refuses, for backends that wrap an unshareable native
    /// resource.
    fn fork(&self, manifest: &Manifest) -> Result<Box<dyn ExecBackend>> {
        let _ = manifest;
        bail!(
            "backend '{}' does not support worker-pool forking",
            self.name()
        )
    }
}
