//! Measured-sparsity traces: the bridge between the functional half
//! (the `runtime` backends, which observe real per-activation zero
//! fractions during inference) and the timing half (the `sim` engine,
//! which needs a sparsity operating point per tiled op).
//!
//! The paper's headline results (Figs. 17-19, Table IV) feed *measured*
//! per-operation activation sparsity into the accelerator model rather
//! than a hand-picked scalar.  This module defines that interchange
//! format:
//!
//! * [`HookRecord`] / [`ActHook`] — one observation from a pruning hook
//!   during a traced forward pass (`ExecBackend::classify_traced`).
//! * [`TraceBuilder`] — element-weighted aggregation of observations
//!   over a whole evaluation set, per `(layer, hook)` cell.
//! * [`SparsityTrace`] — the serializable result: per-layer activation
//!   sparsities at each hook, measured weight-matrix sparsities, the
//!   inherent (tau = 0) activation sparsity, and eval metadata.  It
//!   resolves a per-op [`SparsityProfile`] for any
//!   [`crate::model::OpNode`] via its stable
//!   [`crate::model::TraceClass`] — which is what
//!   `sim::SparsitySource::Trace` feeds the engine.
//!
//! Traces serialize to JSON (`save`/`load`) through `util::json`; the
//! writer is deterministic (sorted keys, round-trip float formatting),
//! so identical captures produce byte-identical files — pinned by
//! `rust/tests/determinism.rs`.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::model::ops::{OpNode, TraceClass};
use crate::sim::engine::SparsityProfile;
use crate::util::json::Json;

/// The ten activation matrices a traced forward pass observes per
/// encoder layer, in hook order (mirrors the `prune_hook` call sites of
/// `runtime::backend::reference::ReferenceBackend::encode`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ActHook {
    /// Hidden state entering the layer (input of C-OP-1..3).
    Input,
    /// Q projection output (left operand of C-OP-4).
    Q,
    /// K projection output (right operand of C-OP-4).
    K,
    /// V projection output (right operand of C-OP-6).
    V,
    /// Pre-softmax attention scores (output of C-OP-4).
    Scores,
    /// Concatenated head contexts (input of C-OP-7).
    Context,
    /// Attention output projection result (input of C-OP-8's add).
    ProjOut,
    /// Pruned layer-norm output entering the FFN (input of C-OP-9).
    FfnIn,
    /// Post-GeLU first-FFN output (input of C-OP-10).
    Gelu,
    /// Second-FFN output (input of C-OP-11's add).
    FfnOut,
}

impl ActHook {
    /// All hooks in capture order.
    pub const ALL: [ActHook; 10] = [
        ActHook::Input,
        ActHook::Q,
        ActHook::K,
        ActHook::V,
        ActHook::Scores,
        ActHook::Context,
        ActHook::ProjOut,
        ActHook::FfnIn,
        ActHook::Gelu,
        ActHook::FfnOut,
    ];

    /// Stable JSON key for this hook.
    pub fn name(self) -> &'static str {
        match self {
            ActHook::Input => "input",
            ActHook::Q => "q",
            ActHook::K => "k",
            ActHook::V => "v",
            ActHook::Scores => "scores",
            ActHook::Context => "context",
            ActHook::ProjOut => "proj_out",
            ActHook::FfnIn => "ffn_in",
            ActHook::Gelu => "gelu",
            ActHook::FfnOut => "ffn_out",
        }
    }

    fn index(self) -> usize {
        Self::ALL.iter().position(|&h| h == self).unwrap()
    }
}

/// One activation-matrix observation from a traced forward pass.
#[derive(Clone, Copy, Debug)]
pub struct HookRecord {
    /// Encoder layer the matrix belongs to.
    pub layer: usize,
    /// Which of the layer's activation matrices was observed.
    pub hook: ActHook,
    /// Zero fraction of the matrix after the DynaTran threshold.
    pub zero_frac: f64,
    /// Matrix elements (the observation's weight in aggregation).
    pub elems: usize,
}

/// Per-layer measured activation sparsity, one value per [`ActHook`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LayerActRho {
    pub input: f64,
    pub q: f64,
    pub k: f64,
    pub v: f64,
    pub scores: f64,
    pub context: f64,
    pub proj_out: f64,
    pub ffn_in: f64,
    pub gelu: f64,
    pub ffn_out: f64,
}

impl LayerActRho {
    /// Read the value recorded for one hook.
    pub fn get(&self, hook: ActHook) -> f64 {
        match hook {
            ActHook::Input => self.input,
            ActHook::Q => self.q,
            ActHook::K => self.k,
            ActHook::V => self.v,
            ActHook::Scores => self.scores,
            ActHook::Context => self.context,
            ActHook::ProjOut => self.proj_out,
            ActHook::FfnIn => self.ffn_in,
            ActHook::Gelu => self.gelu,
            ActHook::FfnOut => self.ffn_out,
        }
    }

    fn set(&mut self, hook: ActHook, v: f64) {
        match hook {
            ActHook::Input => self.input = v,
            ActHook::Q => self.q = v,
            ActHook::K => self.k = v,
            ActHook::V => self.v = v,
            ActHook::Scores => self.scores = v,
            ActHook::Context => self.context = v,
            ActHook::ProjOut => self.proj_out = v,
            ActHook::FfnIn => self.ffn_in = v,
            ActHook::Gelu => self.gelu = v,
            ActHook::FfnOut => self.ffn_out = v,
        }
    }

    /// Unweighted mean over the layer's hooks.
    pub fn mean(&self) -> f64 {
        ActHook::ALL.iter().map(|&h| self.get(h)).sum::<f64>() / ActHook::ALL.len() as f64
    }

    fn to_json(self) -> Json {
        Json::Obj(
            ActHook::ALL
                .iter()
                .map(|&h| (h.name().to_string(), Json::num(self.get(h))))
                .collect(),
        )
    }

    fn from_json(j: &Json) -> Result<LayerActRho> {
        let mut out = LayerActRho::default();
        for h in ActHook::ALL {
            let v = j
                .get(h.name())
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("trace layer entry missing '{}'", h.name()))?;
            out.set(h, v);
        }
        Ok(out)
    }
}

/// Measured static weight-matrix sparsity per weight class.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WeightRho {
    /// Word + position embedding tables (M-OP-0).
    pub embedding: f64,
    /// Fused Q/K/V projection weights (M-OP-1..3).
    pub wqkv: f64,
    /// Attention output projection (M-OP-4).
    pub wo: f64,
    /// First feed-forward matrix (M-OP-5).
    pub wf1: f64,
    /// Second feed-forward matrix (M-OP-6).
    pub wf2: f64,
}

impl WeightRho {
    fn to_json(self) -> Json {
        Json::obj(vec![
            ("embedding", Json::num(self.embedding)),
            ("wqkv", Json::num(self.wqkv)),
            ("wo", Json::num(self.wo)),
            ("wf1", Json::num(self.wf1)),
            ("wf2", Json::num(self.wf2)),
        ])
    }

    fn from_json(j: &Json) -> Result<WeightRho> {
        let f = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("trace weight_rho missing '{k}'"))
        };
        Ok(WeightRho {
            embedding: f("embedding")?,
            wqkv: f("wqkv")?,
            wo: f("wo")?,
            wf1: f("wf1")?,
            wf2: f("wf2")?,
        })
    }
}

/// A measured sparsity trace: everything the simulator needs to resolve
/// a per-op operating point, plus capture metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct SparsityTrace {
    /// Model name from the capturing runtime's manifest.
    pub model: String,
    /// Backend that produced the observations ("reference" / "pjrt").
    pub backend: String,
    /// DynaTran threshold the trace was captured at.
    pub tau: f64,
    /// Evaluation examples the trace aggregates over.
    pub examples: usize,
    /// Classification accuracy over those examples at this tau (the
    /// fig19 accuracy axis, captured in the same pass).
    pub eval_accuracy: f64,
    /// Mean activation sparsity with DynaTran disabled (tau = 0 probe):
    /// natural zeros only, the Table IV "w/o DynaTran" operating point.
    pub inherent_act_rho: f64,
    /// Measured weight-matrix sparsity per class.
    pub weight: WeightRho,
    /// Per-encoder-layer activation sparsities.
    pub layers: Vec<LayerActRho>,
}

impl SparsityTrace {
    /// Element-weighted mean activation sparsity over every hook cell —
    /// the trace's summary scalar (fig19's x axis).
    pub fn mean_act_rho(&self) -> f64 {
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers.iter().map(LayerActRho::mean).sum::<f64>() / self.layers.len() as f64
    }

    /// Overlay an assumed static weight sparsity on every weight class
    /// (activations stay measured).  The deployment flow applies
    /// movement pruning to the weights *after* fine-tuning; the captured
    /// checkpoint itself is dense, so benches reproducing the paper's
    /// MP operating point raise the weight classes to `rho` here
    /// (DESIGN.md "Measured vs assumed sparsity").
    pub fn with_assumed_weight_rho(mut self, rho: f64) -> SparsityTrace {
        self.weight.wqkv = self.weight.wqkv.max(rho);
        self.weight.wo = self.weight.wo.max(rho);
        self.weight.wf1 = self.weight.wf1.max(rho);
        self.weight.wf2 = self.weight.wf2.max(rho);
        self
    }

    /// The measured per-layer sparsities for a sim-side layer index.
    /// Models deeper than the captured trace cycle through the measured
    /// layer pattern (e.g. a 12-layer BERT-Base simulation over a
    /// 2-layer captured trace repeats the pattern six times).
    fn layer(&self, layer: usize) -> LayerActRho {
        if self.layers.is_empty() {
            return LayerActRho::default();
        }
        let idx = if layer == usize::MAX { 0 } else { layer % self.layers.len() };
        self.layers[idx]
    }

    /// Resolve the sparsity operating point of one op.
    ///
    /// The `(weight_rho, act_rho)` pair maps onto the engine's two
    /// operand sides: the "weight" side is whatever streams from the
    /// weight buffer position of the tiled matmul (a true weight matrix
    /// for projections/FFN, the Q operand for C-OP-4, the dense
    /// post-softmax probabilities for C-OP-6), the "act" side the
    /// activation operand.  Effectual-MAC fraction stays the closed form
    /// `(1 - rho_w)(1 - rho_a)` either way.
    pub fn profile_for(&self, node: &OpNode) -> SparsityProfile {
        let l = self.layer(node.layer);
        let (weight_rho, act_rho) = match node.trace_class() {
            TraceClass::Embedding => (self.weight.embedding, 0.0),
            TraceClass::WqkvLoad => (self.weight.wqkv, 0.0),
            TraceClass::WoLoad => (self.weight.wo, 0.0),
            TraceClass::Wf1Load => (self.weight.wf1, 0.0),
            TraceClass::Wf2Load => (self.weight.wf2, 0.0),
            TraceClass::Qkv => (self.weight.wqkv, l.input),
            TraceClass::AttnScore => (l.q, l.k),
            TraceClass::Softmax => (0.0, l.scores),
            // post-softmax probabilities are dense (pruning happened on
            // the pre-softmax scores); only the V operand is sparse
            TraceClass::AttnContext => (0.0, l.v),
            TraceClass::AttnProj => (self.weight.wo, l.context),
            TraceClass::AddNorm1 => (0.0, l.proj_out),
            TraceClass::AddNorm2 => (0.0, l.ffn_out),
            TraceClass::Ffn1 => (self.weight.wf1, l.ffn_in),
            TraceClass::Ffn2 => (self.weight.wf2, l.gelu),
            TraceClass::Other => (0.0, self.mean_act_rho()),
        };
        SparsityProfile {
            weight_rho,
            act_rho,
            inherent_act_rho: self.inherent_act_rho,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::str(self.model.clone())),
            ("backend", Json::str(self.backend.clone())),
            ("tau", Json::num(self.tau)),
            ("examples", Json::num(self.examples as f64)),
            ("eval_accuracy", Json::num(self.eval_accuracy)),
            ("inherent_act_rho", Json::num(self.inherent_act_rho)),
            ("mean_act_rho", Json::num(self.mean_act_rho())),
            ("weight_rho", self.weight.to_json()),
            (
                "layers",
                Json::arr(self.layers.iter().map(|l| l.to_json())),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<SparsityTrace> {
        let s = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("trace missing '{k}'"))
        };
        let f = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("trace missing '{k}'"))
        };
        let layers = j
            .get("layers")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("trace missing 'layers'"))?
            .iter()
            .map(LayerActRho::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(SparsityTrace {
            model: s("model")?,
            backend: s("backend")?,
            tau: f("tau")?,
            examples: f("examples")? as usize,
            eval_accuracy: f("eval_accuracy")?,
            inherent_act_rho: f("inherent_act_rho")?,
            weight: WeightRho::from_json(
                j.get("weight_rho")
                    .ok_or_else(|| anyhow!("trace missing 'weight_rho'"))?,
            )?,
            layers,
        })
    }

    /// Write the trace as pretty JSON (deterministic byte-for-byte for
    /// identical traces).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("writing trace {path:?}"))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<SparsityTrace> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {path:?}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        Self::from_json(&j)
    }
}

/// Element-weighted `(layer, hook)` aggregation of [`HookRecord`]s into
/// a [`SparsityTrace`].
#[derive(Clone, Debug)]
pub struct TraceBuilder {
    /// Per layer, per hook: (sum of zero_frac * elems, sum of elems).
    cells: Vec<[(f64, f64); 10]>,
}

impl TraceBuilder {
    pub fn new(layers: usize) -> TraceBuilder {
        TraceBuilder { cells: vec![[(0.0, 0.0); 10]; layers] }
    }

    /// Fold one observation in.  Records for layers beyond the declared
    /// count are ignored (defensive; capture and manifest agree in
    /// practice).
    pub fn add(&mut self, rec: &HookRecord) {
        if let Some(layer) = self.cells.get_mut(rec.layer) {
            let cell = &mut layer[rec.hook.index()];
            cell.0 += rec.zero_frac * rec.elems as f64;
            cell.1 += rec.elems as f64;
        }
    }

    pub fn add_all(&mut self, recs: &[HookRecord]) {
        for r in recs {
            self.add(r);
        }
    }

    /// True when no observation has been folded in.
    pub fn is_empty(&self) -> bool {
        self.cells.iter().all(|l| l.iter().all(|&(_, n)| n == 0.0))
    }

    /// Element-weighted mean over every recorded cell.
    pub fn mean(&self) -> f64 {
        let (sum, n) = self
            .cells
            .iter()
            .flatten()
            .fold((0.0, 0.0), |(s, n), &(cs, cn)| (s + cs, n + cn));
        if n == 0.0 {
            0.0
        } else {
            sum / n
        }
    }

    /// Finalize into a trace (cells with no observations resolve to 0).
    #[allow(clippy::too_many_arguments)]
    pub fn finish(
        self,
        model: impl Into<String>,
        backend: impl Into<String>,
        tau: f64,
        examples: usize,
        eval_accuracy: f64,
        inherent_act_rho: f64,
        weight: WeightRho,
    ) -> SparsityTrace {
        let layers = self
            .cells
            .iter()
            .map(|cells| {
                let mut l = LayerActRho::default();
                for (hook, &(sum, n)) in ActHook::ALL.iter().zip(cells.iter()) {
                    l.set(*hook, if n == 0.0 { 0.0 } else { sum / n });
                }
                l
            })
            .collect();
        SparsityTrace {
            model: model.into(),
            backend: backend.into(),
            tau,
            examples,
            eval_accuracy,
            inherent_act_rho,
            weight,
            layers,
        }
    }
}

/// Bail-with-context helper for callers that require capture support.
pub fn require_records(records: &[HookRecord], backend: &str) -> Result<()> {
    if records.is_empty() {
        bail!(
            "backend '{backend}' returned no sparsity observations — \
             trace capture needs a backend with a traced inference path \
             (the reference executor)"
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{OpGraph, TransformerConfig};

    fn sample_trace(layers: usize) -> SparsityTrace {
        let mut b = TraceBuilder::new(layers);
        for layer in 0..layers {
            for (i, hook) in ActHook::ALL.into_iter().enumerate() {
                b.add(&HookRecord {
                    layer,
                    hook,
                    zero_frac: 0.05 * (i as f64 + 1.0) + 0.01 * layer as f64,
                    elems: 64 + i,
                });
            }
        }
        b.finish(
            "bert-tiny-synth",
            "reference",
            0.04,
            128,
            0.875,
            0.08,
            WeightRho { embedding: 0.0, wqkv: 0.01, wo: 0.02, wf1: 0.03, wf2: 0.04 },
        )
    }

    #[test]
    fn builder_weights_by_elems() {
        let mut b = TraceBuilder::new(1);
        b.add(&HookRecord { layer: 0, hook: ActHook::Q, zero_frac: 1.0, elems: 30 });
        b.add(&HookRecord { layer: 0, hook: ActHook::Q, zero_frac: 0.0, elems: 10 });
        let t = b.finish("m", "reference", 0.0, 1, 0.5, 0.0, WeightRho::default());
        assert!((t.layers[0].q - 0.75).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let t = sample_trace(2);
        let j = t.to_json();
        let back = SparsityTrace::from_json(&j).unwrap();
        assert_eq!(t, back);
        // and through the textual form (round-trip float formatting)
        let text = j.to_string_pretty();
        let reparsed = SparsityTrace::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(t, reparsed);
    }

    #[test]
    fn profile_resolution_covers_every_op() {
        let t = sample_trace(2);
        let g = OpGraph::build(&TransformerConfig::bert_tiny(), 1, 64);
        for n in &g.nodes {
            let p = t.profile_for(n);
            assert!((0.0..=1.0).contains(&p.weight_rho), "{}", n.label);
            assert!((0.0..=1.0).contains(&p.act_rho), "{}", n.label);
            assert_eq!(p.inherent_act_rho, t.inherent_act_rho);
        }
        // spot checks: FFN2 reads the post-GeLU hook; QKV reads the input
        let ffn2 = g.nodes.iter().find(|n| n.label == "l1.C-OP-10.ffn2").unwrap();
        assert_eq!(t.profile_for(ffn2).act_rho, t.layers[1].gelu);
        assert_eq!(t.profile_for(ffn2).weight_rho, t.weight.wf2);
        let q0 = g.nodes.iter().find(|n| n.label == "l0.h0.C-OP-1.q").unwrap();
        assert_eq!(t.profile_for(q0).act_rho, t.layers[0].input);
    }

    #[test]
    fn deeper_models_cycle_the_layer_pattern() {
        let t = sample_trace(2);
        let g = OpGraph::build(&TransformerConfig::bert_base(), 1, 64);
        let q_at = |layer: usize| {
            let label = format!("l{layer}.h0.C-OP-1.q");
            let n = g.nodes.iter().find(|n| n.label == label).unwrap();
            t.profile_for(n).act_rho
        };
        assert_eq!(q_at(0), q_at(2));
        assert_eq!(q_at(1), q_at(11));
        assert_ne!(q_at(0), q_at(1));
    }

    #[test]
    fn assumed_weight_rho_only_raises() {
        let t = sample_trace(1).with_assumed_weight_rho(0.5);
        assert_eq!(t.weight.wqkv, 0.5);
        assert_eq!(t.weight.wf2, 0.5);
        // embeddings stay measured (MP prunes encoder weights only)
        assert_eq!(t.weight.embedding, 0.0);
        let t2 = t.clone().with_assumed_weight_rho(0.1);
        assert_eq!(t2.weight.wqkv, 0.5, "overlay must never lower");
    }

    #[test]
    fn empty_builder_is_detected() {
        let b = TraceBuilder::new(2);
        assert!(b.is_empty());
        assert_eq!(b.mean(), 0.0);
        assert!(require_records(&[], "pjrt").is_err());
    }
}
