//! Tiled matrix-multiplication decomposition (paper Sec. III-B1, Fig. 3).
//!
//! A (possibly batched) matmul `W[b, i, k] x A[b, k, j]` is cut into tiles
//! of shape `(tile_b, tile_i, tile_k) x (tile_b, tile_k, tile_j)`; each
//! tile pair is one unit of work for a MAC lane.  Elementwise ops
//! (softmax rows, layer-norm rows) tile along rows only.

use crate::model::ops::OpDims;

/// Tile-grid geometry of one tiled op.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileGrid {
    /// Grid extents along b, i, j, k (elementwise ops use k = 1).
    pub nb: usize,
    pub ni: usize,
    pub nj: usize,
    pub nk: usize,
    /// Scalar multiply(-accumulate)s per full tile.
    pub macs_per_tile: usize,
    /// Output elements per (b, i, j) tile (accumulated over k).
    pub out_elems_per_tile: usize,
    /// Operand tile sizes in elements.
    pub w_tile_elems: usize,
    pub a_tile_elems: usize,
}

impl TileGrid {
    /// Total tile-pair work units (each visited once per k-step).
    pub fn total_tiles(&self) -> usize {
        self.nb * self.ni * self.nj * self.nk
    }

    /// Output tiles (accumulations collapse the k axis).
    pub fn output_tiles(&self) -> usize {
        self.nb * self.ni * self.nj
    }
}

/// Ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Tile a matmul of `m x k @ k x n` (batch folded into m by the op-graph
/// builder) with tile sizes `(tb, ti, tj, tk)`.
pub fn tile_matmul(
    m: usize,
    k: usize,
    n: usize,
    tb: usize,
    ti: usize,
    tj: usize,
    tk: usize,
) -> TileGrid {
    // batch is folded into rows upstream; tb retained for generality.
    let nb = 1usize.max(tb.min(1));
    TileGrid {
        nb,
        ni: ceil_div(m, ti),
        nj: ceil_div(n, tj),
        nk: ceil_div(k, tk),
        macs_per_tile: tb.max(1) * ti * tj * tk,
        out_elems_per_tile: tb.max(1) * ti * tj,
        w_tile_elems: tb.max(1) * ti * tk,
        a_tile_elems: tb.max(1) * tk * tj,
    }
}

/// Tile a *batched* tensor multiplication `W[b, m, k] x A[b, k, n]`
/// keeping the batch axis as a real tile loop (tile_b = 1 per the
/// paper's Table II choice) — the form the Fig. 15 dataflow study uses.
pub fn tile_matmul_batched(
    b: usize,
    m: usize,
    k: usize,
    n: usize,
    ti: usize,
    tj: usize,
    tk: usize,
) -> TileGrid {
    TileGrid {
        nb: b.max(1),
        ni: ceil_div(m, ti),
        nj: ceil_div(n, tj),
        nk: ceil_div(k, tk),
        macs_per_tile: ti * tj * tk,
        out_elems_per_tile: ti * tj,
        w_tile_elems: ti * tk,
        a_tile_elems: tk * tj,
    }
}

/// Tile an elementwise / row-wise op of `m x n` into row blocks of
/// `ti` rows (each block is one softmax/LN module work unit covering the
/// full row, matching the modules' full-tile parallel reductions).
pub fn tile_rows(m: usize, n: usize, ti: usize) -> TileGrid {
    TileGrid {
        nb: 1,
        ni: ceil_div(m, ti),
        nj: 1,
        nk: 1,
        macs_per_tile: ti * n,
        out_elems_per_tile: ti * n,
        w_tile_elems: 0,
        a_tile_elems: ti * n,
    }
}

/// Tile any [`OpDims`] under the given tile sizes.
pub fn tile_op(dims: &OpDims, tb: usize, ti: usize, tj: usize, tk: usize) -> TileGrid {
    match *dims {
        OpDims::MatMul { m, k, n } => tile_matmul(m, k, n, tb, ti, tj, tk),
        OpDims::Elem { m, n } => tile_rows(m, n, ti),
        OpDims::Load { elems } => TileGrid {
            nb: 1,
            ni: ceil_div(elems, ti * tj),
            nj: 1,
            nk: 1,
            macs_per_tile: 0,
            out_elems_per_tile: ti * tj,
            w_tile_elems: ti * tj,
            a_tile_elems: 0,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn paper_example_tiling() {
        // C-OP-1 for BERT-Tiny batch 4, seq 512: 2048 x 128 @ 128 x 64.
        let g = tile_matmul(2048, 128, 64, 1, 16, 16, 16);
        assert_eq!((g.ni, g.nj, g.nk), (128, 4, 8));
        assert_eq!(g.total_tiles(), 128 * 4 * 8);
        assert_eq!(g.macs_per_tile, 16 * 16 * 16);
    }

    #[test]
    fn ragged_edges_round_up() {
        let g = tile_matmul(100, 30, 17, 1, 16, 16, 16);
        assert_eq!((g.ni, g.nj, g.nk), (7, 2, 2));
    }

    #[test]
    fn row_tiling_covers_all_rows() {
        let g = tile_rows(2048, 512, 16);
        assert_eq!(g.ni, 128);
        assert_eq!(g.output_tiles(), 128);
    }

    #[test]
    fn tile_work_covers_dense_macs() {
        // Property: tiles * macs_per_tile >= exact macs (padding only adds).
        prop::check(11, 200, |g| {
            let m = g.usize_in(1, 300);
            let k = g.usize_in(1, 300);
            let n = g.usize_in(1, 300);
            let grid = tile_matmul(m, k, n, 1, 16, 16, 16);
            let covered = grid.total_tiles() * grid.macs_per_tile;
            assert!(covered >= m * k * n);
            // ...and padding is bounded by one tile per axis.
            let bound = (m + 16) * (k + 16) * (n + 16);
            assert!(covered <= bound, "covered {covered} bound {bound}");
        });
    }

    #[test]
    fn load_tiling_counts_chunks() {
        let dims = OpDims::Load { elems: 10_000 };
        let g = tile_op(&dims, 1, 16, 16, 16);
        assert_eq!(g.ni, ceil_div(10_000, 256));
    }
}
