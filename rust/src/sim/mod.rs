//! The AccelTran cycle-accurate accelerator simulator (the paper's core
//! contribution, Sec. III-B).
//!
//! Pipeline: an [`crate::model::OpGraph`] (Table I op stream) is tiled
//! ([`tiling`]), ordered under one of 24 dataflows ([`dataflow`]), and
//! issued by the control block ([`scheduler`]) to hardware resources —
//! MAC lanes / softmax / layer-norm modules ([`modules`]) grouped into
//! PEs ([`pe`]) that contain DynaTran pruning ([`dynatran`]) and
//! binary-mask sparsity ([`sparsity`]) stages — against on-chip buffers
//! ([`buffer`]) filled over a DMA-fronted main memory ([`memory`]).
//! The event loop ([`engine`]) advances cycles, accounts stalls, and
//! charges the 14nm area/energy model ([`tech`]); results aggregate in
//! ([`stats`]).

pub mod baselines;
pub mod buffer;
pub mod config;
pub mod dataflow;
pub mod dse;
pub mod dynatran;
pub mod engine;
pub mod memory;
pub mod modules;
pub mod pe;
pub mod scheduler;
pub mod sparsity;
pub mod stats;
pub mod tech;
pub mod tiling;

pub use config::{AcceleratorConfig, MemoryKind};
pub use dse::{
    dominates, frontier_gap, sweep, DsePoint, DseReport, DseSpace, Objectives,
    ParetoFrontier, SweepOptions,
};
pub use engine::{
    simulate, simulate_with, Engine, SimResult, SparsityProfile, SparsitySource,
};
