//! Processing-element composition (paper Sec. III-B3, Fig. 5).
//!
//! A PE bundles: activation/weight FIFOs, a DynaTran module, a
//! pre-compute sparsity module, `mac_lanes_per_pe` MAC lanes (plus the
//! per-PE softmax and layer-norm modules of Fig. 4's organization), and a
//! post-compute sparsity module.  The engine schedules against the
//! *pooled* module counts for efficiency; this module provides the
//! per-PE functional pipeline used by the host-side pruning path and the
//! integration tests — it processes real tile data end-to-end exactly as
//! the hardware pipeline stages would.

use super::dynatran;
use super::modules::{dynatran_cost, sparsity_stage_cost, MacLane, TileCost};
use super::sparsity::{precompute_align, CompressedTile};

/// Functional + costed result of pushing one tile pair through a PE.
#[derive(Debug)]
pub struct PeTileResult {
    /// Dense output (dot products per output element are the engine's
    /// job; the PE pipeline's unit test surface is elementwise products
    /// feeding the adder tree).
    pub products: Vec<f32>,
    /// Output mask after post-compute expansion.
    pub out_mask: Vec<bool>,
    /// Effectual multiplications executed.
    pub effectual_macs: usize,
    /// Aggregate pipeline cost.
    pub cost: TileCost,
}

/// One processing element.
#[derive(Debug)]
pub struct Pe {
    pub lane: MacLane,
    /// DynaTran threshold currently latched in the module register.
    pub tau: f32,
    pub dynatran_enabled: bool,
    pub sparsity_enabled: bool,
}

impl Pe {
    pub fn new(multipliers: usize, tau: f32) -> Pe {
        Pe {
            lane: MacLane::new(multipliers),
            tau,
            dynatran_enabled: true,
            sparsity_enabled: true,
        }
    }

    /// Push an aligned weight/activation tile pair through the full PE
    /// pipeline: DynaTran -> compress -> pre-compute sparsity -> MAC
    /// (elementwise products; accumulation happens in the adder tree) ->
    /// post-compute expansion.
    pub fn process_tile(&self, w_dense: &[f32], a_dense: &[f32]) -> PeTileResult {
        assert_eq!(w_dense.len(), a_dense.len());
        let mut cycles = 0u64;
        let mut energy = 0.0f64;

        // 1. DynaTran prune on the incoming activation tile (weights are
        //    pruned when first loaded; pruning them again is idempotent).
        let (a_pruned, _mask) = if self.dynatran_enabled {
            let c = dynatran_cost(a_dense.len());
            cycles += c.cycles;
            energy += c.energy_pj;
            dynatran::pruned(a_dense, self.tau)
        } else {
            (a_dense.to_vec(), vec![false; a_dense.len()])
        };

        // 2. compress both operands to zero-free form.
        let w = CompressedTile::compress(w_dense);
        let a = CompressedTile::compress(&a_pruned);

        // 3. pre-compute sparsity alignment (or dense fallback).
        let (wv, av, out_mask) = if self.sparsity_enabled {
            let c = sparsity_stage_cost(w_dense.len());
            cycles += c.cycles;
            energy += c.energy_pj;
            let pair = precompute_align(&w, &a);
            (pair.w, pair.a, pair.out_mask)
        } else {
            (w.decompress(), a.decompress(), vec![false; w_dense.len()])
        };

        // 4. MAC lane: effectual multiplications only.
        let eff = wv.len();
        let mac = self.lane.tile_cost(eff, 0);
        cycles += mac.cycles;
        energy += mac.energy_pj;
        let mut products: Vec<f32> = wv.iter().zip(&av).map(|(x, y)| x * y).collect();

        // 5. post-compute sparsity: re-expand to dense positions.
        if self.sparsity_enabled {
            let c = sparsity_stage_cost(out_mask.len());
            cycles += c.cycles;
            energy += c.energy_pj;
            let compressed = CompressedTile {
                values: products.into_iter().filter(|&v| v != 0.0).collect(),
                mask: out_mask.clone(),
            };
            // positions masked out are zeros; compressed.decompress gives
            // the dense product vector — but products with value 0 from
            // effectual pairs must be preserved, so rebuild positionally.
            let mut dense = vec![0.0f32; out_mask.len()];
            let mut it = wv.iter().zip(&av).map(|(x, y)| x * y);
            for (pos, &pruned) in out_mask.iter().enumerate() {
                if !pruned {
                    dense[pos] = it.next().unwrap_or(0.0);
                }
            }
            let _ = compressed;
            products = dense;
        }

        PeTileResult {
            products,
            out_mask,
            effectual_macs: eff,
            cost: TileCost { cycles, energy_pj: energy },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn pipeline_matches_dense_elementwise_product() {
        prop::check(61, 200, |g| {
            let n = g.usize_in(1, 256);
            let w = g.normal_vec(n, 1.0);
            let a = g.normal_vec(n, 1.0);
            let tau = g.f32_in(0.0, 0.5);
            let pe = Pe::new(16, tau);
            let out = pe.process_tile(&w, &a);
            for i in 0..n {
                let a_eff = if a[i].abs() < tau { 0.0 } else { a[i] };
                let expect = w[i] * a_eff;
                assert!(
                    (out.products[i] - expect).abs() < 1e-6,
                    "i={i} got {} want {expect}",
                    out.products[i]
                );
            }
        });
    }

    #[test]
    fn sparsity_disabled_still_correct_but_denser() {
        let w = vec![1.0, 0.0, 2.0, 3.0];
        let a = vec![4.0, 5.0, 0.0, 0.5];
        let mut pe = Pe::new(4, 1.0); // tau=1.0 prunes a[3]=0.5
        let with = pe.process_tile(&w, &a);
        pe.sparsity_enabled = false;
        let without = pe.process_tile(&w, &a);
        assert_eq!(with.products, without.products);
        assert!(with.effectual_macs < without.effectual_macs);
    }

    #[test]
    fn sparsity_modules_pay_off_on_realistic_tiles() {
        // On a 16x16 tile at ~50% sparsity the skipped MAC energy far
        // outweighs the AND/XOR/shifter stage overhead (the reason the
        // modules exist); tiny dense tiles would not amortize it.
        let mut g = crate::util::rng::Rng::new(11);
        let w = g.normal_vec(256, 1.0);
        let a = g.normal_vec(256, 1.0);
        let mut pe = Pe::new(16, 0.7); // prunes ~52% of activations
        let with = pe.process_tile(&w, &a);
        pe.sparsity_enabled = false;
        let without = pe.process_tile(&w, &a);
        assert_eq!(with.products, without.products);
        assert!(
            with.cost.energy_pj < without.cost.energy_pj,
            "with {} without {}",
            with.cost.energy_pj,
            without.cost.energy_pj
        );
        assert!(with.cost.cycles <= without.cost.cycles);
    }

    #[test]
    fn higher_tau_fewer_effectual_macs() {
        let mut g = crate::util::rng::Rng::new(3);
        let w = g.normal_vec(512, 1.0);
        let a = g.normal_vec(512, 1.0);
        let low = Pe::new(16, 0.1).process_tile(&w, &a);
        let high = Pe::new(16, 1.0).process_tile(&w, &a);
        assert!(high.effectual_macs < low.effectual_macs);
        assert!(high.cost.cycles <= low.cost.cycles);
    }

    #[test]
    fn dynatran_disabled_keeps_small_values() {
        let w = vec![1.0f32; 4];
        let a = vec![0.01, 0.02, 0.03, 0.9];
        let mut pe = Pe::new(4, 0.5);
        pe.dynatran_enabled = false;
        let out = pe.process_tile(&w, &a);
        assert_eq!(out.products, a);
    }
}
