//! Binary-mask sparsity pipeline (paper Sec. III-B6, Fig. 8).
//!
//! AccelTran stores tiles *zero-free*: the nonzero values plus a binary
//! mask with one bit per original element (mask bit 1 = ineffectual /
//! pruned, matching the DynaTran module's output convention).  Before a
//! MAC-lane consumes a weight/activation tile pair, the pre-compute
//! sparsity module intersects the two masks (bitwise AND of the *keep*
//! view), filters each operand down to the common support via the filter
//! masks (XOR), and zero-collapses — so the lanes see only effectual
//! multiplications.  The post-compute module re-expands outputs.
//!
//! This module is a *functional* implementation (bit-exact data
//! transformation, used by the host-side pruning experiments and the
//! property tests); the cycle/energy cost of the hardware stage is
//! charged by `tech`/`engine`.

/// A tile in compressed zero-free form.
#[derive(Clone, Debug, PartialEq)]
pub struct CompressedTile {
    /// Non-zero values in row-major order of the original tile.
    pub values: Vec<f32>,
    /// One bit per original element; `true` = ineffectual (value was
    /// pruned/zero), `false` = a value is present.
    pub mask: Vec<bool>,
}

impl CompressedTile {
    /// Compress a dense tile: drop zeros, record the mask.
    pub fn compress(dense: &[f32]) -> CompressedTile {
        let mut values = Vec::with_capacity(dense.len());
        let mut mask = Vec::with_capacity(dense.len());
        for &v in dense {
            if v == 0.0 {
                mask.push(true);
            } else {
                mask.push(false);
                values.push(v);
            }
        }
        CompressedTile { values, mask }
    }

    /// Expand back to dense form (the post-compute sparsity module).
    pub fn decompress(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.mask.len());
        let mut it = self.values.iter();
        for &pruned in &self.mask {
            if pruned {
                out.push(0.0);
            } else {
                out.push(*it.next().expect("mask/value length mismatch"));
            }
        }
        debug_assert!(it.next().is_none(), "extra values beyond mask");
        out
    }

    /// Elements in the original tile.
    pub fn len(&self) -> usize {
        self.mask.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mask.is_empty()
    }

    /// Non-zero count.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Sparsity ratio rho.
    pub fn sparsity(&self) -> f64 {
        if self.mask.is_empty() {
            return 0.0;
        }
        (self.len() - self.nnz()) as f64 / self.len() as f64
    }

    /// Stored bytes under the paper's encoding at `elem_bytes` per value
    /// plus 1 mask bit per element.
    pub fn stored_bytes(&self, elem_bytes: f64) -> f64 {
        self.nnz() as f64 * elem_bytes + self.len() as f64 / 8.0
    }
}

/// Output of the pre-compute sparsity module for one operand pair.
#[derive(Clone, Debug, PartialEq)]
pub struct AlignedPair {
    /// Zero-free weight values on the common support.
    pub w: Vec<f32>,
    /// Zero-free activation values on the common support.
    pub a: Vec<f32>,
    /// Output mask: `true` where *either* operand was ineffectual (the
    /// product is zero there) — i.e. the complement of the AND of keeps.
    pub out_mask: Vec<bool>,
}

/// The Fig. 8 pre-compute sparsity module.
///
/// * output mask  = NOT(keep_w AND keep_a)   (bitwise AND over keeps)
/// * filter_w     = keep_w XOR common_keep   (w values to drop)
/// * filter_a     = keep_a XOR common_keep
/// * zero-collapsing shifter = compaction of the surviving values.
///
/// Elementwise semantics (the operands are aligned element-for-element,
/// as in a Hadamard step of a tiled MAC with matching layouts).
pub fn precompute_align(w: &CompressedTile, a: &CompressedTile) -> AlignedPair {
    assert_eq!(w.len(), a.len(), "operand tiles must agree in shape");
    let mut out_w = Vec::new();
    let mut out_a = Vec::new();
    let mut out_mask = Vec::with_capacity(w.len());
    let mut wi = 0usize;
    let mut ai = 0usize;
    for idx in 0..w.len() {
        let keep_w = !w.mask[idx];
        let keep_a = !a.mask[idx];
        let common = keep_w && keep_a; // the AND gate
        out_mask.push(!common);
        // filter masks: keep_x XOR common = x-only positions (dropped)
        if common {
            out_w.push(w.values[wi]);
            out_a.push(a.values[ai]);
        }
        if keep_w {
            wi += 1;
        }
        if keep_a {
            ai += 1;
        }
    }
    debug_assert_eq!(wi, w.values.len());
    debug_assert_eq!(ai, a.values.len());
    AlignedPair { w: out_w, a: out_a, out_mask }
}

/// Effectual MAC count for a tile pair after pre-compute alignment —
/// what the MAC lane actually executes.
pub fn effectual_macs(w: &CompressedTile, a: &CompressedTile) -> usize {
    precompute_align(w, a).w.len()
}

/// Expected fraction of *effectual* products when weight and activation
/// sparsities are independent: (1 - rho_w)(1 - rho_a).  The engine uses
/// this closed form instead of materializing tiles.
pub fn effectual_fraction(rho_w: f64, rho_a: f64) -> f64 {
    debug_assert!((0.0..=1.0).contains(&rho_w) && (0.0..=1.0).contains(&rho_a));
    (1.0 - rho_w) * (1.0 - rho_a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_sparse(rng: &mut Rng, n: usize, rho: f64) -> Vec<f32> {
        (0..n)
            .map(|_| {
                if rng.chance(rho) {
                    0.0
                } else {
                    rng.normal() + 0.01 // avoid exact zeros among kept
                }
            })
            .collect()
    }

    #[test]
    fn compress_roundtrip_property() {
        prop::check(31, 200, |g| {
            let n = g.usize_in(0, 512);
            let rho = g.f32_in(0.0, 1.0) as f64;
            let dense = random_sparse(g.rng(), n, rho);
            let c = CompressedTile::compress(&dense);
            assert_eq!(c.decompress(), dense);
            assert_eq!(c.nnz(), dense.iter().filter(|&&v| v != 0.0).count());
        });
    }

    #[test]
    fn aligned_products_match_dense_products() {
        prop::check(32, 200, |g| {
            let n = g.usize_in(1, 256);
            let wd = random_sparse(g.rng(), n, 0.5);
            let ad = random_sparse(g.rng(), n, 0.5);
            let w = CompressedTile::compress(&wd);
            let a = CompressedTile::compress(&ad);
            let pair = precompute_align(&w, &a);
            // sum of aligned products == dense dot product
            let sparse_dot: f64 = pair
                .w
                .iter()
                .zip(&pair.a)
                .map(|(&x, &y)| (x as f64) * (y as f64))
                .sum();
            let dense_dot: f64 = wd
                .iter()
                .zip(&ad)
                .map(|(&x, &y)| (x as f64) * (y as f64))
                .sum();
            assert!((sparse_dot - dense_dot).abs() < 1e-4,
                    "{sparse_dot} vs {dense_dot}");
        });
    }

    #[test]
    fn out_mask_is_and_of_keeps() {
        let w = CompressedTile::compress(&[1.0, 0.0, 2.0, 0.0]);
        let a = CompressedTile::compress(&[3.0, 4.0, 0.0, 0.0]);
        let pair = precompute_align(&w, &a);
        assert_eq!(pair.out_mask, vec![false, true, true, true]);
        assert_eq!(pair.w, vec![1.0]);
        assert_eq!(pair.a, vec![3.0]);
    }

    #[test]
    fn mask_and_filter_support_invariants() {
        // Fig. 8 gate-level contract under random tiles: the output mask
        // is the NAND of the keep views, the filtered operands live on
        // exactly the common support, and re-expanding the elementwise
        // products through the post-compute module reproduces the dense
        // Hadamard product bit-for-bit.
        prop::check(34, 200, |g| {
            let n = g.usize_in(1, 256);
            let wd = random_sparse(g.rng(), n, g.f32_in(0.0, 1.0) as f64);
            let ad = random_sparse(g.rng(), n, g.f32_in(0.0, 1.0) as f64);
            let w = CompressedTile::compress(&wd);
            let a = CompressedTile::compress(&ad);
            let pair = precompute_align(&w, &a);
            assert_eq!(pair.out_mask.len(), n);
            let mut common = 0usize;
            for i in 0..n {
                let keep_w = wd[i] != 0.0;
                let keep_a = ad[i] != 0.0;
                assert_eq!(pair.out_mask[i], !(keep_w && keep_a), "idx {i}");
                common += (keep_w && keep_a) as usize;
            }
            assert_eq!(pair.w.len(), common);
            assert_eq!(pair.a.len(), common);
            // post-compute re-expansion of the products == dense products
            let products: Vec<f32> =
                pair.w.iter().zip(&pair.a).map(|(&x, &y)| x * y).collect();
            let expanded = CompressedTile {
                values: products,
                mask: pair.out_mask.clone(),
            }
            .decompress();
            let dense: Vec<f32> =
                wd.iter().zip(&ad).map(|(&x, &y)| x * y).collect();
            assert_eq!(expanded, dense);
        });
    }

    #[test]
    fn effectual_fraction_stays_in_unit_interval() {
        // Closed form and measurement both live in [0, 1] under random
        // tiles and random operating points.
        prop::check(35, 200, |g| {
            let rho_w = g.f32_in(0.0, 1.0) as f64;
            let rho_a = g.f32_in(0.0, 1.0) as f64;
            let f = effectual_fraction(rho_w, rho_a);
            assert!((0.0..=1.0).contains(&f), "closed form {f}");
            let n = g.usize_in(1, 200);
            let w = CompressedTile::compress(&random_sparse(g.rng(), n, rho_w));
            let a = CompressedTile::compress(&random_sparse(g.rng(), n, rho_a));
            let measured = effectual_macs(&w, &a) as f64 / n as f64;
            assert!((0.0..=1.0).contains(&measured), "measured {measured}");
        });
    }

    #[test]
    fn effectual_macs_never_exceed_min_nnz() {
        prop::check(33, 100, |g| {
            let n = g.usize_in(1, 128);
            let w = CompressedTile::compress(&random_sparse(g.rng(), n, 0.3));
            let a = CompressedTile::compress(&random_sparse(g.rng(), n, 0.7));
            let eff = effectual_macs(&w, &a);
            assert!(eff <= w.nnz().min(a.nnz()));
        });
    }

    #[test]
    fn effectual_fraction_closed_form_tracks_measurement() {
        let mut rng = Rng::new(99);
        let n = 200_000;
        let w = CompressedTile::compress(&random_sparse(&mut rng, n, 0.5));
        let a = CompressedTile::compress(&random_sparse(&mut rng, n, 0.3));
        let measured = effectual_macs(&w, &a) as f64 / n as f64;
        let predicted = effectual_fraction(0.5, 0.3);
        assert!((measured - predicted).abs() < 0.01,
                "measured {measured:.3} predicted {predicted:.3}");
    }

    #[test]
    fn stored_bytes_accounts_mask_overhead() {
        let c = CompressedTile::compress(&[0.0; 64]);
        assert_eq!(c.stored_bytes(2.5), 8.0); // only the mask
        let d = CompressedTile::compress(&[1.0; 64]);
        assert_eq!(d.stored_bytes(2.5), 64.0 * 2.5 + 8.0);
    }
}
