//! The cycle-accurate event loop (paper Sec. III-B7/8, Fig. 9).
//!
//! Discrete-event simulation at tile granularity — the same granularity
//! the paper's Python simulator uses.  Resources (MAC lanes, softmax and
//! layer-norm modules, the DMA channel, buffer space) are occupied by
//! tile batches; events mark batch completions; the scheduler picks which
//! ready op feeds each freed module; stalls accumulate as
//! blocked-op-cycles (Fig. 16), and the energy ledger/traces accumulate
//! per-tile costs from the `tech`/`modules` models (Figs. 17–19,
//! Tables III–IV).
//!
//! Tile batching: for large design points (Server × BERT-Base is ~10^8
//! tiles) issuing one event per tile is wasteful; the engine issues
//! *batches* of tiles per module with one completion event per batch.
//! Batch size adapts to keep every module busy (`remaining / modules`,
//! capped) so stagger/utilization dynamics are preserved at the
//! granularity Fig. 17 plots.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::model::ops::{OpDims, OpGraph, OpKind};
use crate::sim::buffer::Buffer;
use crate::sim::config::AcceleratorConfig;
use crate::sim::dataflow;
use crate::sim::memory::Dma;
use crate::sim::modules::{LayerNormModule, MacLane, SoftmaxModule};
use crate::sim::scheduler::{OpState, Policy, Schedule};
use crate::sim::sparsity::effectual_fraction;
use crate::sim::stats::{EnergyLedger, StallCounters, Trace, TraceSample};
use crate::sim::tech;
use crate::sim::tiling;
use crate::trace::SparsityTrace;
use crate::util::json::Json;

/// Runtime sparsity operating point fed to the timing model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SparsityProfile {
    /// Static weight sparsity (e.g. 0.5 from movement pruning).
    pub weight_rho: f64,
    /// Activation sparsity achieved by DynaTran at the chosen tau.
    pub act_rho: f64,
    /// Activation sparsity present *without* DynaTran (natural zeros from
    /// GeLU cutoffs / attention floors; Table IV "w/o DynaTran" row).
    pub inherent_act_rho: f64,
}

impl SparsityProfile {
    /// The paper's headline operating point: 50% weight sparsity via MP,
    /// 50% runtime activation sparsity via DynaTran (Table IV row 1).
    pub fn paper_default() -> Self {
        SparsityProfile { weight_rho: 0.5, act_rho: 0.5, inherent_act_rho: 0.1 }
    }

    pub fn dense() -> Self {
        SparsityProfile { weight_rho: 0.0, act_rho: 0.0, inherent_act_rho: 0.0 }
    }
}

/// Where each tiled op's sparsity operating point comes from.
///
/// The paper's headline figures feed *measured* per-operation sparsity
/// into the timing model; [`SparsitySource::Trace`] does exactly that by
/// resolving a per-op [`SparsityProfile`] from a captured
/// [`SparsityTrace`] via the op's stable
/// [`crate::model::TraceClass`].  [`SparsitySource::Uniform`] is the
/// legacy 3-scalar fallback: one profile applied to every op (what every
/// pre-trace call site uses, bit-identical to the old behavior).
#[derive(Clone, Debug)]
pub enum SparsitySource {
    /// One hand-picked profile for every op.
    Uniform(SparsityProfile),
    /// Per-op profiles resolved from a measured trace.
    Trace(SparsityTrace),
}

impl SparsitySource {
    /// Short name for reports ("uniform" / "trace").
    pub fn name(&self) -> &'static str {
        match self {
            SparsitySource::Uniform(_) => "uniform",
            SparsitySource::Trace(_) => "trace",
        }
    }

    /// Resolve the operating point of one op.
    pub fn profile_for(&self, node: &crate::model::ops::OpNode) -> SparsityProfile {
        match self {
            SparsitySource::Uniform(p) => *p,
            SparsitySource::Trace(t) => t.profile_for(node),
        }
    }
}

/// Final simulation report.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub config_name: String,
    pub model_name: String,
    /// Which sparsity source drove the run ("uniform" / "trace").
    pub sparsity_source: String,
    pub batch: usize,
    pub seq: usize,
    pub total_cycles: u64,
    pub energy: EnergyLedger,
    pub stalls: StallCounters,
    /// Mean utilization over the busy phase, per resource class.
    pub mac_utilization: f64,
    pub softmax_utilization: f64,
    pub dma_utilization: f64,
    pub act_buffer_peak: f64,
    pub weight_buffer_peak: f64,
    pub trace: Vec<TraceSample>,
}

impl SimResult {
    /// Seconds for the simulated batch at the configured clock.
    pub fn latency_s(&self, cfg: &AcceleratorConfig) -> f64 {
        cfg.cycles_to_s(self.total_cycles)
    }

    /// Sequences per second.
    pub fn throughput_seq_s(&self, cfg: &AcceleratorConfig) -> f64 {
        self.batch as f64 / self.latency_s(cfg)
    }

    /// Millijoules per sequence.
    pub fn energy_mj_per_seq(&self) -> f64 {
        self.energy.total_pj() * 1e-9 / self.batch as f64
    }

    /// Average power in watts.
    pub fn avg_power_w(&self, cfg: &AcceleratorConfig) -> f64 {
        self.energy.total_pj() * 1e-12 / self.latency_s(cfg)
    }

    pub fn to_json(&self, cfg: &AcceleratorConfig) -> Json {
        Json::obj(vec![
            ("config", Json::str(self.config_name.clone())),
            ("model", Json::str(self.model_name.clone())),
            ("sparsity_source", Json::str(self.sparsity_source.clone())),
            ("batch", Json::num(self.batch as f64)),
            ("seq", Json::num(self.seq as f64)),
            ("total_cycles", Json::num(self.total_cycles as f64)),
            ("latency_s", Json::num(self.latency_s(cfg))),
            ("throughput_seq_s", Json::num(self.throughput_seq_s(cfg))),
            ("energy_mj_per_seq", Json::num(self.energy_mj_per_seq())),
            ("avg_power_w", Json::num(self.avg_power_w(cfg))),
            ("energy", self.energy.to_json()),
            ("compute_stalls", Json::num(self.stalls.compute_total() as f64)),
            ("memory_stalls", Json::num(self.stalls.memory_total() as f64)),
            ("mac_utilization", Json::num(self.mac_utilization)),
            ("softmax_utilization", Json::num(self.softmax_utilization)),
            ("dma_utilization", Json::num(self.dma_utilization)),
        ])
    }
}

/// Event payload: a batch of tiles completing on a resource class.
#[derive(Debug, PartialEq, Eq)]
struct Event {
    cycle: u64,
    op: usize,
    tiles: usize,
    kind: ResClass,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
enum ResClass {
    Mac,
    Softmax,
    LayerNorm,
    Dma,
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.cycle, self.kind, self.op, self.tiles).cmp(&(
            other.cycle,
            other.kind,
            other.op,
            other.tiles,
        ))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// The simulator.
pub struct Engine<'g> {
    pub cfg: AcceleratorConfig,
    graph: &'g OpGraph,
    sched: Schedule,
    /// Name of the sparsity source the per-op profiles were resolved
    /// from (the profiles themselves live in the schedule records).
    sparsity_source: &'static str,
    // resources
    free_lanes: usize,
    free_softmax: usize,
    free_layernorm: usize,
    lane_model: MacLane,
    softmax_model: SoftmaxModule,
    layernorm_model: LayerNormModule,
    dma: Dma,
    act_buf: Buffer,
    weight_buf: Buffer,
    mask_buf: Buffer,
    // event queue
    events: BinaryHeap<Reverse<Event>>,
    now: u64,
    // accounting
    energy: EnergyLedger,
    stalls: StallCounters,
    trace: Trace,
    /// Per-op buffer-traffic discount from dataflow reuse (sampled once
    /// per distinct grid shape).
    reuse_discount: Vec<f64>,
    /// integral of busy modules over time, for mean utilization
    lane_busy_integral: f64,
    softmax_busy_integral: f64,
    energy_at_last_trace: f64,
    last_event_cycle: u64,
    max_batch_tiles: usize,
    /// Activations spilled to main memory because the activation buffer
    /// window could not hold the full output (op id -> spilled bytes).
    /// Consumers re-fetch over the DMA channel — the paper's
    /// "memory stall if the compute operation is not done before storing
    /// activation data" case (Sec. III-B8).
    spilled: std::collections::HashMap<usize, usize>,
    /// Whole-model weight residency: when ALL compressed weights +
    /// embeddings fit in the weight buffer (BERT-Tiny: ~5.4 MB vs 8 MB
    /// Edge), steady-state serving performs no weight DMA at all —
    /// weights load once and persist across batches.  Larger models
    /// (BERT-Base: ~175 MB) stream per batch, which is what makes them
    /// memory-bound (Sec. I).
    warm_weights: bool,
    /// §Perf: per-op tile costs precomputed at construction — the issue
    /// loop (the profile's top frame after the event heap) must not
    /// re-derive label matches, log2 reduction depths, or ceil'd byte
    /// counts per batch.
    op_costs: Vec<OpCost>,
}

/// Precomputed per-tile costs of one op (see `Engine::op_costs`).
#[derive(Clone, Copy, Debug, Default)]
struct OpCost {
    cycles_per_tile: u64,
    compute_pj_per_tile: f64,
    buffer_pj_per_tile: f64,
    dynatran_pj_per_tile: f64,
    sparsity_pj_per_tile: f64,
    /// M-OP-0 (embeddings) — candidate for steady-state residency.
    is_embedding: bool,
}

impl<'g> Engine<'g> {
    /// Uniform-profile construction (the legacy entry point): every op
    /// runs at the same 3-scalar operating point.
    pub fn new(
        cfg: AcceleratorConfig,
        graph: &'g OpGraph,
        policy: Policy,
        sparsity: SparsityProfile,
    ) -> Engine<'g> {
        Self::with_source(cfg, graph, policy, &SparsitySource::Uniform(sparsity))
    }

    /// Construct with an explicit [`SparsitySource`] — the measured-trace
    /// path resolves one [`SparsityProfile`] per op here, once, before
    /// any cost is computed.
    pub fn with_source(
        cfg: AcceleratorConfig,
        graph: &'g OpGraph,
        policy: Policy,
        source: &SparsitySource,
    ) -> Engine<'g> {
        let grids: Vec<_> = graph
            .nodes
            .iter()
            .map(|n| tiling::tile_op(&n.dims, cfg.tile_b, cfg.tile_i, cfg.tile_j, cfg.tile_k))
            .collect();
        // Sample the dataflow reuse rate for each op's grid: fraction of
        // operand fetches avoided by lane-register reuse (buffer-energy
        // discount; latency is unaffected because transfers are hidden,
        // Sec. V-B).
        let lanes = cfg.total_mac_lanes().min(64); // replay with a capped bank
        let reuse_discount = grids
            .iter()
            .zip(&graph.nodes)
            .map(|(g, n)| {
                if n.kind != OpKind::MatMul || g.total_tiles() == 0 {
                    return 0.0;
                }
                // replay a truncated stream (same reuse rate, cheaper)
                let mut sample = *g;
                while sample.total_tiles() > 4096 {
                    if sample.ni > 1 {
                        sample.ni = sample.ni.div_ceil(2);
                    } else if sample.nj > 1 {
                        sample.nj = sample.nj.div_ceil(2);
                    } else {
                        sample.nk = sample.nk.div_ceil(2);
                    }
                }
                let rep = dataflow::replay(cfg.dataflow, &sample, lanes, 0.0, 0.0);
                rep.reuse_instances() as f64 / (2 * rep.tiles) as f64
            })
            .collect();
        let profiles: Vec<SparsityProfile> =
            graph.nodes.iter().map(|n| source.profile_for(n)).collect();
        let sched = Schedule::new(graph, policy, grids, profiles);
        let lane_model = MacLane::new(cfg.multipliers_per_lane);
        let softmax_model = SoftmaxModule { elems_per_cycle: cfg.special_elems_per_cycle };
        let layernorm_model =
            LayerNormModule { elems_per_cycle: cfg.special_elems_per_cycle };
        let dma = Dma::new(cfg.memory, cfg.clock_hz);
        let mut engine = Engine {
            free_lanes: cfg.total_mac_lanes(),
            free_softmax: cfg.total_softmax(),
            free_layernorm: cfg.total_layernorm(),
            lane_model,
            softmax_model,
            layernorm_model,
            dma,
            act_buf: Buffer::new("activation", cfg.act_buffer_bytes),
            weight_buf: Buffer::new("weight", cfg.weight_buffer_bytes),
            mask_buf: Buffer::new("mask", cfg.mask_buffer_bytes),
            events: BinaryHeap::new(),
            now: 0,
            energy: EnergyLedger::default(),
            stalls: StallCounters::default(),
            trace: Trace::new(1024),
            reuse_discount,
            lane_busy_integral: 0.0,
            softmax_busy_integral: 0.0,
            energy_at_last_trace: 0.0,
            last_event_cycle: 0,
            max_batch_tiles: 256,
            spilled: std::collections::HashMap::new(),
            warm_weights: false,
            op_costs: Vec::new(),
            graph,
            sched,
            cfg,
            sparsity_source: source.name(),
        };
        // Whole-model weight residency is intentionally NOT inferred:
        // the paper streams per-layer weights each batch (Fig. 17 shows
        // M-OP loads during evaluation) and keeps only the embeddings
        // resident (Sec. V-D) — which is what makes the memory
        // technology matter even for BERT-Tiny (Table IV row 5).
        engine.warm_weights = false;
        engine.op_costs = engine.build_op_costs();
        engine
    }

    /// Effectual-MAC fraction for op `id` under its resolved profile.
    fn eff_frac(&self, id: usize) -> f64 {
        let p = self.sched.ops[id].profile;
        if self.cfg.dynatran_enabled {
            effectual_fraction(p.weight_rho, p.act_rho)
        } else {
            effectual_fraction(p.weight_rho, p.inherent_act_rho)
        }
    }

    /// Activation sparsity of op `id`'s stored output under the current
    /// ablation switches (dense without the mask pipeline; inherent
    /// zeros only without DynaTran).
    fn act_rho(&self, id: usize) -> f64 {
        let p = self.sched.ops[id].profile;
        if !self.cfg.sparsity_modules {
            0.0
        } else if self.cfg.dynatran_enabled {
            p.act_rho
        } else {
            p.inherent_act_rho
        }
    }

    /// Run to completion and report.
    pub fn run(mut self) -> SimResult {
        self.try_issue();
        let mut guard: u64 = 0;
        while let Some(Reverse(ev)) = self.events.pop() {
            guard += 1;
            assert!(
                guard < 200_000_000,
                "event budget exceeded — scheduler livelock?"
            );
            self.advance_time(ev.cycle);
            self.handle_completion(ev);
            self.try_issue();
            self.record_trace();
        }
        assert!(
            self.sched.all_done(),
            "simulation drained events with {}/{} ops done — deadlock \
             (buffer too small for a single allocation?)",
            self.sched.done_count,
            self.graph.nodes.len()
        );
        debug_assert!(self.sched.check_invariants().is_ok());
        let total = self.now.max(1);
        // standing leakage + memory idle power over the whole run
        let seconds = total as f64 / self.cfg.clock_hz;
        let buffer_mb = self.cfg.total_buffer_bytes() as f64 / (1 << 20) as f64;
        self.energy.leakage_pj += seconds
            * (buffer_mb * tech::BUFFER_LEAK_W_PER_MB
                + self.cfg.memory.idle_power_w())
            * 1e12;
        let lanes = self.cfg.total_mac_lanes() as f64;
        let smx = self.cfg.total_softmax() as f64;
        SimResult {
            config_name: self.cfg.name.clone(),
            model_name: self.graph.config.name.clone(),
            sparsity_source: self.sparsity_source.to_string(),
            batch: self.graph.batch,
            seq: self.graph.seq,
            total_cycles: total,
            mac_utilization: self.lane_busy_integral / (total as f64 * lanes),
            softmax_utilization: self.softmax_busy_integral / (total as f64 * smx),
            dma_utilization: self.dma.utilization(total),
            act_buffer_peak: self.act_buf.peak_bytes as f64
                / self.act_buf.capacity_bytes as f64,
            weight_buffer_peak: self.weight_buf.peak_bytes as f64
                / self.weight_buf.capacity_bytes as f64,
            energy: self.energy,
            stalls: self.stalls,
            trace: self.trace.samples,
        }
    }

    /// Integrate busy-resource time and stall-cycles up to `cycle`.
    fn advance_time(&mut self, cycle: u64) {
        debug_assert!(cycle >= self.now);
        let dt = (cycle - self.now) as f64;
        if dt > 0.0 {
            let busy_lanes = (self.cfg.total_mac_lanes() - self.free_lanes) as f64;
            let busy_smx = (self.cfg.total_softmax() - self.free_softmax) as f64;
            let busy_ln =
                (self.cfg.total_layernorm() - self.free_layernorm) as f64;
            self.lane_busy_integral += dt * busy_lanes;
            self.softmax_busy_integral += dt * busy_smx;
            // leakage only for powered (busy) modules — unused ones are
            // power-gated (Sec. III-B8)
            let leak_w = busy_lanes * tech::MAC_LANE_LEAK_W
                + busy_smx * tech::SOFTMAX_LEAK_W
                + busy_ln * tech::LAYERNORM_LEAK_W;
            self.energy.leakage_pj += dt / self.cfg.clock_hz * leak_w * 1e12;
            // stall-cycles: ops ready but starved of resources (Fig. 16
            // semantics).  O(1) per event via the scheduler's ready-queue
            // lengths (§Perf: the previous O(ops) scan per event was the
            // engine's top hot spot).
            let (r_mac, r_smx, r_ln, _r_load) = self.sched.ready_counts();
            let mut starved = 0u64;
            if self.free_lanes == 0 {
                starved += r_mac as u64;
            }
            if self.free_softmax == 0 {
                starved += r_smx as u64;
            }
            if self.free_layernorm == 0 {
                starved += r_ln as u64;
            }
            self.stalls.compute_resource += dt as u64 * starved;
        }
        self.now = cycle;
    }

    fn handle_completion(&mut self, ev: Event) {
        match ev.kind {
            ResClass::Mac => self.free_lanes += 1,
            ResClass::Softmax => self.free_softmax += 1,
            ResClass::LayerNorm => self.free_layernorm += 1,
            ResClass::Dma => {}
        }
        let newly_ready =
            self.sched.complete_tiles(self.graph, ev.op, ev.tiles, self.now);
        // when an op fully completes, release its input allocations and
        // stream any spilled output portion to main memory (a "store
        // waits" memory stall, Sec. III-B8)
        if self.sched.ops[ev.op].state == OpState::Done {
            if let Some(&bytes) = self.spilled.get(&ev.op) {
                self.dma.transfer(self.now, bytes);
                self.energy.memory_pj = self.dma.energy_pj;
                self.stalls.memory_pending_compute += 1;
            }
            let deps = self.graph.nodes[ev.op].deps.clone();
            for d in deps {
                match self.graph.nodes[d].kind {
                    OpKind::MemLoad => self.weight_buf.release(d),
                    _ => self.act_buf.release(d),
                }
                self.mask_buf.release(d);
            }
        }
        let _ = newly_ready;
    }

    /// Greedy issue: feed every free resource from the ready queues.
    fn try_issue(&mut self) {
        // ---- memory loads over the DMA channel -------------------------
        while let Some(id) = self.sched.peek_ready(OpKind::MemLoad) {
            // one outstanding transfer per op; batch = whole remaining
            // matrix (streamed; completion fires when fully buffered)
            if self.sched.ops[id].tiles_inflight > 0 {
                break; // already streaming; DMA is serialized anyway
            }
            if !self.reserve_output(id) {
                break; // memory stall: wait for evictions
            }
            let tiles = self.sched.ops[id].tiles_remaining;
            // Embeddings stay resident across batches (Sec. V-D): at
            // steady state M-OP-0 costs neither DMA time nor energy.
            // When the whole model fits the weight buffer, every weight
            // load is warm (see `warm_weights`).
            let warm = self.warm_weights
                || (self.cfg.embeddings_resident
                    && self.graph.nodes[id].label.contains("M-OP-0"));
            let done = if warm {
                self.now + 1
            } else {
                let bytes = self.load_bytes(id);
                let done = self.dma.transfer(self.now, bytes);
                self.energy.memory_pj = self.dma.energy_pj;
                self.energy.buffer_pj += bytes as f64 * tech::BUFFER_PJ_PER_BYTE;
                done
            };
            self.sched.issue_tiles(self.graph, id, tiles);
            self.events.push(Reverse(Event {
                cycle: done,
                op: id,
                tiles,
                kind: ResClass::Dma,
            }));
        }

        // ---- compute resources -----------------------------------------
        self.issue_class(ResClass::Mac);
        self.issue_class(ResClass::Softmax);
        self.issue_class(ResClass::LayerNorm);
    }

    fn issue_class(&mut self, class: ResClass) {
        loop {
            let (free, kinds): (usize, &[OpKind]) = match class {
                ResClass::Mac => (self.free_lanes, &[OpKind::MatMul, OpKind::Add]),
                ResClass::Softmax => (self.free_softmax, &[OpKind::Softmax]),
                ResClass::LayerNorm => (self.free_layernorm, &[OpKind::LayerNorm]),
                ResClass::Dma => return,
            };
            if free == 0 {
                return;
            }
            let mut candidate = None;
            for &k in kinds {
                if let Some(id) = self.sched.peek_ready(k) {
                    candidate = Some(id);
                    break;
                }
            }
            let Some(id) = candidate else { return };
            let first_issue = self.sched.ops[id].tiles_inflight == 0
                && self.sched.ops[id].tiles_remaining
                    == self.sched.ops[id].grid.total_tiles();
            if self.sched.ops[id].tiles_inflight == 0 && !self.reserve_output(id) {
                // output space unavailable: op marked blocked; try others
                // next event (avoid spinning on the same head-of-queue op)
                return;
            }
            // re-fetch any spilled producer data over the DMA channel —
            // the consumer-side memory stall of a spilled activation
            let mut refetch_delay = 0u64;
            if first_issue {
                let deps = self.graph.nodes[id].deps.clone();
                for d in deps {
                    if let Some(&bytes) = self.spilled.get(&d) {
                        let done = self.dma.transfer(self.now, bytes);
                        self.energy.memory_pj = self.dma.energy_pj;
                        refetch_delay = refetch_delay.max(done - self.now);
                        self.stalls.memory_buffer_full += 1;
                    }
                }
            }
            let remaining = self.sched.ops[id].tiles_remaining;
            debug_assert!(remaining > 0);
            let modules = match class {
                ResClass::Mac => self.cfg.total_mac_lanes(),
                ResClass::Softmax => self.cfg.total_softmax(),
                ResClass::LayerNorm => self.cfg.total_layernorm(),
                ResClass::Dma => 1,
            };
            let batch = remaining
                .div_ceil(modules)
                .clamp(1, self.max_batch_tiles)
                .min(remaining);
            let (cycles, energy) = self.tile_batch_cost(id, batch, class);
            self.charge(id, batch, class, energy);
            self.sched.issue_tiles(self.graph, id, batch);
            match class {
                ResClass::Mac => self.free_lanes -= 1,
                ResClass::Softmax => self.free_softmax -= 1,
                ResClass::LayerNorm => self.free_layernorm -= 1,
                ResClass::Dma => {}
            }
            self.events.push(Reverse(Event {
                cycle: self.now + cycles.max(1) + refetch_delay,
                op: id,
                tiles: batch,
                kind: class,
            }));
        }
    }

    /// Precompute the per-tile cost vector (§Perf: called once from
    /// `new`; the issue loop then only multiplies by the batch size).
    fn build_op_costs(&self) -> Vec<OpCost> {
        self.graph
            .nodes
            .iter()
            .map(|node| {
                // per-op operating point (measured trace or uniform)
                let eff_frac = self.eff_frac(node.id);
                let w_keep = if self.cfg.sparsity_modules {
                    1.0 - self.sched.ops[node.id].profile.weight_rho
                } else {
                    1.0
                };
                let a_rho = self.act_rho(node.id);
                let grid = &self.sched.ops[node.id].grid;
                // compute cost per tile by resource class
                let per = match node.kind {
                    OpKind::MatMul | OpKind::Add => {
                        let dense_macs = grid.macs_per_tile;
                        let eff = if node.kind == OpKind::Add {
                            grid.out_elems_per_tile
                        } else if self.cfg.sparsity_modules {
                            ((dense_macs as f64) * eff_frac).ceil() as usize
                        } else {
                            dense_macs // no skipping without sparsity modules
                        };
                        let gelu = if node.label.contains("C-OP-9")
                            || node.label.contains("C-OP-10")
                        {
                            grid.out_elems_per_tile
                        } else {
                            0
                        };
                        self.lane_model.tile_cost(eff, gelu)
                    }
                    OpKind::Softmax => self
                        .softmax_model
                        .tile_cost(self.cfg.tile_i, elem_cols(&node.dims)),
                    OpKind::LayerNorm => self
                        .layernorm_model
                        .tile_cost(self.cfg.tile_i, elem_cols(&node.dims)),
                    OpKind::MemLoad => {
                        crate::sim::modules::TileCost { cycles: 1, energy_pj: 0.0 }
                    }
                };
                // buffer traffic per tile: operand fetches (compressed,
                // discounted by dataflow reuse — dense when the sparsity
                // modules are ablated, Table IV row 4) + masks + output
                let discount = 1.0 - self.reuse_discount[node.id];
                let w_bytes = grid.w_tile_elems as f64 * tech::ELEM_BYTES * w_keep;
                let a_bytes =
                    grid.a_tile_elems as f64 * tech::ELEM_BYTES * (1.0 - a_rho);
                let mask_bytes =
                    (grid.w_tile_elems + grid.a_tile_elems) as f64 / 8.0;
                let out_bytes = grid.out_elems_per_tile as f64 * tech::ELEM_BYTES;
                let buffer_pj = ((w_bytes + a_bytes) * discount
                    + mask_bytes
                    + out_bytes)
                    * tech::BUFFER_PJ_PER_BYTE;
                // DynaTran comparators on output activations (all
                // activations pruned at runtime, Sec. III-A)
                let dynatran_pj = if self.cfg.dynatran_enabled
                    && node.kind != OpKind::MemLoad
                {
                    grid.out_elems_per_tile as f64 * tech::DYNATRAN_PJ_PER_ELEM
                } else {
                    0.0
                };
                // pre+post sparsity stages
                let sparsity_pj = if self.cfg.sparsity_modules {
                    (grid.w_tile_elems + grid.a_tile_elems + grid.out_elems_per_tile)
                        as f64
                        * tech::SPARSITY_PJ_PER_ELEM
                } else {
                    0.0
                };
                OpCost {
                    cycles_per_tile: per.cycles,
                    compute_pj_per_tile: per.energy_pj,
                    buffer_pj_per_tile: buffer_pj,
                    dynatran_pj_per_tile: dynatran_pj,
                    sparsity_pj_per_tile: sparsity_pj,
                    is_embedding: node.label.contains("M-OP-0"),
                }
            })
            .collect()
    }

    /// Cycles + compute energy for `batch` tiles of op `id`.
    #[inline]
    fn tile_batch_cost(&self, id: usize, batch: usize, class: ResClass) -> (u64, f64) {
        if class == ResClass::Dma {
            return (1, 0.0);
        }
        let c = &self.op_costs[id];
        (c.cycles_per_tile * batch as u64, c.compute_pj_per_tile * batch as f64)
    }

    /// Charge buffer/DynaTran/sparsity-stage energies for a tile batch.
    #[inline]
    fn charge(&mut self, id: usize, batch: usize, class: ResClass, compute_pj: f64) {
        match class {
            ResClass::Mac => self.energy.mac_pj += compute_pj,
            ResClass::Softmax => self.energy.softmax_pj += compute_pj,
            ResClass::LayerNorm => self.energy.layernorm_pj += compute_pj,
            ResClass::Dma => {}
        }
        let c = &self.op_costs[id];
        let b = batch as f64;
        self.energy.buffer_pj += b * c.buffer_pj_per_tile;
        self.energy.dynatran_pj += b * c.dynatran_pj_per_tile;
        self.energy.sparsity_pj += b * c.sparsity_pj_per_tile;
    }

    /// Bytes a MemLoad op streams (compressed weights + mask; dense when
    /// the sparsity modules are ablated — compression needs the masks).
    fn load_bytes(&self, id: usize) -> usize {
        let node = &self.graph.nodes[id];
        let elems = match node.dims {
            OpDims::Load { elems } => elems,
            _ => unreachable!("load_bytes on compute op"),
        };
        let dense = elems as f64 * tech::ELEM_BYTES;
        if !self.cfg.sparsity_modules {
            return dense.ceil() as usize;
        }
        let weight_rho = self.sched.ops[id].profile.weight_rho;
        let compressed = dense * (1.0 - weight_rho) + elems as f64 / 8.0;
        compressed.ceil() as usize
    }

    /// Reserve output buffer space for op `id`'s result (and its mask).
    /// Returns false and marks the op blocked on a memory stall if space
    /// is unavailable.
    fn reserve_output(&mut self, id: usize) -> bool {
        let node = &self.graph.nodes[id];
        let consumers = self.sched.ops[id].succs.len();
        let ok = match node.kind {
            OpKind::MemLoad => {
                let bytes = self.load_bytes(id).min(
                    // embedding stream window: don't demand more than 60%
                    // of the weight buffer at once
                    (self.weight_buf.capacity_bytes as f64 * 0.6) as usize,
                );
                self.weight_buf.reserve(id, bytes, consumers)
                    && self.mask_buf.reserve(
                        id,
                        (node.dims.out_elems() / 8).max(1).min(self.mask_buf.capacity_bytes / 8),
                        consumers,
                    )
            }
            _ => {
                // dense storage without the mask pipeline; per-op
                // measured sparsity otherwise (see `act_rho`)
                let a_rho = self.act_rho(id);
                let full = (node.dims.out_elems() as f64
                    * tech::ELEM_BYTES
                    * (1.0 - a_rho))
                    .ceil() as usize;
                // Streaming window: outputs larger than 1/8 of the
                // activation buffer spill to main memory and consumers
                // re-fetch — smaller buffers spill more (Fig. 16's
                // memory-stall axis).
                let window = (self.act_buf.capacity_bytes / 3).max(4096);
                let resident = full.min(window).max(1);
                let ok = self.act_buf.reserve(id, resident, consumers)
                    && self.mask_buf.reserve(
                        id,
                        (node.dims.out_elems() / 8)
                            .max(1)
                            .min(self.mask_buf.capacity_bytes / 8),
                        consumers,
                    );
                if ok && full > resident {
                    self.spilled.insert(id, full - resident);
                }
                ok
            }
        };
        if !ok {
            self.stalls.memory_buffer_full += 1;
            // Admission control: while other work is in flight, simply
            // defer this op — completions will release buffer space (the
            // op accrues stall-cycles meanwhile).  Only when the machine
            // would otherwise go idle (true circular wait on buffer
            // space) force-spill the most recently scheduled resident
            // data (needed furthest in the future) to main memory;
            // consumers refetch over the DMA channel.
            if !self.events.is_empty() {
                self.sched.ops[id].state = OpState::Ready;
                return false;
            }
            let mut exclude = self.graph.nodes[id].deps.clone();
            exclude.push(id);
            let self_only = [id];
            for _ in 0..64 {
                // prefer non-dependency victims; as a last resort spill a
                // dependency too — the op then *streams* that input from
                // main memory (refetch is charged at issue)
                let spilled_one = match node.kind {
                    OpKind::MemLoad => self
                        .weight_buf
                        .spill_victim(&exclude)
                        .or_else(|| self.weight_buf.spill_victim(&self_only)),
                    _ => self
                        .act_buf
                        .spill_victim(&exclude)
                        .or_else(|| self.act_buf.spill_victim(&self_only)),
                };
                let mask_spill = self.mask_buf.spill_victim(&exclude);
                if let Some((vid, bytes)) = spilled_one {
                    *self.spilled.entry(vid).or_insert(0) += bytes;
                    self.dma.transfer(self.now, bytes);
                    self.energy.memory_pj = self.dma.energy_pj;
                } else if mask_spill.is_none() {
                    // nothing spillable at all: genuinely blocked
                    if std::env::var_os("ACCELTRAN_DEBUG").is_some() {
                        eprintln!(
                            "blocked op {} ({}): act {}/{} weight {}/{} mask {}/{}",
                            id,
                            self.graph.nodes[id].label,
                            self.act_buf.used_bytes(),
                            self.act_buf.capacity_bytes,
                            self.weight_buf.used_bytes(),
                            self.weight_buf.capacity_bytes,
                            self.mask_buf.used_bytes(),
                            self.mask_buf.capacity_bytes,
                        );
                    }
                    self.sched.ops[id].state = OpState::Ready;
                    return false;
                }
                if let Some((vid, bytes)) = mask_spill {
                    *self.spilled.entry(vid).or_insert(0) += bytes;
                }
                if self.reserve_output_inner(id) {
                    return true;
                }
            }
            if std::env::var_os("ACCELTRAN_DEBUG").is_some() {
                eprintln!(
                    "spill budget exhausted for op {} ({})",
                    id, self.graph.nodes[id].label
                );
            }
            self.sched.ops[id].state = OpState::Ready;
            return false;
        }
        true
    }

    /// Retry the raw reservations (idempotent on already-held buffers).
    fn reserve_output_inner(&mut self, id: usize) -> bool {
        let node = &self.graph.nodes[id];
        let consumers = self.sched.ops[id].succs.len();
        match node.kind {
            OpKind::MemLoad => {
                let bytes = self.load_bytes(id).min(
                    (self.weight_buf.capacity_bytes as f64 * 0.6) as usize,
                );
                self.weight_buf.reserve(id, bytes, consumers)
                    && self.mask_buf.reserve(
                        id,
                        (node.dims.out_elems() / 8)
                            .max(1)
                            .min(self.mask_buf.capacity_bytes / 8),
                        consumers,
                    )
            }
            _ => {
                let a_rho = self.act_rho(id);
                let full = (node.dims.out_elems() as f64
                    * tech::ELEM_BYTES
                    * (1.0 - a_rho))
                    .ceil() as usize;
                let window = (self.act_buf.capacity_bytes / 3).max(4096);
                let resident = full.min(window).max(1);
                self.act_buf.reserve(id, resident, consumers)
                    && self.mask_buf.reserve(
                        id,
                        (node.dims.out_elems() / 8)
                            .max(1)
                            .min(self.mask_buf.capacity_bytes / 8),
                        consumers,
                    )
            }
        }
    }

    fn record_trace(&mut self) {
        let dyn_pj = self.energy.total_pj() - self.energy.leakage_pj;
        let dt = (self.now - self.last_event_cycle).max(1) as f64;
        let dynamic_power_w = (dyn_pj - self.energy_at_last_trace).max(0.0) * 1e-12
            / (dt / self.cfg.clock_hz);
        let busy_lanes = self.cfg.total_mac_lanes() - self.free_lanes;
        let busy_smx = self.cfg.total_softmax() - self.free_softmax;
        let busy_ln = self.cfg.total_layernorm() - self.free_layernorm;
        self.trace.maybe_record(TraceSample {
            cycle: self.now,
            mac_lanes_active: busy_lanes,
            softmax_active: busy_smx,
            layernorm_active: busy_ln,
            act_buffer_frac: self.act_buf.occupancy(),
            weight_buffer_frac: self.weight_buf.occupancy(),
            dynamic_power_w,
            leakage_power_w: busy_lanes as f64 * tech::MAC_LANE_LEAK_W
                + busy_smx as f64 * tech::SOFTMAX_LEAK_W
                + busy_ln as f64 * tech::LAYERNORM_LEAK_W,
        });
        if self.trace.samples.last().map(|s| s.cycle) == Some(self.now) {
            self.energy_at_last_trace = dyn_pj;
            self.last_event_cycle = self.now;
        }
    }
}

fn elem_cols(dims: &OpDims) -> usize {
    match *dims {
        OpDims::Elem { n, .. } => n,
        OpDims::MatMul { n, .. } => n,
        OpDims::Load { .. } => 1,
    }
}

/// Convenience: simulate `model` on `cfg` at one uniform sparsity
/// operating point (the legacy fallback path).
pub fn simulate(
    cfg: &AcceleratorConfig,
    model: &crate::model::TransformerConfig,
    seq: usize,
    policy: Policy,
    sparsity: SparsityProfile,
) -> SimResult {
    simulate_with(cfg, model, seq, policy, &SparsitySource::Uniform(sparsity))
}

/// Simulate `model` on `cfg` drawing each op's sparsity from `source` —
/// pass `SparsitySource::Trace` to drive the timing model from measured
/// per-op activation sparsities (the Figs. 17-20 path).
pub fn simulate_with(
    cfg: &AcceleratorConfig,
    model: &crate::model::TransformerConfig,
    seq: usize,
    policy: Policy,
    source: &SparsitySource,
) -> SimResult {
    let graph = OpGraph::build(model, cfg.batch, seq);
    Engine::with_source(cfg.clone(), &graph, policy, source).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TransformerConfig;

    fn edge_sim(seq: usize, sparsity: SparsityProfile) -> (AcceleratorConfig, SimResult) {
        let cfg = AcceleratorConfig::edge();
        let model = TransformerConfig::bert_tiny();
        let r = simulate(&cfg, &model, seq, Policy::Staggered, sparsity);
        (cfg, r)
    }

    #[test]
    fn tiny_inference_completes() {
        let (_, r) = edge_sim(128, SparsityProfile::paper_default());
        assert!(r.total_cycles > 1000, "cycles {}", r.total_cycles);
        assert!(r.energy.total_pj() > 0.0);
    }

    #[test]
    fn sparsity_improves_throughput_and_energy() {
        // Fig. 19: higher sparsity -> higher throughput, lower energy.
        let (cfg, dense) = edge_sim(128, SparsityProfile::dense());
        let (_, sparse) = edge_sim(128, SparsityProfile::paper_default());
        assert!(
            sparse.total_cycles < dense.total_cycles,
            "sparse {} dense {}",
            sparse.total_cycles,
            dense.total_cycles
        );
        assert!(sparse.energy.total_pj() < dense.energy.total_pj());
        assert!(sparse.throughput_seq_s(&cfg) > dense.throughput_seq_s(&cfg));
    }

    #[test]
    fn staggered_beats_equal_priority_under_softmax_contention() {
        // Fig. 10: staggering helps when heads contend for the special
        // modules.  One softmax module, four heads (bert-mini): equal
        // priority makes all four softmax ops ready simultaneously and
        // serializes them with MAC lanes idle; staggering overlaps head
        // 0's softmax with heads 1-3's MAC work.
        // balance MAC and softmax times: 144 lanes vs one softmax module
        let mut cfg = AcceleratorConfig::edge();
        cfg.pes = 1;
        cfg.mac_lanes_per_pe = 144;
        cfg.softmax_per_pe = 1;
        let model = TransformerConfig::bert_tiny();
        let stag = simulate(&cfg, &model, 128, Policy::Staggered,
                            SparsityProfile::paper_default());
        let eq = simulate(&cfg, &model, 128, Policy::EqualPriority,
                          SparsityProfile::paper_default());
        assert!(
            stag.total_cycles <= eq.total_cycles,
            "staggered {} vs equal {}",
            stag.total_cycles,
            eq.total_cycles
        );
        // and the stagger produces simultaneous MAC+softmax activity
        assert!(stag
            .trace
            .iter()
            .any(|s| s.mac_lanes_active > 0 && s.softmax_active > 0));
    }

    #[test]
    fn rram_outruns_ddr_for_memory_bound_model() {
        // Table IV last row: replacing mono-3D RRAM with LP-DDR3 drops
        // throughput substantially.  BERT-Base weights (~175 MB) exceed
        // the 64 MB weight buffer, so weights stream per batch and the
        // memory technology binds.  (BERT-Tiny at short sequences fits
        // on-chip entirely — memory choice is then irrelevant, which the
        // warm-weights model correctly reflects.)
        let model = TransformerConfig::bert_base();
        let mut server = AcceleratorConfig::server();
        server.batch = 2;
        let fast = simulate(&server, &model, 64, Policy::Staggered,
                            SparsityProfile::paper_default());
        let mut slow_cfg = server.clone();
        slow_cfg.memory = crate::sim::config::MemoryKind::LpDdr3;
        let slow = simulate(&slow_cfg, &model, 64, Policy::Staggered,
                            SparsityProfile::paper_default());
        assert!(
            slow.total_cycles > fast.total_cycles,
            "ddr {} vs rram {}",
            slow.total_cycles,
            fast.total_cycles
        );
    }

    #[test]
    fn fewer_pes_more_compute_stalls() {
        // Fig. 16: stalls rise as PEs shrink.
        let model = TransformerConfig::bert_tiny();
        let mut small = AcceleratorConfig::edge();
        small.pes = 8;
        let mut big = AcceleratorConfig::edge();
        big.pes = 256;
        let rs = simulate(&small, &model, 128, Policy::Staggered,
                          SparsityProfile::paper_default());
        let rb = simulate(&big, &model, 128, Policy::Staggered,
                          SparsityProfile::paper_default());
        assert!(
            rs.stalls.compute_total() > rb.stalls.compute_total(),
            "small {} big {}",
            rs.stalls.compute_total(),
            rb.stalls.compute_total()
        );
        assert!(rs.total_cycles > rb.total_cycles);
    }

    #[test]
    fn lp_mode_cuts_power_and_throughput() {
        // Table III: LP mode ~39% lower power, ~39% lower throughput.
        let model = TransformerConfig::bert_tiny();
        let full_cfg = AcceleratorConfig::edge();
        let lp_cfg = AcceleratorConfig::edge_lp();
        let full = simulate(&full_cfg, &model, 128, Policy::Staggered,
                            SparsityProfile::paper_default());
        let lp = simulate(&lp_cfg, &model, 128, Policy::Staggered,
                          SparsityProfile::paper_default());
        assert!(lp.total_cycles > full.total_cycles);
        assert!(lp.avg_power_w(&lp_cfg) < full.avg_power_w(&full_cfg));
    }

    #[test]
    fn utilization_fractions_bounded() {
        let (_, r) = edge_sim(128, SparsityProfile::paper_default());
        assert!((0.0..=1.0).contains(&r.mac_utilization));
        assert!((0.0..=1.0).contains(&r.softmax_utilization));
        assert!((0.0..=1.0).contains(&r.dma_utilization));
        assert!(!r.trace.is_empty());
    }

    #[test]
    fn result_json_is_complete() {
        let (cfg, r) = edge_sim(64, SparsityProfile::paper_default());
        let j = r.to_json(&cfg);
        assert_eq!(j.get("sparsity_source").unwrap().as_str(), Some("uniform"));
        for key in ["throughput_seq_s", "energy_mj_per_seq", "total_cycles"] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
    }

    fn flat_trace(rho: f64) -> crate::trace::SparsityTrace {
        use crate::trace::{LayerActRho, SparsityTrace, WeightRho};
        let l = LayerActRho {
            input: rho,
            q: rho,
            k: rho,
            v: rho,
            scores: rho,
            context: rho,
            proj_out: rho,
            ffn_in: rho,
            gelu: rho,
            ffn_out: rho,
        };
        SparsityTrace {
            model: "flat".into(),
            backend: "test".into(),
            tau: 0.04,
            examples: 1,
            eval_accuracy: 0.5,
            inherent_act_rho: 0.05,
            weight: WeightRho {
                embedding: 0.0,
                wqkv: 0.5,
                wo: 0.5,
                wf1: 0.5,
                wf2: 0.5,
            },
            layers: vec![l; 2],
        }
    }

    #[test]
    fn trace_source_drives_per_op_profiles() {
        // A sparser measured trace must simulate faster and cheaper than
        // a denser one, and the result must name its source.
        let model = TransformerConfig::bert_tiny();
        let cfg = AcceleratorConfig::edge();
        let lo = simulate_with(
            &cfg,
            &model,
            128,
            Policy::Staggered,
            &SparsitySource::Trace(flat_trace(0.1)),
        );
        let hi = simulate_with(
            &cfg,
            &model,
            128,
            Policy::Staggered,
            &SparsitySource::Trace(flat_trace(0.6)),
        );
        assert_eq!(lo.sparsity_source, "trace");
        assert!(
            hi.total_cycles < lo.total_cycles,
            "sparser trace must be faster: {} vs {}",
            hi.total_cycles,
            lo.total_cycles
        );
        assert!(hi.energy.total_pj() < lo.energy.total_pj());
    }

    #[test]
    fn uniform_source_is_identical_to_legacy_entry_point() {
        // `simulate` and an explicit Uniform source are the same run.
        let model = TransformerConfig::bert_tiny();
        let cfg = AcceleratorConfig::edge();
        let p = SparsityProfile::paper_default();
        let a = simulate(&cfg, &model, 64, Policy::Staggered, p);
        let b = simulate_with(
            &cfg,
            &model,
            64,
            Policy::Staggered,
            &SparsitySource::Uniform(p),
        );
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(a.stalls, b.stalls);
        assert_eq!(a.energy.total_pj().to_bits(), b.energy.total_pj().to_bits());
    }
}
