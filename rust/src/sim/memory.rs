//! Main-memory + DMA model (paper Sec. III-B2): bandwidth-limited,
//! latency-fronted transfers from LP-DDR3 or monolithic-3D RRAM into the
//! on-chip buffers.
//!
//! The DMA controller serializes transfers on the memory channel: a
//! transfer of `bytes` issued at cycle `t` completes at
//! `max(t, channel_free) + latency + ceil(bytes / bytes_per_cycle)`.
//! Energy is charged per byte moved plus a standing idle power.

use super::config::MemoryKind;

/// DMA/main-memory channel state.
#[derive(Debug)]
pub struct Dma {
    pub kind: MemoryKind,
    /// Bytes the channel moves per accelerator cycle.
    pub bytes_per_cycle: f64,
    /// First-word latency in cycles.
    pub latency: u64,
    /// Cycle at which the channel becomes free.
    channel_free: u64,
    /// Totals for reporting.
    pub bytes_transferred: u64,
    pub transfers: u64,
    pub energy_pj: f64,
    /// Cycles the channel spent busy (utilization reporting).
    pub busy_cycles: u64,
}

impl Dma {
    pub fn new(kind: MemoryKind, clock_hz: f64) -> Dma {
        Dma {
            kind,
            bytes_per_cycle: kind.bandwidth_bytes_per_s() / clock_hz,
            latency: kind.latency_cycles(),
            channel_free: 0,
            bytes_transferred: 0,
            transfers: 0,
            energy_pj: 0.0,
            busy_cycles: 0,
        }
    }

    /// Schedule a transfer of `bytes` requested at `now`; returns the
    /// completion cycle.
    pub fn transfer(&mut self, now: u64, bytes: usize) -> u64 {
        let start = now.max(self.channel_free);
        let occupancy = ((bytes as f64 / self.bytes_per_cycle).ceil() as u64).max(1);
        let done = start + self.latency + occupancy;
        // The channel itself is occupied for the streaming portion only;
        // latency overlaps with the next command's setup.
        self.channel_free = start + occupancy;
        self.bytes_transferred += bytes as u64;
        self.transfers += 1;
        self.busy_cycles += occupancy;
        self.energy_pj += bytes as f64 * self.kind.energy_pj_per_byte();
        done
    }

    /// Earliest cycle a new transfer could start streaming.
    pub fn free_at(&self) -> u64 {
        self.channel_free
    }

    /// Channel utilization over a window of `total_cycles`.
    pub fn utilization(&self, total_cycles: u64) -> f64 {
        if total_cycles == 0 {
            return 0.0;
        }
        self.busy_cycles as f64 / total_cycles as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dma(kind: MemoryKind) -> Dma {
        Dma::new(kind, 700.0e6)
    }

    #[test]
    fn rram_is_faster_than_ddr() {
        let mut r = dma(MemoryKind::Mono3dRram);
        let mut d = dma(MemoryKind::LpDdr3);
        let br = r.transfer(0, 1 << 20);
        let bd = d.transfer(0, 1 << 20);
        assert!(br < bd, "rram {br} vs ddr {bd}");
    }

    #[test]
    fn transfers_serialize_on_the_channel() {
        let mut d = dma(MemoryKind::LpDdr3);
        let t1 = d.transfer(0, 36_571); // ~1000 cycles at 36.57 B/cyc
        let t2 = d.transfer(0, 36_571);
        assert!(t2 > t1);
        assert!(t2 >= 2000, "t2 {t2}");
    }

    #[test]
    fn latency_fronts_each_transfer() {
        let mut d = dma(MemoryKind::LpDdr3);
        let done = d.transfer(100, 1);
        assert_eq!(done, 100 + d.latency + 1);
    }

    #[test]
    fn energy_is_per_byte() {
        let mut d = dma(MemoryKind::LpDdr3);
        d.transfer(0, 1000);
        let e1 = d.energy_pj;
        d.transfer(0, 1000);
        assert!((d.energy_pj - 2.0 * e1).abs() < 1e-9);
    }

    #[test]
    fn utilization_bounded() {
        let mut d = dma(MemoryKind::Mono3dRram);
        let done = d.transfer(0, 1 << 22);
        assert!(d.utilization(done) <= 1.0);
        assert!(d.utilization(done) > 0.5);
    }
}
