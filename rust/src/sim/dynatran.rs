//! The DynaTran module (paper Sec. III-A, III-B5): single-cycle
//! magnitude-threshold pruning with a transfer-function-driven threshold
//! calculator.
//!
//! Hardware behaviour: `b*x*y` parallel comparators zero every element
//! with `|m| < tau` and set the corresponding mask bit, all in one clock
//! cycle.  `tau` itself is *not* computed — it is looked up from a
//! pre-profiled sparsity transfer function rho(tau) stored in the
//! module's internal register, given a user-level target (desired
//! sparsity or accuracy).

/// Prune a dense tile in place and return the mask (`true` = pruned).
/// This is the functional twin of the Pallas `dynatran_prune` kernel
/// (python/compile/kernels/dynatran.py) and is tested against the same
/// semantics.
pub fn prune(values: &mut [f32], tau: f32) -> Vec<bool> {
    let mut mask = Vec::with_capacity(values.len());
    for v in values.iter_mut() {
        if v.abs() < tau {
            *v = 0.0;
            mask.push(true);
        } else {
            mask.push(false);
        }
    }
    mask
}

/// Non-destructive variant.
pub fn pruned(values: &[f32], tau: f32) -> (Vec<f32>, Vec<bool>) {
    let mut out = values.to_vec();
    let mask = prune(&mut out, tau);
    (out, mask)
}

/// Sparsity rho of a slice.
pub fn sparsity(values: &[f32]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    values.iter().filter(|&&v| v == 0.0).count() as f64 / values.len() as f64
}

/// The top-k baseline (SpAtten): keep the `k` largest |values| per row of
/// an `rows x cols` matrix, zero the rest.  O(N log N) per row here
/// (the hardware's sorting engine is what gives it the paper's O(N^3)
/// full-matrix complexity); compare with `prune`'s single pass — this
/// asymmetry is exactly the Fig. 13 experiment.
pub fn topk_prune_rows(values: &mut [f32], cols: usize, k: usize) {
    assert!(cols > 0 && values.len() % cols == 0);
    if k >= cols {
        return;
    }
    let mut mags: Vec<f32> = Vec::with_capacity(cols);
    for row in values.chunks_mut(cols) {
        mags.clear();
        mags.extend(row.iter().map(|v| v.abs()));
        // threshold = k-th largest magnitude
        mags.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
        let thr = mags[k - 1];
        let mut kept = 0usize;
        for v in row.iter_mut() {
            // keep ties up to exactly k survivors (hardware keeps first-k)
            if v.abs() > thr || (v.abs() == thr && kept < k) {
                if v.abs() >= thr {
                    kept += 1;
                }
            } else {
                *v = 0.0;
            }
        }
    }
}

/// A profiled rho(tau) transfer function: monotone samples of threshold
/// -> resulting sparsity for one (model, task) pair, as stored in the
/// DynaTran module's internal register (Sec. III-B5 "threshold
/// calculator").
#[derive(Clone, Debug)]
pub struct TransferFunction {
    /// (tau, rho) samples sorted by tau, rho non-decreasing.
    pub samples: Vec<(f32, f64)>,
    pub label: String,
}

impl TransferFunction {
    /// Profile a transfer function from representative activation data:
    /// evaluate rho at `steps` thresholds in `[0, tau_max]`.
    pub fn profile(label: &str, data: &[f32], tau_max: f32, steps: usize) -> Self {
        assert!(steps >= 2);
        let mut mags: Vec<f32> = data.iter().map(|v| v.abs()).collect();
        mags.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        let n = mags.len().max(1) as f64;
        let samples = (0..steps)
            .map(|s| {
                let tau = tau_max * s as f32 / (steps - 1) as f32;
                // rho = fraction of |x| < tau, via binary search
                let idx = mags.partition_point(|&m| m < tau);
                (tau, idx as f64 / n)
            })
            .collect();
        TransferFunction { samples, label: label.to_string() }
    }

    /// rho(tau) by linear interpolation.
    pub fn sparsity_at(&self, tau: f32) -> f64 {
        let s = &self.samples;
        if s.is_empty() {
            return 0.0;
        }
        if tau <= s[0].0 {
            return s[0].1;
        }
        if tau >= s[s.len() - 1].0 {
            return s[s.len() - 1].1;
        }
        let i = s.partition_point(|&(t, _)| t < tau);
        let (t0, r0) = s[i - 1];
        let (t1, r1) = s[i];
        if t1 == t0 {
            return r1;
        }
        r0 + (r1 - r0) * ((tau - t0) / (t1 - t0)) as f64
    }

    /// The threshold-calculator look-up (Fig. 7): smallest tau achieving
    /// the desired sparsity `rho` (clamped to the profiled range).  This
    /// is the "simple look-up operation" that keeps DynaTran at one
    /// cycle.
    pub fn tau_for_sparsity(&self, rho: f64) -> f32 {
        let s = &self.samples;
        if s.is_empty() {
            return 0.0;
        }
        if rho <= s[0].1 {
            return s[0].0;
        }
        if rho >= s[s.len() - 1].1 {
            return s[s.len() - 1].0;
        }
        let i = s.partition_point(|&(_, r)| r < rho);
        let (t0, r0) = s[i - 1];
        let (t1, r1) = s[i];
        if (r1 - r0).abs() < f64::EPSILON {
            return t1;
        }
        t0 + (t1 - t0) * ((rho - r0) / (r1 - r0)) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn prune_matches_definition() {
        let mut v = vec![0.5, -0.05, 0.2, -0.9, 0.0];
        let mask = prune(&mut v, 0.25);
        assert_eq!(v, vec![0.5, 0.0, 0.0, -0.9, 0.0]);
        assert_eq!(mask, vec![false, true, true, false, true]);
    }

    #[test]
    fn prune_boundary_keeps_equal_magnitude() {
        // |m| >= tau is kept (paper's definition uses >=).
        let mut v = vec![0.25, -0.25];
        let mask = prune(&mut v, 0.25);
        assert_eq!(mask, vec![false, false]);
    }

    #[test]
    fn sparsity_monotone_in_tau_property() {
        prop::check(41, 100, |g| {
            let n = g.usize_in(1, 400);
            let data = g.normal_vec(n, 1.0);
            let t1 = g.f32_in(0.0, 2.0);
            let t2 = g.f32_in(0.0, 2.0);
            let (lo, hi) = if t1 < t2 { (t1, t2) } else { (t2, t1) };
            let (a, _) = pruned(&data, lo);
            let (b, _) = pruned(&data, hi);
            assert!(sparsity(&b) >= sparsity(&a));
        });
    }

    #[test]
    fn topk_keeps_exactly_k_per_row() {
        prop::check(42, 100, |g| {
            let rows = g.usize_in(1, 8);
            let cols = g.usize_in(2, 64);
            let k = g.usize_in(1, cols);
            let mut data = g.normal_vec(rows * cols, 1.0);
            topk_prune_rows(&mut data, cols, k);
            for row in data.chunks(cols) {
                let nnz = row.iter().filter(|&&v| v != 0.0).count();
                assert!(nnz <= k, "nnz {nnz} > k {k}");
                // standard normals: ties have measure zero, so == k
                assert!(nnz == k.min(cols), "nnz {nnz} k {k}");
            }
        });
    }

    #[test]
    fn topk_keeps_the_largest() {
        let mut v = vec![0.1, -0.9, 0.5, 0.2];
        topk_prune_rows(&mut v, 4, 2);
        assert_eq!(v, vec![0.0, -0.9, 0.5, 0.0]);
    }

    #[test]
    fn transfer_function_inverts_itself() {
        let mut g = crate::util::rng::Rng::new(7);
        let data = g.normal_vec(20_000, 0.5);
        let tf = TransferFunction::profile("test", &data, 1.0, 64);
        for &target in &[0.1, 0.3, 0.5, 0.7, 0.9] {
            let tau = tf.tau_for_sparsity(target);
            let achieved = tf.sparsity_at(tau);
            assert!(
                (achieved - target).abs() < 0.02,
                "target {target} achieved {achieved} (tau {tau})"
            );
        }
    }

    #[test]
    fn transfer_function_matches_actual_pruning() {
        let mut g = crate::util::rng::Rng::new(8);
        let data = g.normal_vec(50_000, 1.0);
        let tf = TransferFunction::profile("gauss", &data, 2.0, 128);
        let tau = tf.tau_for_sparsity(0.6);
        let (pruned_vals, _) = pruned(&data, tau);
        let rho = sparsity(&pruned_vals);
        assert!((rho - 0.6).abs() < 0.02, "rho {rho}");
    }

    #[test]
    fn transfer_function_is_monotone() {
        let mut g = crate::util::rng::Rng::new(9);
        let data = g.normal_vec(10_000, 1.0);
        let tf = TransferFunction::profile("gauss", &data, 2.0, 32);
        for w in tf.samples.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
    }

    #[test]
    fn transfer_function_roundtrip_is_monotone_property() {
        // Property over random profiles: (a) both lookup directions are
        // monotone non-decreasing, and (b) the threshold-calculator
        // round-trip rho -> tau_for_sparsity -> sparsity_at lands back on
        // rho wherever rho lies inside the profiled range.  These are
        // structural guarantees of the piecewise-linear table, so they
        // must hold for *any* activation distribution the profiler sees.
        prop::check(44, 60, |g| {
            let n = g.usize_in(500, 4000);
            let std = g.f32_in(0.2, 2.0);
            let data = g.normal_vec(n, std);
            let tau_max = g.f32_in(0.5, 4.0);
            let tf = TransferFunction::profile("prop", &data, tau_max, 48);

            // (a) monotone in both directions
            let mut last_rho = -1.0f64;
            let mut last_tau = -1.0f32;
            for i in 0..=20 {
                let tau = tau_max * i as f32 / 20.0;
                let rho = tf.sparsity_at(tau);
                assert!(rho >= last_rho - 1e-12, "sparsity_at not monotone");
                last_rho = rho;
                let target = i as f64 / 20.0;
                let t = tf.tau_for_sparsity(target);
                assert!(t >= last_tau - 1e-6, "tau_for_sparsity not monotone");
                last_tau = t;
            }

            // (b) round-trip identity inside the profiled rho range
            let lo = tf.samples.first().unwrap().1;
            let hi = tf.samples.last().unwrap().1;
            for i in 0..=10 {
                let rho = lo + (hi - lo) * i as f64 / 10.0;
                let tau = tf.tau_for_sparsity(rho);
                let back = tf.sparsity_at(tau);
                // interpolation is exact on the table except where a
                // flat segment makes the inverse a set; allow the table
                // quantization as slack.
                assert!(
                    (back - rho).abs() < 0.08,
                    "roundtrip rho {rho} -> tau {tau} -> {back}"
                );
            }
        });
    }
}
