//! Dataflows: the 24 loop-unrolling orders of the tiled matmul loop nest
//! (paper Sec. III-B1 and Fig. 15).
//!
//! A dataflow is a permutation of the four tile loops [b, i, j, k].  The
//! order in which tile pairs are streamed to MAC lanes determines how
//! often a lane can *reuse* the weight/activation tile already in its
//! local registers instead of re-reading it from the buffers — reuse
//! instances convert directly into saved buffer-read energy (Fig. 15's
//! bars), while latency is unchanged because transfers are hidden by the
//! control flow (Sec. V-B).

use super::tiling::TileGrid;
use std::fmt;

/// One of the four tile-loop axes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Axis {
    B,
    I,
    J,
    K,
}

/// A loop order (outermost first).
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Dataflow(pub [Axis; 4]);

impl Dataflow {
    /// The paper's selected dataflow [b, i, j, k] (Sec. IV-B).
    pub const BIJK: Dataflow = Dataflow([Axis::B, Axis::I, Axis::J, Axis::K]);

    /// All 24 permutations, in lexicographic order of their names.
    pub fn all() -> Vec<Dataflow> {
        let axes = [Axis::B, Axis::I, Axis::J, Axis::K];
        let mut out = Vec::with_capacity(24);
        for &a in &axes {
            for &b in &axes {
                if b == a {
                    continue;
                }
                for &c in &axes {
                    if c == a || c == b {
                        continue;
                    }
                    let d = *axes
                        .iter()
                        .find(|&&x| x != a && x != b && x != c)
                        .unwrap();
                    out.push(Dataflow([a, b, c, d]));
                }
            }
        }
        out
    }

    /// Compact 4-letter name ("bijk") — the inverse of [`Dataflow::parse`],
    /// used by the DSE report and the `dse --dataflows` CLI flag
    /// (`Display` prints the bracketed loop-nest form instead).
    pub fn compact_name(&self) -> String {
        self.0
            .iter()
            .map(|a| match a {
                Axis::B => 'b',
                Axis::I => 'i',
                Axis::J => 'j',
                Axis::K => 'k',
            })
            .collect()
    }

    /// Parse "bijk"-style names.
    pub fn parse(s: &str) -> Option<Dataflow> {
        let mut axes = [Axis::B; 4];
        if s.len() != 4 {
            return None;
        }
        for (i, c) in s.chars().enumerate() {
            axes[i] = match c.to_ascii_lowercase() {
                'b' => Axis::B,
                'i' => Axis::I,
                'j' => Axis::J,
                'k' => Axis::K,
                _ => return None,
            };
        }
        let df = Dataflow(axes);
        // must be a permutation
        let mut seen = [false; 4];
        for a in df.0 {
            let idx = a as usize;
            if seen[idx] {
                return None;
            }
            seen[idx] = true;
        }
        Some(df)
    }

    /// Extent of each axis position for a grid.
    fn extents(&self, g: &TileGrid) -> [usize; 4] {
        self.0.map(|a| match a {
            Axis::B => g.nb,
            Axis::I => g.ni,
            Axis::J => g.nj,
            Axis::K => g.nk,
        })
    }

    /// Stream the tile coordinates `(b, i, j, k)` of grid `g` in this
    /// dataflow's order, calling `f` for each.
    pub fn for_each_tile<F: FnMut(usize, usize, usize, usize)>(
        &self,
        g: &TileGrid,
        mut f: F,
    ) {
        let ext = self.extents(g);
        let mut idx = [0usize; 4];
        loop {
            let mut coord = [0usize; 4]; // b, i, j, k
            for pos in 0..4 {
                coord[self.0[pos] as usize] = idx[pos];
            }
            f(coord[0], coord[1], coord[2], coord[3]);
            // odometer increment, innermost (pos 3) fastest
            let mut pos = 3usize;
            loop {
                idx[pos] += 1;
                if idx[pos] < ext[pos] {
                    break;
                }
                idx[pos] = 0;
                if pos == 0 {
                    return;
                }
                pos -= 1;
            }
        }
    }
}

impl fmt::Display for Dataflow {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[")?;
        for (i, a) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(
                f,
                "{}",
                match a {
                    Axis::B => "b",
                    Axis::I => "i",
                    Axis::J => "j",
                    Axis::K => "k",
                }
            )?;
        }
        write!(f, "]")
    }
}

impl fmt::Debug for Dataflow {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

/// Result of replaying one matmul's tile stream over a bank of MAC lanes.
#[derive(Clone, Debug, PartialEq)]
pub struct ReuseReport {
    pub dataflow_name: String,
    /// Tiles whose weight operand was already in the assigned lane's
    /// register (no buffer read needed).
    pub weight_reuse: usize,
    /// Same for the activation operand.
    pub act_reuse: usize,
    /// Total tile-pair issues.
    pub tiles: usize,
    /// Buffer reads actually performed (weight tiles + activation tiles).
    pub buffer_reads: usize,
    /// Dynamic energy in pJ: buffer reads + MAC work (see `tech`).
    pub dynamic_energy_pj: f64,
}

impl ReuseReport {
    /// Total reuse instances (the dashed line of Fig. 15).
    pub fn reuse_instances(&self) -> usize {
        self.weight_reuse + self.act_reuse
    }
}

/// Replay the tile stream of `grid` under `df` over `lanes` MAC lanes
/// with one weight-tile and one activation-tile register each (the
/// Fig. 15 experiment: W x A on four MAC lanes).
///
/// Tiles are issued round-robin in stream order; a lane reuses an operand
/// if the incoming tile coordinate matches what its register holds.
pub fn replay(
    df: Dataflow,
    grid: &TileGrid,
    lanes: usize,
    buffer_read_pj_per_elem: f64,
    mac_pj: f64,
) -> ReuseReport {
    assert!(lanes > 0);
    // (b, i, k) identifies a weight tile; (b, k, j) an activation tile.
    let mut w_reg: Vec<Option<(usize, usize, usize)>> = vec![None; lanes];
    let mut a_reg: Vec<Option<(usize, usize, usize)>> = vec![None; lanes];
    let mut weight_reuse = 0usize;
    let mut act_reuse = 0usize;
    let mut tiles = 0usize;
    let mut buffer_reads = 0usize;
    let mut energy = 0.0f64;
    let mut lane = 0usize;
    df.for_each_tile(grid, |b, i, j, k| {
        let w_id = (b, i, k);
        let a_id = (b, k, j);
        if w_reg[lane] == Some(w_id) {
            weight_reuse += 1;
        } else {
            w_reg[lane] = Some(w_id);
            buffer_reads += 1;
            energy += grid.w_tile_elems as f64 * buffer_read_pj_per_elem;
        }
        if a_reg[lane] == Some(a_id) {
            act_reuse += 1;
        } else {
            a_reg[lane] = Some(a_id);
            buffer_reads += 1;
            energy += grid.a_tile_elems as f64 * buffer_read_pj_per_elem;
        }
        energy += grid.macs_per_tile as f64 * mac_pj;
        tiles += 1;
        lane = (lane + 1) % lanes;
    });
    ReuseReport {
        dataflow_name: df.to_string(),
        weight_reuse,
        act_reuse,
        tiles,
        buffer_reads,
        dynamic_energy_pj: energy,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::tiling::tile_matmul;
    use crate::util::prop;

    #[test]
    fn compact_name_round_trips_all_24() {
        for df in Dataflow::all() {
            let name = df.compact_name();
            assert_eq!(Dataflow::parse(&name), Some(df), "round-trip of {name}");
        }
        assert_eq!(Dataflow::BIJK.compact_name(), "bijk");
    }

    #[test]
    fn there_are_24_dataflows() {
        let all = Dataflow::all();
        assert_eq!(all.len(), 24);
        let unique: std::collections::HashSet<_> =
            all.iter().map(|d| d.to_string()).collect();
        assert_eq!(unique.len(), 24);
    }

    #[test]
    fn parse_roundtrip() {
        for df in Dataflow::all() {
            let name: String = df
                .to_string()
                .chars()
                .filter(|c| c.is_alphabetic())
                .collect();
            assert_eq!(Dataflow::parse(&name), Some(df));
        }
        assert_eq!(Dataflow::parse("bbjk"), None);
        assert_eq!(Dataflow::parse("abc"), None);
    }

    #[test]
    fn every_dataflow_visits_every_tile_once() {
        let grid = tile_matmul(64, 48, 32, 1, 16, 16, 16);
        for df in Dataflow::all() {
            let mut seen = std::collections::HashSet::new();
            df.for_each_tile(&grid, |b, i, j, k| {
                assert!(seen.insert((b, i, j, k)));
            });
            assert_eq!(seen.len(), grid.total_tiles());
        }
    }

    #[test]
    fn every_dataflow_covers_random_op_dims_exactly_once() {
        // Tiling/dataflow contract for the whole op language: for a
        // random `OpDims` (matmul, elementwise or load) under random
        // tile sizes, every one of the 24 loop orders streams each tile
        // of the grid exactly once, within the grid's extents.
        use crate::model::ops::OpDims;
        use crate::sim::tiling::tile_op;
        prop::check(22, 30, |g| {
            let dims = match g.usize_in(0, 2) {
                0 => OpDims::MatMul {
                    m: g.usize_in(1, 40),
                    k: g.usize_in(1, 40),
                    n: g.usize_in(1, 40),
                },
                1 => OpDims::Elem { m: g.usize_in(1, 60), n: g.usize_in(1, 60) },
                _ => OpDims::Load { elems: g.usize_in(1, 4000) },
            };
            let ts = [4usize, 8, 16];
            let grid =
                tile_op(&dims, 1, *g.pick(&ts), *g.pick(&ts), *g.pick(&ts));
            for df in Dataflow::all() {
                let mut seen = std::collections::HashSet::new();
                df.for_each_tile(&grid, |b, i, j, k| {
                    assert!(b < grid.nb && i < grid.ni && j < grid.nj && k < grid.nk,
                            "{df} out of extent: ({b},{i},{j},{k}) for {dims:?}");
                    assert!(seen.insert((b, i, j, k)),
                            "{df} revisited ({b},{i},{j},{k}) for {dims:?}");
                });
                assert_eq!(seen.len(), grid.total_tiles(), "{df} for {dims:?}");
            }
        });
    }

    #[test]
    fn bijk_with_k_inner_reuses_nothing_but_symmetry_holds() {
        // With one lane, [b,i,j,k] changes k fastest -> both operands
        // change every step (k in both ids) => zero reuse; [b,i,k,j]
        // holds (b,i,k) fixed while j varies => weight reuse.
        let grid = tile_matmul(64, 64, 64, 1, 16, 16, 16);
        let r_bijk = replay(Dataflow::parse("bijk").unwrap(), &grid, 1, 1.0, 0.0);
        let r_bikj = replay(Dataflow::parse("bikj").unwrap(), &grid, 1, 1.0, 0.0);
        assert_eq!(r_bijk.reuse_instances(), 0);
        assert!(r_bikj.weight_reuse > 0);
        assert!(r_bikj.dynamic_energy_pj < r_bijk.dynamic_energy_pj);
    }

    #[test]
    fn four_lanes_match_paper_reuse_structure() {
        // Fig. 15 setup: four MAC lanes.  With 4 lanes and k innermost of
        // extent 4, each lane sees a fixed k — so when j advances the
        // weight tile (b,i,k) is unchanged per-lane: [b,i,j,k] reuses
        // weights, which is why the paper picks it.
        let grid = tile_matmul(64, 64, 64, 1, 16, 16, 16);
        let r = replay(Dataflow::BIJK, &grid, 4, 1.0, 0.0);
        assert!(r.weight_reuse > 0, "{r:?}");
    }

    #[test]
    fn reuse_plus_reads_equals_two_per_tile() {
        prop::check(21, 50, |g| {
            let grid = tile_matmul(
                g.usize_in(1, 5) * 16,
                g.usize_in(1, 5) * 16,
                g.usize_in(1, 5) * 16,
                1,
                16,
                16,
                16,
            );
            let lanes = *g.pick(&[1usize, 2, 4, 8]);
            let df = *g.pick(&Dataflow::all());
            let r = replay(df, &grid, lanes, 1.0, 0.1);
            assert_eq!(
                r.reuse_instances() + r.buffer_reads,
                2 * r.tiles,
                "{df} lanes={lanes}"
            );
            assert_eq!(r.tiles, grid.total_tiles());
        });
    }

    #[test]
    fn symmetric_dataflows_have_equal_energy() {
        // Fig. 15: [b,i,j,k] and [k,i,j,b] tie — with batch extent 1 the b
        // and k positions are interchangeable in reuse terms when the
        // other axes keep their relative order.
        let grid = tile_matmul(64, 64, 64, 1, 16, 16, 16);
        let a = replay(Dataflow::parse("bijk").unwrap(), &grid, 4, 1.0, 0.1);
        let b = replay(Dataflow::parse("kijb").unwrap(), &grid, 4, 1.0, 0.1);
        // b extent is 1, so [k,i,j,b] streams identically to [k,i,j];
        // both orders keep (i, j) outer — equal reuse by symmetry of W/A.
        assert_eq!(
            a.reuse_instances() > 0,
            b.reuse_instances() > 0
        );
    }
}
