//! On-chip buffers (activation, weight, mask) with occupancy tracking,
//! reference-counted residency, and eviction (paper Sec. III-B2/8).
//!
//! The control block loads a matrix's tiles into a buffer before compute
//! ops consume them; data stays resident until its last consumer
//! finishes, then becomes evictable.  A *memory stall* occurs when a load
//! wants space and nothing is evictable (Sec. III-B8); the engine counts
//! those via [`Buffer::reserve`] failures.

use std::collections::HashMap;

/// Identifies a resident allocation (one matrix / tensor).
pub type AllocId = usize;

/// One on-chip buffer.
#[derive(Debug)]
pub struct Buffer {
    pub name: &'static str,
    pub capacity_bytes: usize,
    used_bytes: usize,
    /// Live allocations: id -> (bytes, consumers remaining, evictable).
    allocs: HashMap<AllocId, Alloc>,
    /// Peak occupancy observed (for Fig. 17(c)).
    pub peak_bytes: usize,
    /// Total bytes ever written / read (for energy accounting).
    pub bytes_written: u64,
    pub bytes_read: u64,
    /// Eviction events (buffer-usage "drops" in Fig. 17(c)).
    pub evictions: u64,
}

#[derive(Debug)]
struct Alloc {
    bytes: usize,
    consumers: usize,
    evictable: bool,
}

impl Buffer {
    pub fn new(name: &'static str, capacity_bytes: usize) -> Buffer {
        Buffer {
            name,
            capacity_bytes,
            used_bytes: 0,
            allocs: HashMap::new(),
            peak_bytes: 0,
            bytes_written: 0,
            bytes_read: 0,
            evictions: 0,
        }
    }

    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    pub fn free_bytes(&self) -> usize {
        self.capacity_bytes - self.used_bytes
    }

    pub fn occupancy(&self) -> f64 {
        self.used_bytes as f64 / self.capacity_bytes as f64
    }

    /// Try to reserve `bytes` for allocation `id` with `consumers`
    /// downstream readers.  Idempotent: re-reserving a live id succeeds
    /// without double-counting (ops retry reservations after stalls).
    /// Evicts evictable allocations (LRU-free order is immaterial at
    /// this granularity) until it fits.  Returns false — a memory stall —
    /// if even after eviction there is no room.
    pub fn reserve(&mut self, id: AllocId, bytes: usize, consumers: usize) -> bool {
        if self.allocs.contains_key(&id) {
            return true;
        }
        if bytes > self.capacity_bytes {
            return false; // cannot ever fit: caller splits or stalls forever
        }
        while self.free_bytes() < bytes {
            // evict any evictable allocation
            let victim = self
                .allocs
                .iter()
                .find(|(_, a)| a.evictable)
                .map(|(&k, _)| k);
            match victim {
                Some(k) => {
                    let a = self.allocs.remove(&k).unwrap();
                    self.used_bytes -= a.bytes;
                    self.evictions += 1;
                }
                None => return false,
            }
        }
        self.allocs.insert(
            id,
            Alloc { bytes, consumers, evictable: consumers == 0 },
        );
        self.used_bytes += bytes;
        self.peak_bytes = self.peak_bytes.max(self.used_bytes);
        self.bytes_written += bytes as u64;
        true
    }

    /// Force-spill a live allocation to make room (the control block's
    /// admission-control fallback when dependency chains would otherwise
    /// circularly wait on buffer space).  Picks the *highest-id* live
    /// allocation not in `exclude` — the most recently scheduled
    /// producer, i.e. the data needed furthest in the future.  Returns
    /// `(id, bytes)` of the spilled allocation.
    pub fn spill_victim(&mut self, exclude: &[AllocId]) -> Option<(AllocId, usize)> {
        let victim = self
            .allocs
            .keys()
            .copied()
            .filter(|k| !exclude.contains(k))
            .max()?;
        let a = self.allocs.remove(&victim).unwrap();
        self.used_bytes -= a.bytes;
        self.evictions += 1;
        Some((victim, a.bytes))
    }

    /// Whether `id` is resident.
    pub fn resident(&self, id: AllocId) -> bool {
        self.allocs.contains_key(&id)
    }

    /// Record a read of `bytes` from allocation `id` (energy accounting).
    pub fn read(&mut self, id: AllocId, bytes: usize) {
        debug_assert!(self.resident(id), "read of non-resident alloc {id}");
        self.bytes_read += bytes as u64;
    }

    /// One consumer of `id` finished; when the count hits zero the data
    /// becomes evictable (it stays resident until space is needed, which
    /// produces the sudden usage drops of Fig. 17(c)).
    pub fn release(&mut self, id: AllocId) {
        if let Some(a) = self.allocs.get_mut(&id) {
            debug_assert!(a.consumers > 0, "release underflow on {id}");
            a.consumers -= 1;
            if a.consumers == 0 {
                a.evictable = true;
            }
        }
    }

    /// Conservation check: used == sum of live allocation sizes.
    pub fn check_conservation(&self) -> bool {
        self.used_bytes == self.allocs.values().map(|a| a.bytes).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn reserve_and_evict() {
        let mut b = Buffer::new("act", 1000);
        assert!(b.reserve(1, 600, 1));
        assert!(!b.reserve(2, 600, 1)); // no space, nothing evictable
        b.release(1); // now evictable
        assert!(b.reserve(2, 600, 1)); // evicts 1
        assert!(!b.resident(1));
        assert!(b.resident(2));
        assert_eq!(b.evictions, 1);
    }

    #[test]
    fn oversized_request_fails() {
        let mut b = Buffer::new("w", 100);
        assert!(!b.reserve(1, 101, 0));
    }

    #[test]
    fn occupancy_tracks_peak() {
        let mut b = Buffer::new("act", 1000);
        b.reserve(1, 300, 1);
        b.reserve(2, 500, 1);
        assert_eq!(b.peak_bytes, 800);
        b.release(1);
        b.release(2);
        assert!(b.reserve(3, 900, 0)); // evicts both
        assert_eq!(b.peak_bytes, 900);
    }

    #[test]
    fn zero_consumer_allocs_are_immediately_evictable() {
        let mut b = Buffer::new("mask", 100);
        assert!(b.reserve(1, 80, 0));
        assert!(b.reserve(2, 80, 1)); // evicts 1 without a release
    }

    #[test]
    fn conservation_property() {
        prop::check(51, 100, |g| {
            let cap = g.usize_in(100, 10_000);
            let mut b = Buffer::new("t", cap);
            let mut live: Vec<AllocId> = Vec::new();
            let mut next_id = 0;
            for _ in 0..g.usize_in(1, 60) {
                if g.bool() || live.is_empty() {
                    let bytes = g.usize_in(1, cap / 2);
                    let consumers = g.usize_in(0, 3);
                    if b.reserve(next_id, bytes, consumers) {
                        live.push(next_id);
                    }
                    next_id += 1;
                } else {
                    let idx = g.usize_in(0, live.len() - 1);
                    b.release(live[idx]);
                }
                assert!(b.check_conservation());
                assert!(b.used_bytes() <= cap);
            }
        });
    }
}
