//! Control-block scheduling state (paper Sec. III-B8): per-op tile
//! bookkeeping, dependency tracking, and the staggered-head issue policy
//! of Fig. 10.
//!
//! The engine owns the clock and resources; this module owns *which* op
//! should get the next free module.  Two policies are modeled:
//!
//! * [`Policy::Staggered`] (the paper's choice): heads are prioritized
//!   depth-first in program order, so head 0's MAC work drains first and
//!   its softmax overlaps head 1's MAC work — simultaneous MAC-lane and
//!   softmax-module utilization (Fig. 10(b)).
//! * [`Policy::EqualPriority`]: round-robin across heads (Fig. 10(a)),
//!   kept as the ablation baseline.

use crate::model::ops::{OpGraph, OpKind};
use crate::sim::engine::SparsityProfile;
use crate::sim::tiling::TileGrid;

/// Scheduling policy for ready compute ops.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    Staggered,
    EqualPriority,
}

/// Lifecycle of one op in the schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpState {
    /// Waiting on dependencies.
    Waiting,
    /// Dependencies met; tiles may issue (subject to operands/space).
    Ready,
    /// Blocked on buffer space for its output (memory stall source).
    BlockedSpace,
    /// All tiles issued, some still in flight.
    Draining,
    Done,
}

/// Per-op scheduling record.
#[derive(Clone, Debug)]
pub struct OpSched {
    pub state: OpState,
    pub deps_remaining: usize,
    /// Tile-work units remaining to issue.
    pub tiles_remaining: usize,
    pub tiles_inflight: usize,
    pub grid: TileGrid,
    /// Sparsity operating point resolved for this op — a per-op value
    /// from a measured `trace::SparsityTrace`, or one shared uniform
    /// profile (the legacy 3-scalar fallback).  The engine's cost model
    /// reads it per tiled op.
    pub profile: SparsityProfile,
    /// Successor op ids (reverse edges).
    pub succs: Vec<usize>,
    /// Cycle at which the op became ready / finished (reporting).
    pub ready_at: u64,
    pub done_at: u64,
}

/// Schedule bookkeeping over a whole graph.
#[derive(Debug)]
pub struct Schedule {
    pub ops: Vec<OpSched>,
    pub policy: Policy,
    /// Ready compute ops by kind (indices into `ops`), kept sorted per
    /// the policy each time ops are inserted.
    ready_mac: Vec<usize>,
    ready_softmax: Vec<usize>,
    ready_layernorm: Vec<usize>,
    ready_load: Vec<usize>,
    /// Round-robin cursor for EqualPriority.
    rr_cursor: usize,
    pub done_count: usize,
}

impl Schedule {
    pub fn new(
        graph: &OpGraph,
        policy: Policy,
        grids: Vec<TileGrid>,
        profiles: Vec<SparsityProfile>,
    ) -> Schedule {
        assert_eq!(graph.nodes.len(), grids.len());
        assert_eq!(graph.nodes.len(), profiles.len());
        let n = graph.nodes.len();
        let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
        for node in &graph.nodes {
            for &d in &node.deps {
                succs[d].push(node.id);
            }
        }
        let mut ops = Vec::with_capacity(n);
        for ((node, grid), profile) in graph.nodes.iter().zip(grids).zip(profiles) {
            ops.push(OpSched {
                state: if node.deps.is_empty() {
                    OpState::Ready
                } else {
                    OpState::Waiting
                },
                deps_remaining: node.deps.len(),
                tiles_remaining: grid.total_tiles(),
                tiles_inflight: 0,
                grid,
                profile,
                succs: std::mem::take(&mut succs[node.id]),
                ready_at: 0,
                done_at: 0,
            });
        }
        let mut s = Schedule {
            ops,
            policy,
            ready_mac: Vec::new(),
            ready_softmax: Vec::new(),
            ready_layernorm: Vec::new(),
            ready_load: Vec::new(),
            rr_cursor: 0,
            done_count: 0,
        };
        for id in 0..n {
            if s.ops[id].state == OpState::Ready {
                s.push_ready(graph, id);
            }
        }
        s
    }

    fn queue_for(&mut self, kind: OpKind) -> &mut Vec<usize> {
        match kind {
            OpKind::MatMul | OpKind::Add => &mut self.ready_mac,
            OpKind::Softmax => &mut self.ready_softmax,
            OpKind::LayerNorm => &mut self.ready_layernorm,
            OpKind::MemLoad => &mut self.ready_load,
        }
    }

    fn push_ready(&mut self, graph: &OpGraph, id: usize) {
        let kind = graph.nodes[id].kind;
        let policy = self.policy;
        let q = self.queue_for(kind);
        q.push(id);
        // Queues stay sorted by id (program order); the *policy* acts at
        // pick time: Staggered drains the head-of-queue op (head-major
        // depth-first, Fig. 10(b)); EqualPriority round-robins picks
        // across all ready ops so heads advance in lock-step
        // (Fig. 10(a)).  §Perf: sorted-position insert (O(log n) search)
        // instead of a full re-sort per readiness event.
        let _ = policy;
        let last = q.pop().unwrap();
        let pos = q.partition_point(|&x| x < last);
        q.insert(pos, last);
    }

    /// Next ready op of `kind` with issuable tiles, per policy.
    /// EqualPriority advances its round-robin cursor on every pick so
    /// consecutive issues spread across all ready ops.
    pub fn peek_ready(&mut self, kind: OpKind) -> Option<usize> {
        let policy = self.policy;
        let cursor = self.rr_cursor;
        let q = self.queue_for(kind);
        if q.is_empty() {
            return None;
        }
        match policy {
            Policy::Staggered => Some(q[0]),
            Policy::EqualPriority => {
                let pick = q[cursor % q.len()];
                self.rr_cursor = self.rr_cursor.wrapping_add(1);
                Some(pick)
            }
        }
    }

    /// Account `n` tiles issued on op `id`; removes it from the ready
    /// queue when fully issued.
    pub fn issue_tiles(&mut self, graph: &OpGraph, id: usize, n: usize) {
        let op = &mut self.ops[id];
        debug_assert!(matches!(op.state, OpState::Ready));
        debug_assert!(n <= op.tiles_remaining);
        op.tiles_remaining -= n;
        op.tiles_inflight += n;
        if op.tiles_remaining == 0 {
            op.state = OpState::Draining;
            let kind = graph.nodes[id].kind;
            let q = self.queue_for(kind);
            q.retain(|&x| x != id);
            self.rr_cursor = self.rr_cursor.wrapping_add(1);
        }
    }

    /// Account `n` in-flight tiles completing on op `id` at `now`;
    /// returns the successor ids that became ready.
    pub fn complete_tiles(
        &mut self,
        graph: &OpGraph,
        id: usize,
        n: usize,
        now: u64,
    ) -> Vec<usize> {
        let op = &mut self.ops[id];
        debug_assert!(op.tiles_inflight >= n, "inflight underflow on op {id}");
        op.tiles_inflight -= n;
        if op.tiles_inflight > 0 || op.tiles_remaining > 0 {
            return Vec::new();
        }
        op.state = OpState::Done;
        op.done_at = now;
        self.done_count += 1;
        let succs = op.succs.clone();
        let mut newly_ready = Vec::new();
        for s in succs {
            let sop = &mut self.ops[s];
            debug_assert!(sop.deps_remaining > 0);
            sop.deps_remaining -= 1;
            if sop.deps_remaining == 0 && sop.state == OpState::Waiting {
                sop.state = OpState::Ready;
                sop.ready_at = now;
                self.push_ready(graph, s);
                newly_ready.push(s);
            }
        }
        newly_ready
    }

    /// Mark an op blocked on buffer space (memory stall bookkeeping) —
    /// it keeps its ready-queue position and is retried by the engine.
    pub fn ops_blocked_on_space(&self) -> usize {
        self.ops
            .iter()
            .filter(|o| o.state == OpState::BlockedSpace)
            .count()
    }

    pub fn all_done(&self) -> bool {
        self.done_count == self.ops.len()
    }

    /// Ready-op counts per resource class — O(1) view for the engine's
    /// stall-cycle integration (every op in a ready queue is starved
    /// whenever its resource class has no free module).
    pub fn ready_counts(&self) -> (usize, usize, usize, usize) {
        (
            self.ready_mac.len(),
            self.ready_softmax.len(),
            self.ready_layernorm.len(),
            self.ready_load.len(),
        )
    }

    /// Invariant: tile counts are conserved per op.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (i, op) in self.ops.iter().enumerate() {
            let total = op.grid.total_tiles();
            if op.tiles_remaining + op.tiles_inflight > total {
                return Err(format!(
                    "op {i}: remaining {} + inflight {} > total {total}",
                    op.tiles_remaining, op.tiles_inflight
                ));
            }
            if op.state == OpState::Done
                && (op.tiles_remaining != 0 || op.tiles_inflight != 0)
            {
                return Err(format!("op {i}: done with tiles outstanding"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::TransformerConfig;
    use crate::sim::tiling;

    fn schedule(policy: Policy) -> (OpGraph, Schedule) {
        let graph = OpGraph::build(&TransformerConfig::bert_tiny(), 1, 64);
        let grids: Vec<TileGrid> = graph
            .nodes
            .iter()
            .map(|n| tiling::tile_op(&n.dims, 1, 16, 16, 16))
            .collect();
        let profiles = vec![SparsityProfile::paper_default(); graph.nodes.len()];
        let s = Schedule::new(&graph, policy, grids, profiles);
        (graph, s)
    }

    #[test]
    fn initial_ready_set_is_dep_free() {
        let (graph, mut s) = schedule(Policy::Staggered);
        // all MemLoads are dep-free; first compute ops wait on them.
        let first = s.peek_ready(OpKind::MemLoad).unwrap();
        assert!(graph.nodes[first].deps.is_empty());
        assert!(s.peek_ready(OpKind::MatMul).is_none());
    }

    #[test]
    fn completing_deps_unlocks_successors() {
        let (graph, mut s) = schedule(Policy::Staggered);
        // finish M-OP-0 and l0 wqkv -> the six l0 Q/K/V matmuls unlock.
        for id in 0..graph.nodes.len() {
            if graph.nodes[id].kind == OpKind::MemLoad
                && (graph.nodes[id].label.contains("M-OP-0")
                    || graph.nodes[id].label.contains("l0.M-OP-1"))
            {
                let total = s.ops[id].grid.total_tiles();
                s.issue_tiles(&graph, id, total);
                s.complete_tiles(&graph, id, total, 10);
            }
        }
        let ready = s.peek_ready(OpKind::MatMul).unwrap();
        assert!(graph.nodes[ready].label.contains("C-OP-1"), "{}",
                graph.nodes[ready].label);
    }

    #[test]
    fn staggered_prefers_lower_head() {
        let (graph, mut s) = schedule(Policy::Staggered);
        for id in 0..graph.nodes.len() {
            if graph.nodes[id].kind == OpKind::MemLoad {
                let total = s.ops[id].grid.total_tiles();
                s.issue_tiles(&graph, id, total);
                s.complete_tiles(&graph, id, total, 0);
            }
        }
        let first = s.peek_ready(OpKind::MatMul).unwrap();
        assert_eq!(graph.nodes[first].head, Some(0));
    }

    #[test]
    fn tile_conservation_through_lifecycle() {
        let (graph, mut s) = schedule(Policy::Staggered);
        let id = s.peek_ready(OpKind::MemLoad).unwrap();
        let total = s.ops[id].grid.total_tiles();
        s.issue_tiles(&graph, id, total / 2);
        s.check_invariants().unwrap();
        s.complete_tiles(&graph, id, total / 2, 5);
        s.issue_tiles(&graph, id, total - total / 2);
        s.check_invariants().unwrap();
        assert_eq!(s.ops[id].state, OpState::Draining);
        s.complete_tiles(&graph, id, total - total / 2, 9);
        assert_eq!(s.ops[id].state, OpState::Done);
        s.check_invariants().unwrap();
    }

    #[test]
    fn whole_graph_drains_without_deadlock() {
        // Simulate unlimited resources: issue+complete everything ready
        // until done; must terminate with all ops done (no deadlock).
        for policy in [Policy::Staggered, Policy::EqualPriority] {
            let (graph, mut s) = schedule(policy);
            let mut guard = 0;
            while !s.all_done() {
                guard += 1;
                assert!(guard < 10_000, "deadlock under {policy:?}");
                let mut progressed = false;
                for kind in [
                    OpKind::MemLoad,
                    OpKind::MatMul,
                    OpKind::Softmax,
                    OpKind::LayerNorm,
                ] {
                    while let Some(id) = s.peek_ready(kind) {
                        let total = s.ops[id].tiles_remaining;
                        s.issue_tiles(&graph, id, total);
                        s.complete_tiles(&graph, id, total, guard);
                        progressed = true;
                    }
                }
                assert!(progressed, "no progress under {policy:?}");
            }
            s.check_invariants().unwrap();
        }
    }
}
