//! Compute-module cost models: MAC lane (Fig. 6), softmax module, and
//! layer-norm module (Sec. III-B3/4), plus the per-PE DynaTran and
//! sparsity stages' cycle/energy charges.
//!
//! These are *timing/energy* models at tile granularity — the functional
//! math runs in the PJRT runtime (L2 artifacts); the simulator only needs
//! how many cycles and picojoules each tile costs on each module.

use super::tech;

/// Cycle/energy cost of one unit of work on a module.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TileCost {
    pub cycles: u64,
    pub energy_pj: f64,
}

/// MAC lane: `M` multipliers feeding a log2(M)-deep adder tree; GeLU
/// optionally applied at the output (C-OP-9/10).
#[derive(Clone, Copy, Debug)]
pub struct MacLane {
    /// Multipliers per lane (paper: M = 16).
    pub multipliers: usize,
}

impl MacLane {
    pub fn new(multipliers: usize) -> MacLane {
        assert!(multipliers.is_power_of_two(), "adder tree needs 2^n inputs");
        MacLane { multipliers }
    }

    /// Pipeline fill latency: multiplier stage + adder-tree stages.
    pub fn pipeline_depth(&self) -> u64 {
        1 + (self.multipliers as f64).log2() as u64
    }

    /// Cost of one tile-pair with `macs` *effectual* multiplications
    /// (post sparsity filtering).  Minimum cycles = n_o / M (Sec. III-B4),
    /// plus the pipeline fill; energy charges only effectual MACs — the
    /// zero-free data guarantee.
    pub fn tile_cost(&self, macs: usize, gelu_elems: usize) -> TileCost {
        let compute = (macs as u64).div_ceil(self.multipliers as u64);
        TileCost {
            cycles: compute.max(1) + self.pipeline_depth(),
            energy_pj: macs as f64 * tech::MAC_PJ
                + gelu_elems as f64 * tech::GELU_PJ_PER_ELEM,
        }
    }
}

/// Softmax module: processes a row-block tile, computing exp and the
/// row-wise exponential sum over the whole tile in parallel
/// (`elems_per_cycle` element-slots per cycle), then divides.
#[derive(Clone, Copy, Debug)]
pub struct SoftmaxModule {
    pub elems_per_cycle: usize,
}

impl SoftmaxModule {
    /// Cost of one `rows x cols` row-block tile.  Three passes over the
    /// data (max-subtract+exp, sum, divide) pipelined into ~1 visit per
    /// element plus a fixed reduction latency.
    pub fn tile_cost(&self, rows: usize, cols: usize) -> TileCost {
        let elems = rows * cols;
        let cycles = (elems as u64).div_ceil(self.elems_per_cycle as u64)
            + (cols as f64).log2().ceil() as u64 // reduction tree
            + 2; // divide + writeback
        TileCost {
            cycles,
            energy_pj: elems as f64 * tech::SOFTMAX_PJ_PER_ELEM,
        }
    }
}

/// Layer-norm module: mean/variance reduction + rsqrt + affine.
#[derive(Clone, Copy, Debug)]
pub struct LayerNormModule {
    pub elems_per_cycle: usize,
}

impl LayerNormModule {
    pub fn tile_cost(&self, rows: usize, cols: usize) -> TileCost {
        let elems = rows * cols;
        let cycles = (elems as u64).div_ceil(self.elems_per_cycle as u64)
            + (cols as f64).log2().ceil() as u64
            + 3; // mean, rsqrt, affine latch
        TileCost {
            cycles,
            energy_pj: elems as f64 * tech::LAYERNORM_PJ_PER_ELEM,
        }
    }
}

/// DynaTran stage: one cycle per tile regardless of size (parallel
/// comparators, Fig. 7) — the paper's headline micro-architectural claim.
pub fn dynatran_cost(elems: usize) -> TileCost {
    TileCost {
        cycles: 1,
        energy_pj: elems as f64 * tech::DYNATRAN_PJ_PER_ELEM,
    }
}

/// Pre- or post-compute sparsity stage over a tile: AND/XOR mask logic +
/// zero-collapsing shift, one cycle per tile slice (pipelined with the
/// consuming module, so it adds latency but not throughput).
pub fn sparsity_stage_cost(elems: usize) -> TileCost {
    TileCost {
        cycles: 1,
        energy_pj: elems as f64 * tech::SPARSITY_PJ_PER_ELEM,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mac_lane_min_cycles_is_no_over_m() {
        // Sec. III-B4: minimum cycles for n_o ops with M multipliers.
        let lane = MacLane::new(16);
        let c = lane.tile_cost(16 * 16 * 16, 0);
        assert_eq!(c.cycles, (4096 / 16) as u64 + lane.pipeline_depth());
    }

    #[test]
    fn sparse_tile_is_cheaper() {
        let lane = MacLane::new(16);
        let dense = lane.tile_cost(4096, 0);
        let sparse = lane.tile_cost(1024, 0); // 75% effectual skipped
        assert!(sparse.cycles < dense.cycles);
        assert!(sparse.energy_pj < dense.energy_pj / 3.0);
    }

    #[test]
    fn empty_tile_still_costs_a_cycle() {
        let lane = MacLane::new(16);
        assert!(lane.tile_cost(0, 0).cycles >= 1);
    }

    #[test]
    fn adder_tree_depth() {
        assert_eq!(MacLane::new(16).pipeline_depth(), 5);
        assert_eq!(MacLane::new(4).pipeline_depth(), 3);
    }

    #[test]
    #[should_panic(expected = "adder tree")]
    fn non_power_of_two_rejected() {
        MacLane::new(12);
    }

    #[test]
    fn softmax_cost_scales_with_tile() {
        let m = SoftmaxModule { elems_per_cycle: 16 };
        let small = m.tile_cost(16, 64);
        let big = m.tile_cost(16, 512);
        assert!(big.cycles > 7 * small.cycles / 2);
        assert!(big.energy_pj > 7.0 * small.energy_pj);
    }

    #[test]
    fn dynatran_is_single_cycle_at_any_size() {
        assert_eq!(dynatran_cost(16).cycles, 1);
        assert_eq!(dynatran_cost(1 << 20).cycles, 1);
        assert!(dynatran_cost(1 << 20).energy_pj > dynatran_cost(16).energy_pj);
    }

    #[test]
    fn gelu_adds_energy_not_cycles() {
        let lane = MacLane::new(16);
        let plain = lane.tile_cost(4096, 0);
        let with_gelu = lane.tile_cost(4096, 256);
        assert_eq!(plain.cycles, with_gelu.cycles);
        assert!(with_gelu.energy_pj > plain.energy_pj);
    }
}
