//! Baseline platform models for the Fig. 20 comparison.
//!
//! The paper evaluates AccelTran against off-the-shelf devices (Raspberry
//! Pi 4B, Intel NCS2, Apple M1 CPU/GPU for edge; NVIDIA A100 for server)
//! and prior accelerators (OPTIMUS, SpAtten, Energon), normalizing
//! throughput/energy to 14nm via scaling equations.  We cannot run those
//! testbeds, so each baseline is an *analytic platform model*: absolute
//! throughput/energy estimates assembled from public benchmark data,
//! normalized to 14nm with [`super::tech::scale_to_14nm`], with the
//! paper's own reported relative factors carried alongside so the bench
//! prints paper-vs-measured factors side by side (DESIGN.md
//! §Substitutions).

use super::tech::scale_to_14nm;

/// One baseline platform at a given workload.
#[derive(Clone, Debug)]
pub struct Baseline {
    pub name: &'static str,
    /// Sequences per second on the workload (at the platform's native
    /// node, before normalization).
    pub throughput_seq_s: f64,
    /// Millijoules per sequence (native node).
    pub energy_mj_per_seq: f64,
    /// Process node in nm (for 14nm normalization).
    pub node_nm: f64,
    /// The paper's reported factor: AccelTran throughput / this platform
    /// (NaN where the paper gives no number — read from Fig. 20's log
    /// axes, so order-of-magnitude).
    pub paper_throughput_factor: f64,
    /// The paper's reported energy factor (platform / AccelTran).
    pub paper_energy_factor: f64,
}

impl Baseline {
    /// Throughput normalized to 14nm (inverter-delay proxy, Sec. IV-C).
    pub fn norm_throughput(&self) -> f64 {
        let (delay, _) = scale_to_14nm(self.node_nm);
        self.throughput_seq_s * delay
    }

    /// Energy normalized to 14nm.
    pub fn norm_energy_mj(&self) -> f64 {
        let (_, energy) = scale_to_14nm(self.node_nm);
        self.energy_mj_per_seq / energy
    }
}

/// Edge-side baselines: BERT-Tiny inference (paper Fig. 20(a)).
/// Absolute estimates: RPi 4B from ARM PyTorch fp16 runs of tiny
/// transformers (~2 seq/s at seq 128, ~5 W); NCS2 from OpenVINO NPU
/// numbers; M1 from TensorFlow-metal.  Paper factors: RPi quoted in the
/// text (330,578x / 93,300x); the others read from Fig. 20(a)'s log axes.
pub fn edge_baselines() -> Vec<Baseline> {
    vec![
        Baseline {
            name: "Raspberry Pi 4B",
            throughput_seq_s: 2.0,
            energy_mj_per_seq: 2500.0,
            node_nm: 28.0,
            paper_throughput_factor: 330_578.0,
            paper_energy_factor: 93_300.0,
        },
        Baseline {
            name: "Intel NCS v2",
            throughput_seq_s: 25.0,
            energy_mj_per_seq: 60.0,
            node_nm: 16.0,
            paper_throughput_factor: 40_000.0,
            paper_energy_factor: 20_000.0,
        },
        Baseline {
            name: "Apple M1 CPU",
            throughput_seq_s: 120.0,
            energy_mj_per_seq: 120.0,
            node_nm: 5.0,
            paper_throughput_factor: 16_000.0,
            paper_energy_factor: 8_000.0,
        },
        Baseline {
            name: "Apple M1 GPU",
            throughput_seq_s: 350.0,
            energy_mj_per_seq: 30.0,
            node_nm: 5.0,
            paper_throughput_factor: 5_000.0,
            paper_energy_factor: 3_000.0,
        },
    ]
}

/// Server-side baselines: BERT-Base inference (paper Fig. 20(b)).
/// A100 absolutes from public BERT-Base fp16 throughput at batch 32 /
/// seq 128 on its native 7nm node.  The prior accelerators publish
/// numbers the paper itself re-normalized to 14nm relative to the A100,
/// so their entries here carry *already-normalized* absolutes
/// (node_nm = 14): OPTIMUS / SpAtten / Energon placed at the paper's
/// relative positions below AccelTran-Server.
pub fn server_baselines() -> Vec<Baseline> {
    vec![
        Baseline {
            name: "NVIDIA A100",
            throughput_seq_s: 2_000.0,
            energy_mj_per_seq: 200.0,
            node_nm: 7.0,
            paper_throughput_factor: 63.0,
            paper_energy_factor: 10_805.0,
        },
        Baseline {
            name: "OPTIMUS",
            throughput_seq_s: 3_000.0,
            energy_mj_per_seq: 25.0,
            node_nm: 14.0,
            paper_throughput_factor: 25.0,
            paper_energy_factor: 50.0,
        },
        Baseline {
            name: "SpAtten",
            throughput_seq_s: 6_000.0,
            energy_mj_per_seq: 12.0,
            node_nm: 14.0,
            paper_throughput_factor: 10.0,
            paper_energy_factor: 12.0,
        },
        Baseline {
            name: "Energon",
            throughput_seq_s: 9_000.0,
            energy_mj_per_seq: 7.0,
            node_nm: 14.0,
            paper_throughput_factor: 5.73,
            paper_energy_factor: 3.69,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalization_moves_in_the_right_direction() {
        // a 28nm platform gets *faster* when normalized to 14nm
        let rpi = &edge_baselines()[0];
        assert!(rpi.norm_throughput() > rpi.throughput_seq_s);
        assert!(rpi.norm_energy_mj() < rpi.energy_mj_per_seq);
        // a 5nm platform gets slower/hungrier at 14nm
        let m1 = &edge_baselines()[2];
        assert!(m1.norm_throughput() < m1.throughput_seq_s);
        assert!(m1.norm_energy_mj() > m1.energy_mj_per_seq);
    }

    #[test]
    fn baseline_ordering_matches_fig20() {
        // edge: RPi slowest, M1 GPU fastest among baselines
        let edge = edge_baselines();
        assert!(edge[0].throughput_seq_s < edge[3].throughput_seq_s);
        // server: Energon is the strongest prior accelerator
        let server = server_baselines();
        let energon = server.iter().find(|b| b.name == "Energon").unwrap();
        for b in &server {
            assert!(b.throughput_seq_s <= energon.throughput_seq_s);
        }
        // paper factors: Energon is the closest competitor
        assert!(energon.paper_throughput_factor < 10.0);
    }
}
