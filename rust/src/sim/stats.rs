//! Simulation statistics: stall counters, energy ledger, utilization and
//! power traces (Figs. 16/17), and the final report structure.

use crate::util::json::Json;

/// Energy ledger in picojoules, split by subsystem (Fig. 18(b) axes plus
/// buffers/memory for Table III power rows).
#[derive(Clone, Debug, Default)]
pub struct EnergyLedger {
    pub mac_pj: f64,
    pub softmax_pj: f64,
    pub layernorm_pj: f64,
    pub dynatran_pj: f64,
    pub sparsity_pj: f64,
    pub buffer_pj: f64,
    pub memory_pj: f64,
    pub leakage_pj: f64,
}

impl EnergyLedger {
    pub fn compute_pj(&self) -> f64 {
        self.mac_pj + self.softmax_pj + self.layernorm_pj + self.dynatran_pj
            + self.sparsity_pj
    }

    pub fn total_pj(&self) -> f64 {
        self.compute_pj() + self.buffer_pj + self.memory_pj + self.leakage_pj
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mac_pj", Json::num(self.mac_pj)),
            ("softmax_pj", Json::num(self.softmax_pj)),
            ("layernorm_pj", Json::num(self.layernorm_pj)),
            ("dynatran_pj", Json::num(self.dynatran_pj)),
            ("sparsity_pj", Json::num(self.sparsity_pj)),
            ("buffer_pj", Json::num(self.buffer_pj)),
            ("memory_pj", Json::num(self.memory_pj)),
            ("leakage_pj", Json::num(self.leakage_pj)),
            ("total_pj", Json::num(self.total_pj())),
        ])
    }
}

/// Stall counters (Fig. 16 semantics, Sec. III-B8).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StallCounters {
    /// Compute op ready but all modules of its kind busy.
    pub compute_resource: u64,
    /// Compute op ready but an operand not yet buffered.
    pub compute_operand: u64,
    /// Memory load blocked on buffer space (nothing evictable).
    pub memory_buffer_full: u64,
    /// Memory store blocked on an unfinished compute op.
    pub memory_pending_compute: u64,
}

impl StallCounters {
    pub fn compute_total(&self) -> u64 {
        self.compute_resource + self.compute_operand
    }

    pub fn memory_total(&self) -> u64 {
        self.memory_buffer_full + self.memory_pending_compute
    }
}

/// One sample of the per-cycle trace (Fig. 17): utilization of each
/// resource class, buffer occupancy, and instantaneous power.
#[derive(Clone, Copy, Debug)]
pub struct TraceSample {
    pub cycle: u64,
    pub mac_lanes_active: usize,
    pub softmax_active: usize,
    pub layernorm_active: usize,
    pub act_buffer_frac: f64,
    pub weight_buffer_frac: f64,
    pub dynamic_power_w: f64,
    pub leakage_power_w: f64,
}

/// Trace recorder with fixed-width cycle bins to bound memory.
#[derive(Debug)]
pub struct Trace {
    pub bin_cycles: u64,
    pub samples: Vec<TraceSample>,
}

impl Trace {
    pub fn new(bin_cycles: u64) -> Trace {
        assert!(bin_cycles > 0);
        Trace { bin_cycles, samples: Vec::new() }
    }

    /// Record a sample if `cycle` entered a new bin.
    pub fn maybe_record(&mut self, sample: TraceSample) {
        match self.samples.last() {
            Some(last) if sample.cycle / self.bin_cycles == last.cycle / self.bin_cycles => {}
            _ => self.samples.push(sample),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::arr(self.samples.iter().map(|s| {
            Json::obj(vec![
                ("cycle", Json::num(s.cycle as f64)),
                ("mac", Json::num(s.mac_lanes_active as f64)),
                ("softmax", Json::num(s.softmax_active as f64)),
                ("layernorm", Json::num(s.layernorm_active as f64)),
                ("act_buf", Json::num(s.act_buffer_frac)),
                ("w_buf", Json::num(s.weight_buffer_frac)),
                ("dyn_w", Json::num(s.dynamic_power_w)),
                ("leak_w", Json::num(s.leakage_power_w)),
            ])
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_totals() {
        let l = EnergyLedger {
            mac_pj: 10.0,
            softmax_pj: 5.0,
            buffer_pj: 1.0,
            memory_pj: 2.0,
            leakage_pj: 0.5,
            ..Default::default()
        };
        assert_eq!(l.compute_pj(), 15.0);
        assert_eq!(l.total_pj(), 18.5);
    }

    #[test]
    fn trace_bins_dedupe() {
        let mut t = Trace::new(100);
        for c in [0u64, 5, 50, 150, 160, 320] {
            t.maybe_record(TraceSample {
                cycle: c,
                mac_lanes_active: 0,
                softmax_active: 0,
                layernorm_active: 0,
                act_buffer_frac: 0.0,
                weight_buffer_frac: 0.0,
                dynamic_power_w: 0.0,
                leakage_power_w: 0.0,
            });
        }
        let cycles: Vec<u64> = t.samples.iter().map(|s| s.cycle).collect();
        assert_eq!(cycles, vec![0, 150, 320]);
    }

    #[test]
    fn ledger_json_has_total() {
        let l = EnergyLedger { mac_pj: 3.0, ..Default::default() };
        let j = l.to_json();
        assert_eq!(j.get("total_pj").unwrap().as_f64(), Some(3.0));
    }
}
